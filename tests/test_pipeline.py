"""Pipeline parallelism: GPipe schedule == serial execution (fwd + grads)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.pipeline import pipeline_apply, stage_params

    S, L, M, MB, D = 4, 8, 6, 2, 16
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.standard_normal((L, D, D)).astype(np.float32) * 0.2)
    x = jnp.asarray(rng.standard_normal((M, MB, D)).astype(np.float32))

    def layer(w, h):
        return jnp.tanh(h @ w)

    def stage_fn(w_slab, h):  # (L/S, D, D)
        for i in range(w_slab.shape[0]):
            h = layer(w_slab[i], h)
        return h

    def serial(Ws, x):
        h = x
        for i in range(L):
            h = layer(Ws[i], h)
        return h

    mesh = jax.make_mesh((S,), ("stage",))
    staged = stage_params({"w": Ws}, S)["w"]
    y_pipe = pipeline_apply(stage_fn, staged, x, mesh)
    y_ser = jax.vmap(lambda xi: serial(Ws, xi))(x)
    fwd_err = float(jnp.abs(y_pipe - y_ser).max())
    assert fwd_err < 1e-5, f"fwd {fwd_err}"

    # grads through the pipeline == serial grads
    def loss_pipe(staged):
        return (pipeline_apply(stage_fn, staged, x, mesh) ** 2).sum()
    def loss_ser(Ws):
        return (jax.vmap(lambda xi: serial(Ws, xi))(x) ** 2).sum()
    g_pipe = jax.grad(loss_pipe)(staged).reshape(L, D, D)
    g_ser = jax.grad(loss_ser)(Ws)
    g_err = float(jnp.abs(g_pipe - g_ser).max() / (jnp.abs(g_ser).max() + 1e-9))
    assert g_err < 1e-4, f"grad {g_err}"
    print("OK", fwd_err, g_err)
""" % SRC)


def test_pipeline_matches_serial():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600)
    assert res.returncode == 0, f"stdout:{res.stdout}\nstderr:{res.stderr[-3000:]}"
    assert "OK" in res.stdout
