"""SELL-C-sigma: container/plan/execute, Pallas kernel sweeps, routing.

Covers the acceptance bar for SELL as a first-class dynamic format: plan
JSON round-trips (permutation + slice caps are plan metadata), the jit-able
numeric phase, f64-oracle kernel sweeps over ragged/power-law/empty-row
shapes with bitwise-determinism asserts, batched (per-shard) plans, the
kernel-tune (c, sigma) axis, and the measured-faster-than-ref veto.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Format, SwitchPlan, convert, convert_execute,
                        convert_execute_batch, coo_from_arrays,
                        coo_from_dense_np, coo_to_sell, plan_switch,
                        plan_switch_batch, random_coo, sell_to_coo,
                        to_dense_np)
from repro.core.formats import COO, SELL
from repro.kernels import ops as kops

RNG = np.random.default_rng(0)


def _powerlaw(seed, m, n, shape_a=1.3, scale=3.0):
    """Power-law row lengths: the irregular-row family SELL exists for."""
    rng = np.random.default_rng(seed)
    counts = np.minimum(1 + (rng.pareto(shape_a, m) * scale).astype(np.int64),
                        n)
    rows = np.repeat(np.arange(m, dtype=np.int64), counts)
    cols = np.concatenate([rng.choice(n, k, replace=False) for k in counts])
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    vals = np.where(np.abs(vals) < 1e-3, 1e-3, vals)
    return coo_from_arrays(rows, cols, vals, (m, n))


# ---------------------------------------------------------------------------
# Container + conversion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,density", [
    ((97, 83), 0.08), ((513, 401), 0.03), ((64, 64), 0.1), ((5, 7), 0.4),
])
@pytest.mark.parametrize("c,sigma", [(8, 64), (4, 4), (32, 256)])
def test_sell_conversion_roundtrip(shape, density, c, sigma):
    A = random_coo(1, shape, density=density)
    S = coo_to_sell(A, c=c, sigma=sigma)
    assert S.c == c and S.sigma >= c
    np.testing.assert_allclose(to_dense_np(S), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(to_dense_np(sell_to_coo(S)), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)


def test_sell_handles_empty_matrix_and_empty_rows():
    D = np.zeros((40, 30), np.float32)
    S0 = convert(coo_from_dense_np(D, capacity=7), Format.SELL)
    np.testing.assert_array_equal(to_dense_np(S0), D)
    D[7, [2, 9, 11]] = [1.0, -2.0, 3.0]  # single live row, rest empty
    S1 = convert(coo_from_dense_np(D), Format.SELL)
    np.testing.assert_allclose(to_dense_np(S1), D)


def test_sell_padding_waste_histogram():
    from repro.obs import metrics

    metrics.reset(["sell.padding_waste"])
    convert(_powerlaw(2, 256, 256), Format.SELL)
    snap = metrics.snapshot()["histograms"]
    assert snap["sell.padding_waste"]["count"] == 1
    assert 0.0 <= snap["sell.padding_waste"]["max"] < 1.0


def test_sigma_sort_reduces_padding_vs_ell():
    """On power-law rows the per-slice widths must beat the global kmax —
    the entire point of the format."""
    A = _powerlaw(3, 512, 512)
    plan = plan_switch(A, Format.SELL, c=32, sigma=256)
    ell = plan_switch(A, Format.ELL)
    sell_slots = plan.sell_slice_ptrs[-1]
    assert sell_slots < ell.ell_k * A.shape[0] // 2


# ---------------------------------------------------------------------------
# Plans: JSON round-trip, reuse, staleness
# ---------------------------------------------------------------------------

def test_sell_plan_json_roundtrip():
    A = _powerlaw(4, 128, 96)
    plan = plan_switch(A, Format.SELL, c=16, sigma=64)
    assert plan.sell_c == 16 and plan.sell_sigma == 64
    assert isinstance(plan.sell_perm, tuple)
    assert isinstance(plan.sell_slice_ptrs, tuple)
    assert len(plan.sell_slice_ptrs) == -(-128 // 16) + 1
    assert isinstance(hash(plan), int)
    rt = SwitchPlan.from_json(plan.to_json())
    assert rt == plan


def test_sell_plan_reuse_same_pattern_is_exact():
    A = _powerlaw(5, 200, 150)
    B = COO(A.row, A.col, A.data * -2.0, A.shape, A.nnz)
    plan = plan_switch(A, Format.SELL)
    ex = jax.jit(convert_execute, static_argnums=1)
    np.testing.assert_allclose(to_dense_np(ex(B, plan)),
                               -2.0 * to_dense_np(A), rtol=1e-5, atol=1e-5)


def test_sell_stale_plan_drops_only_overflow():
    """Guard-slot contract: live entries whose within-row rank exceeds the
    planned slice cap are parked in the dropped guard slot; every planned
    entry survives untouched (same contract as the distributed caps)."""
    A = _powerlaw(6, 64, 64)
    plan = plan_switch(A, Format.SELL, c=8, sigma=32)
    r = np.asarray(A.row)
    c_ = np.asarray(A.col)
    v = np.asarray(A.data)
    # append extra live entries to row 0 in columns it does not touch yet
    free = np.setdiff1d(np.arange(64), c_[r == 0])[:8]
    r2 = np.concatenate([r, np.zeros(len(free), np.int64)])
    c2 = np.concatenate([c_, free])
    v2 = np.concatenate([v, np.full(len(free), 7.0, np.float32)])
    B = coo_from_arrays(r2, c2, v2, A.shape)
    out = to_dense_np(convert_execute(B, plan))
    expect = to_dense_np(A).copy()
    # row 0 may keep as many of the new entries as its planned width allows;
    # all other rows must be bit-exact and nothing may corrupt the storage
    np.testing.assert_allclose(out[1:], expect[1:], rtol=1e-6, atol=1e-6)
    kept = np.flatnonzero(out[0] != expect[0])
    assert set(kept) <= set(free.tolist())


def test_distplan_roundtrip_carries_sell_plans():
    from repro.core import hpcg
    from repro.core.distributed import (DistPlan, _check_plan_fits,
                                        _split_caps, partition_execute_jit,
                                        plan_dist_formats, plan_partition,
                                        split_local_execute_jit)

    prob = hpcg.generate_problem(4, 4, 8)
    plan = plan_partition(prob.row, prob.col, prob.val, prob.shape, 4)
    icap, bcap = _split_caps(prob.row, prob.col, prob.val, plan.mp, 4)
    plan = dataclasses.replace(plan, interior_cap=icap, boundary_cap=bcap,
                               pattern_sig="deadbeef")
    local, remote = partition_execute_jit(prob.row, prob.col, prob.val,
                                          plan=plan)
    interior, boundary = split_local_execute_jit(local, remote, mp=plan.mp,
                                                 icap=icap, bcap=bcap)
    cands = (Format.CSR, Format.ELL, Format.SELL)
    plan = plan_dist_formats(interior, remote, plan, cands,
                             boundary=boundary)
    sell_plan = plan.interior_plans[cands.index(Format.SELL)]
    assert Format(sell_plan.target) == Format.SELL
    # batch plans share static slice caps; the per-part permutation is
    # derived on device, never memoised
    assert sell_plan.sell_perm is None
    assert sell_plan.sell_slice_ptrs is not None
    rt = DistPlan.from_json(plan.to_json())
    assert rt == plan
    # staleness machinery unchanged by the new fields: shrunken split caps
    # on a plan carrying SELL plans still fail loudly
    stale = dataclasses.replace(plan, interior_cap=max(1, icap // 2))
    with pytest.raises(ValueError, match="stale DistPlan"):
        _check_plan_fits(prob.row, prob.col, stale, val=prob.val)


# ---------------------------------------------------------------------------
# Batched (per-shard) plans
# ---------------------------------------------------------------------------

def test_sell_batch_plan_shared_caps_fit_every_part():
    parts_np = [np.asarray(to_dense_np(_powerlaw(10 + i, 96, 80)))
                for i in range(3)]
    cap = max(int((d != 0).sum()) for d in parts_np) + 50
    coos = [coo_from_dense_np(d, capacity=cap) for d in parts_np]
    stacked = COO(jnp.stack([p.row for p in coos]),
                  jnp.stack([p.col for p in coos]),
                  jnp.stack([p.data for p in coos]),
                  (96, 80), cap)
    plan = plan_switch_batch(stacked, Format.SELL, c=8, sigma=64)
    assert plan.sell_perm is None
    # shared caps >= each part's own planned caps, elementwise
    for coo in coos:
        own = plan_switch(coo, Format.SELL, c=8, sigma=64)
        shared = np.diff(np.asarray(plan.sell_slice_ptrs))
        mine = np.diff(np.asarray(own.sell_slice_ptrs))
        assert (shared >= mine).all()
    out = convert_execute_batch(stacked, plan)
    for i, d in enumerate(parts_np):
        part = jax.tree_util.tree_map(lambda t: t[i], out)
        np.testing.assert_allclose(to_dense_np(part), d, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Pallas kernel: cfg sweeps vs the f64 dense oracle
# ---------------------------------------------------------------------------

SELL_GEOMS = [(8, 64), (32, 256), (64, 64)]
SELL_TS = [1, 2, 8]


@pytest.mark.parametrize("c,sigma", SELL_GEOMS)
@pytest.mark.parametrize("ts", SELL_TS)
@pytest.mark.parametrize("shape", [(97, 83), (513, 401)])
def test_sell_kernel_cfg_sweep_ragged(shape, c, sigma, ts):
    A = coo_to_sell(_powerlaw(20, *shape), c=c, sigma=sigma)
    x = jnp.asarray(RNG.standard_normal(shape[1]).astype(np.float32))
    y = kops.sell_spmv(A, x, cfg={"ts": ts})
    oracle = to_dense_np(A).astype(np.float64) @ np.asarray(x, np.float64)
    np.testing.assert_allclose(np.asarray(y, np.float64), oracle,
                               rtol=2e-5, atol=2e-5)
    # bitwise determinism of a fixed config
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(kops.sell_spmv(A, x,
                                                            cfg={"ts": ts})))


@pytest.mark.parametrize("ts", SELL_TS)
def test_sell_kernel_empty_rows(ts):
    """Empty slices (zero-width windows) under every launch geometry."""
    D = np.zeros((300, 300), np.float32)
    mask = RNG.random((100, 300)) < 0.05
    D[200:, :] = np.where(mask, RNG.standard_normal((100, 300)),
                          0).astype(np.float32)
    A = coo_to_sell(coo_from_dense_np(D), c=16, sigma=128)
    x = jnp.asarray(RNG.standard_normal(300).astype(np.float32))
    y = kops.sell_spmv(A, x, cfg={"ts": ts})
    np.testing.assert_allclose(np.asarray(y, np.float64),
                               D.astype(np.float64) @ np.asarray(x, np.float64),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b", [1, 5, 16])
def test_sell_spmm_and_spmm_t_sweep(b):
    A = coo_to_sell(_powerlaw(21, 200, 160), c=16, sigma=64)
    D = to_dense_np(A)
    B = jnp.asarray(RNG.standard_normal((160, b)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(kops.sell_spmm(A, B)),
                               D @ np.asarray(B), rtol=1e-4, atol=1e-4)
    X = jnp.asarray(RNG.standard_normal((b, 160)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(kops.sell_spmm_t(A, X)),
                               np.asarray(X) @ D.T, rtol=1e-4, atol=1e-4)


def test_sell_core_pallas_backend_agrees_with_ref():
    from repro.core import spmv

    A = convert(_powerlaw(22, 256, 256), Format.SELL)
    x = jnp.asarray(RNG.standard_normal(256).astype(np.float32))
    np.testing.assert_allclose(np.asarray(spmv(A, x, backend="pallas")),
                               np.asarray(spmv(A, x, backend="ref")),
                               rtol=1e-4, atol=1e-4)


def test_sell_vmem_budget_fallback():
    n = 2_000_000  # x alone blows the VMEM budget -> ref fallback
    rows = np.arange(256, dtype=np.int64)
    A = coo_to_sell(coo_from_arrays(rows, rows * 7000,
                                    np.ones(256, np.float32), (256, n)))
    y = kops.sell_spmv(A, jnp.ones((n,), jnp.float32))
    np.testing.assert_allclose(np.asarray(y), np.ones(256), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Tuning: (c, sigma) on the kernel-tune grid, veto, policy threading
# ---------------------------------------------------------------------------

def test_kernel_tune_sell_records_container_geometry(tmp_path):
    from repro.tuning.cache import SelectionCache
    from repro.tuning.kernel_tune import (best_config, default_grid,
                                          tune_kernel)

    A = coo_to_sell(_powerlaw(30, 512, 512), c=32, sigma=256)
    grid = default_grid(A, smoke=True)
    assert all({"c", "sigma", "ts"} <= set(g) for g in grid)
    assert any(g["c"] != A.c for g in grid)  # a rebuild variant is searched
    cache = SelectionCache(str(tmp_path / "k.json"))
    rec = tune_kernel(A, cache=cache, grid=grid, iters=2, inner=1)
    assert rec.fmt == "SELL" and {"c", "sigma", "ts"} <= set(rec.cfg)
    fresh = best_config(A, cache=SelectionCache(str(tmp_path / "k.json")))
    assert fresh is not None and fresh.cfg == rec.cfg


def test_kernel_route_veto_respected_for_sell(tmp_path, monkeypatch):
    """auto must never route a SELL config that measured slower than ref."""
    import json

    from repro.core import ops as core_ops
    from repro.tuning.cache import CACHE_PATH_ENV, SelectionCache
    from repro.tuning.kernel_tune import KernelRecord, kernel_key

    path = str(tmp_path / "k.json")
    monkeypatch.setenv(CACHE_PATH_ENV, path)
    A = coo_to_sell(_powerlaw(31, 128, 128), c=8, sigma=64)
    cache = SelectionCache(path)
    losing = KernelRecord(fmt="SELL", op="spmv",
                          cfg={"c": 8, "sigma": 64, "ts": 2},
                          kernel_us=100.0, ref_us=50.0)
    cache.put_raw(kernel_key(Format.SELL, A.shape[0], A.shape[1],
                             int(A.nnz)), losing.to_json())
    backend, _ = core_ops.kernel_route(A, cache=SelectionCache(path))
    assert backend == "ref"
    winning = dataclasses.replace(losing, kernel_us=10.0)
    cache.put_raw(kernel_key(Format.SELL, A.shape[0], A.shape[1],
                             int(A.nnz)), winning.to_json())
    backend, cfg = core_ops.kernel_route(A, cache=SelectionCache(path))
    assert backend == "pallas" and cfg == winning.cfg


def test_policy_plan_for_threads_tuned_geometry(tmp_path):
    """A cached SELL kernel record's (c, sigma) must seed the plan the
    policy hands out — the measured slicing survives the format switch."""
    from repro.tuning import FormatPolicy
    from repro.tuning.cache import SelectionCache
    from repro.tuning.kernel_tune import KernelRecord, kernel_key

    A = _powerlaw(32, 256, 256)
    path = str(tmp_path / "cache.json")
    cache = SelectionCache(path)
    rec = KernelRecord(fmt="SELL", op="spmv",
                       cfg={"c": 64, "sigma": 512, "ts": 4},
                       kernel_us=10.0, ref_us=100.0)
    cache.put_raw(kernel_key(Format.SELL, 256, 256, int(A.nnz)),
                  rec.to_json())
    pol = FormatPolicy("analytic", cache=cache)
    plan = pol.plan_for(A, fmt=Format.SELL)
    assert plan.sell_c == 64 and plan.sell_sigma == 512
    # an explicit hint still wins over the record
    plan = pol.plan_for(A, fmt=Format.SELL, c=8)
    assert plan.sell_c == 8


def test_ell_overflow_reports_row_and_required_k():
    """Satellite fix: the overflow error names the offending row and the
    width it needs, not just 'overflow'."""
    from repro.core.convert import coo_to_ell

    d = np.zeros((16, 16), np.float32)
    d[11, :7] = 1.0
    d[3, :2] = 1.0
    A = coo_from_dense_np(d)
    with pytest.raises(ValueError, match=r"row 11 holds 7"):
        coo_to_ell(A, k=2)


# ---------------------------------------------------------------------------
# Selection: SELL is reachable through the auto route
# ---------------------------------------------------------------------------

def test_sell_in_default_candidate_menus():
    from repro.core.dynamic import DEFAULT_CANDIDATES
    from repro.tuning import corpus

    assert Format.SELL in DEFAULT_CANDIDATES
    assert Format.SELL in corpus.DEFAULT_CANDIDATES


def test_profile_select_considers_sell():
    from repro.tuning.engines import profile_select

    A = _powerlaw(33, 256, 256)
    x = jnp.ones((256,), jnp.float32)
    rep = profile_select(A, x, candidates=(Format.CSR, Format.SELL),
                         iters=2, inner=1)
    assert set(rep.times) == {Format.CSR, Format.SELL}
    assert rep.best in (Format.CSR, Format.SELL)


def test_dynamic_matrix_activates_sell():
    from repro.core import DynamicMatrix

    A = _powerlaw(34, 128, 128)
    dm = DynamicMatrix(A)
    plan = dm.plan(Format.SELL)
    switched = dm.activate(Format.SELL, plan=plan)
    assert switched.active == Format.SELL
    np.testing.assert_allclose(to_dense_np(switched.concrete),
                               to_dense_np(A), rtol=1e-6, atol=1e-6)
