"""Solver substrate: CG / fixed-iteration CG / Jacobi-PCG on HPCG systems."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DynamicMatrix, Format, convert, extract_diagonal,
                        hpcg, spmv)
from repro.core.solvers import cg, cg_fixed_iters, operator, pcg


def _system(nx=6, ny=6, nz=6, fmt=Format.CSR):
    prob = hpcg.generate_problem(nx, ny, nz)
    A = convert(hpcg.to_coo(prob), fmt)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    return A, b


@pytest.mark.parametrize("fmt", [Format.CSR, Format.DIA, Format.ELL, Format.HYB])
def test_cg_converges_any_format(fmt):
    A, b = _system(fmt=fmt)
    res = cg(lambda v: spmv(A, v), b, tol=1e-7, maxiter=300)
    np.testing.assert_allclose(np.asarray(res.x), 1.0, rtol=1e-3, atol=1e-3)


def test_pcg_converges_and_is_no_slower():
    A, b = _system(8, 8, 8)
    d = extract_diagonal(A)
    apply_A = lambda v: spmv(A, v)
    r1 = cg(apply_A, b, tol=1e-7, maxiter=500)
    r2 = pcg(apply_A, b, d, tol=1e-7, maxiter=500)
    np.testing.assert_allclose(np.asarray(r2.x), 1.0, rtol=1e-3, atol=1e-3)
    assert int(r2.iters) <= int(r1.iters) + 2  # Jacobi ~ CG on this operator


def test_pcg_helps_on_scaled_system():
    """Jacobi shines when the diagonal varies: rescale rows of the HPCG
    operator (keeps SPD via symmetric scaling D^1/2 A D^1/2)."""
    prob = hpcg.generate_problem(6, 6, 6)
    n = prob.shape[0]
    rng = np.random.default_rng(0)
    s = 10.0 ** rng.uniform(-1.5, 1.5, n)
    val = prob.val * s[prob.row] * s[prob.col]
    from repro.core import coo_from_arrays
    A = convert(coo_from_arrays(prob.row, prob.col, val, prob.shape), Format.CSR)
    x_true = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    b = spmv(A, x_true)
    d = extract_diagonal(A)
    apply_A = lambda v: spmv(A, v)
    r_cg = cg(apply_A, b, tol=1e-9, maxiter=2000)
    r_pcg = pcg(apply_A, b, d, tol=1e-9, maxiter=2000)
    assert int(r_pcg.iters) < int(r_cg.iters), (int(r_pcg.iters), int(r_cg.iters))


def test_cg_respects_maxiter():
    A, b = _system(4, 4, 4)
    res = cg(lambda v: spmv(A, v), b, tol=1e-30, maxiter=5)
    assert int(res.iters) == 5


def test_cg_fixed_iters_matches_cg_trajectory():
    A, b = _system(4, 4, 4)
    apply_A = lambda v: spmv(A, v)
    r1 = cg(apply_A, b, tol=1e-30, maxiter=10)
    r2 = cg_fixed_iters(apply_A, b, iters=10)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=1e-4, atol=1e-4)


def test_pcg_apply_M_generalizes_jacobi():
    """pcg(apply_M=) with the Jacobi map reproduces pcg(diag_A=) exactly."""
    A, b = _system(6, 6, 6)
    d = extract_diagonal(A)
    apply_A = lambda v: spmv(A, v)
    r1 = pcg(apply_A, b, d, tol=1e-7, maxiter=300)
    minv = 1.0 / d
    r2 = pcg(apply_A, b, tol=1e-7, maxiter=300, apply_M=lambda r: minv * r)
    assert int(r1.iters) == int(r2.iters)
    np.testing.assert_allclose(np.asarray(r1.x), np.asarray(r2.x),
                               rtol=1e-6, atol=1e-6)


def test_pcg_requires_some_preconditioner():
    A, b = _system(4, 4, 4)
    with pytest.raises(ValueError, match="apply_M"):
        pcg(lambda v: spmv(A, v), b)


def test_operator_threads_cfg_to_kernels():
    """operator(cfg=) pins an explicit kernel tile config (satellite of the
    kernel-config autotuning PR: the solver-facing closure accepts it)."""
    A, b = _system(4, 4, 4)  # CSR
    y_ref = np.asarray(spmv(A, b))
    y_cfg = np.asarray(operator(A, backend="pallas",
                                cfg={"tm": 32, "tk": 256})(b))
    np.testing.assert_allclose(y_cfg, y_ref, rtol=1e-4, atol=1e-4)


def test_cg_with_dynamic_matrix_switching():
    """Solve, switch format mid-workflow, solve again — same answer."""
    A, b = _system(5, 5, 5, Format.COO)
    dm = DynamicMatrix(A)
    x1 = cg(lambda v: dm.spmv(v), b, tol=1e-7, maxiter=300).x
    dm2 = dm.activate(Format.DIA)
    x2 = cg(lambda v: dm2.spmv(v), b, tol=1e-7, maxiter=300).x
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-4, atol=1e-4)
