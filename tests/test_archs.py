"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement).
Full configs are exercised only via the dry-run (abstract, no allocation).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, skip_reason
from repro.models import build_model

RNG = np.random.default_rng(0)
B, S = 2, 64


def _smoke_batch(cfg, b=B, s=S, labels=True):
    if cfg.frontend == "audio":
        batch = {"frames": jnp.asarray(
            RNG.standard_normal((b, s, cfg.frontend_dim)), jnp.bfloat16)}
    elif cfg.frontend == "vision":
        batch = {"patches": jnp.asarray(
            RNG.standard_normal((b, cfg.n_patches, cfg.frontend_dim)), jnp.bfloat16),
            "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s - cfg.n_patches)), jnp.int32)}
    else:
        batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32)}
    if labels:
        batch["labels"] = jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    logits, aux = m.forward(params, batch, q_chunk=32, kv_chunk=32)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    """One SGD step: loss finite, decreases over two steps, grads finite."""
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = _smoke_batch(cfg)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(
            lambda pp: m.loss(pp, batch, q_chunk=32, kv_chunk=32))(p)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)
        return p, loss, g

    params, l0, g = step(params)
    finite = all(np.isfinite(np.asarray(x, np.float32)).all()
                 for x in jax.tree.leaves(g))
    assert finite, "non-finite grads"
    # a single step can raise the loss on top-1 MoE (routing flips);
    # require progress within a few steps instead
    losses = [float(l0)]
    for _ in range(3):
        params, li, _ = step(params)
        losses.append(float(li))
    assert all(np.isfinite(l) for l in losses)
    assert min(losses[1:]) < losses[0], losses


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_config(a, smoke=True).family != "audio"])
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    cache = m.init_cache(B, 32)
    toks = jnp.zeros((B,), jnp.int32)
    step = jax.jit(m.decode_step)
    logits, cache = step(params, cache, toks, jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits, cache = step(params, cache, toks, jnp.ones((B,), jnp.int32))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "mamba2_2_7b", "zamba2_2_7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode reproduces full-sequence forward logits."""
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(3))
    s = 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, s)), jnp.int32)
    fwd, _ = m.forward(params, {"tokens": toks}, remat=False, q_chunk=4, kv_chunk=4)
    cache = m.init_cache(B, s, dtype=jnp.float32)
    step = jax.jit(m.decode_step)
    for i in range(s):
        lg, cache = step(params, cache, toks[:, i], jnp.full((B,), i, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(fwd[:, i]),
                                   rtol=1e-3, atol=1e-3)


def test_moe_dispatch_impls_agree():
    """The three dynamic dispatch 'formats' (dense / sort / coo-library)
    compute the same MoE output — the paper's format-invariance, applied to
    expert dispatch."""
    from repro.models.moe import moe_apply
    cfg = dataclasses.replace(get_config("deepseek_moe_16b", smoke=True),
                              dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(4))
    p0 = jax.tree.map(lambda a: a[0], params["blocks"])["moe"]
    x = jnp.asarray(RNG.standard_normal((4, 8, cfg.d_model)).astype(np.float32))
    outs = {d: np.asarray(moe_apply(p0, x, cfg, dispatch=d)[0])
            for d in ["dense", "sort", "coo"]}
    np.testing.assert_allclose(outs["dense"], outs["sort"], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs["sort"], outs["coo"], rtol=1e-6, atol=1e-6)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (property of the
    state-space duality algorithm)."""
    from repro.models.mamba2 import ssd_chunked
    b, t, h, p, n = 2, 64, 3, 8, 16
    x = jnp.asarray(RNG.standard_normal((b, t, h, p)).astype(np.float32))
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, t, h))).astype(np.float32) * 0.1)
    A = jnp.asarray(-np.abs(RNG.standard_normal(h)).astype(np.float32))
    Bm = jnp.asarray(RNG.standard_normal((b, t, n)).astype(np.float32))
    Cm = jnp.asarray(RNG.standard_normal((b, t, n)).astype(np.float32))
    y8, s8 = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y64, s64 = ssd_chunked(x, dt, A, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y64), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s8), np.asarray(s64), rtol=1e-4, atol=1e-4)


def test_skip_rules():
    """The assignment's shape-cell skip rules."""
    assert skip_reason(get_config("qwen1_5_32b"), "long_500k")
    assert skip_reason(get_config("mamba2_2_7b"), "long_500k") is None
    assert skip_reason(get_config("zamba2_2_7b"), "long_500k") is None
    assert skip_reason(get_config("hubert_xlarge"), "decode_32k")
    assert skip_reason(get_config("hubert_xlarge"), "prefill_32k") is None
    assert skip_reason(get_config("qwen1_5_32b"), "train_4k") is None


def test_exact_assigned_configs():
    """Pin the exact assigned architecture hyperparameters."""
    c = get_config("qwen1_5_32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (64, 5120, 40, 40, 27392, 152064) and c.qkv_bias
    c = get_config("command_r_plus_104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (64, 12288, 96, 8, 33792, 256000) and not c.qkv_bias
    c = get_config("stablelm_1_6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (24, 2048, 32, 32, 5632, 100352)
    c = get_config("minitron_8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (32, 4096, 32, 8, 16384, 256000)
    c = get_config("llama4_scout_17b_a16e")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab,
            c.n_experts, c.top_k) == (48, 5120, 40, 8, 8192, 202048, 16, 1)
    c = get_config("deepseek_moe_16b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab,
            c.n_experts, c.top_k, c.n_shared_experts) == \
        (28, 2048, 16, 16, 1408, 102400, 64, 6, 2)
    c = get_config("hubert_xlarge")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (48, 1280, 16, 16, 5120, 504) and c.encoder_only
    c = get_config("zamba2_2_7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab,
            c.ssm_state) == (54, 2560, 32, 32, 10240, 32000, 64)
    c = get_config("mamba2_2_7b")
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_state) == (64, 2560, 50280, 128)
    c = get_config("internvl2_26b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (48, 6144, 48, 8, 16384, 92553)
