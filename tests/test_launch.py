"""Launch-layer units: sharding rules, shape cells, HLO collective parser."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, input_specs, token_input_specs
from repro.launch import sharding as shd
from repro.launch.mesh import dp_axes, flat_axes, make_mesh
from repro.models import build_model
from repro.models.spec import P as SpecP


def _mesh11():
    return make_mesh((1, 1), ("data", "model"))


def test_spec_to_pspec_divisibility_fallback():
    mesh = make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    s = SpecP((40, 128), ("heads", "embed"))  # 40 % 16 != 0 -> replicate
    ps = shd.spec_to_pspec(s, FakeMesh(), shd.TRAIN_RULES)
    assert ps == P(None, "data")
    s = SpecP((5120, 27392), ("embed", "mlp"))
    ps = shd.spec_to_pspec(s, FakeMesh(), shd.TRAIN_RULES)
    assert ps == P("data", "model")


def test_spec_to_pspec_no_axis_reuse():
    class FakeMesh:
        shape = {"data": 4, "model": 4}
        axis_names = ("data", "model")

    s = SpecP((16, 16, 16), ("mlp", "heads", "kv"))  # all map to 'model'
    ps = shd.spec_to_pspec(s, FakeMesh(), shd.TRAIN_RULES)
    assert list(ps).count("model") == 1


def test_batch_pspec_divisibility():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    sp = shd.batch_pspec(FakeMesh(), {
        "tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
        "odd": jax.ShapeDtypeStruct((3, 128), jnp.int32)})
    assert sp["tokens"][0] == "data"
    assert sp["odd"][0] is None


def test_cache_pspec_kv_vs_seq():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    # kv divisible -> head sharding
    specs = {"k": jax.ShapeDtypeStruct((24, 128, 4096, 32, 64), jnp.bfloat16)}
    ps = shd.cache_pspec(FakeMesh(), specs, None)
    assert ps["k"][3] == "model" and ps["k"][2] is None
    # kv NOT divisible -> sequence sharding
    specs = {"k": jax.ShapeDtypeStruct((64, 128, 32768, 40, 128), jnp.bfloat16)}
    ps = shd.cache_pspec(FakeMesh(), specs, None)
    assert ps["k"][2] == "model" and ps["k"][3] is None


def test_mesh_helpers():
    m = make_mesh((1, 1), ("data", "model"))  # single-device pytest view
    assert dp_axes(m) == ("data",)
    assert flat_axes(m) == ("data", "model")


def test_input_specs_all_cells():
    """Every non-skip (arch, shape) must produce well-formed abstract
    inputs (the dry-run's precondition)."""
    from repro.configs import list_archs, skip_reason
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in SHAPES:
            if skip_reason(cfg, shape):
                continue
            spec = input_specs(cfg, shape)
            leaves = jax.tree.leaves(spec)
            assert leaves, (arch, shape)
            for l in leaves:
                assert isinstance(l, jax.ShapeDtypeStruct)
                assert all(d > 0 for d in l.shape)


def test_token_input_specs_shapes():
    cfg = get_config("internvl2_26b")
    cell = SHAPES["train_4k"]
    spec = token_input_specs(cfg, cell, with_labels=True)
    # patches + text tokens == seq_len total
    assert spec["patches"].shape == (256, cfg.n_patches, cfg.frontend_dim)
    assert spec["tokens"].shape == (256, 4096 - cfg.n_patches)
    assert spec["labels"].shape == (256, 4096)


def test_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = """
  %ag = bf16[16,4096,5120]{2,1,0} all-gather(bf16[1,4096,5120]{2,1,0} %x), dims={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %cp = f32[64]{0} collective-permute(f32[64]{0} %z), source_target_pairs={{0,1}}
  %done = f32[8]{0} all-reduce-done(f32[8]{0} %w)
  %notacoll = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
"""
    out = parse_collective_bytes(hlo)
    assert out["all-gather"] == 16 * 4096 * 5120 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["collective-permute"] == 64 * 4
    assert "total" in out


def test_accum_steps_policy():
    from repro.launch.dryrun import accum_steps
    from repro.configs.shapes import SHAPES
    cell = SHAPES["train_4k"]
    assert accum_steps(get_config("command_r_plus_104b"), cell) == 16
    assert accum_steps(get_config("qwen1_5_32b"), cell) == 16
    assert accum_steps(get_config("stablelm_1_6b"), cell) == 4
    # cap: batch 256 / dp 16 = 16
    assert accum_steps(get_config("llama4_scout_17b_a16e"), cell) <= 16


def test_int8_cache_specs():
    m = build_model(get_config("qwen1_5_32b"))
    cs = m.cache_specs(8, 128, kv_quant=True)
    assert cs["k"].dtype == jnp.int8
    assert cs["k_scale"].shape == cs["k"].shape[:-1]
    cs = m.cache_specs(8, 128)
    assert "k_scale" not in cs


def test_int8_decode_matches_forward():
    """int8 KV cache: decode within 2% of full-precision forward."""
    import dataclasses
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(get_config("stablelm_1_6b", smoke=True),
                              dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    fwd, _ = m.forward(params, {"tokens": toks}, remat=False, q_chunk=4, kv_chunk=4)
    cache = m.init_cache(B, S, kv_quant=True)
    step = jax.jit(m.decode_step)
    errs = []
    for i in range(S):
        lg, cache = step(params, cache, toks[:, i], jnp.full((B,), i, jnp.int32))
        errs.append(np.abs(np.asarray(lg) - np.asarray(fwd[:, i])).max())
    rel = max(errs) / np.abs(np.asarray(fwd)).max()
    assert rel < 0.02, rel
