"""repro.mg: coarsening oracles, colored SymGS vs sequential GS, V-cycle
symmetry/PD, MG-PCG iteration counts, distributed MG-PCG (subprocess)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Format, convert, hpcg, spmv, to_dense_np
from repro.core.solvers import cg, pcg
from repro.mg import (build_colored, build_hierarchy, check_coloring,
                      coarsen_execute, color_grid, galerkin_coarse,
                      plan_coarsen, prolong, restrict, stencil27_coo,
                      symgs, symgs_reference_np)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# Coarsening: restriction / prolongation vs dense oracles
# ---------------------------------------------------------------------------


def _dense_injection_np(nxc, nyc, nzc, nxf, nyf, nzf):
    """Independent dense R (nc x nf): coarse (x,y,z) <- fine (2x,2y,2z)."""
    nc, nf = nxc * nyc * nzc, nxf * nyf * nzf
    R = np.zeros((nc, nf))
    for zc in range(nzc):
        for yc in range(nyc):
            for xc in range(nxc):
                i = xc + nxc * (yc + nyc * zc)
                j = 2 * xc + nxf * (2 * yc + nyf * 2 * zc)
                R[i, j] = 1.0
    return R


def _dense_trilinear_np(nxc, nyc, nzc, nxf, nyf, nzf):
    """Independent dense P (nf x nc): per-axis weight 1 (even) / 0.5 (odd),
    out-of-grid corners dropped (Dirichlet-0 ghost)."""
    nc, nf = nxc * nyc * nzc, nxf * nyf * nzf
    P = np.zeros((nf, nc))
    for zf in range(nzf):
        for yf in range(nyf):
            for xf in range(nxf):
                i = xf + nxf * (yf + nyf * zf)
                axes = []
                for cf, ncdim in ((xf, nxc), (yf, nyc), (zf, nzc)):
                    if cf % 2 == 0:
                        axes.append([(cf // 2, 1.0)])
                    else:
                        opts = [(cf // 2, 0.5)]
                        if cf // 2 + 1 < ncdim:
                            opts.append((cf // 2 + 1, 0.5))
                        axes.append(opts)
                for xc, wx in axes[0]:
                    for yc, wy in axes[1]:
                        for zc, wz in axes[2]:
                            P[i, xc + nxc * (yc + nyc * zc)] += wx * wy * wz
    return P


def test_injection_restrict_prolong_vs_dense_oracle():
    plan = plan_coarsen(4, 4, 4)
    c = coarsen_execute(plan)
    R = _dense_injection_np(2, 2, 2, 4, 4, 4)
    rng = np.random.default_rng(0)
    rf = rng.standard_normal(plan.nf).astype(np.float32)
    xc = rng.standard_normal(plan.nc).astype(np.float32)
    np.testing.assert_allclose(np.asarray(restrict(c, jnp.asarray(rf))),
                               R @ rf, rtol=1e-6, atol=1e-6)
    # injection pairing: P = R^T exactly (V-cycle symmetry requirement)
    np.testing.assert_allclose(np.asarray(prolong(c, jnp.asarray(xc))),
                               R.T @ xc, rtol=1e-6, atol=1e-6)


def test_trilinear_restrict_prolong_vs_dense_oracle():
    plan = plan_coarsen(4, 6, 4, prolong="trilinear")
    c = coarsen_execute(plan)
    P = _dense_trilinear_np(2, 3, 2, 4, 6, 4)
    rng = np.random.default_rng(1)
    rf = rng.standard_normal(plan.nf).astype(np.float32)
    xc = rng.standard_normal(plan.nc).astype(np.float32)
    np.testing.assert_allclose(np.asarray(prolong(c, jnp.asarray(xc))),
                               P @ xc, rtol=1e-5, atol=1e-5)
    # full weighting: R = P^T / 8
    np.testing.assert_allclose(np.asarray(restrict(c, jnp.asarray(rf))),
                               P.T @ rf / 8.0, rtol=1e-5, atol=1e-5)


def test_stencil27_matches_generate_problem():
    prob = hpcg.generate_problem(3, 4, 2)
    D_ref = to_dense_np(hpcg.to_coo(prob))
    D_dev = to_dense_np(stencil27_coo(3, 4, 2))
    np.testing.assert_allclose(D_dev, D_ref, rtol=0, atol=0)


def test_galerkin_coarse_symmetric_and_coarsens():
    prob = hpcg.generate_problem(4, 4, 4)
    plan = plan_coarsen(4, 4, 4, prolong="trilinear", coarse_op="galerkin")
    Ac = galerkin_coarse(hpcg.to_coo(prob), plan)
    D = to_dense_np(Ac)
    assert D.shape == (8, 8)
    np.testing.assert_allclose(D, D.T, rtol=1e-6, atol=1e-6)
    assert np.all(np.linalg.eigvalsh(D.astype(np.float64)) > 0)


def test_plan_coarsen_validation():
    with pytest.raises(ValueError):
        plan_coarsen(3, 4, 4)  # odd dim
    with pytest.raises(ValueError):
        plan_coarsen(4, 4, 4, coarse_op="galerkin")  # degenerate pairing


# ---------------------------------------------------------------------------
# Colored SymGS vs the sequential NumPy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dims", [(4, 4, 4), (5, 3, 4), (8, 8, 2)])
def test_colored_symgs_matches_sequential_gs(dims):
    prob = hpcg.generate_problem(*dims)
    C = hpcg.to_coo(prob)
    colors = color_grid(*dims)
    cs = build_colored(C, dims=dims, fmt=Format.CSR, check=True)
    rng = np.random.default_rng(0)
    n = prob.shape[0]
    b = rng.standard_normal(n).astype(np.float32)
    x0 = rng.standard_normal(n).astype(np.float32)
    got = symgs(cs, jnp.asarray(b), jnp.asarray(x0), sweeps=2, backend="ref")
    want = symgs_reference_np(prob.row, prob.col, prob.val, colors, b, x0,
                              sweeps=2)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)


def test_colored_blocks_any_format_agree():
    dims = (4, 4, 4)
    prob = hpcg.generate_problem(*dims)
    C = hpcg.to_coo(prob)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    base = symgs(build_colored(C, dims=dims, fmt=Format.CSR), b, backend="ref")
    for fmt in (Format.ELL, Format.DIA, Format.COO):
        cs = build_colored(C, dims=dims, fmt=fmt)
        assert set(cs.formats) == {fmt}
        got = symgs(cs, b, backend="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                                   rtol=1e-5, atol=1e-5)


def test_check_coloring_rejects_improper():
    prob = hpcg.generate_problem(4, 4, 4)
    with pytest.raises(ValueError, match="improper coloring"):
        check_coloring(hpcg.to_coo(prob),
                       np.zeros(prob.shape[0], np.int32))


# ---------------------------------------------------------------------------
# V-cycle: symmetry + positive definiteness (PCG's requirements)
# ---------------------------------------------------------------------------


def test_vcycle_apply_M_symmetric_positive_definite():
    prob = hpcg.generate_problem(4, 4, 4)
    hier = build_hierarchy(prob, backend="ref")
    n = prob.shape[0]
    M = np.asarray(jax.jit(jax.vmap(hier.apply_M()))(jnp.eye(n, dtype=jnp.float32))).T
    sym_err = np.abs(M - M.T).max() / np.abs(M).max()
    assert sym_err < 1e-5, sym_err
    w = np.linalg.eigvalsh(((M + M.T) / 2).astype(np.float64))
    assert w.min() > 0, w.min()


# ---------------------------------------------------------------------------
# MG-PCG convergence (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_mg_pcg_beats_cg_16cubed():
    prob = hpcg.generate_problem(16, 16, 16)
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    apply_A = lambda v: spmv(A, v)  # noqa: E731
    hier = build_hierarchy(prob, backend="ref")
    r_cg = jax.jit(lambda bb: cg(apply_A, bb, tol=1e-8, maxiter=500))(b)
    r_mg = jax.jit(lambda bb: pcg(apply_A, bb, tol=1e-8, maxiter=500,
                                  apply_M=hier.apply_M()))(b)
    assert int(r_mg.iters) < int(r_cg.iters), (int(r_mg.iters),
                                               int(r_cg.iters))
    assert int(r_cg.iters) < 500  # both actually converged
    np.testing.assert_allclose(np.asarray(r_mg.x), 1.0, rtol=1e-3, atol=1e-3)


def test_mg_pcg_trilinear_galerkin_converges():
    prob = hpcg.generate_problem(8, 8, 8)
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    apply_A = lambda v: spmv(A, v)  # noqa: E731
    hier = build_hierarchy(prob, prolong="trilinear", coarse_op="galerkin",
                           backend="ref")
    res = pcg(apply_A, b, tol=1e-8, maxiter=200, apply_M=hier.apply_M())
    assert int(res.iters) < 200
    np.testing.assert_allclose(np.asarray(res.x), 1.0, rtol=1e-3, atol=1e-3)


def test_hierarchy_per_level_format_selection():
    from repro.tuning import FormatPolicy

    prob = hpcg.generate_problem(8, 8, 8)
    policy = FormatPolicy("analytic")
    hier = build_hierarchy(prob, policy=policy, backend="ref")
    fmts = hier.formats()
    assert len(fmts) >= 2
    for rec in fmts:
        assert rec["A"] in [f.name for f in policy.candidates]
        assert rec["colors"] is not None and len(rec["colors"]) == 8
    # the selection is real: solve still converges with the chosen formats
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    res = pcg(lambda v: spmv(A, v), b, tol=1e-8, maxiter=100,
              apply_M=hier.apply_M())
    assert int(res.iters) < 100


def test_jacobi_smoother_hierarchy_converges():
    prob = hpcg.generate_problem(8, 8, 8)
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    hier = build_hierarchy(prob, smoother="jacobi", pre=2, post=2,
                           backend="ref")
    res = pcg(lambda v: spmv(A, v), b, tol=1e-8, maxiter=200,
              apply_M=hier.apply_M())
    assert int(res.iters) < 200
    np.testing.assert_allclose(np.asarray(res.x), 1.0, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Distributed MG-PCG (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------


def _run_subprocess(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core import hpcg, Format
        from repro.core.distributed import distribute_vector
        from repro.core.solvers import cg, pcg, operator
        from repro.mg import build_dist_hierarchy
    """ % os.path.abspath(SRC)) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def test_dist_mg_pcg_beats_cg_8shards():
    out = _run_subprocess("""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(16, 16, 16)
        hier = build_dist_hierarchy(prob, mesh, "rows", mode="multiformat",
                                    tune="analytic")
        assert hier.nlevels >= 2, hier
        fmts = hier.formats()
        for rec in fmts:  # per-level per-shard selection ran
            assert len(rec["local"]) == 8, rec
        A = hier.levels[0].A
        b = distribute_vector(hpcg.rhs_for_ones(prob), mesh, "rows")
        apply_A = operator(A, mesh, backend="ref")
        r_cg = jax.jit(lambda bb: cg(apply_A, bb, tol=1e-8, maxiter=500))(b)
        r_mg = jax.jit(lambda bb: pcg(apply_A, bb, tol=1e-8, maxiter=500,
                                      apply_M=hier.apply_M()))(b)
        assert int(r_mg.iters) < int(r_cg.iters), (int(r_mg.iters),
                                                   int(r_cg.iters))
        assert int(r_cg.iters) < 500
        err = float(np.abs(np.asarray(r_mg.x) - 1.0).max())
        assert err < 1e-3, err
        print("DIST_MG_OK", int(r_mg.iters), int(r_cg.iters))
    """)
    assert "DIST_MG_OK" in out
