"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Format, banded_coo, coo_from_dense_np, convert,
                        random_coo, to_dense_np)
from repro.kernels import ops as kops
from repro.kernels.ref import (bsr_spmm_ref, csr_spmv_ref, dia_spmv_ref,
                               ell_spmv_ref)

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# DIA SpMV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,offsets", [
    ((64, 64), [0]),
    ((128, 128), [-1, 0, 1]),
    ((300, 300), [-17, -3, 0, 3, 17]),
    ((1000, 1000), [-96, -32, -1, 0, 1, 32, 96]),
    ((128, 200), [0, 64, 150]),          # rectangular, remote-part shape
    ((200, 128), [-150, -10, 0]),        # tall rectangular
    ((513, 513), [-5, 0, 5]),            # non-tile-aligned rows
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dia_kernel_sweep(shape, offsets, dtype):
    A = convert(banded_coo(shape, offsets, dtype=dtype), Format.DIA)
    x = jnp.asarray(RNG.standard_normal(shape[1]), dtype=dtype)
    y_k = kops.dia_spmv(A, x)
    y_r = dia_spmv_ref(A.offsets, A.data, x, shape[1])
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("tm", [128, 256, 512])
def test_dia_kernel_tile_sizes(tm):
    A = convert(banded_coo((700, 700), [-30, 0, 30]), Format.DIA)
    x = jnp.asarray(RNG.standard_normal(700).astype(np.float32))
    y_k = kops.dia_spmv(A, x, tm=tm)
    np.testing.assert_allclose(np.asarray(y_k), to_dense_np(A) @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ELL SpMV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,density", [
    ((64, 64), 0.1), ((200, 150), 0.08), ((513, 400), 0.05), ((1024, 1024), 0.01),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ell_kernel_sweep(shape, density, dtype):
    A = convert(random_coo(7, shape, density=density, dtype=dtype), Format.ELL)
    x = jnp.asarray(RNG.standard_normal(shape[1]), dtype=dtype)
    y_k = kops.ell_spmv(A, x)
    y_r = ell_spmv_ref(A.cols, A.data, x)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# CSR SpMV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,density", [
    ((64, 64), 0.1),         # single tile
    ((200, 150), 0.08),      # rectangular, non-tile-aligned rows
    ((513, 400), 0.05),      # non-multiple-of-tile rows AND cols
    ((1024, 1024), 0.01),    # multi-tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_csr_kernel_sweep(shape, density, dtype):
    A = convert(random_coo(13, shape, density=density, dtype=dtype), Format.CSR)
    x = jnp.asarray(RNG.standard_normal(shape[1]), dtype=dtype)
    y_k = kops.csr_spmv(A, x)
    y_r = csr_spmv_ref(A.indptr, A.indices, A.data, x, shape[0])
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("tm,tk", [(128, 256), (256, 512), (512, 128)])
def test_csr_kernel_tile_sizes(tm, tk):
    A = convert(random_coo(14, (700, 700), density=0.03), Format.CSR)
    x = jnp.asarray(RNG.standard_normal(700).astype(np.float32))
    y_k = kops.csr_spmv(A, x, tm=tm, tk=tk)
    np.testing.assert_allclose(np.asarray(y_k), to_dense_np(A) @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Tile-config sweeps over adversarial shapes (the autotuner's search space)
# ---------------------------------------------------------------------------
# Every config the tuner may emit must agree with the reference SpMV to
# f32 machine precision (verified against a float64 dense oracle — exact
# bitwise identity with ref is not the spec: different tile boundaries
# legally reassociate the f32 accumulation) and must be bitwise
# *deterministic*: the same config always produces the same bits.

CSR_CFG_GRID = [{"tm": 32, "tk": 64}, {"tm": 128, "tk": 512},
                {"tm": 512, "tk": 128}, {"tm": 1024, "tk": 4096}]

# m (and n) chosen so m % tm != 0 for every tm in the grid: the last row
# tile is ragged and the last nnz chunk is partial.
CSR_RAGGED_SHAPES = [((97, 83), 0.08), ((513, 401), 0.03),
                     ((1021, 999), 0.01)]


@pytest.mark.parametrize("cfg", CSR_CFG_GRID)
@pytest.mark.parametrize("shape,density", CSR_RAGGED_SHAPES)
def test_csr_kernel_cfg_sweep_ragged(shape, density, cfg):
    A = convert(random_coo(21, shape, density=density), Format.CSR)
    x = jnp.asarray(RNG.standard_normal(shape[1]).astype(np.float32))
    y = kops.csr_spmv(A, x, cfg=cfg)
    oracle = to_dense_np(A).astype(np.float64) @ np.asarray(x, np.float64)
    np.testing.assert_allclose(np.asarray(y, np.float64), oracle,
                               rtol=2e-5, atol=2e-5)
    # bitwise determinism of a fixed config
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(kops.csr_spmv(A, x, cfg=cfg)))


def test_csr_kernel_mixed_magnitude_rows():
    """The segmented reduction must keep a tiny row's own relative accuracy
    when it shares an nnz chunk with huge rows — a plain prefix-sum
    difference loses it to catastrophic cancellation (error scales with
    the chunk's running total, not the row's magnitude)."""
    D = np.zeros((8, 8), np.float32)
    D[0:4, :4] = 1e7
    D[4, :4] = 1e-3
    A = convert(coo_from_dense_np(D), Format.CSR)
    x = jnp.ones((8,), jnp.float32)
    y = np.asarray(kops.csr_spmv(A, x, cfg={"tm": 8, "tk": 32}))
    assert y[4] == pytest.approx(4e-3, rel=1e-6), y


@pytest.mark.parametrize("cfg", CSR_CFG_GRID)
def test_csr_kernel_cfg_sweep_empty_rows(cfg):
    """Entire empty row-tiles (zero-width nnz windows) under every config."""
    D = np.zeros((300, 300), np.float32)
    mask = RNG.random((100, 300)) < 0.05
    D[200:, :] = np.where(mask, RNG.standard_normal((100, 300)), 0).astype(np.float32)
    A = convert(coo_from_dense_np(D, capacity=D.astype(bool).sum() + 333),
                Format.CSR)
    x = jnp.asarray(RNG.standard_normal(300).astype(np.float32))
    y = kops.csr_spmv(A, x, cfg=cfg)
    np.testing.assert_allclose(np.asarray(y, np.float64),
                               D.astype(np.float64) @ np.asarray(x, np.float64),
                               rtol=2e-5, atol=2e-5)


ELL_CFG_GRID = [{"tm": 32, "layout": "row"}, {"tm": 32, "layout": "col"},
                {"tm": 256, "layout": "col"}, {"tm": 1024, "layout": "row"}]


@pytest.mark.parametrize("cfg", ELL_CFG_GRID)
@pytest.mark.parametrize("shape,density", [((97, 83), 0.08), ((513, 401), 0.03)])
def test_ell_kernel_cfg_sweep_ragged(shape, density, cfg):
    A = convert(random_coo(22, shape, density=density), Format.ELL)
    x = jnp.asarray(RNG.standard_normal(shape[1]).astype(np.float32))
    y = kops.ell_spmv(A, x, cfg=cfg)
    oracle = to_dense_np(A).astype(np.float64) @ np.asarray(x, np.float64)
    np.testing.assert_allclose(np.asarray(y, np.float64), oracle,
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(kops.ell_spmv(A, x, cfg=cfg)))


@pytest.mark.parametrize("cfg", ELL_CFG_GRID)
def test_ell_kernel_k0(cfg):
    """k=0 ELL (all rows empty): nothing to stream, result is exactly 0."""
    from repro.core.formats import ELL
    A = ELL(jnp.zeros((70, 0), jnp.int32), jnp.zeros((70, 0), jnp.float32),
            (70, 50), 0)
    x = jnp.asarray(RNG.standard_normal(50).astype(np.float32))
    y = kops.ell_spmv(A, x, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(70, np.float32))


@pytest.mark.parametrize("cfg", [{"tm": 32}, {"tm": 128}, {"tm": 1024}])
def test_dia_kernel_cfg_sweep_ragged(cfg):
    A = convert(banded_coo((517, 517), [-19, -3, 0, 3, 19]), Format.DIA)
    x = jnp.asarray(RNG.standard_normal(517).astype(np.float32))
    y = kops.dia_spmv(A, x, cfg=cfg)
    oracle = to_dense_np(A).astype(np.float64) @ np.asarray(x, np.float64)
    np.testing.assert_allclose(np.asarray(y, np.float64), oracle,
                               rtol=2e-5, atol=2e-5)


def test_csr_kernel_empty_rows_and_padding():
    """Empty rows cost nothing (zero-width windows); capacity padding past
    indptr[-1] is never read."""
    D = np.zeros((300, 300), np.float32)
    mask = RNG.random((150, 300)) < 0.05
    D[150:, :] = np.where(mask, RNG.standard_normal((150, 300)), 0).astype(np.float32)
    A = convert(coo_from_dense_np(D, capacity=D.astype(bool).sum() + 777),
                Format.CSR)
    x = jnp.asarray(RNG.standard_normal(300).astype(np.float32))
    np.testing.assert_allclose(np.asarray(kops.csr_spmv(A, x)),
                               D @ np.asarray(x), rtol=1e-4, atol=1e-4)


def test_csr_vmem_budget_fallback():
    """nnz arrays + x too large for VMEM residency -> ref fallback."""
    n = 2_000_000  # 8 MB f32 > budget
    A = convert(banded_coo((256, n), [0, 1000]), Format.CSR)
    x = jnp.ones((n,), jnp.float32)
    y = kops.csr_spmv(A, x)
    np.testing.assert_allclose(np.asarray(y), to_dense_np(A) @ np.ones(n),
                               rtol=1e-4, atol=1e-4)


def test_hyb_pallas_routes_tail_through_csr_kernel():
    A = random_coo(15, (200, 160), density=0.06)
    H = convert(A, Format.HYB, k=2)  # force a populated COO tail
    assert H.coo.capacity > 1
    x = jnp.asarray(RNG.standard_normal(160).astype(np.float32))
    y = kops.hyb_spmv(H, x)
    np.testing.assert_allclose(np.asarray(y), to_dense_np(A) @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# BSR SpMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,bs,kb", [
    ((256, 256), 64, 64), ((256, 384), 64, 96), ((512, 256), 128, 128),
    ((384, 384), 128, 40),   # K not a tile multiple
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bsr_kernel_sweep(shape, bs, kb, dtype):
    A = convert(random_coo(9, shape, density=0.15, dtype=dtype), Format.BSR,
                block_size=bs)
    B = jnp.asarray(RNG.standard_normal((shape[1], kb)), dtype=dtype)
    y_k = kops.bsr_spmm(A, B)
    y_r = bsr_spmm_ref(A.indptr, A.indices, A.data, B, shape[0])
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


def test_bsr_empty_row_fallback():
    """Kernel precondition violated -> wrapper must fall back, stay correct."""
    # only one nonzero => most block rows empty
    A = convert(banded_coo((256, 256), [0], fill=[2.0]), Format.BSR, block_size=64)
    import dataclasses
    # carve out an empty block row by zeroing indptr ranges is fiddly; instead
    # build from a matrix with an all-zero top half
    import numpy as _np
    D = _np.zeros((256, 256), _np.float32)
    D[128:, :] = _np.asarray(to_dense_np(A))[128:, :]
    from repro.core import coo_from_dense_np
    Ab = convert(coo_from_dense_np(D), Format.BSR, block_size=64)
    B = jnp.asarray(RNG.standard_normal((256, 32)).astype(np.float32))
    y = kops.bsr_spmm(Ab, B)
    np.testing.assert_allclose(np.asarray(y), D @ np.asarray(B), rtol=1e-4, atol=1e-4)


def test_bsr_spmv_path():
    A = convert(random_coo(11, (256, 256), density=0.2), Format.BSR, block_size=64)
    x = jnp.asarray(RNG.standard_normal(256).astype(np.float32))
    np.testing.assert_allclose(np.asarray(kops.bsr_spmv(A, x)),
                               to_dense_np(A) @ np.asarray(x), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# backend="pallas" dispatch through the core API
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", [Format.CSR, Format.DIA, Format.ELL, Format.HYB])
def test_core_pallas_backend(fmt):
    from repro.core import spmv
    A = convert(banded_coo((256, 256), [-4, 0, 4]), fmt)
    x = jnp.asarray(RNG.standard_normal(256).astype(np.float32))
    y_p = spmv(A, x, backend="pallas")
    y_r = spmv(A, x, backend="ref")
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_r), rtol=1e-4, atol=1e-4)


def test_force_interpret_env_override(monkeypatch):
    """REPRO_FORCE_INTERPRET pins the interpret flag in both directions,
    re-read per call — no TPU-detection heuristic, no module reload."""
    monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    assert kops.interpret_mode() == kops.INTERPRET
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert kops.interpret_mode() is True
    # the forced-interpret path must execute end to end
    A = convert(banded_coo((128, 128), [-1, 0, 1]), Format.CSR)
    x = jnp.ones((128,), jnp.float32)
    np.testing.assert_allclose(np.asarray(kops.csr_spmv(A, x)),
                               to_dense_np(A) @ np.ones(128), rtol=1e-4, atol=1e-4)
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert kops.interpret_mode() is False
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "")  # unset-equivalent
    assert kops.interpret_mode() == kops.INTERPRET


def test_vmem_budget_fallback():
    """x too large for VMEM residency -> ref fallback, still correct."""
    n = 2_000_000  # 8 MB f32 > budget
    A = convert(banded_coo((1024, n), [0, 100]), Format.DIA)
    x = jnp.ones((n,), jnp.float32)
    y = kops.dia_spmv(A, x)
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# SpMM / transposed-rhs SpMM: rhs-width sweeps vs the dense oracle
# ---------------------------------------------------------------------------

SPMM_SHAPES = [((64, 64), 0.1), ((300, 257), 0.05), ((128, 512), 0.02)]


@pytest.mark.parametrize("shape,density", SPMM_SHAPES)
@pytest.mark.parametrize("b", [1, 5, 16])
def test_csr_spmm_sweep(shape, density, b):
    A = convert(random_coo(3, shape, density), Format.CSR)
    B = jnp.asarray(RNG.standard_normal((shape[1], b)).astype(np.float32))
    y = kops.csr_spmm(A, B)
    np.testing.assert_allclose(np.asarray(y), to_dense_np(A) @ np.asarray(B),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape,density", SPMM_SHAPES)
@pytest.mark.parametrize("b", [1, 5, 16])
def test_csr_spmm_t_sweep(shape, density, b):
    A = convert(random_coo(4, shape, density), Format.CSR)
    X = jnp.asarray(RNG.standard_normal((b, shape[1])).astype(np.float32))
    y = kops.csr_spmm_t(A, X)
    np.testing.assert_allclose(np.asarray(y), np.asarray(X) @ to_dense_np(A).T,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("layout", ["row", "col"])
@pytest.mark.parametrize("b", [1, 7, 16])
def test_ell_spmm_sweep(layout, b):
    A = convert(random_coo(5, (200, 160), 0.05), Format.ELL)
    B = jnp.asarray(RNG.standard_normal((160, b)).astype(np.float32))
    y = kops.ell_spmm(A, B, layout=layout)
    np.testing.assert_allclose(np.asarray(y), to_dense_np(A) @ np.asarray(B),
                               rtol=1e-4, atol=1e-4)
    X = jnp.asarray(RNG.standard_normal((b, 160)).astype(np.float32))
    yt = kops.ell_spmm_t(A, X, layout=layout)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(X) @ to_dense_np(A).T,
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", [1, 9])
def test_hyb_spmm_sweep(b):
    # skewed rows so the COO tail is non-empty
    d = np.zeros((96, 80), np.float32)
    d[:, :2] = RNG.standard_normal((96, 2))
    d[0, :] = RNG.standard_normal(80)
    A = convert(coo_from_dense_np(d), Format.HYB)
    assert int(A.coo.nnz) > 0
    B = jnp.asarray(RNG.standard_normal((80, b)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(kops.hyb_spmm(A, B)),
                               d @ np.asarray(B), rtol=1e-4, atol=1e-4)
    X = jnp.asarray(RNG.standard_normal((b, 80)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(kops.hyb_spmm_t(A, X)),
                               np.asarray(X) @ d.T, rtol=1e-4, atol=1e-4)


def test_core_spmm_t_backends_agree():
    from repro.core import spmm_t
    A = convert(random_coo(6, (128, 96), 0.08), Format.CSR)
    X = jnp.asarray(RNG.standard_normal((4, 96)).astype(np.float32))
    y_ref = spmm_t(A, X, backend="ref")
    y_pal = spmm_t(A, X, backend="pallas")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    # ref path IS the double transpose it replaced at the layer level
    from repro.core import spmm
    np.testing.assert_allclose(np.asarray(y_ref),
                               np.asarray(spmm(A, X.T, backend="ref").T),
                               rtol=1e-6, atol=1e-6)
