"""repro.tuning: features, tree, cache, policy — selection quality included.

The acceptance-critical assertions live here:
  * FormatPolicy("ml") with the shipped tree picks DIA on the HPCG stencil;
  * ml agrees with the profiling oracle on >= 80% of a held-out corpus;
  * the cache round-trips to disk and survives a fresh process;
  * a warm FormatPolicy("cached") lookup triggers no profiling runs and no
    tree inference.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DynamicMatrix, Format, SwitchDynamicMatrix, autotune,
                        banded_coo, hpcg, random_coo, to_dense_np)
from repro.tuning import (FEATURE_NAMES, DecisionTree, FormatPolicy,
                          PatternFeatures, SelectionCache, load_default_tree,
                          pattern_signature, profile_select)
from repro.tuning import engines
from repro.tuning.corpus import DEFAULT_CANDIDATES, generate_corpus


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------


def test_features_vector_matches_names():
    A = banded_coo((64, 64), [-2, 0, 2])
    f = PatternFeatures.from_coo(A)
    v = f.vector()
    assert v.shape == (len(FEATURE_NAMES),)
    assert np.isfinite(v).all()
    assert f.ndiag == 3
    assert f.bandwidth == 2
    assert f.row_nnz_max == 3
    # every diagonal is near-full on a square banded matrix
    assert f.diag_fill > 0.9
    stats = f.to_stats()
    assert (stats.m, stats.n, stats.nnz) == (64, 64, f.nnz)
    assert stats.ndiag == 3


def test_pattern_signature_discriminates():
    a = PatternFeatures.from_coo(banded_coo((64, 64), [-1, 0, 1]))
    b = PatternFeatures.from_coo(banded_coo((64, 64), [-1, 0, 1]))
    c = PatternFeatures.from_coo(random_coo(0, (64, 64), density=0.1))
    assert pattern_signature(a) == pattern_signature(b)
    assert pattern_signature(a) != pattern_signature(c)


# ---------------------------------------------------------------------------
# decision tree
# ---------------------------------------------------------------------------


def test_tree_fit_predict_serialize(tmp_path):
    rng = np.random.default_rng(0)
    X = rng.standard_normal((300, 5))
    y = np.where(X[:, 2] > 0.5, int(Format.DIA),
                 np.where(X[:, 0] > 0, int(Format.ELL), int(Format.CSR)))
    t = DecisionTree(("a", "b", "c", "d", "e")).fit(X, y, max_depth=6)
    assert t.score(X, y) > 0.95
    # dict and file round-trips preserve predictions exactly
    t2 = DecisionTree.from_dict(t.to_dict())
    np.testing.assert_array_equal(t.predict(X), t2.predict(X))
    path = str(tmp_path / "tree.json")
    t.save(path)
    t3 = DecisionTree.load(path)
    np.testing.assert_array_equal(t.predict(X), t3.predict(X))
    assert t3.feature_names == ("a", "b", "c", "d", "e")


def test_default_tree_ships_with_package():
    t = load_default_tree()
    assert t is not None, "default_tree.json missing from repro.tuning"
    assert t.n_nodes > 1
    assert tuple(t.feature_names) == FEATURE_NAMES


# ---------------------------------------------------------------------------
# engines (satellite regressions)
# ---------------------------------------------------------------------------


def test_profile_select_clear_error_when_all_candidates_fail():
    A = random_coo(3, (100, 60), density=0.05)  # not 64-block-aligned
    x = jnp.ones((60,), jnp.float32)
    with pytest.raises(ValueError, match="BSR"):
        profile_select(A, x, candidates=(Format.BSR,),
                       conv_kwargs={Format.BSR: {"block_size": 64}})


def test_calibrate_penalty_cached_per_backend():
    engines._CALIBRATED_PENALTY.clear()
    p1 = engines.calibrate_gather_penalty(n=1 << 12, iters=2)
    assert list(engines._CALIBRATED_PENALTY) == [jax.default_backend()]
    p2 = engines.calibrate_gather_penalty(n=1 << 12, iters=2)
    assert p1 == p2 >= 1.0


# ---------------------------------------------------------------------------
# selection quality (acceptance criteria)
# ---------------------------------------------------------------------------


def test_ml_picks_dia_on_hpcg_stencil():
    prob = hpcg.generate_problem(16, 16, 16)
    A = hpcg.to_coo(prob)
    rep = FormatPolicy("ml").select(A)
    assert rep.mode == "ml"  # the shipped tree answered, not a fallback
    assert rep.best == Format.DIA


def test_ml_agrees_with_profile_on_holdout():
    # Held-out corpus: same generator families, a seed the tree never saw.
    # Agreement uses the labeler's own tie philosophy (corpus.label_matrix,
    # tie_tol): a pick whose measured SpMV lands within the near-tie band
    # of the profiled winner IS the oracle answer — with SELL in the menu
    # several formats routinely measure within noise of each other, and
    # demanding exact label equality would gate on which near-tie the
    # timing jitter happened to crown, not on selection quality.
    tie_tol = 1.5
    mats, fams = generate_corpus(24, seed=1234)
    policy = FormatPolicy("ml")
    hits, detail = 0, []
    for A, fam in zip(mats, fams):
        x = jnp.ones((A.shape[1],), A.dtype)
        # best-of-two profiling passes: a single scheduler spike on one
        # format's measurement must not crown (or dethrone) a winner
        rep = profile_select(A, x, candidates=DEFAULT_CANDIDATES, iters=8)
        rep2 = profile_select(A, x, candidates=DEFAULT_CANDIDATES, iters=8)
        times = {f: min(t, rep2.times.get(f, t))
                 for f, t in rep.times.items()}
        winner = min(times, key=times.get)
        best_t = times[winner]
        pick = policy.select(A).best
        pick_t = times.get(pick)
        if pick_t is not None and pick_t <= best_t * (1 + tie_tol):
            hits += 1
        else:
            detail.append((fam, winner.name, pick.name,
                           None if pick_t is None else
                           round(pick_t / best_t, 2)))
    agreement = hits / len(mats)
    assert agreement >= 0.8, f"agreement {agreement:.2f}; misses: {detail}"


def test_analytic_and_ml_modes_via_autotune_shim():
    A = banded_coo((256, 256), [-1, 0, 1])
    assert autotune(A, mode="analytic").best in DEFAULT_CANDIDATES
    assert autotune(A, mode="ml").best in DEFAULT_CANDIDATES
    with pytest.raises(ValueError):
        autotune(A, mode="nope")


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def test_cache_roundtrip_survives_fresh_process(tmp_path):
    path = str(tmp_path / "sel.json")
    feats = PatternFeatures.from_coo(banded_coo((128, 128), [-1, 0, 1]))
    key = SelectionCache.key(feats, DEFAULT_CANDIDATES, "cpu", "testdev")
    cache = SelectionCache(path)
    assert cache.get(key) is None
    cache.put(key, Format.DIA)
    assert cache.get(key) == Format.DIA
    # a *fresh process* must see the persisted selection
    code = (
        "import sys, json\n"
        "from repro.tuning import SelectionCache\n"
        f"c = SelectionCache({path!r})\n"
        f"print(c.get({key!r}).name)\n"
    )
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True,
                         env={**os.environ, "PYTHONPATH": "src"},
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr
    assert res.stdout.strip() == "DIA"


def test_cache_unwritable_path_degrades_to_memory():
    cache = SelectionCache("/proc/1/nope/sel.json")
    with pytest.warns(UserWarning, match="not persistable"):
        cache.put("k", Format.DIA)
    assert cache.get("k") == Format.DIA  # in-memory still works
    cache.put("k2", Format.ELL)  # and warns only once


def test_cache_ignores_corrupt_file(tmp_path):
    path = str(tmp_path / "sel.json")
    with open(path, "w") as f:
        f.write("{not json")
    cache = SelectionCache(path)
    assert len(cache) == 0
    cache.put("k", Format.ELL)
    assert SelectionCache(path).get("k") == Format.ELL


def test_cached_policy_warm_hit_runs_no_profiling(tmp_path, monkeypatch):
    A = banded_coo((512, 512), [-1, 0, 1, 8, -8])
    cache = SelectionCache(str(tmp_path / "sel.json"))
    policy = FormatPolicy("cached", cache=cache)
    cold = policy.select(A)
    assert cold.mode.startswith("cached-miss")

    # Warm path: any profiling run or tree/analytic inference is a failure.
    def boom(*a, **k):
        raise AssertionError("selection work ran on a warm cache hit")

    monkeypatch.setattr(engines, "profile_select", boom)
    monkeypatch.setattr("repro.tuning.policy.profile_select", boom)
    monkeypatch.setattr(FormatPolicy, "_select_ml", boom)
    warm = policy.select(A)
    assert warm.mode == "cached"
    assert warm.best == cold.best
    # and the decision is jit-stability-safe: same pick on a fresh policy
    fresh = FormatPolicy("cached", cache=SelectionCache(cache.path))
    monkeypatch.setattr(FormatPolicy, "_select_ml", boom, raising=True)
    assert fresh.select(A).best == cold.best


# ---------------------------------------------------------------------------
# integration: auto() constructors + distributed-style use
# ---------------------------------------------------------------------------


def test_dynamic_auto_constructor():
    A = banded_coo((256, 256), [-16, -1, 0, 1, 16])
    dm = DynamicMatrix.auto(A)  # default ML policy
    assert dm.active in DEFAULT_CANDIDATES
    x = np.random.default_rng(0).standard_normal(256).astype(np.float32)
    np.testing.assert_allclose(np.asarray(dm.spmv(jnp.asarray(x))),
                               to_dense_np(A) @ x, rtol=1e-4, atol=1e-4)


def test_switch_dynamic_auto_constructor():
    A = banded_coo((128, 128), [-1, 0, 1])
    sw = SwitchDynamicMatrix.auto(A, policy="analytic")
    assert sw.candidates == DEFAULT_CANDIDATES
    active = sw.candidates[int(sw.active_id)]
    assert active == Format.DIA  # analytic model: banded -> DIA
    x = np.random.default_rng(1).standard_normal(128).astype(np.float32)
    np.testing.assert_allclose(np.asarray(sw.spmv(jnp.asarray(x))),
                               to_dense_np(A) @ x, rtol=1e-4, atol=1e-4)


def test_linear_sparse_ml_policy():
    from repro.models.linear_sparse import LinearSparse, prune_magnitude
    w = prune_magnitude(
        np.random.default_rng(2).standard_normal((64, 48)).astype(np.float32),
        density=0.2)
    layer = LinearSparse.from_dense(w, tune="ml")
    x = np.random.default_rng(3).standard_normal((4, 64)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(layer(jnp.asarray(x))), x @ w,
                               rtol=1e-4, atol=1e-4)
