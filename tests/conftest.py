"""Test-session guards.

The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 in
its OWN process only; tests must run with the default single-device view
(multi-device tests spawn subprocesses). Fail fast if the env leaks.
"""
import os


def pytest_configure(config):
    flags = os.environ.get("XLA_FLAGS", "")
    assert "xla_force_host_platform_device_count" not in flags, (
        "XLA_FLAGS device-count override leaked into the test session; "
        "the dry-run must set it only inside launch/dryrun.py")
