"""repro.obs: tracer, metrics, ledger, report, provenance, solver history."""
import collections
import json
import os
import sys
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Format, hpcg
from repro.core.convert import convert, planned_pulls_scope
from repro.core.ops import spmv
from repro.core.solvers import cg, cg_fixed_iters, pcg
from repro.obs import explain, ledger, metrics, trace
from repro.obs import report
from repro.obs.provenance import env_info


@pytest.fixture(autouse=True)
def _clean_trace():
    """Each test starts from an empty trace in the mode the env dictates."""
    trace.clear()
    yield
    trace.clear()


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------


def test_spans_nest_and_record_parentage():
    with trace.tracing("full"):
        with trace.span("build.outer", kind="t") as outer:
            with trace.span("plan.inner") as inner:
                pass
            trace.event("kernel.route", route="ref")
    evs = {e["name"]: e for e in trace.events()}
    assert set(evs) == {"build.outer", "plan.inner", "kernel.route"}
    assert evs["build.outer"]["parent"] is None
    assert evs["plan.inner"]["parent"] == evs["build.outer"]["id"]
    # the event fired while build.outer was still open -> it is a child too
    assert evs["kernel.route"]["parent"] == evs["build.outer"]["id"]
    assert inner.id != outer.id
    # durations: the parent covers the child
    assert evs["build.outer"]["dur"] >= evs["plan.inner"]["dur"]


def test_summary_mode_aggregates_without_ring():
    with trace.tracing("summary"):
        for _ in range(3):
            with trace.span("select.policy"):
                pass
    assert trace.events() == []  # no per-event storage in summary mode
    agg = trace.aggregate()
    assert agg["select.policy"]["count"] == 3
    assert "select.policy" in trace.summary()


def test_off_mode_emits_nothing_and_never_touches_jax(monkeypatch):
    """The REPRO_TRACE=off hot path must not record, sync, or import-touch
    jax: sp.sync() on the null span is a pure no-op."""
    def _boom(*a, **k):  # any block_until_ready call would be a sync leak
        raise AssertionError("block_until_ready called on the off path")

    monkeypatch.setattr(jax, "block_until_ready", _boom)
    trace.set_mode("off")
    y = jnp.arange(4.0)
    with jax.transfer_guard("disallow"):
        with trace.span("kernel.anything", x=1) as sp:
            sp.sync(y)
            sp.set(a=2)
        trace.event("kernel.evt")
    assert trace.events() == []
    assert trace.aggregate() == {}
    # the off span is one shared singleton — no allocation per call
    assert trace.span("a") is trace.span("b")


def test_tracing_scope_restores_mode():
    trace.set_mode("off")
    with trace.tracing("full"):
        assert trace.mode() == "full"
        with trace.tracing("summary"):
            assert trace.mode() == "summary"
        assert trace.mode() == "full"
    assert trace.mode() == "off"


def test_export_chrome_roundtrip(tmp_path):
    with trace.tracing("full"):
        with trace.span("solver.solve", precond="mg") as sp:
            with trace.span("exchange.dist_spmv"):
                pass
    path = trace.export_chrome(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert all(e["ph"] == "X" for e in doc["traceEvents"])
    evs = report.load_trace(path)
    assert {e["name"] for e in evs} == {"solver.solve", "exchange.dist_spmv"}
    child = next(e for e in evs if e["name"] == "exchange.dist_spmv")
    parent = next(e for e in evs if e["name"] == "solver.solve")
    assert child["parent"] == parent["id"]
    assert parent["args"]["precond"] == "mg"  # ids popped out of args


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def test_metrics_snapshot_reset_roundtrip():
    metrics.reset(["t.a", "t.b", "t.h"])
    metrics.inc("t.a")
    metrics.inc("t.a", 2)
    metrics.inc("t.b", 5)
    metrics.observe("t.h", 0.25)
    metrics.observe("t.h", 0.75)
    snap = metrics.snapshot()
    assert snap["counters"]["t.a"] == 3
    assert snap["counters"]["t.b"] == 5
    h = snap["histograms"]["t.h"]
    assert (h["count"], h["sum"], h["min"], h["max"]) == (2, 1.0, 0.25, 0.75)
    assert h["mean"] == 0.5
    json.dumps(snap)  # JSON-ready
    metrics.reset(["t.a"])
    assert metrics.value("t.a") == 0
    assert metrics.value("t.b") == 5  # scoped reset leaves others alone
    metrics.reset(["t.b", "t.h"])


def test_metrics_scope_is_order_independent():
    metrics.inc("t.scope", 100)  # unrelated earlier activity
    with metrics.scope() as s:
        metrics.inc("t.scope", 3)
        assert s.delta("t.scope") == 3
    # a second scope sees only its own window, not the 103 before it
    with metrics.scope() as s2:
        assert s2.delta("t.scope") == 0
        metrics.inc("t.scope")
        assert s2.deltas() == {"t.scope": 1}
    metrics.reset(["t.scope"])


def test_quantile_vs_numpy_oracle():
    """Bucket-estimated p50/p95/p99 must land within the 1-2-5 series'
    resolution (~±25%) of numpy's exact quantiles on a skewed sample."""
    metrics.reset(["t.q"])
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=5.0, sigma=1.5, size=4000)
    for v in vals:
        metrics.observe("t.q", v)
    for q in (0.5, 0.95, 0.99):
        est = metrics.quantile("t.q", q)
        ref = float(np.quantile(vals, q))
        assert abs(est - ref) / ref < 0.25, (q, est, ref)
    qs = metrics.quantiles("t.q")
    assert set(qs) == {"p50", "p95", "p99"}
    assert qs["p50"] <= qs["p95"] <= qs["p99"]
    metrics.reset(["t.q"])


def test_quantile_edge_cases():
    metrics.reset(["t.single", "t.empty"])
    assert metrics.quantile("t.empty", 0.5) is None  # never observed
    metrics.observe("t.single", 42.0)
    # single observation: min==max clamping makes every quantile exact
    for q in (0.0, 0.5, 0.99, 1.0):
        assert metrics.quantile("t.single", q) == pytest.approx(42.0)
    with pytest.raises(ValueError):
        metrics.quantile("t.single", 1.5)
    metrics.reset(["t.single"])


def test_define_histogram_and_gauges():
    metrics.reset(["t.custom", "t.gauge"])
    metrics.define_histogram("t.custom", [1.0, 2.0, 4.0])
    for v in (0.5, 1.5, 3.0, 8.0):
        metrics.observe("t.custom", v)
    assert metrics.quantile("t.custom", 0.5) == pytest.approx(1.75, rel=0.3)
    with pytest.raises(ValueError):  # re-binning live counts is impossible
        metrics.define_histogram("t.custom", [10.0])
    metrics.set_gauge("t.gauge", 3)
    metrics.set_gauge("t.gauge", 7)  # last write wins
    assert metrics.gauge("t.gauge") == 7
    snap = metrics.snapshot()
    assert snap["gauges"]["t.gauge"] == 7
    json.dumps(snap)
    metrics.reset(["t.custom", "t.gauge"])
    assert metrics.gauge("t.gauge", default=-1) == -1


def test_trace_ring_drop_counter_and_warn_once(tmp_path, monkeypatch):
    """A wrapped full-mode ring counts drops in trace.dropped_events and
    export_chrome warns exactly once per collection."""
    monkeypatch.setattr(trace, "RING_CAPACITY", 8)
    with metrics.scope() as s:
        with trace.tracing("full"):
            trace.clear()
            for i in range(12):
                trace.event("kernel.route", i=i)
            assert trace.dropped() == 4
            assert s.delta("trace.dropped_events") == 4
            assert len(trace.events()) == 8
            # newest events win: the first 4 are gone
            assert [e["args"]["i"] for e in trace.events()] == list(range(4, 12))
            with pytest.warns(RuntimeWarning, match="truncated"):
                trace.export_chrome(str(tmp_path / "t1.json"))
            with warnings.catch_warnings():
                warnings.simplefilter("error")  # second export: silent
                trace.export_chrome(str(tmp_path / "t2.json"))
            doc = json.load(open(tmp_path / "t1.json"))
            assert doc["otherData"]["dropped_events"] == 4
            trace.clear()  # re-arms the warning
            trace.event("kernel.route", i=0)
            with warnings.catch_warnings():
                warnings.simplefilter("error")  # no drops -> no warning
                trace.export_chrome(str(tmp_path / "t3.json"))


def test_planned_pulls_scope_counts_only_inside():
    A = jnp.zeros((4, 4)).at[0, 0].set(1.0)
    from repro.core.formats import Dense
    D = Dense(A, (4, 4), 16)
    convert(D, Format.COO)  # pulls before the scope must not count
    with planned_pulls_scope() as s:
        before = s.count
        convert(D, Format.COO)
        assert s.count > before
    first = s.count
    convert(D, Format.COO)  # pulls after the scope must not count either
    assert s.count == first


# ---------------------------------------------------------------------------
# Instrumented layers, end to end
# ---------------------------------------------------------------------------


def test_hpcg_trace_contains_phases_with_sane_parentage():
    """An hpcg build + multiformat selection + auto-routed solve leaves
    select/plan/convert/kernel spans in the trace, with every recorded
    parent id belonging to a recorded span."""
    from repro.core.distributed import build_dist_matrix, distribute_vector
    from repro.core.solvers import operator

    with trace.tracing("full"):
        trace.clear()
        prob = hpcg.generate_problem(4, 4, 4)
        mesh = jax.make_mesh((1,), ("rows",))
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape,
                              mesh, "rows", mode="multiformat",
                              tune="analytic")
        b = distribute_vector(hpcg.rhs_for_ones(prob), mesh, "rows")
        res = jax.block_until_ready(
            cg(operator(A, mesh, backend="auto"), b, tol=1e-6, maxiter=50))
        evs = trace.events()
    assert float(res.resnorm) < 1e-3
    phases = {report.phase_of(e["name"]) for e in evs}
    assert {"select", "plan", "convert", "kernel", "build"} <= phases, phases
    ids = {e["id"] for e in evs}
    by_id = {e["id"]: e for e in evs}
    for e in evs:
        if e["parent"] is not None and e["parent"] in ids:
            parent = by_id[e["parent"]]
            # a child span starts no earlier than its parent
            assert e["ts"] >= parent["ts"] - 1e-3, (e, parent)
    # the build.dist span must be an ancestor of at least one plan span
    build_ids = {e["id"] for e in evs if e["name"] == "build.dist"}
    assert any(e["parent"] in build_ids for e in evs
               if e["name"].startswith(("plan.", "select.", "convert.")))


def test_selection_cache_counters(tmp_path):
    from repro.tuning.cache import SelectionCache
    from repro.tuning.policy import FormatPolicy
    from repro.core import random_coo

    C = random_coo(0, (32, 32), 0.1)
    cache = SelectionCache(str(tmp_path / "sel.json"))
    policy = FormatPolicy("cached", cache=cache)
    with metrics.scope() as s:
        policy.select(C)
        assert s.delta("selection.cache_miss") == 1
        policy.select(C)
        assert s.delta("selection.cache_hit") == 1


def test_kernel_route_counters():
    from repro.core.ops import kernel_route
    from repro.core import random_coo

    A = convert(random_coo(1, (64, 64), 0.1), Format.CSR)
    with metrics.scope() as s:
        route, cfg = kernel_route(A)  # empty cache: unmeasured -> ref
        assert route in ("ref", "pallas")
        deltas = s.deltas()
    assert any(k.startswith("kernel.route.") for k in deltas), deltas


def test_padding_waste_histograms():
    from repro.core import random_coo

    metrics.reset(["ell.padding_waste", "hyb.padding_waste"])
    C = random_coo(3, (64, 64), 0.05)
    convert(C, Format.ELL)
    convert(C, Format.HYB)
    snap = metrics.snapshot()["histograms"]
    assert snap["ell.padding_waste"]["count"] == 1
    assert 0.0 <= snap["ell.padding_waste"]["max"] <= 1.0
    assert snap["hyb.padding_waste"]["count"] == 1


# ---------------------------------------------------------------------------
# Decision ledger + explain
# ---------------------------------------------------------------------------


@pytest.fixture
def _ledger_on():
    ledger.set_enabled(True)
    ledger.clear()
    yield
    ledger.clear()


def test_ledger_ring_drops_and_dump_roundtrip(tmp_path, monkeypatch, _ledger_on):
    monkeypatch.setattr(ledger, "CAPACITY", 4)
    monkeypatch.setattr(ledger, "_RING", collections.deque(maxlen=4))
    for i in range(6):
        ledger.record("kernel.route", i=i)
    recs = ledger.records()
    assert len(recs) == 4
    assert [r["i"] for r in recs] == [2, 3, 4, 5]  # newest win
    assert ledger.dropped() == 2
    seqs = [r["seq"] for r in recs]
    assert seqs == sorted(seqs)
    path = ledger.dump_json(str(tmp_path / "led.json"))
    doc = ledger.load_json(path)
    assert len(doc["records"]) == 4 and doc["dropped"] == 2
    # seq stays monotonic across clear(): dumps never alias
    ledger.clear()
    ledger.record("kernel.route", i=99)
    assert ledger.records()[0]["seq"] > seqs[-1]
    with open(tmp_path / "bad.json", "w") as f:
        json.dump({"nope": 1}, f)
    with pytest.raises(ValueError):
        ledger.load_json(str(tmp_path / "bad.json"))


def test_ledger_disabled_records_nothing(_ledger_on):
    ledger.set_enabled(False)
    ledger.record("format.select", chosen="CSR")
    assert ledger.records() == []
    ledger.set_enabled(True)


def test_policy_select_emits_explainable_records(tmp_path, _ledger_on):
    """A cached-mode selection leaves a format.select record carrying the
    feature vector, the CART path (or analytic scores), the cache
    hit/miss, and the kernel veto reason; the second select is a hit."""
    from repro.core import random_coo
    from repro.tuning.cache import SelectionCache
    from repro.tuning.policy import FormatPolicy

    C = random_coo(0, (64, 64), 0.1)
    policy = FormatPolicy("cached", cache=SelectionCache(
        str(tmp_path / "sel.json")))
    policy.select(C)
    policy.select(C)
    recs = ledger.records(kind="format.select")
    assert len(recs) == 2
    miss, hit = recs
    assert miss["cache"] == "miss" and hit["cache"] == "hit"
    assert miss["chosen"] in Format.__members__
    assert set(miss["features"]) >= {"log_m", "row_cv", "ell_efficiency"}
    assert "tree_path" in miss or "scores" in miss
    if "tree_path" in miss:
        leaf = miss["tree_path"][-1]
        assert leaf["leaf"] and leaf["predict_name"] in Format.__members__
    # empty kernel cache: the pin must carry its veto reason
    assert "no tuned kernel record" in miss["kernel_veto"]
    text = explain.render(recs)
    assert "cache: miss" in text and "cache: hit" in text
    if "tree_path" in miss:
        assert "CART path" in text and "leaf[" in text


def test_plan_for_records_sell_geometry_source(tmp_path, _ledger_on):
    from benchmarks.bench_formats import powerlaw_coo
    from repro.tuning import kernel_tune
    from repro.tuning.cache import SelectionCache
    from repro.tuning.policy import FormatPolicy

    C = powerlaw_coo(3, 512)
    cache = SelectionCache(str(tmp_path / "k.json"))
    A = convert(C, Format.SELL)
    kernel_tune.tune_kernel(A, cache=cache,
                            grid=kernel_tune.default_grid(A, smoke=True),
                            iters=1, inner=1)
    policy = FormatPolicy("cached", cache=cache)
    ledger.clear()
    policy.plan_for(C, fmt=Format.SELL)
    recs = ledger.records(kind="plan.switch")
    assert len(recs) == 1
    assert recs[0]["fmt"] == "SELL"
    # the tuned record's (c, sigma) seeded the plan and said so
    assert recs[0]["geometry_source"] == "tuned kernel record"
    assert "c" in recs[0]["hints"] and "sigma" in recs[0]["hints"]
    assert "SELL" in explain.render(recs)


def test_kernel_route_ledger_reasons(tmp_path, _ledger_on):
    from repro.core import random_coo
    from repro.core.ops import kernel_route
    from repro.tuning.cache import SelectionCache

    A = convert(random_coo(1, (64, 64), 0.1), Format.CSR)
    empty = SelectionCache(str(tmp_path / "empty.json"))
    route, _ = kernel_route(A, cache=empty)
    assert route == "ref"
    recs = ledger.records(kind="kernel.route")
    assert len(recs) == 1
    assert recs[0]["route"] == "ref"
    assert "no tuned record" in recs[0]["reason"]
    assert recs[0]["bucket"].startswith("kernel:")
    text = explain.render(recs)
    assert "reason:" in text and "bucket:" in text


def test_explain_render_kernel_record_with_sell_geometry(_ledger_on):
    rec = {"seq": 1, "ts": 0.0, "kind": "kernel.route", "op": "spmv",
           "fmt": "SELL", "route": "pallas",
           "kernel": {"fmt": "SELL", "op": "spmv",
                      "cfg": {"c": 32, "sigma": 256},
                      "kernel_us": 120.0, "ref_us": 300.0, "speedup": 2.5}}
    text = explain.render_record(rec)
    assert "c=32" in text and "sigma=256" in text
    assert "2.50x" in text


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


def test_attribution_self_time():
    evs = [
        {"name": "build.dist", "ts": 0.0, "dur": 100.0, "tid": 1, "id": 1,
         "parent": None, "args": {}},
        {"name": "plan.partition", "ts": 10.0, "dur": 30.0, "tid": 1, "id": 2,
         "parent": 1, "args": {}},
        {"name": "convert.execute", "ts": 50.0, "dur": 50.0, "tid": 1, "id": 3,
         "parent": 1, "args": {}},
    ]
    rows = {r["phase"]: r for r in report.attribution(evs)}
    assert rows["build"]["self_ms"] == pytest.approx(0.020)  # 100-30-50 us
    assert rows["plan"]["self_ms"] == pytest.approx(0.030)
    assert rows["convert"]["self_ms"] == pytest.approx(0.050)
    assert sum(r["share"] for r in rows.values()) == pytest.approx(1.0)
    assert "build" in report.render_attribution(list(rows.values()))


def test_overlap_rows_from_bench_doc():
    doc = {"rows": [
        {"name": "obs_overlap_ghost_p4", "us_per_call": 120.0,
         "derived": "local_us=100;exch_us=60;hidden_us=40;hidden_frac=0.667"},
        {"name": "obs_overlap_ghost_p8", "us_per_call": 180.0,
         "derived": "local_us=100;exch_us=80;hidden_us=0;hidden_frac=0.0"},
        {"name": "scaling_spmv_ghost_p8", "us_per_call": 1.0, "derived": ""},
    ]}
    rows = report.overlap_rows(doc)
    assert [r["p"] for r in rows] == [4, 8]
    text = report.render_overlap(rows)
    assert "hidden" in text and "ghost" in text


def test_report_cli_renders(tmp_path, capsys):
    with trace.tracing("full"):
        with trace.span("solver.solve"):
            with trace.span("kernel.spmv"):
                pass
    path = str(tmp_path / "t.json")
    trace.export_chrome(path)
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert "solver" in out and "kernel" in out


# ---------------------------------------------------------------------------
# Provenance + solver history
# ---------------------------------------------------------------------------


def test_env_info_shape():
    info = env_info()
    assert info["jax_version"] == jax.__version__
    assert info["backend"] == jax.default_backend()
    assert info["device_count"] >= 1
    json.dumps(info)


def test_cg_history_fixed_size_and_monotone_tail():
    prob = hpcg.generate_problem(4, 4, 4)
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = hpcg.rhs_for_ones(prob)
    res = jax.block_until_ready(
        cg(lambda v: spmv(A, v), b, tol=1e-8, maxiter=40))
    hist = np.asarray(res.history)
    assert hist.shape == (41,)  # maxiter + 1, regardless of convergence
    k = int(res.iters)
    assert hist[0] > 0
    assert np.isfinite(hist[:k + 1]).all()
    assert np.isnan(hist[k + 1:]).all()  # untouched tail stays NaN
    assert hist[k] == pytest.approx(float(res.resnorm), rel=1e-4)


def test_pcg_and_fixed_iters_history():
    prob = hpcg.generate_problem(4, 4, 4)
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = hpcg.rhs_for_ones(prob)
    diag = jnp.full((prob.shape[0],), 26.0, jnp.float32)
    res = jax.block_until_ready(
        pcg(lambda v: spmv(A, v), b, diag, tol=1e-8, maxiter=30))
    hist = np.asarray(res.history)
    assert hist.shape == (31,)
    assert hist[int(res.iters)] == pytest.approx(float(res.resnorm), rel=1e-4)

    res = jax.block_until_ready(
        cg_fixed_iters(lambda v: spmv(A, v), b, iters=7))
    hist = np.asarray(res.history)
    assert hist.shape == (8,)
    assert np.isfinite(hist).all()
    assert hist[-1] == pytest.approx(float(res.resnorm), rel=1e-4)
