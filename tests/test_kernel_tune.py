"""Kernel-config autotuning: records, cache, routing, policy decisions.

The acceptance-critical assertion lives here: under a *seeded* cache,
``resolve_backend("auto")`` never routes to a kernel config that measured
slower than the reference path — an unmeasured kernel is never presumed
faster, and a measured loser is vetoed.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Format, banded_coo, convert, random_coo, spmv
from repro.core import ops as core_ops
from repro.tuning import (CACHE_PATH_ENV, FormatPolicy, PatternFeatures,
                          SelectionCache)
from repro.tuning import kernel_tune as kt

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# records & keys
# ---------------------------------------------------------------------------


def test_kernel_record_json_roundtrip():
    rec = kt.KernelRecord("CSR", "spmv", {"tm": 256, "tk": 2048},
                          kernel_us=123.4, ref_us=456.7)
    back = kt.KernelRecord.from_json(rec.to_json())
    assert back == rec
    assert back.speedup == pytest.approx(456.7 / 123.4)
    # corrupt / foreign-schema values decode to None, never raise
    assert kt.KernelRecord.from_json("{not json") is None
    assert kt.KernelRecord.from_json(json.dumps({"v": 999})) is None


def test_shape_bucket_quantizes():
    # same power-of-two bucket: one tuned HPCG slab covers its siblings
    assert kt.shape_bucket(1000, 1000, 27000) == kt.shape_bucket(1024, 1024, 27648)
    assert kt.shape_bucket(512, 512, 13824) != kt.shape_bucket(4096, 4096, 110592)
    # density is part of the bucket: same dims, very different row fill
    assert kt.shape_bucket(1024, 1024, 4096) != kt.shape_bucket(1024, 1024, 262144)


def test_backend_tag_tracks_interpret_mode(monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert kt.backend_tag().endswith("-interp")
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert kt.backend_tag().endswith("-native")


# ---------------------------------------------------------------------------
# tuner: persist + round-trip
# ---------------------------------------------------------------------------


def test_tune_kernel_persists_and_roundtrips(tmp_path):
    path = str(tmp_path / "kernels.json")
    A = convert(random_coo(5, (300, 280), density=0.04), Format.CSR)
    rec = kt.tune_kernel(A, cache=SelectionCache(path),
                         grid=kt.default_grid(A, smoke=True),
                         iters=2, inner=1)
    assert rec.fmt == "CSR" and rec.kernel_us > 0 and rec.ref_us > 0
    # a *fresh* cache handle (new process stand-in) sees the same winner
    fresh = kt.best_config(A, cache=SelectionCache(path))
    assert fresh is not None
    assert fresh.cfg == rec.cfg
    assert fresh.kernel_us == pytest.approx(rec.kernel_us)
    # the record rides the kernel: namespace of the shared store
    with open(path) as f:
        raw = json.load(f)
    assert all(k.startswith("kernel:") for k in raw)


def test_tuner_grid_configs_agree_with_ref():
    """Every config the tuner may emit computes the same SpMV as ref."""
    mats = [
        convert(random_coo(7, (97, 83), density=0.08), Format.CSR),
        convert(random_coo(8, (513, 401), density=0.02), Format.ELL),
        convert(banded_coo((300, 300), [-7, 0, 7]), Format.DIA),
        convert(random_coo(9, (200, 160), density=0.06), Format.HYB, k=2),
    ]
    for A in mats:
        x = jnp.asarray(RNG.standard_normal(A.shape[1]).astype(np.float32))
        y_ref = np.asarray(spmv(A, x, backend="ref"), np.float64)
        for cfg in kt.default_grid(A):
            y = np.asarray(spmv(A, x, backend="pallas", cfg=cfg), np.float64)
            np.testing.assert_allclose(
                y, y_ref, rtol=2e-5, atol=2e-5,
                err_msg=f"{type(A).__name__} cfg={cfg}")


# ---------------------------------------------------------------------------
# routing: auto never takes a measured-slower config (seeded cache)
# ---------------------------------------------------------------------------


def _seed(A, kernel_us, ref_us, cfg=None):
    cache = kt.default_kernel_cache()
    rec = kt.KernelRecord(Format(A.format).name, "spmv",
                          cfg or {"tm": 64, "tk": 128}, kernel_us, ref_us)
    cache.put_raw(kt.kernel_key(Format(A.format), A.shape[0], A.shape[1],
                                A.nnz), rec.to_json())
    return rec


def test_auto_routing_seeded_cache(tmp_path, monkeypatch):
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    A = convert(random_coo(11, (300, 300), density=0.05), Format.CSR)
    x = jnp.asarray(RNG.standard_normal(300).astype(np.float32))

    # 1. no record: never presume the kernel is faster
    assert core_ops.kernel_route(A) == ("ref", None)
    assert core_ops.resolve_backend("auto", A) == "ref"

    # 2. measured slower: vetoed
    _seed(A, kernel_us=100.0, ref_us=50.0)
    assert core_ops.kernel_route(A) == ("ref", None)
    assert core_ops.resolve_backend("auto", A) == "ref"

    # 3. measured faster: routed, with the winning config threaded
    rec = _seed(A, kernel_us=50.0, ref_us=100.0, cfg={"tm": 128, "tk": 512})
    backend, cfg = core_ops.kernel_route(A)
    assert backend == "pallas" and cfg == rec.cfg
    assert core_ops.resolve_backend("auto", A) == "pallas"
    np.testing.assert_allclose(np.asarray(spmv(A, x, backend="auto")),
                               np.asarray(spmv(A, x, backend="ref")),
                               rtol=1e-4, atol=1e-4)

    # 4. explicit backends always pass through untouched
    assert core_ops.resolve_backend("ref", A) == "ref"
    assert core_ops.resolve_backend("pallas", A) == "pallas"


def test_auto_routing_interpret_tag_isolation(tmp_path, monkeypatch):
    """A config tuned under interpret mode never routes native kernels."""
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    A = convert(random_coo(12, (256, 256), density=0.05), Format.CSR)
    _seed(A, kernel_us=10.0, ref_us=100.0)
    assert core_ops.kernel_route(A)[0] == "pallas"
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    # same cache, native tag: the interp-keyed record must not match
    assert core_ops.kernel_route(A) == ("ref", None)


# ---------------------------------------------------------------------------
# policy decisions: (format, backend, cfg) tuples, schema v2 + v1 compat
# ---------------------------------------------------------------------------


def test_decision_v2_schema_roundtrip_and_v1_compat(tmp_path):
    cache = SelectionCache(str(tmp_path / "s.json"))
    cache.put_decision("k2", Format.DIA, "pallas", {"tm": 512}, tag="cpu-interp")
    assert cache.get("k2") == Format.DIA           # legacy reader still works
    assert cache.get_decision("k2") == (Format.DIA, "pallas", {"tm": 512},
                                        "cpu-interp")
    cache.put("k1", Format.ELL)                    # legacy writer
    assert cache.get_decision("k1") == (Format.ELL, None, None, None)
    # the v2 value survives a disk round-trip
    fresh = SelectionCache(cache.path)
    assert fresh.get_decision("k2") == (Format.DIA, "pallas", {"tm": 512},
                                        "cpu-interp")
    # format-only v2 decisions are representable too
    cache.put_decision("k3", Format.CSR)
    assert cache.get_decision("k3") == (Format.CSR, None, None, None)


def test_cached_policy_pins_kernel_decision(tmp_path, monkeypatch):
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    A = banded_coo((512, 512), [-1, 0, 1])
    fmt = FormatPolicy("ml").select(A).best
    feats = PatternFeatures.from_coo(A)
    # seed a winning kernel record for the picked format's shape bucket
    rec = kt.KernelRecord(fmt.name, "spmv", {"tm": 256}, 10.0, 100.0)
    kt.default_kernel_cache().put_raw(
        kt.kernel_key(fmt, feats.m, feats.n, feats.nnz), rec.to_json())

    policy = FormatPolicy("cached", cache=SelectionCache(str(tmp_path / "sel.json")))
    cold = policy.select(A)
    assert cold.best == fmt
    assert cold.backend == "pallas" and cold.cfg == {"tm": 256}
    warm = policy.select(A)
    assert warm.mode == "cached"
    assert (warm.best, warm.backend, warm.cfg) == (fmt, "pallas", {"tm": 256})


def test_cached_policy_pin_never_replays_across_modes(tmp_path, monkeypatch):
    """A (backend, cfg) pinned under interpret mode must not replay in a
    native-mode process sharing the cache file: the pin is re-derived from
    the current mode's kernel records instead (here: none -> unpinned)."""
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    A = banded_coo((512, 512), [-1, 0, 1])
    fmt = FormatPolicy("ml").select(A).best
    feats = PatternFeatures.from_coo(A)
    rec = kt.KernelRecord(fmt.name, "spmv", {"tm": 8192}, 10.0, 100.0)
    kt.default_kernel_cache().put_raw(
        kt.kernel_key(fmt, feats.m, feats.n, feats.nnz), rec.to_json())
    cache = SelectionCache(str(tmp_path / "sel.json"))
    cold = FormatPolicy("cached", cache=cache).select(A)
    assert cold.backend == "pallas"  # pinned under the interp tag

    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")  # "native" process
    native = FormatPolicy("cached", cache=SelectionCache(cache.path)).select(A)
    assert native.mode == "cached"
    assert native.best == fmt        # the format pick itself is reused
    assert native.backend is None    # the interp-tuned pin is NOT replayed


def test_profile_select_over_backends(tmp_path, monkeypatch):
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    from repro.tuning import profile_select
    A = banded_coo((256, 256), [-4, 0, 4])
    x = jnp.ones((256,), jnp.float32)
    rep = profile_select(A, x, candidates=(Format.CSR, Format.DIA),
                         iters=2, inner=1, backends=("ref", "pallas"))
    assert rep.best in (Format.CSR, Format.DIA)
    assert rep.backend in ("ref", "pallas")  # the decision is now a tuple
    # historical call shape stays format-only
    rep1 = profile_select(A, x, candidates=(Format.DIA,), iters=2, inner=1)
    assert rep1.backend is None and rep1.cfg is None


# ---------------------------------------------------------------------------
# rhs-width bucket: a record tuned at b=1 is never replayed at b=256
# ---------------------------------------------------------------------------


def test_spmm_keys_carry_width_bucket():
    k1 = kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmm", ncols=1)
    k256 = kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmm", ncols=256)
    assert k1 != k256 and "|b0|" in k1 and "|b8|" in k256
    # ncols=None aliases with the b=1 bucket (read/write consistent)
    assert kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmm") == k1
    # spmv keys never grew a width segment (historical records stay valid)
    s = kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmv", ncols=256)
    assert s == kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmv")
    # widths in one pow2 bucket share a record; different ops never do
    assert kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmm", ncols=200) \
        == kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmm", ncols=256)
    assert kt.kernel_key(Format.CSR, 1024, 1024, 4096, op="spmm_t", ncols=1) \
        != k1


def test_b1_record_not_consulted_at_b256(tmp_path):
    """The regression the width axis exists to prevent: tune at b=1, then
    look up at b=256 — the narrow record must be invisible."""
    cache = SelectionCache(str(tmp_path / "k.json"))
    A = convert(random_coo(0, (256, 256), 0.05), Format.CSR)
    rec = kt.tune_kernel(A, op="spmm", B_cols=1, cache=cache,
                         grid=[{"tm": 128, "tk": 256, "tn": 1}],
                         iters=1, inner=1)
    assert rec.cfg["tn"] == 1
    assert kt.best_config(A, op="spmm", ncols=1, cache=cache) is not None
    assert kt.best_config(A, op="spmm", ncols=256, cache=cache) is None
    assert kt.best_config(A, op="spmm", cache=cache) is not None  # b0 alias
    # the spmm record is invisible to every other op too
    assert kt.best_config(A, op="spmv", cache=cache) is None
    assert kt.best_config(A, op="spmm_t", ncols=1, cache=cache) is None


def test_auto_route_respects_width_bucket(tmp_path, monkeypatch):
    """spmm(backend="auto") consults the record for ITS width bucket: a
    winner at b=1 routes pallas at b=1 but ref at b=256."""
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    A = convert(random_coo(1, (128, 128), 0.1), Format.CSR)
    rec = kt.KernelRecord("CSR", "spmm", {"tm": 128, "tk": 256, "tn": 1},
                          kernel_us=1.0, ref_us=100.0)
    kt.default_kernel_cache().put_raw(
        kt.kernel_key(Format.CSR, 128, 128, int(A.nnz), op="spmm", ncols=1),
        rec.to_json())
    assert core_ops.kernel_route(A, op="spmm", ncols=1) == \
        ("pallas", {"tm": 128, "tk": 256, "tn": 1})
    assert core_ops.kernel_route(A, op="spmm", ncols=256) == ("ref", None)
    # and the full op agrees with ref numerics on both routes
    B1 = jnp.ones((128, 1), jnp.float32)
    B256 = jnp.ones((128, 256), jnp.float32)
    for B in (B1, B256):
        np.testing.assert_allclose(
            np.asarray(core_ops.spmm(A, B, backend="auto")),
            np.asarray(core_ops.spmm(A, B, backend="ref")),
            rtol=1e-4, atol=1e-4)


def test_cached_policy_width_buckets_store_distinct_decisions(tmp_path,
                                                              monkeypatch):
    """FormatPolicy("cached") keys spmm_t decisions by width bucket: a
    pallas pin recorded at b=1 must not leak into the b=256 decision."""
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    A = random_coo(2, (256, 256), 0.05)
    fmt = FormatPolicy("ml").select(A).best
    feats = PatternFeatures.from_coo(A)
    rec = kt.KernelRecord(fmt.name, "spmm_t", {"tm": 128, "tn": 1},
                          kernel_us=1.0, ref_us=100.0)
    kt.default_kernel_cache().put_raw(
        kt.kernel_key(fmt, feats.m, feats.n, feats.nnz, op="spmm_t",
                      ncols=1), rec.to_json())
    cache = SelectionCache(str(tmp_path / "sel.json"))
    narrow = FormatPolicy("cached", cache=cache).select(A, op="spmm_t",
                                                        ncols=1)
    wide = FormatPolicy("cached", cache=cache).select(A, op="spmm_t",
                                                      ncols=256)
    assert narrow.backend == "pallas" and narrow.cfg == {"tm": 128, "tn": 1}
    assert wide.backend is None  # no b=256 measurement -> no pin
    # both are warm on re-read, from distinct cache entries
    warm_n = FormatPolicy("cached", cache=cache).select(A, op="spmm_t",
                                                        ncols=1)
    warm_w = FormatPolicy("cached", cache=cache).select(A, op="spmm_t",
                                                        ncols=256)
    assert warm_n.mode == "cached" and warm_n.backend == "pallas"
    assert warm_w.mode == "cached" and warm_w.backend is None
