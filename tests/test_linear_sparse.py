"""LinearSparse: the paper's technique on pruned-model weights (minitron)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Format
from repro.models.linear_sparse import LinearSparse, prune_magnitude

RNG = np.random.default_rng(0)


def test_prune_density():
    w = RNG.standard_normal((64, 96)).astype(np.float32)
    wp = prune_magnitude(w, 0.25)
    density = (wp != 0).mean()
    assert 0.2 < density <= 0.3
    # survivors unchanged
    keep = wp != 0
    np.testing.assert_array_equal(wp[keep], w[keep])


@pytest.mark.parametrize("fmt", [Format.CSR, Format.ELL, Format.HYB, Format.COO])
def test_linear_sparse_matches_dense(fmt):
    w = prune_magnitude(RNG.standard_normal((48, 80)).astype(np.float32), 0.3)
    b = jnp.asarray(RNG.standard_normal(80).astype(np.float32))
    layer = LinearSparse.from_dense(w, fmt=fmt, bias=b)
    x = jnp.asarray(RNG.standard_normal((4, 7, 48)).astype(np.float32))
    y = layer(x)
    assert y.shape == (4, 7, 80)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w + np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_linear_sparse_autotune_and_switch():
    w = prune_magnitude(RNG.standard_normal((64, 64)).astype(np.float32), 0.2)
    layer = LinearSparse.from_dense(w)  # analytic autotune
    x = jnp.ones((3, 64), jnp.float32)
    y1 = layer(x)
    switched = layer.activate(Format.COO)
    assert switched.format == Format.COO
    np.testing.assert_allclose(np.asarray(y1), np.asarray(switched(x)),
                               rtol=1e-4, atol=1e-4)


def test_linear_sparse_under_jit():
    w = prune_magnitude(RNG.standard_normal((32, 32)).astype(np.float32), 0.4)
    layer = LinearSparse.from_dense(w, fmt=Format.ELL)
    x = jnp.ones((5, 32), jnp.float32)
    y = jax.jit(lambda l, v: l(v))(layer, x)  # LinearSparse is a pytree
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w,
                               rtol=1e-4, atol=1e-4)


def test_bandwidth_savings_model():
    """The point of sparse serving: stored bytes shrink with density."""
    from repro.core import bytes_of
    w = RNG.standard_normal((256, 256)).astype(np.float32)
    dense_bytes = w.size * 4
    for density in (0.5, 0.25, 0.1):
        layer = LinearSparse.from_dense(prune_magnitude(w, density), fmt=Format.CSR)
        assert bytes_of(layer.weight.concrete) < dense_bytes * (density * 2 + 0.1)


@pytest.mark.parametrize("fmt", [Format.CSR, Format.ELL, Format.HYB, Format.COO])
def test_call_matches_old_double_transpose_path(fmt):
    """The transposed-rhs fast path replaced ``spmm(W, x.T).T``; the two
    formulations must stay interchangeable for every weight format."""
    from repro.core import spmm
    w = prune_magnitude(RNG.standard_normal((40, 56)).astype(np.float32), 0.3)
    layer = LinearSparse.from_dense(w, fmt=fmt)
    x = jnp.asarray(RNG.standard_normal((6, 40)).astype(np.float32))
    y_old = spmm(layer.weight, x.T, backend="ref").T
    np.testing.assert_allclose(np.asarray(layer(x)), np.asarray(y_old),
                               rtol=1e-4, atol=1e-4)


def test_from_dense_width_aware_profile(tmp_path, monkeypatch):
    """ncols reaches the profiling tuner: a width-stated build succeeds and
    the layer computes correctly at that width."""
    from repro.tuning import CACHE_PATH_ENV
    monkeypatch.setenv(CACHE_PATH_ENV, str(tmp_path / "sel.json"))
    w = prune_magnitude(RNG.standard_normal((32, 48)).astype(np.float32), 0.2)
    layer = LinearSparse.from_dense(w, tune="profile", ncols=16)
    x = jnp.asarray(RNG.standard_normal((16, 32)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(layer(x)), np.asarray(x) @ w,
                               rtol=1e-4, atol=1e-4)
