"""Plan/execute format switching: jit-ability, zero host syncs, validation.

The acceptance bar for the device-resident switch pipeline: given a
precomputed ``SwitchPlan``, ``convert_execute`` must trace under ``jax.jit``
(plan as a static argument) and run with device->host transfers disallowed,
for every COO -> {CSR, ELL, DIA, BSR, HYB} conversion.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DynamicMatrix, Format, SwitchDynamicMatrix,
                        SwitchPlan, convert, convert_execute, dense_from_array,
                        plan_switch, random_coo, to_dense_np)
from repro.core.convert import coo_to_ell

PLANNED = [Format.CSR, Format.ELL, Format.DIA, Format.BSR, Format.HYB,
           Format.SELL]


def _mat(seed=0, shape=(300, 200), density=0.05, capacity=None):
    return random_coo(seed, shape, density=density, capacity=capacity)


def _bsr_kw(fmt, shape):
    return {"block_size": 100} if fmt == Format.BSR else {}


@pytest.mark.parametrize("fmt", PLANNED)
def test_execute_jits_with_no_host_transfer(fmt):
    A = _mat(0, capacity=4000)
    plan = plan_switch(A, fmt, **_bsr_kw(fmt, A.shape))
    ex = jax.jit(convert_execute, static_argnums=1)
    with jax.transfer_guard_device_to_host("disallow"):
        out = ex(A, plan)
        jax.block_until_ready(jax.tree_util.tree_leaves(out))
    np.testing.assert_allclose(to_dense_np(out), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("fmt", PLANNED)
def test_plan_is_static_and_reusable(fmt):
    """Same plan on two same-pattern matrices -> one trace, both correct."""
    A = _mat(1)
    B = type(A)(A.row, A.col, A.data * 3.0, A.shape, A.nnz)
    plan = plan_switch(A, fmt, **_bsr_kw(fmt, A.shape))
    assert isinstance(hash(plan), int)
    assert plan == plan_switch(A, fmt, **_bsr_kw(fmt, A.shape))
    ex = jax.jit(convert_execute, static_argnums=1)
    np.testing.assert_allclose(to_dense_np(ex(A, plan)), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(to_dense_np(ex(B, plan)), 3 * to_dense_np(A),
                               rtol=1e-5, atol=1e-5)


def test_dense_source_jits_with_planned_capacity():
    rng = np.random.default_rng(2)
    a = np.where(rng.random((64, 48)) < 0.1, 1.0, 0.0).astype(np.float32)
    D = dense_from_array(a)
    plan = plan_switch(D, Format.CSR)
    assert plan.capacity == int((a != 0).sum())
    out = jax.jit(convert_execute, static_argnums=1)(D, plan)
    np.testing.assert_allclose(to_dense_np(out), a)


def test_convert_accepts_plan_and_checks_target():
    A = _mat(3)
    plan = plan_switch(A, Format.DIA)
    np.testing.assert_allclose(to_dense_np(convert(A, Format.DIA, plan=plan)),
                               to_dense_np(A), rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError):
        convert(A, Format.ELL, plan=plan)


def test_explicit_hints_short_circuit_analysis():
    A = _mat(4)
    p = plan_switch(A, Format.DIA, offsets=[-1, 0, 1])
    assert p.dia_offsets == (-1, 0, 1)
    # unsorted hints are sorted for the searchsorted-routed numeric phase
    assert plan_switch(A, Format.DIA, offsets=[1, -1, 0]).dia_offsets == (-1, 0, 1)
    p = plan_switch(A, Format.ELL, k=64)
    assert p.ell_k == 64
    p = plan_switch(A, Format.HYB, k=2)
    assert p.ell_k == 2 and p.hyb_coo_capacity >= 1


def test_hyb_plan_capacity_is_exact():
    A = _mat(5, shape=(100, 80), density=0.1)
    counts = np.bincount(np.asarray(A.row)[np.asarray(A.data) != 0],
                         minlength=100)
    k = 3
    plan = plan_switch(A, Format.HYB, k=k)
    assert plan.hyb_coo_capacity == max(1, int(np.maximum(counts - k, 0).sum()))
    H = convert(A, Format.HYB, plan=plan)
    np.testing.assert_allclose(to_dense_np(H), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)


def test_ell_explicit_k_overflow_raises():
    """Satellite fix: overflow used to be silently clipped/dropped."""
    A = _mat(6, shape=(32, 32), density=0.3)
    with pytest.raises(ValueError, match="overflow"):
        coo_to_ell(A, k=2)
    with pytest.raises(ValueError, match="overflow"):
        convert(A, Format.ELL, k=2)
    # HYB is the sanctioned home for overflow — same k must NOT raise
    np.testing.assert_allclose(to_dense_np(convert(A, Format.HYB, k=2)),
                               to_dense_np(A), rtol=1e-6, atol=1e-6)


def test_ell_wide_explicit_k_still_works():
    A = _mat(7, shape=(48, 64), density=0.05)
    E = coo_to_ell(A, k=64)
    assert E.k == 64
    np.testing.assert_allclose(to_dense_np(E), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)


def test_dynamic_matrix_plan_then_activate():
    A = _mat(8)
    dm = DynamicMatrix(A)
    plan = dm.plan(Format.ELL)
    with jax.transfer_guard_device_to_host("disallow"):
        switched = jax.jit(
            lambda m: m.activate(Format.ELL, plan=plan), static_argnums=())(dm)
        jax.block_until_ready(jax.tree_util.tree_leaves(switched))
    assert switched.active == Format.ELL
    np.testing.assert_allclose(to_dense_np(switched.concrete), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)


def test_switch_dynamic_build_with_plans():
    A = _mat(9)
    fmts = (Format.CSR, Format.ELL, Format.DIA)
    plans = {f: plan_switch(A, f) for f in fmts}
    sw = SwitchDynamicMatrix.build(A, candidates=fmts, active=Format.ELL,
                                   plans=plans)
    x = jnp.ones((A.shape[1],), jnp.float32)
    np.testing.assert_allclose(np.asarray(sw.spmv(x)),
                               to_dense_np(A) @ np.ones(A.shape[1]),
                               rtol=1e-4, atol=1e-4)


def test_policy_supplies_plan():
    from repro.tuning import FormatPolicy
    A = _mat(10)
    plan = FormatPolicy("analytic").plan_for(A)
    assert isinstance(plan, SwitchPlan)
    out = convert_execute(A, plan)
    assert Format(out.format) == Format(plan.target)
    np.testing.assert_allclose(to_dense_np(out), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)
    # pinned format + hint
    plan = FormatPolicy("analytic").plan_for(A, fmt=Format.ELL, k=80)
    assert plan.target == Format.ELL and plan.ell_k == 80


def test_interleaved_dead_entries_do_not_drop_data():
    """Slot ranks must count live entries only: explicit zeros interleaved
    with data (e.g. the COO view of a partially-filled diagonal) used to
    inflate within-row ranks and silently drop the trailing live entries."""
    from repro.core import coo_from_arrays
    A = coo_from_arrays([0, 0, 0, 0, 0], [0, 1, 2, 3, 4],
                        [0.0, 0.0, 5.0, 6.0, 7.0], (2, 5))
    D = to_dense_np(A)
    assert D[0, 4] == 7.0
    for fmt in (Format.ELL, Format.HYB):
        np.testing.assert_allclose(to_dense_np(convert(A, fmt)), D,
                                   err_msg=fmt.name)
    # the DIA -> {ELL, HYB} switch is the real-world path that hits this
    Ad = convert(_mat(12, shape=(64, 64), density=0.08), Format.DIA)
    for fmt in (Format.ELL, Format.HYB):
        np.testing.assert_allclose(to_dense_np(convert(Ad, fmt)),
                                   to_dense_np(Ad), rtol=1e-6, atol=1e-6,
                                   err_msg=fmt.name)


def test_unsorted_offsets_hint_converts_correctly():
    from repro.core import banded_coo
    A = banded_coo((8, 8), [-1, 0, 1])
    out = convert(A, Format.DIA, offsets=[1, -1, 0])
    np.testing.assert_allclose(to_dense_np(out), to_dense_np(A))


def test_build_rejects_mismatched_plan():
    A = _mat(13)
    with pytest.raises(ValueError, match="targets"):
        SwitchDynamicMatrix.build(
            A, candidates=(Format.CSR, Format.ELL),
            plans={Format.CSR: plan_switch(A, Format.ELL)})


def test_convert_accepts_legacy_bsr_triple():
    A = _mat(14, shape=(300, 200))
    sp = plan_switch(A, Format.BSR, block_size=100)
    triple = (np.asarray(sp.bsr_indptr), np.asarray(sp.bsr_indices), None)
    out = convert(A, Format.BSR, plan=triple, block_size=100)
    np.testing.assert_allclose(to_dense_np(out), to_dense_np(A),
                               rtol=1e-6, atol=1e-6)


def test_plan_matches_legacy_defaults():
    """The planned symbolic quantities equal the old host-numpy analysis."""
    A = _mat(11, shape=(120, 90), density=0.07)
    r = np.asarray(A.row)
    c = np.asarray(A.col)
    live = np.asarray(A.data) != 0
    assert plan_switch(A, Format.ELL).ell_k == int(
        np.bincount(r[live], minlength=120).max())
    assert plan_switch(A, Format.DIA).dia_offsets == tuple(
        np.unique((c.astype(np.int64) - r)[live]).tolist())
