"""Flash attention (custom_vjp) vs naive softmax attention: fwd + grads."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.flash import flash_attention

RNG = np.random.default_rng(0)


def naive(q, k, v, causal=True):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def _mk(b=2, s=128, h=8, kvh=4, d=32, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, kvh, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, kvh, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qc,kc", [(32, 64), (128, 128), (64, 32), (16, 16)])
def test_flash_forward(causal, qc, kc):
    q, k, v = _mk()
    o = flash_attention(q, k, v, causal, qc, kc, False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(naive(q, k, v, causal)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qc,kc", [(32, 64), (64, 32)])
def test_flash_grads(causal, qc, kc):
    q, k, v = _mk()

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, causal, qc, kc, False) ** 2).sum()

    def loss_naive(q, k, v):
        return (naive(q, k, v, causal) ** 2).sum()

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_flash_unroll_matches_scan():
    q, k, v = _mk()
    o1 = flash_attention(q, k, v, True, 32, 32, False)
    o2 = flash_attention(q, k, v, True, 32, 32, True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)
    g1 = jax.grad(lambda q: (flash_attention(q, k, v, True, 32, 32, False) ** 2).sum())(q)
    g2 = jax.grad(lambda q: (flash_attention(q, k, v, True, 32, 32, True) ** 2).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)


def test_flash_bf16():
    q, k, v = _mk(dtype=jnp.bfloat16)
    o = flash_attention(q, k, v, True, 32, 64, False)
    ref = naive(q, k, v, True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), rtol=5e-2, atol=5e-2)


def test_flash_mha_no_gqa():
    q, k, v = _mk(h=4, kvh=4)
    o = flash_attention(q, k, v, True, 32, 32, False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(naive(q, k, v, True)),
                               rtol=1e-4, atol=1e-4)
