"""Distributed sparse runtime tests.

Multi-device tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single-device view (dry-run isolation, see dryrun.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Format, hpcg, random_coo, to_dense_np
from repro.core.convert import (convert_execute_batch, planned_pulls_scope,
                                plan_switch_batch)
from repro.core.distributed import (DistPlan, build_dist_matrix, dist_spmv,
                                    distribute_vector, partition_coo,
                                    partition_execute_jit, plan_partition)
from repro.core.formats import COO
from repro.core.solvers import cg, cg_fixed_iters

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str, env=None):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core import hpcg, Format
        from repro.core.distributed import (activate_dist, build_dist_matrix,
                                            dist_spmv, distribute_vector)
        from repro.core.solvers import cg, operator
    """ % os.path.abspath(SRC)) + textwrap.dedent(body)
    full_env = dict(os.environ, **(env or {}))
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=full_env)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def _dense(shape, row, col, val):
    D = np.zeros(shape)
    np.add.at(D, (np.asarray(row), np.asarray(col)), np.asarray(val))
    return D


# ---------------------------------------------------------------------------
# Partitioner (host logic — no devices needed)
# ---------------------------------------------------------------------------

def test_partition_local_remote_split():
    prob = hpcg.generate_problem(4, 4, 8)
    pc = partition_coo(prob.row, prob.col, prob.val, prob.shape, 4)
    assert pc.halo_mode == "neighbor"
    assert pc.hw == 16  # one plane = nx*ny
    # all entries accounted for
    total = sum(len(t[0]) for t in pc.local) + sum(len(t[0]) for t in pc.remote)
    assert total == len(prob.row)
    # local columns are in-range
    for (r, c, v) in pc.local:
        assert (c >= 0).all() and (c < pc.mp).all()
    for (r, c, v) in pc.remote:
        assert (c >= 0).all() and (c < 2 * pc.hw).all()


def test_partition_requires_divisible():
    with pytest.raises(ValueError):
        partition_coo([0], [0], [1.0], (10, 10), 3)


def test_partition_irregular_falls_back_to_gather():
    A = random_coo(0, (64, 64), density=0.2)
    pc = partition_coo(np.asarray(A.row), np.asarray(A.col), np.asarray(A.data),
                       (64, 64), 8)
    assert pc.halo_mode == "gather"


def test_partition_block_diagonal_marks_remote_empty():
    """Satellite fix: reach == 0 must not force hw=1 and a pointless
    exchange — the remote part is statically empty."""
    row = col = np.arange(64)
    val = np.ones(64, np.float32)
    plan = plan_partition(row, col, val, (64, 64), 8)
    assert plan.remote_empty and plan.hw == 0
    assert plan.halo_mode == "neighbor"  # collapsed auto branch
    pc = partition_coo(row, col, val, (64, 64), 8)
    assert pc.remote_empty and pc.hw == 0
    assert all(len(t[0]) == 0 for t in pc.remote)


# ---------------------------------------------------------------------------
# Batched device partitioner (plan_partition + partition_execute)
# ---------------------------------------------------------------------------


def _stacked_parts(prob, nshards):
    plan = plan_partition(prob.row, prob.col, prob.val, prob.shape, nshards)
    local, remote = partition_execute_jit(prob.row, prob.col, prob.val,
                                          plan=plan)
    return local, remote, plan


def test_partition_execute_matches_host_partitioner():
    prob = hpcg.generate_problem(4, 4, 8)
    local, remote, plan = _stacked_parts(prob, 4)
    pc = partition_coo(prob.row, prob.col, prob.val, prob.shape, 4)
    assert (plan.mp, plan.hw, plan.halo_mode) == (pc.mp, pc.hw, pc.halo_mode)
    for p in range(4):
        for part, stacked in ((pc.local, local), (pc.remote, remote)):
            want = _dense(stacked.shape, *part[p])
            got = _dense(stacked.shape, stacked.row[p], stacked.col[p],
                         stacked.data[p])
            np.testing.assert_allclose(got, want, atol=1e-6)


def test_partition_execute_gather_mode_random():
    A = random_coo(3, (64, 64), density=0.15)
    r, c, v = np.asarray(A.row), np.asarray(A.col), np.asarray(A.data)
    plan = plan_partition(r, c, v, (64, 64), 8)
    assert plan.halo_mode == "gather"
    local, remote = partition_execute_jit(r, c, v, plan=plan)
    D = _dense((64, 64), r, c, v)
    # reassemble: local blocks on the diagonal, remote with global columns
    got = np.zeros((64, 64))
    for p in range(8):
        got[p * 8:(p + 1) * 8, p * 8:(p + 1) * 8] += _dense(
            (8, 8), local.row[p], local.col[p], local.data[p])
        got[p * 8:(p + 1) * 8, :] += _dense(
            (8, 64), remote.row[p], remote.col[p], remote.data[p])
    np.testing.assert_allclose(got, D, atol=1e-6)


def test_batched_build_constant_planned_pulls():
    """Acceptance: the batched build pipeline performs no per-shard host
    transfers — the planned-pull count is independent of shard count, and
    nothing else crosses device->host (transfer guard disallows it)."""
    from repro.tuning.cache import SelectionCache
    from repro.tuning.policy import FormatPolicy

    prob = hpcg.generate_problem(4, 4, 8)
    candidates = (Format.COO, Format.CSR, Format.DIA, Format.ELL)
    pulls = {}
    for nshards in (2, 8):
        import tempfile
        cache = SelectionCache(os.path.join(tempfile.mkdtemp(), "sel.json"))
        policy = FormatPolicy("cached", candidates=candidates, cache=cache)
        plan = plan_partition(prob.row, prob.col, prob.val, prob.shape, nshards)
        # planned_pulls_scope: order-independent count of the pulls this
        # block performs, regardless of what ran earlier in the suite
        with planned_pulls_scope() as scope, \
                jax.transfer_guard_device_to_host("disallow"):
            local, remote = partition_execute_jit(prob.row, prob.col,
                                                  prob.val, plan=plan)
            for part in (local, remote):
                ids = policy.select_batch(part)
                assert ids.shape == (nshards,)
                for fmt in candidates:
                    sp = plan_switch_batch(part, fmt)
                    out = convert_execute_batch(part, sp)
                    jax.block_until_ready(jax.tree_util.tree_leaves(out))
        pulls[nshards] = scope.count
    assert pulls[2] == pulls[8], pulls


# ---------------------------------------------------------------------------
# Batched symbolic phase (shared plans across shards)
# ---------------------------------------------------------------------------


def _stack_coos(mats):
    cap = max(m.capacity for m in mats)
    def pad(a):
        return np.pad(np.asarray(a), (0, cap - a.shape[0]))
    return COO(jnp.asarray(np.stack([pad(m.row) for m in mats])),
               jnp.asarray(np.stack([pad(m.col) for m in mats])),
               jnp.asarray(np.stack([pad(m.data) for m in mats])),
               mats[0].shape, cap)


def test_batch_dia_plan_unions_and_dedupes_offsets():
    """Satellite regression: heterogeneous per-shard diagonal sets used to
    be padded with a duplicated live offset; the shared batch plan is the
    deduped union, and every shard converts exactly."""
    from repro.core.formats import banded_coo

    a = banded_coo((32, 32), [0])              # 1 diagonal
    b = banded_coo((32, 32), [-3, 0, 5])       # 3 diagonals
    stacked = _stack_coos([a, b])
    plan = plan_switch_batch(stacked, Format.DIA)
    assert plan.dia_offsets == (-3, 0, 5)
    assert len(set(plan.dia_offsets)) == len(plan.dia_offsets)
    out = convert_execute_batch(stacked, plan)
    for i, src in enumerate((a, b)):
        part = jax.tree.map(lambda x, i=i: x[i], out)
        np.testing.assert_allclose(to_dense_np(part), to_dense_np(src),
                                   atol=1e-6)
    # explicit duplicate offsets hints are deduped too (single + batch)
    from repro.core import plan_switch
    assert plan_switch(a, Format.DIA, offsets=[0, 0, 5]).dia_offsets == (0, 5)
    assert plan_switch_batch(stacked, Format.DIA,
                             offsets=[5, 0, 0, -3]).dia_offsets == (-3, 0, 5)


def test_stale_plan_raises_instead_of_dropping():
    """Review fix: a reused DistPlan whose capacities or halo width no
    longer fit the triplets must fail loudly, not silently drop entries in
    the guard-slot scatter."""
    prob = hpcg.generate_problem(4, 4, 8)
    mesh = jax.make_mesh((1,), ("rows",))
    plan = plan_partition(prob.row, prob.col, prob.val, prob.shape, 1)
    # denser matrix than the plan was made for -> capacity overflow
    import dataclasses
    small = dataclasses.replace(plan, local_cap=7)
    with pytest.raises(ValueError, match="stale DistPlan"):
        build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                          "rows", plan=small)
    # wrong P still raises the original mismatch error
    with pytest.raises(ValueError, match="plan is for"):
        build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                          "rows", plan=dataclasses.replace(plan, nshards=2))


def test_hpcg_partition_problem_matches_general_path():
    """slab-aware fast path == general plan_partition + partition_execute."""
    prob = hpcg.generate_problem(4, 4, 8)
    l_gen, r_gen, plan_gen = _stacked_parts(prob, 4)
    l_slab, r_slab, plan_slab = hpcg.partition_problem(prob, 4)
    assert (plan_slab.mp, plan_slab.hw, plan_slab.halo_mode) == \
           (plan_gen.mp, plan_gen.hw, plan_gen.halo_mode)
    assert (plan_slab.local_cap, plan_slab.remote_cap) == \
           (plan_gen.local_cap, plan_gen.remote_cap)
    for a, b in ((l_gen, l_slab), (r_gen, r_slab)):
        for p in range(4):
            np.testing.assert_allclose(
                _dense(a.shape, a.row[p], a.col[p], a.data[p]),
                _dense(b.shape, b.row[p], b.col[p], b.data[p]), atol=1e-6)
    with pytest.raises(ValueError, match="nz % P"):
        hpcg.slab_plan(prob, 3)


def test_reused_plan_replans_on_live_pattern_change():
    """Review fix: memoised format plans are fingerprinted against the live
    pattern — a numeric update that turns zeros live must re-plan, not
    silently convert with stale DIA offsets / ELL widths."""
    mesh = jax.make_mesh((1,), ("rows",))
    row = np.arange(16).repeat(2)
    col = np.concatenate([np.stack([np.arange(16),
                                    (np.arange(16) + 1) % 16]).T.ravel()])
    val = np.where(np.arange(32) % 2 == 0, 1.0, 0.0).astype(np.float32)
    A = build_dist_matrix(row, col, val, (16, 16), mesh, "rows",
                          mode="multiformat", tune="analytic")
    assert A.plan.pattern_sig is not None
    # same pattern, same values -> memoised plans reused, result correct
    A2 = build_dist_matrix(row, col, val, (16, 16), mesh, "rows",
                          mode="multiformat", tune="analytic", plan=A.plan)
    # off-diagonal entries become live: plan fingerprint mismatch -> re-plan
    val2 = np.ones(32, np.float32)
    A3 = build_dist_matrix(row, col, val2, (16, 16), mesh, "rows",
                           mode="multiformat", tune="analytic", plan=A.plan)
    x = distribute_vector(np.ones(16, np.float32), mesh, "rows")
    D = _dense((16, 16), row, col, val2)
    for part in ("local", "remote"):
        ids = np.asarray(getattr(A3, part).active_id)
        assert ids.shape == (1,)
    y = np.asarray(dist_spmv(A3, x, mesh))
    np.testing.assert_allclose(y, D @ np.ones(16), atol=1e-5)
    # and every resident variant is correct, not just the active one
    for fmt in (Format.COO, Format.CSR, Format.DIA, Format.ELL):
        from repro.core.distributed import activate_dist
        Af = activate_dist(activate_dist(A3, "local", fmt), "remote", fmt)
        yf = np.asarray(dist_spmv(Af, x, mesh))
        np.testing.assert_allclose(yf, D @ np.ones(16), atol=1e-5, err_msg=fmt.name)


def test_plan_switch_batch_ell_overflow_raises():
    """Review fix: an explicit undersized k must raise (parity with
    plan_switch), not silently drop row overflow."""
    A = random_coo(6, (32, 32), density=0.3)
    stacked = _stack_coos([A, A])
    with pytest.raises(ValueError, match="overflow"):
        plan_switch_batch(stacked, Format.ELL, k=2)
    assert plan_switch_batch(stacked, Format.ELL, k=2, check=False).ell_k == 2


def test_batch_plans_match_per_shard_unions():
    prob = hpcg.generate_problem(4, 4, 8)
    local, _, _ = _stacked_parts(prob, 4)
    kplan = plan_switch_batch(local, Format.ELL)
    per_shard_k = []
    for p in range(4):
        rows = np.asarray(local.row[p])[np.asarray(local.data[p]) != 0]
        per_shard_k.append(np.bincount(rows, minlength=local.shape[0]).max())
    assert kplan.ell_k == max(per_shard_k)
    hplan = plan_switch_batch(local, Format.HYB)
    assert hplan.ell_k >= 1 and hplan.hyb_coo_capacity >= 1
    out = convert_execute_batch(local, hplan)
    for p in range(4):
        part = jax.tree.map(lambda x, p=p: x[p], out)
        want = _dense(local.shape, local.row[p], local.col[p], local.data[p])
        np.testing.assert_allclose(to_dense_np(part), want, atol=1e-5)


def test_select_batch_matches_per_shard_select():
    from repro.tuning.policy import FormatPolicy

    prob = hpcg.generate_problem(4, 4, 8)
    local, remote, _ = _stacked_parts(prob, 4)
    for mode in ("ml", "analytic"):
        policy = FormatPolicy(mode)
        for part in (local, remote):
            ids = policy.select_batch(part)
            single = [policy.select(jax.tree.map(lambda a, p=p: a[p], part)).best
                      for p in range(4)]
            assert [policy.candidates[i] for i in ids] == single, mode


def test_select_batch_cached_warm_hits(tmp_path):
    from repro.tuning.cache import SelectionCache
    from repro.tuning.policy import FormatPolicy

    prob = hpcg.generate_problem(4, 4, 8)
    local, _, _ = _stacked_parts(prob, 4)
    cache = SelectionCache(str(tmp_path / "sel.json"))
    policy = FormatPolicy("cached", cache=cache)
    ids = policy.select_batch(local)
    assert len(cache) >= 1
    ids2 = FormatPolicy("cached", cache=SelectionCache(str(tmp_path / "sel.json"))
                        ).select_batch(local)
    np.testing.assert_array_equal(ids, ids2)


def test_batch_features_match_host_featuriser():
    from repro.tuning.features import PatternFeatures, batch_features

    prob = hpcg.generate_problem(4, 4, 8)
    local, remote, _ = _stacked_parts(prob, 4)
    for part in (local, remote):
        feats = batch_features(part)
        for p, f in enumerate(feats):
            ref = PatternFeatures.from_coo(
                COO(part.row[p], part.col[p], part.data[p], part.shape,
                    int(part.row.shape[1])))
            np.testing.assert_allclose(f.vector(), ref.vector(),
                                       rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Single-device mesh (in-process)
# ---------------------------------------------------------------------------

def test_dist_spmv_single_shard():
    mesh = jax.make_mesh((1,), ("rows",))
    prob = hpcg.generate_problem(4, 4, 4)
    A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh, "rows",
                          local_format=Format.DIA, remote_format=Format.COO)
    x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
    y = dist_spmv(A, x, mesh)
    D = np.zeros(prob.shape)
    np.add.at(D, (prob.row, prob.col), prob.val)
    np.testing.assert_allclose(np.asarray(y), D @ np.ones(prob.shape[0]),
                               rtol=1e-5, atol=1e-5)


def test_cg_single_device():
    prob = hpcg.generate_problem(6, 6, 6)
    from repro.core import convert, to_coo
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    from repro.core import spmv
    res = cg(lambda v: spmv(A, v), b, tol=1e-7, maxiter=300)
    np.testing.assert_allclose(np.asarray(res.x), 1.0, rtol=1e-3, atol=1e-3)


def test_cg_fixed_iters_runs():
    prob = hpcg.generate_problem(4, 4, 4)
    from repro.core import convert, spmv
    A = convert(hpcg.to_coo(prob), Format.ELL)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    res = cg_fixed_iters(lambda v: spmv(A, v), b, iters=30)
    assert np.isfinite(float(res.resnorm))


# ---------------------------------------------------------------------------
# 8-shard SPMD (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,lf,rf", [
    ("uniform", "CSR", "CSR"),
    ("uniform", "DIA", "COO"),
    ("multiformat", "CSR", "CSR"),
])
def test_dist_spmv_8shards(mode, lf, rf):
    out = _run_subprocess(f"""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(8, 8, 16)
        D = np.zeros(prob.shape); np.add.at(D, (prob.row, prob.col), prob.val)
        x_np = np.random.default_rng(0).standard_normal(prob.shape[0]).astype(np.float32)
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", local_format=Format.{lf},
                              remote_format=Format.{rf}, mode="{mode}")
        x = distribute_vector(x_np, mesh, "rows")
        y = jax.jit(lambda a, v: dist_spmv(a, v, mesh))(A, x)
        err = abs(np.asarray(y) - D @ x_np).max() / abs(D @ x_np).max()
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_dist_cg_8shards_converges_to_ones():
    out = _run_subprocess("""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(8, 8, 16)
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", local_format=Format.DIA,
                              remote_format=Format.COO)
        b = distribute_vector(hpcg.rhs_for_ones(prob), mesh, "rows")
        res = jax.jit(lambda a, bb: cg(lambda v: dist_spmv(a, v, mesh), bb,
                                       tol=1e-7, maxiter=300))(A, b)
        err = abs(np.asarray(res.x) - 1.0).max()
        assert err < 1e-3, err
        print("OK", int(res.iters), err)
    """)
    assert "OK" in out


def test_dist_matches_single_device_result():
    """Invariant: distribution must not change the math."""
    out = _run_subprocess("""
        from repro.core import convert, spmv
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(6, 6, 8)
        x_np = np.random.default_rng(1).standard_normal(prob.shape[0]).astype(np.float32)
        A1 = convert(hpcg.to_coo(prob), Format.CSR)
        y1 = np.asarray(spmv(A1, jnp.asarray(x_np)))
        A8 = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                               "rows", mode="multiformat")
        y8 = np.asarray(dist_spmv(A8, distribute_vector(x_np, mesh, "rows"), mesh))
        err = abs(y1 - y8).max() / abs(y1).max()
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


@pytest.mark.parametrize("tune", ["cached", "ml"])
def test_dist_multiformat_policy_8shards(tune, tmp_path):
    """Multiformat build with the batched cached/ml policies: correct SpMV
    vs the dense oracle, and the whole build runs with device->host
    transfers disallowed (zero unplanned pulls, full stack)."""
    out = _run_subprocess(f"""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(8, 8, 16)
        D = np.zeros(prob.shape); np.add.at(D, (prob.row, prob.col), prob.val)
        x_np = np.random.default_rng(2).standard_normal(prob.shape[0]).astype(np.float32)
        with jax.transfer_guard_device_to_host("disallow"):
            A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape,
                                  mesh, "rows", mode="multiformat",
                                  tune="{tune}")
        y = np.asarray(dist_spmv(A, distribute_vector(x_np, mesh, "rows"), mesh))
        err = abs(y - D @ x_np).max() / abs(D @ x_np).max()
        assert err < 1e-5, err
        print("OK", err)
    """, env={"REPRO_TUNING_CACHE": str(tmp_path / "selections.json")})
    assert "OK" in out


def test_dist_activate_roundtrip_8shards():
    out = _run_subprocess("""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(8, 8, 16)
        D = np.zeros(prob.shape); np.add.at(D, (prob.row, prob.col), prob.val)
        x_np = np.random.default_rng(3).standard_normal(prob.shape[0]).astype(np.float32)
        x = distribute_vector(x_np, mesh, "rows")
        ref = D @ x_np
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", mode="multiformat", tune="analytic")
        orig = np.asarray(A.local.active_id)
        check = lambda a: abs(np.asarray(dist_spmv(a, x, mesh)) - ref).max() / abs(ref).max()
        assert check(A) < 1e-5
        A2 = activate_dist(A, "local", Format.CSR)       # uniform switch
        assert (np.asarray(A2.local.active_id) == 1).all()
        assert check(A2) < 1e-5
        A3 = activate_dist(A2, "local", orig)            # per-shard ids back
        assert (np.asarray(A3.local.active_id) == orig).all()
        assert check(A3) < 1e-5
        A4 = activate_dist(A3, "remote", Format.COO)
        assert check(A4) < 1e-5
        print("OK")
    """)
    assert "OK" in out


def test_dist_overlapped_spmv_random_gather_8shards():
    """Overlap refactor must hold for the all_gather (irregular) path."""
    out = _run_subprocess("""
        from repro.core import random_coo
        mesh = jax.make_mesh((8,), ("rows",))
        A0 = random_coo(7, (256, 256), density=0.08)
        r, c, v = np.asarray(A0.row), np.asarray(A0.col), np.asarray(A0.data)
        D = np.zeros((256, 256)); np.add.at(D, (r, c), v)
        x_np = np.random.default_rng(4).standard_normal(256).astype(np.float32)
        A = build_dist_matrix(r, c, v, (256, 256), mesh, "rows",
                              mode="multiformat", tune="analytic")
        assert A.halo_mode == "gather", A
        y = np.asarray(dist_spmv(A, distribute_vector(x_np, mesh, "rows"), mesh))
        err = abs(y - D @ x_np).max() / abs(D @ x_np).max()
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_dist_block_diagonal_skips_exchange_8shards():
    out = _run_subprocess("""
        mesh = jax.make_mesh((8,), ("rows",))
        row = col = np.arange(64); val = np.arange(1, 65, dtype=np.float32)
        A = build_dist_matrix(row, col, val, (64, 64), mesh, "rows")
        assert A.remote_empty and A.hw == 0, A
        x_np = np.ones(64, np.float32)
        y = np.asarray(dist_spmv(A, distribute_vector(x_np, mesh, "rows"), mesh))
        np.testing.assert_allclose(y, val)
        print("OK")
    """)
    assert "OK" in out


def test_dist_cg_slab_plan_auto_backend_8shards():
    """HPCG end-to-end on the slab-aware fast path with operator(auto)."""
    out = _run_subprocess("""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(8, 8, 16)
        plan = hpcg.slab_plan(prob, 8)
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", local_format=Format.DIA,
                              remote_format=Format.CSR, plan=plan)
        b = distribute_vector(hpcg.rhs_for_ones(prob), mesh, "rows")
        res = jax.jit(lambda a, bb: cg(operator(a, mesh), bb,
                                       tol=1e-7, maxiter=300))(A, b)
        err = abs(np.asarray(res.x) - 1.0).max()
        assert err < 1e-3, err
        print("OK", int(res.iters), err)
    """)
    assert "OK" in out
