"""Distributed sparse runtime tests.

Multi-device tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single-device view (dry-run isolation, see dryrun.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Format, hpcg, random_coo
from repro.core.distributed import (build_dist_matrix, dist_spmv,
                                    distribute_vector, partition_coo)
from repro.core.solvers import cg, cg_fixed_iters

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str):
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, %r)
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core import hpcg, Format
        from repro.core.distributed import (build_dist_matrix, dist_spmv,
                                            distribute_vector)
        from repro.core.solvers import cg
    """ % os.path.abspath(SRC)) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


# ---------------------------------------------------------------------------
# Partitioner (host logic — no devices needed)
# ---------------------------------------------------------------------------

def test_partition_local_remote_split():
    prob = hpcg.generate_problem(4, 4, 8)
    pc = partition_coo(prob.row, prob.col, prob.val, prob.shape, 4)
    assert pc.halo_mode == "neighbor"
    assert pc.hw == 16  # one plane = nx*ny
    # all entries accounted for
    total = sum(len(t[0]) for t in pc.local) + sum(len(t[0]) for t in pc.remote)
    assert total == len(prob.row)
    # local columns are in-range
    for (r, c, v) in pc.local:
        assert (c >= 0).all() and (c < pc.mp).all()
    for (r, c, v) in pc.remote:
        assert (c >= 0).all() and (c < 2 * pc.hw).all()


def test_partition_requires_divisible():
    with pytest.raises(ValueError):
        partition_coo([0], [0], [1.0], (10, 10), 3)


def test_partition_irregular_falls_back_to_gather():
    A = random_coo(0, (64, 64), density=0.2)
    pc = partition_coo(np.asarray(A.row), np.asarray(A.col), np.asarray(A.data),
                       (64, 64), 8)
    assert pc.halo_mode == "gather"


# ---------------------------------------------------------------------------
# Single-device mesh (in-process)
# ---------------------------------------------------------------------------

def test_dist_spmv_single_shard():
    mesh = jax.make_mesh((1,), ("rows",))
    prob = hpcg.generate_problem(4, 4, 4)
    A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh, "rows",
                          local_format=Format.DIA, remote_format=Format.COO)
    x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
    y = dist_spmv(A, x, mesh)
    D = np.zeros(prob.shape)
    np.add.at(D, (prob.row, prob.col), prob.val)
    np.testing.assert_allclose(np.asarray(y), D @ np.ones(prob.shape[0]),
                               rtol=1e-5, atol=1e-5)


def test_cg_single_device():
    prob = hpcg.generate_problem(6, 6, 6)
    from repro.core import convert, to_coo
    A = convert(hpcg.to_coo(prob), Format.CSR)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    from repro.core import spmv
    res = cg(lambda v: spmv(A, v), b, tol=1e-7, maxiter=300)
    np.testing.assert_allclose(np.asarray(res.x), 1.0, rtol=1e-3, atol=1e-3)


def test_cg_fixed_iters_runs():
    prob = hpcg.generate_problem(4, 4, 4)
    from repro.core import convert, spmv
    A = convert(hpcg.to_coo(prob), Format.ELL)
    b = jnp.asarray(hpcg.rhs_for_ones(prob))
    res = cg_fixed_iters(lambda v: spmv(A, v), b, iters=30)
    assert np.isfinite(float(res.resnorm))


# ---------------------------------------------------------------------------
# 8-shard SPMD (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,lf,rf", [
    ("uniform", "CSR", "CSR"),
    ("uniform", "DIA", "COO"),
    ("multiformat", "CSR", "CSR"),
])
def test_dist_spmv_8shards(mode, lf, rf):
    out = _run_subprocess(f"""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(8, 8, 16)
        D = np.zeros(prob.shape); np.add.at(D, (prob.row, prob.col), prob.val)
        x_np = np.random.default_rng(0).standard_normal(prob.shape[0]).astype(np.float32)
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", local_format=Format.{lf},
                              remote_format=Format.{rf}, mode="{mode}")
        x = distribute_vector(x_np, mesh, "rows")
        y = jax.jit(lambda a, v: dist_spmv(a, v, mesh))(A, x)
        err = abs(np.asarray(y) - D @ x_np).max() / abs(D @ x_np).max()
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_dist_cg_8shards_converges_to_ones():
    out = _run_subprocess("""
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(8, 8, 16)
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", local_format=Format.DIA,
                              remote_format=Format.COO)
        b = distribute_vector(hpcg.rhs_for_ones(prob), mesh, "rows")
        res = jax.jit(lambda a, bb: cg(lambda v: dist_spmv(a, v, mesh), bb,
                                       tol=1e-7, maxiter=300))(A, b)
        err = abs(np.asarray(res.x) - 1.0).max()
        assert err < 1e-3, err
        print("OK", int(res.iters), err)
    """)
    assert "OK" in out


def test_dist_matches_single_device_result():
    """Invariant: distribution must not change the math."""
    out = _run_subprocess("""
        from repro.core import convert, spmv
        mesh = jax.make_mesh((8,), ("rows",))
        prob = hpcg.generate_problem(6, 6, 8)
        x_np = np.random.default_rng(1).standard_normal(prob.shape[0]).astype(np.float32)
        A1 = convert(hpcg.to_coo(prob), Format.CSR)
        y1 = np.asarray(spmv(A1, jnp.asarray(x_np)))
        A8 = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                               "rows", mode="multiformat")
        y8 = np.asarray(dist_spmv(A8, distribute_vector(x_np, mesh, "rows"), mesh))
        err = abs(y1 - y8).max() / abs(y1).max()
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out
