"""Unit + property tests: containers, conversions, SpMV/SpMM correctness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (BSR, COO, CSR, DIA, ELL, Dense, Format,
                        banded_coo, bytes_of, convert, coo_from_dense_np,
                        deep_copy, dense_from_array, extract_diagonal,
                        random_coo, shallow_copy, spmm, spmv, to_coo,
                        to_dense_np, update_diagonal)

ALL_FORMATS = [Format.COO, Format.CSR, Format.DIA, Format.ELL, Format.DENSE]


def _rand(seed, shape, density=0.08, dtype=jnp.float32):
    return random_coo(seed, shape, density=density, dtype=dtype)


@pytest.mark.parametrize("fmt", ALL_FORMATS)
@pytest.mark.parametrize("shape", [(32, 32), (64, 48), (48, 96), (1, 7)])
def test_convert_roundtrip(fmt, shape):
    A = _rand(0, shape)
    D = to_dense_np(A)
    Af = convert(A, fmt)
    np.testing.assert_allclose(to_dense_np(Af), D, rtol=1e-6, atol=1e-6)
    # back through the COO proxy
    np.testing.assert_allclose(to_dense_np(to_coo(Af)), D, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("fmt", ALL_FORMATS)
@pytest.mark.parametrize("shape", [(32, 32), (64, 48), (48, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_spmv_matches_dense(fmt, shape, dtype):
    A = _rand(1, shape, dtype=jnp.float32)
    D = to_dense_np(A).astype(np.float64)
    x = np.random.default_rng(2).standard_normal(shape[1]).astype(np.float32)
    y = spmv(convert(A, fmt), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), D @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", ALL_FORMATS)
def test_spmm_matches_dense(fmt):
    A = _rand(3, (48, 40))
    D = to_dense_np(A)
    B = np.random.default_rng(4).standard_normal((40, 12)).astype(np.float32)
    Y = spmm(convert(A, fmt), jnp.asarray(B))
    np.testing.assert_allclose(np.asarray(Y), D @ B, rtol=1e-4, atol=1e-4)


def test_bsr_roundtrip_and_spmv():
    A = _rand(5, (256, 128), density=0.1)
    Ab = convert(A, Format.BSR, block_size=64)
    D = to_dense_np(A)
    np.testing.assert_allclose(to_dense_np(Ab), D, rtol=1e-6, atol=1e-6)
    x = np.random.default_rng(6).standard_normal(128).astype(np.float32)
    np.testing.assert_allclose(np.asarray(spmv(Ab, jnp.asarray(x))), D @ x,
                               rtol=1e-4, atol=1e-4)


def test_bsr_requires_block_aligned():
    A = _rand(7, (100, 60))
    with pytest.raises(ValueError):
        convert(A, Format.BSR, block_size=64)


def test_dia_banded_exact():
    A = banded_coo((128, 128), [-16, -1, 0, 1, 16])
    Ad = convert(A, Format.DIA)
    assert Ad.ndiag == 5
    np.testing.assert_allclose(to_dense_np(Ad), to_dense_np(A))


def test_capacity_padding_is_inert():
    A = random_coo(8, (32, 32), density=0.1, capacity=500)
    D = to_dense_np(A)
    x = np.random.default_rng(9).standard_normal(32).astype(np.float32)
    for fmt in ALL_FORMATS:
        y = spmv(convert(A, fmt), jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(y), D @ x, rtol=1e-4, atol=1e-4,
                                   err_msg=str(fmt))


def test_copy_semantics():
    A = _rand(10, (16, 16))
    S = shallow_copy(A)
    assert S.data is A.data  # aliasing, zero cost
    Dc = deep_copy(A)
    assert Dc.data is not A.data
    np.testing.assert_array_equal(np.asarray(Dc.data), np.asarray(A.data))
    assert bytes_of(A) > 0


def test_diag_update_extract():
    A = _rand(11, (32, 32))
    # ensure the diagonal exists in the pattern
    D = to_dense_np(A)
    np.fill_diagonal(D, 3.0)
    A = coo_from_dense_np(D)
    for fmt in ALL_FORMATS:
        Af = convert(A, fmt)
        d = extract_diagonal(Af)
        np.testing.assert_allclose(np.asarray(d), np.diagonal(D), rtol=1e-6)
        Au = update_diagonal(Af, jnp.full((32,), 7.0))
        np.testing.assert_allclose(np.asarray(extract_diagonal(Au)),
                                   np.full(32, 7.0), rtol=1e-6, err_msg=str(fmt))


def test_spmv_under_jit():
    A = _rand(12, (64, 64))
    x = jnp.ones((64,))
    f = jax.jit(lambda a, v: spmv(a, v))
    for fmt in ALL_FORMATS:
        Af = convert(A, fmt)
        np.testing.assert_allclose(np.asarray(f(Af, x)),
                                   to_dense_np(A) @ np.ones(64), rtol=1e-4, atol=1e-4)

# The property-based (hypothesis) block lives in test_formats_properties.py,
# guarded by pytest.importorskip — a bare `import hypothesis` here was a
# collection error aborting the whole tier-1 run when it isn't installed.
