"""Interior/boundary overlap split, persistent DistPlans, and repro.env.

The split parity oracle is the unsplit path: ``split_local_execute`` must
partition every live local entry into exactly one of interior/boundary
(dense sums match per shard), with interior rows having no live remote
entry — so the interior SpMV is provably independent of the halo.
Multi-device behaviour (the overlapped ``dist_spmv`` itself, per-split
multiformat selection) runs in an 8-forced-host-device subprocess, same
harness as ``test_distributed``.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Format, hpcg
from repro.core.convert import (SwitchPlan, convert_execute_batch,
                                planned_pulls_scope, plan_switch_batch)
from repro.core.distributed import (DistPlan, _split_caps, partition_coo,
                                    partition_execute_jit, plan_partition,
                                    split_local_execute_jit)
from repro.obs import metrics

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str, env=None):
    script = textwrap.dedent("""
        import sys
        sys.path.insert(0, %r)
        from repro import env
        env.apply(host_devices=8)
        import os
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core import hpcg, Format
        from repro.core.distributed import (activate_dist, build_dist_matrix,
                                            dist_spmv, dist_spmv_phase,
                                            distribute_vector)
    """ % os.path.abspath(SRC)) + textwrap.dedent(body)
    full_env = dict(os.environ, **(env or {}))
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=full_env)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def _dense(shape, row, col, val):
    D = np.zeros(shape)
    np.add.at(D, (np.asarray(row), np.asarray(col)), np.asarray(val))
    return D


def _random_triplets(seed, n, m, band=None):
    rng = np.random.default_rng(seed)
    row = rng.integers(0, n, m)
    if band is None:
        col = rng.integers(0, n, m)
    else:
        col = np.clip(row + rng.integers(-band, band + 1, m), 0, n - 1)
    val = rng.standard_normal(m).astype(np.float32)
    return row, col, val


def _split_and_check(row, col, val, shape, nshards, force_split=False):
    """Run the split scatter and assert the structural invariants; returns
    (plan, interior, boundary, local, remote)."""
    plan = plan_partition(row, col, val, shape, nshards)
    icap, bcap = _split_caps(row, col, val, plan.mp, nshards)
    local, remote = partition_execute_jit(row, col, val, plan=plan)
    interior, boundary = split_local_execute_jit(local, remote, mp=plan.mp,
                                                 icap=icap, bcap=bcap)
    mp = plan.mp
    for p in range(nshards):
        dl = _dense((mp, mp), local.row[p], local.col[p], local.data[p])
        di = _dense((mp, mp), interior.row[p], interior.col[p],
                    interior.data[p])
        db = _dense((mp, mp), boundary.row[p], boundary.col[p],
                    boundary.data[p])
        # the split is a partition of the local block: nothing lost, nothing
        # duplicated
        np.testing.assert_allclose(di + db, dl, rtol=1e-6, atol=1e-6)
        # interior rows have no live remote entry (their SpMV never waits
        # on the halo) and no live boundary entry (the halves are disjoint)
        rrow = np.asarray(remote.row[p])
        rlive = np.asarray(remote.data[p]) != 0
        brows = np.zeros(mp, bool)
        brows[rrow[rlive]] = True
        ilive = np.asarray(interior.data[p]) != 0
        assert not brows[np.asarray(interior.row[p])[ilive]].any()
        blive = np.asarray(boundary.data[p]) != 0
        assert brows[np.asarray(boundary.row[p])[blive]].all()
    return plan, interior, boundary, local, remote


# ---------------------------------------------------------------------------
# Split scatter invariants (host+device, single-device view)
# ---------------------------------------------------------------------------


def test_split_parity_stencil():
    prob = hpcg.generate_problem(4, 4, 8)
    _split_and_check(prob.row, prob.col, prob.val, prob.shape, 4)


def test_split_parity_random_gather():
    row, col, val = _random_triplets(0, 64, 700)  # random -> gather mode
    plan = plan_partition(row, col, val, (64, 64), 4)
    assert plan.halo_mode == "gather"
    _split_and_check(row, col, val, (64, 64), 4)


def test_split_parity_banded_neighbor():
    row, col, val = _random_triplets(1, 64, 900, band=10)
    plan = plan_partition(row, col, val, (64, 64), 4)
    assert plan.halo_mode == "neighbor"
    _split_and_check(row, col, val, (64, 64), 4)


def test_split_block_diagonal_hw0_all_interior():
    """A statically-empty remote part (hw=0) has no boundary rows: a forced
    split must put every live entry in the interior container."""
    n = 32
    row = col = np.arange(n)
    val = np.ones(n, np.float32)
    plan = plan_partition(row, col, val, (n, n), 4)
    assert plan.remote_empty and plan.hw == 0
    icap, bcap = _split_caps(row, col, val, plan.mp, 4)
    assert bcap == 1  # floor capacity, no real boundary entries
    local, remote = partition_execute_jit(row, col, val, plan=plan)
    interior, boundary = split_local_execute_jit(local, remote, mp=plan.mp,
                                                 icap=icap, bcap=bcap)
    assert int((np.asarray(boundary.data) != 0).sum()) == 0
    assert int((np.asarray(interior.data) != 0).sum()) == n


def test_split_caps_count_live_entries_only():
    """Dead (val == 0) entries are dropped by the device split, so the cap
    scan must not count them either — or caps (and ELL widths downstream)
    would be inflated by padding."""
    prob = hpcg.generate_problem(4, 4, 4)
    icap, bcap = _split_caps(prob.row, prob.col, prob.val, prob.shape[0] // 2, 2)
    val0 = prob.val.copy()
    val0[::2] = 0.0
    icap0, bcap0 = _split_caps(prob.row, prob.col, val0, prob.shape[0] // 2, 2)
    assert icap0 < icap and bcap0 <= bcap


def test_stale_split_caps_raise():
    """Reusing a plan whose split caps are too small for denser triplets
    must fail loudly, not silently drop entries (same contract as the
    partition caps)."""
    from repro.core.distributed import _check_plan_fits

    prob = hpcg.generate_problem(4, 4, 8)
    plan = plan_partition(prob.row, prob.col, prob.val, prob.shape, 4)
    icap, bcap = _split_caps(prob.row, prob.col, prob.val, plan.mp, 4)
    import dataclasses
    stale = dataclasses.replace(plan, interior_cap=max(1, icap // 2),
                                boundary_cap=bcap)
    with pytest.raises(ValueError, match="stale DistPlan"):
        _check_plan_fits(prob.row, prob.col, stale, val=prob.val)
    ok = dataclasses.replace(plan, interior_cap=icap, boundary_cap=bcap)
    _check_plan_fits(prob.row, prob.col, ok, val=prob.val)  # no raise


def test_slab_plan_carries_split_caps():
    """The analytic z-slab plan precomputes the overlap caps (boundary =
    the slab's first/last x-y planes), so a split build does no extra
    host scan."""
    prob = hpcg.generate_problem(4, 4, 8)
    plan = hpcg.slab_plan(prob, 4)
    icap, bcap = _split_caps(prob.row, prob.col, prob.val, plan.mp, 4)
    assert (plan.interior_cap, plan.boundary_cap) == (icap, bcap)
    p1 = hpcg.slab_plan(prob, 1)
    assert p1.interior_cap is None and p1.remote_empty


# ---------------------------------------------------------------------------
# Transfer discipline: the 3-way pipeline stays device-resident
# ---------------------------------------------------------------------------


def test_three_way_split_constant_planned_pulls():
    """The split scatter plus per-split batched selection/conversion adds
    no per-shard host transfers: the planned-pull count is independent of
    the shard count, and nothing else crosses device->host."""
    import tempfile

    from repro.tuning.cache import SelectionCache
    from repro.tuning.policy import FormatPolicy

    prob = hpcg.generate_problem(4, 4, 8)
    candidates = (Format.COO, Format.CSR, Format.DIA, Format.ELL)
    pulls = {}
    for nshards in (2, 8):
        cache = SelectionCache(os.path.join(tempfile.mkdtemp(), "sel.json"))
        policy = FormatPolicy("cached", candidates=candidates, cache=cache)
        plan = plan_partition(prob.row, prob.col, prob.val, prob.shape,
                              nshards)
        icap, bcap = _split_caps(prob.row, prob.col, prob.val, plan.mp,
                                 nshards)
        with planned_pulls_scope() as scope, \
                jax.transfer_guard_device_to_host("disallow"):
            local, remote = partition_execute_jit(prob.row, prob.col,
                                                  prob.val, plan=plan)
            interior, boundary = split_local_execute_jit(
                local, remote, mp=plan.mp, icap=icap, bcap=bcap)
            for part in (interior, boundary, remote):
                ids = policy.select_batch(part)
                assert ids.shape == (nshards,)
                for fmt in candidates:
                    sp = plan_switch_batch(part, fmt)
                    out = convert_execute_batch(part, sp)
                    jax.block_until_ready(jax.tree_util.tree_leaves(out))
        pulls[nshards] = scope.count
    assert pulls[2] == pulls[8], pulls


# ---------------------------------------------------------------------------
# DistPlan persistence
# ---------------------------------------------------------------------------


def test_dist_plan_json_roundtrip_bare():
    prob = hpcg.generate_problem(4, 4, 8)
    plan = plan_partition(prob.row, prob.col, prob.val, prob.shape, 4)
    assert DistPlan.from_json(plan.to_json()) == plan


def test_dist_plan_json_roundtrip_enriched():
    """Round-trip with everything a production plan carries: split caps,
    per-candidate SwitchPlans for all three parts, pattern fingerprint."""
    import dataclasses

    prob = hpcg.generate_problem(4, 4, 8)
    from repro.core.distributed import plan_dist_formats

    plan = plan_partition(prob.row, prob.col, prob.val, prob.shape, 4)
    icap, bcap = _split_caps(prob.row, prob.col, prob.val, plan.mp, 4)
    plan = dataclasses.replace(plan, interior_cap=icap, boundary_cap=bcap,
                               pattern_sig="deadbeef")
    local, remote = partition_execute_jit(prob.row, prob.col, prob.val,
                                          plan=plan)
    interior, boundary = split_local_execute_jit(local, remote, mp=plan.mp,
                                                 icap=icap, bcap=bcap)
    plan = plan_dist_formats(interior, remote, plan,
                             (Format.COO, Format.CSR, Format.DIA, Format.ELL),
                             boundary=boundary)
    rt = DistPlan.from_json(plan.to_json())
    assert rt == plan
    assert rt.interior_plans is not None and rt.boundary_plans is not None
    assert all(isinstance(p, SwitchPlan) for p in rt.interior_plans)


def test_switch_plan_json_roundtrip():
    sp = SwitchPlan(target=Format.DIA, dia_offsets=(-4, -1, 0, 1, 4))
    assert SwitchPlan.from_json(sp.to_json()) == sp
    sp2 = SwitchPlan(target=Format.ELL, ell_k=7)
    assert SwitchPlan.from_json(sp2.to_json()) == sp2


def test_plan_cache_restart_skips_planning(tmp_path):
    """A fresh SelectionCache instance over the same store (the restart)
    must hit the persisted plan: distplan.cache_hit increments, the loaded
    plan carries the memoised format plans, and the build still matches
    the from-scratch result."""
    body = """
    import tempfile, json
    from repro.tuning.cache import SelectionCache
    from repro.obs import metrics

    mesh = jax.make_mesh((8,), ("rows",))
    prob = hpcg.generate_problem(4, 4, 8)
    x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
    path = os.environ["PLAN_CACHE_PATH"]
    kw = dict(mode="multiformat", tune="analytic")

    with metrics.scope() as s:
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", plan_cache=SelectionCache(path), **kw)
        assert s.delta("distplan.cache_miss") == 1, metrics.snapshot()
        assert s.delta("distplan.cache_hit") == 0
    y0 = np.asarray(dist_spmv(A, x, mesh))

    # the "restart": a fresh cache object over the same on-disk store
    with metrics.scope() as s:
        B = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", plan_cache=SelectionCache(path), **kw)
        assert s.delta("distplan.cache_hit") == 1, metrics.snapshot()
        assert s.delta("distplan.cache_miss") == 0
    assert B.plan.interior_plans is not None  # planning was skipped, not redone
    assert B.plan.pattern_sig == A.plan.pattern_sig
    y1 = np.asarray(dist_spmv(B, x, mesh))
    np.testing.assert_allclose(y1, y0, rtol=1e-5, atol=1e-5)
    print("OK")
    """
    out = _run_subprocess(
        body, env={"PLAN_CACHE_PATH": str(tmp_path / "plans.json")})
    assert "OK" in out


# ---------------------------------------------------------------------------
# Overlapped dist_spmv (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------


def test_dist_split_spmv_parity_8shards():
    """Split vs unsplit vs dense oracle, plus the phase decomposition:
    interior + boundary == local, and the production result is identical
    either way."""
    body = """
    mesh = jax.make_mesh((8,), ("rows",))
    prob = hpcg.generate_problem(4, 4, 8)
    n = prob.shape[0]
    D = np.zeros((n, n))
    np.add.at(D, (prob.row, prob.col), prob.val)
    xh = np.arange(n, dtype=np.float32) / n
    x = distribute_vector(xh, mesh, "rows")
    ref = D @ xh

    A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                          "rows", local_format=Format.CSR,
                          remote_format=Format.COO)
    assert A.split, A
    B = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                          "rows", local_format=Format.CSR,
                          remote_format=Format.COO, split=False)
    assert not B.split, B
    ya = np.asarray(dist_spmv(A, x, mesh))
    yb = np.asarray(dist_spmv(B, x, mesh))
    np.testing.assert_allclose(ya, ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ya, yb, rtol=1e-5, atol=1e-5)

    loc = np.asarray(dist_spmv_phase(A, x, mesh, phase="local"))
    intr = np.asarray(dist_spmv_phase(A, x, mesh, phase="interior"))
    bnd = np.asarray(dist_spmv_phase(A, x, mesh, phase="boundary"))
    exc = np.asarray(dist_spmv_phase(A, x, mesh, phase="exchange"))
    np.testing.assert_allclose(intr + bnd, loc, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(loc + exc, ya, rtol=1e-4, atol=1e-4)
    try:
        dist_spmv_phase(B, x, mesh, phase="interior")
    except ValueError as e:
        assert "split" in str(e)
    else:
        raise AssertionError("interior phase on unsplit matrix must raise")
    print("OK")
    """
    assert "OK" in _run_subprocess(body)


def test_dist_split_multiformat_and_boundary_activate_8shards():
    """Per-split multiformat selection: three independent SwitchDynamic
    parts, runtime activate() of the boundary part preserves results."""
    body = """
    from repro.core.dynamic import SwitchDynamicMatrix

    mesh = jax.make_mesh((8,), ("rows",))
    prob = hpcg.generate_problem(4, 4, 8)
    n = prob.shape[0]
    xh = np.ones(n, np.float32)
    x = distribute_vector(xh, mesh, "rows")
    A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                          "rows", mode="multiformat", tune="analytic")
    assert A.split
    assert isinstance(A.boundary, SwitchDynamicMatrix)
    assert A.plan.interior_plans is not None
    assert A.plan.boundary_plans is not None
    y0 = np.asarray(dist_spmv(A, x, mesh))
    D = np.zeros((n, n))
    np.add.at(D, (prob.row, prob.col), prob.val)
    np.testing.assert_allclose(y0, D @ xh, rtol=1e-4, atol=1e-4)
    for fmt in (Format.COO, Format.CSR, Format.ELL):
        A2 = activate_dist(A, "boundary", fmt)
        y2 = np.asarray(dist_spmv(A2, x, mesh))
        np.testing.assert_allclose(y2, y0, rtol=1e-5, atol=1e-5)
    try:
        activate_dist(build_dist_matrix(prob.row, prob.col, prob.val,
                                        prob.shape, mesh, "rows",
                                        mode="multiformat", tune="analytic",
                                        split=False), "boundary", Format.COO)
    except ValueError as e:
        assert "boundary" in str(e)
    else:
        raise AssertionError("boundary activate on unsplit matrix must raise")
    print("OK")
    """
    assert "OK" in _run_subprocess(body)


def test_dist_split_gather_mode_8shards():
    """Random pattern -> gather halo; the split schedule must agree with
    the dense oracle there too."""
    body = """
    mesh = jax.make_mesh((8,), ("rows",))
    rng = np.random.default_rng(7)
    n, m = 128, 2000
    row = rng.integers(0, n, m)
    col = rng.integers(0, n, m)
    val = rng.standard_normal(m).astype(np.float32)
    D = np.zeros((n, n))
    np.add.at(D, (row, col), val)
    xh = rng.standard_normal(n).astype(np.float32)
    x = distribute_vector(xh, mesh, "rows")
    A = build_dist_matrix(row, col, val, (n, n), mesh, "rows")
    assert A.halo_mode == "gather" and A.split
    y = np.asarray(dist_spmv(A, x, mesh))
    np.testing.assert_allclose(y, D @ xh, rtol=2e-4, atol=2e-4)
    print("OK")
    """
    assert "OK" in _run_subprocess(body)


# ---------------------------------------------------------------------------
# repro.env (no jax involvement by construction)
# ---------------------------------------------------------------------------


def test_env_resolve_backend(monkeypatch):
    from repro import env

    monkeypatch.delenv("JAX_PLATFORMS", raising=False)
    monkeypatch.delenv("JAX_PLATFORM_NAME", raising=False)
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert env.resolve_backend() == "cpu"
    assert env.resolve_backend("GPU") == "gpu"
    monkeypatch.setenv("JAX_PLATFORMS", "cuda,cpu")
    assert env.resolve_backend() == "cuda"


def test_env_apply_backend_gated(monkeypatch):
    """CPU gets only the device-count flag; GPU adds the async-collective
    set; a caller's unrelated XLA_FLAGS survive the merge."""
    from repro import env

    monkeypatch.setenv("XLA_FLAGS", "--xla_dump_to=/tmp/d")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # jax already imported in pytest
        info = env.apply(backend="cpu", host_devices=16)
    assert "--xla_force_host_platform_device_count=16" in info["xla_flags"]
    assert "--xla_dump_to=/tmp/d" in info["xla_flags"]
    assert "async_collectives" not in info["xla_flags"]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        info = env.apply(backend="gpu", host_devices=4)
    assert "--xla_gpu_enable_async_collectives=true" in info["xla_flags"]
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in info["xla_flags"]
    assert "--xla_force_host_platform_device_count=4" in info["xla_flags"]
    # managed flags were replaced, not duplicated
    assert info["xla_flags"].count("device_count") == 1
    assert env.describe()["backend"] == "gpu"


def test_env_apply_warns_after_jax_import(monkeypatch):
    from repro import env

    monkeypatch.setenv("XLA_FLAGS", "")
    with pytest.warns(RuntimeWarning, match="after jax"):
        env.apply(backend="cpu", host_devices=2)
