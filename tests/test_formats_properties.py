"""Property-based system invariants (split from test_formats.py).

Skipped wholesale when hypothesis isn't installed — property coverage is a
test extra (`pip install .[test]`), not a tier-1 requirement.
"""
import numpy as np
import pytest

import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Format, convert, random_coo, spmv, to_dense_np  # noqa: E402

ALL_FORMATS = [Format.COO, Format.CSR, Format.DIA, Format.ELL, Format.DENSE]


@st.composite
def sparse_mats(draw):
    m = draw(st.integers(4, 40))
    n = draw(st.integers(4, 40))
    density = draw(st.floats(0.02, 0.4))
    seed = draw(st.integers(0, 2**16))
    return random_coo(seed, (m, n), density=density)


@given(sparse_mats(), st.sampled_from(ALL_FORMATS))
@settings(max_examples=25, deadline=None)
def test_prop_conversion_preserves_matrix(A, fmt):
    """Invariant: convert() never changes the represented matrix."""
    np.testing.assert_allclose(to_dense_np(convert(A, fmt)), to_dense_np(A),
                               rtol=1e-5, atol=1e-5)


@given(sparse_mats(), st.sampled_from(ALL_FORMATS), st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_prop_spmv_format_invariant(A, fmt, xseed):
    """Invariant: SpMV result is independent of the storage format."""
    x = np.random.default_rng(xseed).standard_normal(A.shape[1]).astype(np.float32)
    y_coo = np.asarray(spmv(A, jnp.asarray(x)))
    y_fmt = np.asarray(spmv(convert(A, fmt), jnp.asarray(x)))
    np.testing.assert_allclose(y_fmt, y_coo, rtol=1e-4, atol=1e-4)


@given(sparse_mats())
@settings(max_examples=15, deadline=None)
def test_prop_spmv_linearity(A):
    """Invariant: A(ax + by) == a Ax + b Ay (exercises padding safety)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(A.shape[1]).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(A.shape[1]).astype(np.float32))
    lhs = np.asarray(spmv(A, 2.0 * x + 3.0 * y))
    rhs = 2.0 * np.asarray(spmv(A, x)) + 3.0 * np.asarray(spmv(A, y))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)
