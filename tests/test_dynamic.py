"""Tests for the DynamicMatrix abstractions and the auto-tuner."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DEFAULT_CANDIDATES, DynamicMatrix, Format,
                        SwitchDynamicMatrix, analytic_select, autotune,
                        banded_coo, random_coo, spmv, to_dense_np)
from repro.core.autotune import PatternStats


def test_dynamic_state_switching():
    A = random_coo(0, (48, 48), density=0.1)
    dm = DynamicMatrix(A)
    assert dm.active == Format.COO
    for fmt in [Format.CSR, Format.DIA, Format.ELL, Format.COO]:
        dm2 = dm.activate(fmt)
        assert dm2.active == fmt
        np.testing.assert_allclose(to_dense_np(dm2.concrete), to_dense_np(A),
                                   rtol=1e-6, atol=1e-6)


def test_dynamic_same_interface_as_concrete():
    """Paper §III: algorithms take dynamic and concrete types uniformly."""
    A = random_coo(1, (32, 24), density=0.15)
    x = jnp.ones((24,))
    y_concrete = spmv(A, x)
    y_dynamic = spmv(DynamicMatrix(A), x)
    np.testing.assert_allclose(np.asarray(y_concrete), np.asarray(y_dynamic))


def test_dynamic_is_pytree():
    A = random_coo(2, (16, 16), density=0.2)
    dm = DynamicMatrix(A).activate(Format.CSR)
    out = jax.jit(lambda m, v: m.spmv(v))(dm, jnp.ones((16,)))
    np.testing.assert_allclose(np.asarray(out), to_dense_np(A) @ np.ones(16),
                               rtol=1e-5, atol=1e-5)


def test_switch_dynamic_runtime_dispatch():
    """lax.switch dispatch returns the same result for every active id."""
    A = random_coo(3, (40, 40), density=0.1)
    sw = SwitchDynamicMatrix.from_matrix(A)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(40).astype(np.float32))
    ref = to_dense_np(A) @ np.asarray(x)
    f = jax.jit(lambda m, v: m.spmv(v))
    for i in range(len(sw.candidates)):
        np.testing.assert_allclose(np.asarray(f(sw.activate_id(i), x)), ref,
                                   rtol=1e-4, atol=1e-4)


def test_switch_activate_by_format():
    A = random_coo(4, (24, 24), density=0.2)
    sw = SwitchDynamicMatrix.from_matrix(A)
    sw2 = sw.activate(Format.DIA)
    assert int(sw2.active_id) == list(DEFAULT_CANDIDATES).index(Format.DIA)


def test_switch_traced_active_id():
    """The active id can be a traced value — true runtime selection."""
    A = random_coo(5, (32, 32), density=0.1)
    sw = SwitchDynamicMatrix.from_matrix(A)
    x = jnp.ones((32,))
    ref = to_dense_np(A) @ np.ones(32)

    @jax.jit
    def run(m, i, v):
        return m.activate_id(i).spmv(v)

    for i in range(4):
        np.testing.assert_allclose(np.asarray(run(sw, jnp.asarray(i), x)), ref,
                                   rtol=1e-4, atol=1e-4)


def test_autotune_profile_picks_valid_format():
    A = banded_coo((256, 256), [-8, 0, 8])
    rep = autotune(A, jnp.ones((256,)), mode="profile", iters=3)
    assert rep.best in DEFAULT_CANDIDATES
    assert all(t > 0 for t in rep.times.values())


def test_autotune_analytic_prefers_dia_for_banded():
    """The analytic model must reproduce the paper's core single-node
    result: DIA wins on regular banded (stencil) matrices."""
    A = banded_coo((4096, 4096), [-64, -1, 0, 1, 64])
    rep = autotune(A, mode="analytic")
    assert rep.best == Format.DIA


def test_autotune_analytic_prefers_csr_for_irregular():
    """...and CSR/COO on irregular patterns where DIA would zero-pad
    catastrophically (the paper's remote-matrix observation)."""
    stats = PatternStats(m=4096, n=4096, nnz=40960, max_row_nnz=200, ndiag=3000)
    rep = analytic_select(stats)
    assert rep.best in (Format.CSR, Format.COO)


def test_analytic_dense_regime():
    """Near-dense small problems: dense/CSR beat DIA zero-padding (paper's
    64-node observation)."""
    stats = PatternStats(m=128, n=128, nnz=128 * 100, max_row_nnz=110, ndiag=255)
    rep = analytic_select(stats, candidates=(Format.CSR, Format.DIA, Format.DENSE))
    assert rep.best != Format.DIA
