"""repro.obs.regress: tolerance classes, gate exit codes, trajectory store.

The load-bearing acceptance flows: the same artifacts compared against
their own bless exit 0; an injected 2x slowdown exits nonzero and the
report names the row; a row missing from the baseline is informational,
never a failure; a baseline from a different environment downgrades
timing comparisons instead of failing them.
"""
import json
import os

import pytest

from repro.obs import regress

ENV = {"backend": "cpu", "device_kind": "cpu", "interpret_mode": True}
OTHER_ENV = {"backend": "tpu", "device_kind": "TPU v4", "interpret_mode": False}


def _rows():
    return [
        {"name": "kernel_tuned_csr", "us_per_call": 400.0,
         "derived": "cfg=tk256/tm128;ref_us=700;speedup_vs_ref=1.75"},
        {"name": "serve_sparse_mlp_b8", "us_per_call": 50.0,
         "derived": "tok_per_s=20000.0;fmt_up=CSR;fmt_down=ELL"},
        {"name": "convert_coo_to_csr", "us_per_call": 123.0, "derived": ""},
        {"name": "serve_decision_b1", "us_per_call": 0.0,
         "derived": "fmt=CSR;backend=auto"},
    ]


def _write_artifact(d, rows, env=ENV):
    path = os.path.join(str(d), "BENCH_spmv.json")
    with open(path, "w") as f:
        json.dump({"meta": {"env": env}, "rows": rows}, f)
    return path


# ---------------------------------------------------------------------------
# Row classification
# ---------------------------------------------------------------------------


def test_classify_tolerance_classes():
    speedup, throughput, time_, info = _rows()
    assert regress.classify(speedup) == ("speedup", 1.75)
    assert regress.classify(throughput) == ("throughput", 20000.0)
    assert regress.classify(time_) == ("time", 123.0)
    assert regress.classify(info) == ("info", 0.0)


def test_compare_row_bands():
    base = {"us_per_call": 100.0, "derived": ""}
    # inside the wide raw-time band: ok
    assert regress.compare_row("r", base,
                               {"us_per_call": 160.0, "derived": ""}
                               )["status"] == "ok"
    # beyond baseline * 1.75: regression
    assert regress.compare_row("r", base,
                               {"us_per_call": 180.0, "derived": ""}
                               )["status"] == "regression"
    # speedup rows get the tighter band
    b = {"us_per_call": 10.0, "derived": "speedup_vs_ref=2.0"}
    assert regress.compare_row("r", b,
                               {"us_per_call": 10.0,
                                "derived": "speedup_vs_ref=1.5"}
                               )["status"] == "ok"
    f = regress.compare_row("r", b, {"us_per_call": 10.0,
                                     "derived": "speedup_vs_ref=1.0"})
    assert f["status"] == "regression"


def test_win_flip_rule_bites_inside_relative_band():
    # 1.4x -> 0.85x is only a 39% relative drop (inside the 45% band) but
    # flips a clear win to a clear loss — must regress.
    base = {"us_per_call": 10.0, "derived": "speedup_vs_ref=1.40"}
    cur = {"us_per_call": 10.0, "derived": "speedup_vs_ref=0.85"}
    f = regress.compare_row("r", base, cur)
    assert f["status"] == "regression"
    assert "flipped" in f["note"]


def test_missing_and_new_rows_are_informational():
    f = regress.compare_row("gone", {"us_per_call": 5.0, "derived": ""}, None)
    assert f["status"] == "missing"
    f = regress.compare_row("born", None, {"us_per_call": 5.0, "derived": ""})
    assert f["status"] == "new"
    # decision rows never regress, but a changed decision is noted
    f = regress.compare_row("d", {"us_per_call": 0.0, "derived": "fmt=CSR"},
                            {"us_per_call": 0.0, "derived": "fmt=ELL"})
    assert f["status"] == "info"
    assert "decision changed" in f["note"]


# ---------------------------------------------------------------------------
# Gate CLI flows (the CI acceptance criteria)
# ---------------------------------------------------------------------------


def test_bless_then_identical_compare_exits_zero(tmp_path, capsys):
    _write_artifact(tmp_path, _rows())
    baseline = str(tmp_path / "baseline.json")
    assert regress.main(["--bless", "--json-dir", str(tmp_path),
                         "--baseline", baseline]) == 0
    assert regress.main(["--json-dir", str(tmp_path),
                         "--baseline", baseline]) == 0
    out = capsys.readouterr().out
    assert "0 regression(s)" in out


def test_injected_slowdown_exits_nonzero_and_names_row(tmp_path, capsys):
    _write_artifact(tmp_path, _rows())
    baseline = str(tmp_path / "baseline.json")
    regress.main(["--bless", "--json-dir", str(tmp_path),
                  "--baseline", baseline])
    report = str(tmp_path / "report.md")
    rc = regress.main(["--json-dir", str(tmp_path), "--baseline", baseline,
                       "--inject-slowdown", "kernel_tuned_csr:2.0",
                       "--report", report])
    assert rc == 1
    text = open(report).read()
    assert "kernel_tuned_csr" in text
    assert "Regressions" in text
    # the injected factor halves the speedup AND doubles the raw time
    err = capsys.readouterr().err
    assert "kernel_tuned_csr" in err


def test_missing_baseline_row_is_informational_exit_zero(tmp_path):
    _write_artifact(tmp_path, _rows()[:2])
    baseline = str(tmp_path / "baseline.json")
    regress.main(["--bless", "--json-dir", str(tmp_path),
                  "--baseline", baseline])
    # new rows appear that the baseline has never seen
    _write_artifact(tmp_path, _rows() + [
        {"name": "brand_new_row", "us_per_call": 9.0, "derived": ""}])
    assert regress.main(["--json-dir", str(tmp_path),
                         "--baseline", baseline]) == 0
    findings = regress.compare(regress.load_baseline(baseline),
                               json_dir=str(tmp_path))
    by_name = {f["name"]: f for f in findings}
    assert by_name["brand_new_row"]["status"] == "new"


def test_env_mismatch_downgrades_to_informational(tmp_path):
    _write_artifact(tmp_path, _rows(), env=OTHER_ENV)
    baseline = str(tmp_path / "baseline.json")
    regress.main(["--bless", "--json-dir", str(tmp_path),
                  "--baseline", baseline])
    # same rows, 10x slower, but from a different device: not enforced
    slow = [dict(r, us_per_call=r["us_per_call"] * 10) for r in _rows()]
    for r in slow:
        r["derived"] = r["derived"].replace("speedup_vs_ref=1.75",
                                            "speedup_vs_ref=0.2")
    _write_artifact(tmp_path, slow, env=ENV)
    assert regress.main(["--json-dir", str(tmp_path),
                         "--baseline", baseline]) == 0
    findings = regress.compare(regress.load_baseline(baseline),
                               json_dir=str(tmp_path))
    assert all(f["status"] != "regression" for f in findings)
    assert any("env mismatch" in str(f.get("note")) for f in findings)


def test_no_baseline_is_not_a_failure(tmp_path):
    _write_artifact(tmp_path, _rows())
    assert regress.main(["--json-dir", str(tmp_path), "--baseline",
                         str(tmp_path / "nope.json")]) == 0


# ---------------------------------------------------------------------------
# Trajectory store
# ---------------------------------------------------------------------------


def test_history_append_and_load_roundtrip(tmp_path):
    hdir = str(tmp_path / "history")
    meta = {"env": {"git_rev": "abc123", **ENV}}
    rows = [("kernel_tuned_csr", 400.0, "speedup_vs_ref=1.75"),
            ("convert_coo_to_csr", 123.0, "")]
    regress.append_history("BENCH_spmv", rows, meta, history_dir=hdir)
    regress.append_history("BENCH_serve",
                           [("serve_decode_b8", 50.0, "tok_per_s=20000.0")],
                           meta, history_dir=hdir)
    entries = regress.load_history(hdir)
    assert [e["artifact"] for e in entries] == ["BENCH_spmv", "BENCH_serve"]
    assert entries[0]["git_rev"] == "abc123"
    assert entries[0]["env"]["device_kind"] == "cpu"
    assert entries[0]["rows"][0]["name"] == "kernel_tuned_csr"
    # a corrupt line is skipped, not fatal
    with open(os.path.join(hdir, regress.HISTORY_FILE), "a") as f:
        f.write("not json\n")
    assert len(regress.load_history(hdir)) == 2
    assert regress.load_history(str(tmp_path / "void")) == []


def test_render_markdown_sections():
    findings = [
        {"name": "bad", "artifact": "BENCH_spmv", "cls": "speedup",
         "status": "regression", "baseline": 2.0, "current": 1.0,
         "ratio": 0.5, "note": "1.00 vs baseline 2.00 (x0.50)"},
        {"name": "fine", "artifact": "BENCH_spmv", "cls": "time",
         "status": "ok", "baseline": 10.0, "current": 11.0, "ratio": 1.1,
         "note": ""},
        {"name": "fresh", "artifact": "BENCH_serve", "cls": "time",
         "status": "new", "current": 5.0, "note": "no baseline row"},
    ]
    text = regress.render_markdown(findings, "results/baseline.json")
    assert "1 regression(s)" in text
    assert "`bad`" in text and "Regressions" in text
    assert "`fresh`" in text  # surfaced under notable
    assert "`fine`" not in text  # ok rows stay out of the tables
