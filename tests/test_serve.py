"""Serving engine: batched jit'd prefill correctness + the smoke CLI.

The load-bearing claims: (1) ONE ``prefill_cache`` forward primes the
decode cache *identically* to the per-token prefill-by-decode loop it
replaced — same greedy continuations, ragged prompt lengths and pow2
row/len padding included; (2) the CLI subprocess completes every request;
(3) runtime ``activate()`` format switches between decode steps are
numerically invisible (the paper's dynamic-format claim, serving-shaped).
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import Format
from repro.launch.serve import DecodeEngine, _pow2_at_least, serve
from repro.models import build_model
from repro.models.linear_sparse import LinearSparse, prune_magnitude

RNG = np.random.default_rng(0)


def _f32_model(arch="stablelm_1_6b"):
    # bf16 flash-prefill vs einsum-decode can flip argmax on near-ties;
    # parity tests pin f32 so greedy token ids are deterministic.
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ref_greedy(model, params, prompt, max_new, max_len):
    """Single-request greedy decode with per-token prefill-by-decode —
    the behaviour the batched prefill must reproduce exactly."""
    cache = model.init_cache(1, max_len)
    step = jax.jit(model.decode_step)
    logits = None
    for i, t in enumerate(prompt):
        logits, cache = step(params, cache,
                             jnp.asarray([t], jnp.int32),
                             jnp.asarray([i], jnp.int32))
    tok = int(np.argmax(np.asarray(logits)[0]))
    out, pos = [tok], len(prompt)
    while len(out) < max_new:
        logits, cache = step(params, cache,
                             jnp.asarray([tok], jnp.int32),
                             jnp.asarray([pos], jnp.int32))
        tok = int(np.argmax(np.asarray(logits)[0]))
        out.append(tok)
        pos += 1
    return out


def test_pow2_bucket():
    assert [_pow2_at_least(n, 8) for n in (1, 2, 3, 5, 8, 9)] == \
        [1, 2, 4, 8, 8, 8]


def test_batched_prefill_matches_per_token_decode():
    """Ragged prompts through the batched engine == per-request reference.

    Lengths 3/5/6 in a 2-slot engine force: pow2 P padding (to 8), pow2 R
    padding (admission of 1 pending request pads the row axis), slot
    refill between steps, and the duplicate-slot pad-row scatter."""
    cfg, model, params = _f32_model()
    max_new, max_len = 5, 32
    prompts = [RNG.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in (3, 5, 6)]
    engine = DecodeEngine(model, params, slots=2, max_len=max_len)
    done, _ = serve(engine, list(enumerate(prompts)), max_new)
    got = dict(done)
    assert sorted(got) == [0, 1, 2]
    for rid, prompt in enumerate(prompts):
        ref = _ref_greedy(model, params, prompt, max_new, max_len)
        assert got[rid] == ref, f"request {rid} diverged"


def test_prefill_by_decode_fallback_families():
    """ssm has no addressable kv cache: the engine must fall back to the
    per-token path and still finish every request."""
    cfg, model, params = _f32_model("mamba2_2_7b")
    assert not model.supports_prefill_cache()
    engine = DecodeEngine(model, params, slots=2, max_len=24)
    prompts = [RNG.integers(0, cfg.vocab, (4,)).astype(np.int32)
               for _ in range(3)]
    done, _ = serve(engine, list(enumerate(prompts)), max_new=3)
    assert sorted(r for r, _ in done) == [0, 1, 2]
    assert all(len(o) == 3 for _, o in done)
    assert engine.prefill_calls == 3  # one per request, not batched


@pytest.mark.slow
def test_serve_smoke_subprocess():
    """The CI entry point: every request completes, output lists printed.
    Run in a subprocess so serve's env.apply() cannot touch this session's
    XLA flags (conftest asserts the device-count override never leaks)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    n = 6
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "stablelm_1_6b", "--smoke", "--requests", str(n), "--slots", "3",
         "--prompt-len", "5", "--max-new", "4"],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    assert f"served {n} requests, {n * 4} tokens" in out.stdout, out.stdout
    for rid in range(n):
        assert f"req {rid}:" in out.stdout, out.stdout


def test_request_telemetry_spans_and_histograms():
    """Every finished request leaves a complete telemetry span: per-phase
    latencies in request_log (queue + prefill + decode ~ total), the
    serve.* histograms carry p50/p95/p99, and the ledger replays the
    requests. Oversubscribed slots make queue_us real for late requests."""
    from repro.obs import ledger, metrics

    cfg, model, params = _f32_model()
    ledger.set_enabled(True)
    ledger.clear()
    metrics.reset(["serve.requests", "serve.tokens", "serve.latency_us",
                   "serve.queue_us", "serve.prefill_us", "serve.decode_us",
                   "serve.queue_depth", "serve.retune",
                   "serve.format_switch"])
    engine = DecodeEngine(model, params, slots=2, max_len=32)
    n, max_new = 5, 3
    prompts = [(i, RNG.integers(0, cfg.vocab, (4,)).astype(np.int32))
               for i in range(n)]
    done, _ = serve(engine, prompts, max_new)
    assert len(done) == n
    assert len(engine.request_log) == n
    for entry in engine.request_log:
        assert entry["tokens"] == max_new
        assert entry["queue_us"] >= 0 and entry["prefill_us"] > 0
        assert entry["decode_us"] > 0
        # phases compose into the end-to-end span (prefill is the batched
        # call's per-request share, so <= its slice of the total)
        assert entry["total_us"] >= entry["queue_us"] + entry["decode_us"]
    snap = metrics.snapshot()
    assert snap["counters"]["serve.requests"] == n
    assert snap["counters"]["serve.tokens"] == n * max_new
    lat = snap["histograms"]["serve.latency_us"]
    assert lat["count"] == n
    assert lat["p50"] is not None and lat["p50"] <= lat["p99"]
    assert snap["histograms"]["serve.queue_depth"]["max"] >= 1  # real queueing
    recs = ledger.records(kind="serve.request")
    assert sorted(r["rid"] for r in recs) == list(range(n))
    ledger.clear()


def test_retune_counters_track_switch_vs_keep():
    """serve.retune counts every re-selection; serve.format_switch only the
    ones that changed the container."""
    from repro.obs import metrics

    w = prune_magnitude(RNG.standard_normal((48, 48)).astype(np.float32), 0.2)
    layer = LinearSparse.from_dense(w, fmt=Format.COO)
    with metrics.scope() as s:
        retuned = layer.retune(ncols=64, tune="analytic")
        assert s.delta("serve.retune") == 1
        expected = 1 if retuned.format != layer.format else 0
        assert s.delta("serve.format_switch") == expected
        # retuning the retuned layer at the same width is now a no-switch
        again = retuned.retune(ncols=64, tune="analytic")
        assert s.delta("serve.retune") == 2
        assert again.format == retuned.format


def test_format_switch_between_decode_steps_parity():
    """activate() between steps (the serving-loop format switch) is
    numerically invisible: a decode-shaped loop whose sparse layer hops
    CSR -> ELL -> HYB -> COO matches the fixed-format run exactly."""
    w = prune_magnitude(RNG.standard_normal((32, 32)).astype(np.float32), 0.3)
    layer = LinearSparse.from_dense(w, fmt=Format.CSR)
    x0 = jnp.asarray(RNG.standard_normal((1, 32)).astype(np.float32))

    def roll(layers):
        x, outs = x0, []
        for L in layers:
            x = jnp.tanh(L(x))
            outs.append(np.asarray(x))
        return outs

    base = roll([layer] * 4)
    hops = [layer, layer.activate(Format.ELL), layer.activate(Format.HYB),
            layer.activate(Format.COO)]
    for step, (a, b) in enumerate(zip(base, roll(hops))):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                   err_msg=f"switch at step {step}")


def test_retune_under_decode_parity():
    """retune(ncols) — the width-aware re-selection hook — may switch the
    stored format but never the numbers."""
    w = prune_magnitude(RNG.standard_normal((48, 48)).astype(np.float32), 0.2)
    layer = LinearSparse.from_dense(w, fmt=Format.COO)
    x1 = jnp.asarray(RNG.standard_normal((1, 48)).astype(np.float32))
    x64 = jnp.asarray(RNG.standard_normal((64, 48)).astype(np.float32))
    wide = layer.retune(ncols=64, tune="analytic")
    np.testing.assert_allclose(np.asarray(layer(x1)), np.asarray(wide(x1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(layer(x64)), np.asarray(wide(x64)),
                               rtol=1e-5, atol=1e-5)
