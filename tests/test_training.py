"""Training-substrate tests: optimizer, data pipeline, checkpointing
(fault tolerance + elastic resharding), gradient compression, train loop."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.pipeline import MemmapTokens, SyntheticFrames, SyntheticLM
from repro.optim.adamw import AdamW
from repro.optim.grad_compress import compress_tree, decompress_tree, dequantize, quantize


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=100)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, state = opt.update({"w": jnp.full((3,), 1e6)}, state, params)
    # clipped first moment magnitude bounded by (1-b1)*clip
    assert float(jnp.abs(state.m["w"]).max()) <= 0.11


def test_adamw_schedule_warmup_and_decay():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=100, lr_min_ratio=0.1)
    assert float(opt.schedule(0)) < float(opt.schedule(9))
    assert abs(float(opt.schedule(10)) - 1.0) < 0.05
    assert float(opt.schedule(99)) < 0.2


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_seekable():
    src = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=7)
    b1 = src.batch_at(42)
    b2 = src.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_memmap_source(tmp_path):
    path = tmp_path / "toks.bin"
    np.arange(10000, dtype=np.int32).tofile(path)
    src = MemmapTokens(str(path), seq_len=32, global_batch=2, seed=0)
    b = src.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b["labels"], b["tokens"] + 1)


def test_frames_source():
    src = SyntheticFrames(dim=8, vocab=10, seq_len=12, global_batch=3)
    b = src.batch_at(5)
    assert b["frames"].shape == (3, 12, 8)
    assert b["labels"].min() >= 0 and b["labels"].max() < 10


# ---------------------------------------------------------------------------
# Checkpointing (fault tolerance)
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ckpt_lib.save(str(tmp_path), 5, tree)
    assert ckpt_lib.latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    out = ckpt_lib.restore(str(tmp_path), 5, like)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10.0))


def test_ckpt_partial_write_ignored(tmp_path):
    """A crash mid-write must not corrupt resume (atomic publish)."""
    tree = {"a": jnp.ones(4)}
    ckpt_lib.save(str(tmp_path), 1, tree)
    # simulate a torn step: directory without manifest
    os.makedirs(tmp_path / "step_00000002")
    assert ckpt_lib.latest_step(str(tmp_path)) == 1


def test_ckpt_checksum_detects_corruption(tmp_path):
    tree = {"a": jnp.ones(64)}
    d = ckpt_lib.save(str(tmp_path), 3, tree)
    f = os.path.join(d, "arr_00000.npy")
    with open(f, "r+b") as fh:
        fh.seek(-4, 2)
        fh.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError):
        ckpt_lib.restore(str(tmp_path), 3, tree)


def test_ckpt_cleanup(tmp_path):
    tree = {"a": jnp.ones(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt_lib.save(str(tmp_path), s, tree)
    ckpt_lib.cleanup(str(tmp_path), keep=2)
    assert ckpt_lib.latest_step(str(tmp_path)) == 5
    assert not os.path.exists(tmp_path / "step_00000001")


def test_elastic_resume_subprocess(tmp_path):
    """Checkpoint on 1 device, resume on 4 (node-failure re-mesh)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ, PYTHONPATH=src)
    r1 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "stablelm_1_6b",
         "--smoke", "--steps", "4", "--batch", "4", "--seq", "32",
         "--ckpt-dir", str(tmp_path), "--ckpt-every", "4", "--log-every", "2"],
        env=env, capture_output=True, text=True, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    env4 = dict(env, XLA_FLAGS="--xla_force_host_platform_device_count=4")
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "stablelm_1_6b",
         "--smoke", "--steps", "6", "--batch", "4", "--seq", "32",
         "--ckpt-dir", str(tmp_path), "--resume", "--log-every", "1"],
        env=env4, capture_output=True, text=True, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "restoring step 4" in r2.stdout


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)).astype(np.float32))
    q, s = quantize(g)
    deq = dequantize(q, s, g.shape, g.dtype)
    # error bounded by scale/2 per block
    err = np.abs(np.asarray(deq) - np.asarray(g))
    bound = np.repeat(np.asarray(s)[:, 0] / 2 * 1.01, 256)[:1000]
    assert (err <= bound + 1e-7).all()


def test_error_feedback_accumulates():
    """With error feedback, the quantization bias vanishes over steps."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal((512,)).astype(np.float32)) * 1e-3
    tree = {"g": g_true}
    errors = None
    total = np.zeros(512, np.float32)
    for _ in range(50):
        payload, errors = compress_tree(tree, errors)
        deq = decompress_tree(payload, tree)
        total += np.asarray(deq["g"])
    # mean transmitted ~= mean true signal (error feedback flushes residual)
    np.testing.assert_allclose(total / 50, np.asarray(g_true), atol=2e-4)
