"""HYB (ELL+COO hybrid) format — the extensibility demonstration: a new
format added without touching DynamicMatrix or the algorithm layer."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (DynamicMatrix, Format, autotune, coo_from_arrays,
                        convert, extract_diagonal, random_coo, spmm, spmv,
                        to_dense_np)


def _powerlaw_coo(seed=0, m=150, n=200):
    rng = np.random.default_rng(seed)
    rows, cols, vals = [], [], []
    for i in range(m):
        k = 1 + int(rng.pareto(1.2))
        c = rng.choice(n, size=min(k, n), replace=False)
        rows += [i] * len(c)
        cols += list(c)
        vals += list(rng.standard_normal(len(c)))
    return coo_from_arrays(rows, cols, vals, (m, n))


def test_hyb_roundtrip():
    A = _powerlaw_coo()
    D = to_dense_np(A)
    H = convert(A, Format.HYB)
    np.testing.assert_allclose(to_dense_np(H), D, rtol=1e-6, atol=1e-6)
    # back through the proxy
    np.testing.assert_allclose(to_dense_np(convert(H, Format.CSR)), D,
                               rtol=1e-6, atol=1e-6)


def test_hyb_spmv_spmm():
    A = _powerlaw_coo(1)
    D = to_dense_np(A)
    H = convert(A, Format.HYB)
    x = jnp.asarray(np.random.default_rng(2).standard_normal(200).astype(np.float32))
    np.testing.assert_allclose(np.asarray(spmv(H, x)), D @ np.asarray(x),
                               rtol=1e-4, atol=1e-4)
    B = jnp.asarray(np.random.default_rng(3).standard_normal((200, 6)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(spmm(H, B)), D @ np.asarray(B),
                               rtol=1e-4, atol=1e-4)


def test_hyb_memory_advantage():
    """HYB's point: ELL pads to the max row length; HYB bounds it at k."""
    A = _powerlaw_coo(4)
    E = convert(A, Format.ELL)
    H = convert(A, Format.HYB)
    hyb_cells = H.ell.data.size + H.coo.capacity
    assert hyb_cells < E.data.size, (hyb_cells, E.data.size)


def test_hyb_dynamic_and_jit():
    A = _powerlaw_coo(5)
    dm = DynamicMatrix(A).activate(Format.HYB)
    assert dm.active == Format.HYB
    x = jnp.ones((200,), jnp.float32)
    y = jax.jit(lambda m, v: m.spmv(v))(dm, x)
    np.testing.assert_allclose(np.asarray(y), to_dense_np(A) @ np.ones(200),
                               rtol=1e-4, atol=1e-4)


def test_hyb_explicit_k():
    A = _powerlaw_coo(6)
    H = convert(A, Format.HYB, k=3)
    assert H.k == 3
    np.testing.assert_allclose(to_dense_np(H), to_dense_np(A), rtol=1e-6, atol=1e-6)


def test_hyb_analytic_tuner_prefers_on_powerlaw():
    A = _powerlaw_coo(7)
    rep = autotune(A, mode="analytic", candidates=(Format.ELL, Format.HYB))
    assert rep.best == Format.HYB


def test_hyb_diag():
    rng = np.random.default_rng(8)
    D = np.diag(rng.standard_normal(32).astype(np.float32))
    D[0, 1:] = 1.0  # irregular first row -> overflow into COO
    from repro.core import coo_from_dense_np
    H = convert(coo_from_dense_np(D), Format.HYB, k=2)
    np.testing.assert_allclose(np.asarray(extract_diagonal(H)), np.diagonal(D),
                               rtol=1e-6)
