"""Benchmark harness (deliverable d): one family per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.

  bench_overhead   Fig. 3  dynamic-dispatch overhead vs concrete CSR
  bench_formats    Fig. 4  single-node format comparison + autotuner pick
  bench_scaling    Fig. 5  multi-shard strong scaling (4 Morpheus versions)
  bench_convert    §III-B  conversion (format-switch) amortisation
  bench_kernels    —       Pallas kernels (interpret) vs pure-jnp reference
  roofline         —       dry-run roofline table (if results are present)

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]
"""
import argparse
import sys
import time


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.core import Format, banded_coo, convert, random_coo
    from repro.core.ops import spmv as core_spmv, spmm as core_spmm
    from repro.kernels import ops as kops

    def _t(fn, *a, iters=10, warmup=2):
        for _ in range(warmup):
            jax.block_until_ready(fn(*a))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*a))
        return (time.perf_counter() - t0) / iters

    rows = []
    A = convert(banded_coo((4096, 4096), [-64, -1, 0, 1, 64]), Format.DIA)
    x = jnp.ones((4096,), jnp.float32)
    rows.append(("kernel_dia_spmv_interp", _t(lambda: kops.dia_spmv(A, x)) * 1e6,
                 f"ref_us={_t(jax.jit(lambda a, v: core_spmv(a, v)), A, x) * 1e6:.0f}"))
    Ae = convert(random_coo(0, (4096, 4096), 0.01), Format.ELL)
    rows.append(("kernel_ell_spmv_interp", _t(lambda: kops.ell_spmv(Ae, x)) * 1e6,
                 f"ref_us={_t(jax.jit(lambda a, v: core_spmv(a, v)), Ae, x) * 1e6:.0f}"))
    Ab = convert(random_coo(1, (1024, 1024), 0.1), Format.BSR, block_size=128)
    B = jnp.ones((1024, 128), jnp.float32)
    rows.append(("kernel_bsr_spmm_interp", _t(lambda: kops.bsr_spmm(Ab, B)) * 1e6,
                 f"ref_us={_t(jax.jit(lambda a, b: core_spmm(a, b)), Ab, B) * 1e6:.0f}"))
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="")
    p.add_argument("--quick", action="store_true",
                   help="smaller sizes / fewer shard counts")
    args = p.parse_args(argv)

    from benchmarks import bench_convert, bench_formats, bench_overhead, bench_scaling

    suites = {
        "overhead": lambda: bench_overhead.run(
            sizes=((8, 8, 8), (16, 16, 16)) if args.quick else
            ((8, 8, 8), (16, 16, 16), (24, 24, 24), (32, 32, 32))),
        "formats": lambda: bench_formats.run(
            sizes=((8, 8, 8), (16, 16, 16)) if args.quick else
            ((8, 8, 8), (16, 16, 16), (32, 32, 32), (48, 48, 48))),
        "convert": bench_convert.run,
        "kernels": bench_kernels,
        "scaling": lambda: bench_scaling.run((1, 2, 4) if args.quick else (1, 2, 4, 8)),
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and args.only != name:
            continue
        try:
            for r in fn():
                print(",".join(str(c) for c in r))
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{e!r}")

    # roofline table pointer (if the dry-run has produced results)
    if not args.only or args.only == "roofline":
        try:
            from benchmarks import roofline
            cells = roofline.load_cells("pod")
            if cells:
                print(f"roofline_cells_available,{len(cells)},see EXPERIMENTS.md")
        except Exception as e:  # noqa: BLE001
            print(f"roofline_FAILED,0,{e!r}")


if __name__ == "__main__":
    main()
