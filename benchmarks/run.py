"""Benchmark harness (deliverable d): one family per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and persists the perf trajectory:

  bench_overhead   Fig. 3  dynamic-dispatch overhead vs concrete CSR
  bench_formats    Fig. 4  single-node format comparison + autotuner pick
  bench_scaling    Fig. 5  multi-shard strong scaling: distributed build
                           (cold/warm) + SpMV for the 4 Morpheus versions
  bench_convert    §III-B  conversion (format-switch) amortisation
  switch           —       host-sync vs device-resident switch overhead
  bench_kernels    —       Pallas kernels (interpret) vs pure-jnp reference
  bench_select     —       selection-mode shoot-out (ml/analytic/cached/
                           profile) over the corpus families incl. the
                           power-law irregular-row regime SELL covers
  bench_hpcg       —       HPCG solves: CG vs Jacobi-PCG vs MG-PCG
                           (iterations-to-tol + wall-clock, uniform-CSR vs
                           per-level multiformat hierarchies)
  bench_obs        —       exchange/local overlap decomposition per shard
                           count (the p8 diagnostic; see repro.obs.report)
  bench_serve      —       batch-width-aware SpMM (ref vs tuned per rhs
                           width), per-width format decisions, and decode
                           tokens/s through launch.serve (BENCH_serve.json)
  roofline         —       dry-run roofline table (if results are present)

SpMV-side suites (formats/kernels/overhead) are written to
``BENCH_spmv.json``, conversion-side suites (convert/switch) to
``BENCH_convert.json``, the distributed scaling suite to
``BENCH_dist.json``, the HPCG solver suite to ``BENCH_hpcg.json`` and the
observability suite to ``BENCH_obs.json`` in ``--json-dir`` (default:
cwd). Every artifact's meta embeds ``repro.obs.env_info()`` (jax version,
backend, device kind/count, interpret mode, git rev) so numbers are
attributable to the environment that produced them. Re-runs with
``--only`` merge rows by name into the existing files instead of wiping
them, so partial runs keep the trajectory intact.

Run: PYTHONPATH=src python -m benchmarks.run [--only A,B] [--quick]
"""
import argparse
import json
import os
import sys

# Backend-gated XLA flags must land before any jax import in this process
# (the bench subprocesses run their own env.apply with forced host devices).
from repro import env as _env

_env.apply()

SPMV_SUITES = ("overhead", "formats", "kernels", "select")
CONVERT_SUITES = ("convert", "switch")
DIST_SUITES = ("scaling",)
HPCG_SUITES = ("hpcg",)
OBS_SUITES = ("obs",)
SERVE_SUITES = ("serve",)


def _emit_json(path, rows, meta):
    """Merge ``rows`` (by name) into the JSON perf artifact at ``path``."""
    doc = {"meta": {}, "rows": []}
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        pass
    by_name = {r["name"]: r for r in doc.get("rows", [])}
    for name, us, derived in rows:
        by_name[str(name)] = {"name": str(name), "us_per_call": float(us),
                              "derived": str(derived)}
    doc["meta"] = {**doc.get("meta", {}), **meta}
    doc["rows"] = sorted(by_name.values(), key=lambda r: r["name"])
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return path


def _cfg_str(cfg):
    """Compact config rendering safe for the CSV/derived field."""
    return "/".join(f"{k}{v}" for k, v in sorted((cfg or {}).items()))


def bench_kernels():
    """Pallas kernels (default cfg) vs the jnp reference, plus a
    ``kernel_tuned_*`` row per kernel: the autotuner's winner on the same
    matrix (ephemeral cache — the bench never pollutes the user's)."""
    import tempfile

    import jax
    import jax.numpy as jnp
    from repro.core import Format, banded_coo, convert, random_coo
    from repro.core.ops import spmv as core_spmv, spmm as core_spmm
    from repro.kernels import ops as kops
    from repro.tuning import SelectionCache, kernel_tune

    from repro.tuning import time_fn as _t  # one timing harness for the repo

    rows = []
    with tempfile.TemporaryDirectory() as td:
        kcache = SelectionCache(os.path.join(td, "kernels.json"))
        from benchmarks.bench_formats import powerlaw_coo

        x = jnp.ones((4096,), jnp.float32)
        suite = [
            ("dia_spmv", convert(banded_coo((4096, 4096), [-64, -1, 0, 1, 64]),
                                 Format.DIA), "spmv", x),
            ("ell_spmv", convert(random_coo(0, (4096, 4096), 0.01),
                                 Format.ELL), "spmv", x),
            ("csr_spmv", convert(random_coo(2, (4096, 4096), 0.01),
                                 Format.CSR), "spmv", x),
            ("sell_spmv", convert(powerlaw_coo(7, 4096), Format.SELL),
             "spmv", x),
            ("bsr_spmm", convert(random_coo(1, (1024, 1024), 0.1), Format.BSR,
                                 block_size=128), "spmm",
             jnp.ones((1024, 128), jnp.float32)),
        ]
        for name, A, op, operand in suite:
            if op == "spmv":
                ref_fn = jax.jit(lambda a, v: core_spmv(a, v))
                kern_fn = kops.SPMV_PALLAS[type(A)]
            else:
                ref_fn = jax.jit(lambda a, b: core_spmm(a, b))
                kern_fn = kops.SPMM_PALLAS[type(A)]
            t_ref = _t(ref_fn, A, operand)
            t_kern = _t(lambda: kern_fn(A, operand))
            rows.append((f"kernel_{name}_interp", t_kern * 1e6,
                         f"ref_us={t_ref * 1e6:.0f};"
                         f"speedup_vs_ref={t_ref / t_kern:.2f}"))
            rec = kernel_tune.tune_kernel(A, operand, op=op, cache=kcache,
                                          iters=5, inner=2)
            t_tuned = _t(lambda: kern_fn(A, operand, cfg=rec.cfg))
            rows.append((f"kernel_tuned_{name}", t_tuned * 1e6,
                         f"cfg={_cfg_str(rec.cfg)};ref_us={t_ref * 1e6:.0f};"
                         f"speedup_vs_ref={t_ref / t_tuned:.2f}"))
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="",
                   help="comma-separated suite names (default: all)")
    p.add_argument("--quick", action="store_true",
                   help="smaller sizes / fewer shard counts")
    p.add_argument("--json-dir", default=".",
                   help="where BENCH_spmv.json / BENCH_convert.json land")
    args = p.parse_args(argv)
    only = tuple(s for s in args.only.split(",") if s)

    from benchmarks import (bench_convert, bench_formats, bench_hpcg,
                            bench_obs, bench_overhead, bench_scaling,
                            bench_select, bench_serve)

    suites = {
        "overhead": lambda: bench_overhead.run(
            sizes=((8, 8, 8), (16, 16, 16)) if args.quick else
            ((8, 8, 8), (16, 16, 16), (24, 24, 24), (32, 32, 32))),
        "formats": lambda: bench_formats.run(
            sizes=((8, 8, 8), (16, 16, 16)) if args.quick else
            ((8, 8, 8), (16, 16, 16), (32, 32, 32), (48, 48, 48)),
            pow_sizes=(1024,) if args.quick else (4096,)),
        "convert": bench_convert.run,
        "switch": lambda: bench_overhead.run_switch(
            sizes=((8, 8, 8), (16, 16, 16)) if args.quick else
            ((8, 8, 8), (16, 16, 16), (24, 24, 24))),
        "kernels": bench_kernels,
        "select": lambda: bench_select.run(
            samples=6, iters=4) if args.quick else bench_select.run(),
        "scaling": lambda: bench_scaling.run(
            (1, 2, 4, 8), grid=(8, 8, 16), iters=10,
            restart_shards=(4,)) if args.quick else
            bench_scaling.run((1, 2, 4, 8, 16, 32)),
        "hpcg": lambda: bench_hpcg.run(
            grids=((8, 8, 8),), iters=1) if args.quick else
            bench_hpcg.run(),
        "obs": lambda: bench_obs.run(
            (1, 2, 4), grid=(8, 8, 16), iters=10,
            attempts=1) if args.quick else
            bench_obs.run((1, 2, 4, 8, 16, 32)),
        "serve": lambda: bench_serve.run(
            widths=(1, 8) if args.quick else (1, 8, 64, 256),
            quick=args.quick),
    }
    results = {}
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        try:
            results[name] = fn()
            for r in results[name]:
                print(",".join(str(c) for c in r))
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{e!r}")

    import jax
    from repro.obs import env_info
    meta = {"backend": jax.default_backend(), "quick": bool(args.quick),
            "env": env_info()}
    spmv_rows = [r for s in SPMV_SUITES for r in results.get(s, ())]
    convert_rows = [r for s in CONVERT_SUITES for r in results.get(s, ())]
    dist_rows = [r for s in DIST_SUITES for r in results.get(s, ())]
    hpcg_rows = [r for s in HPCG_SUITES for r in results.get(s, ())]
    obs_rows = [r for s in OBS_SUITES for r in results.get(s, ())]
    serve_rows = [r for s in SERVE_SUITES for r in results.get(s, ())]
    if spmv_rows:
        print("wrote", _emit_json(os.path.join(args.json_dir, "BENCH_spmv.json"),
                                  spmv_rows, meta))
    if convert_rows:
        print("wrote", _emit_json(os.path.join(args.json_dir, "BENCH_convert.json"),
                                  convert_rows, meta))
    if dist_rows:
        print("wrote", _emit_json(os.path.join(args.json_dir, "BENCH_dist.json"),
                                  dist_rows, meta))
    if hpcg_rows:
        print("wrote", _emit_json(os.path.join(args.json_dir, "BENCH_hpcg.json"),
                                  hpcg_rows, meta))
    if obs_rows:
        print("wrote", _emit_json(os.path.join(args.json_dir, "BENCH_obs.json"),
                                  obs_rows, meta))
    if serve_rows:
        print("wrote", _emit_json(os.path.join(args.json_dir, "BENCH_serve.json"),
                                  serve_rows, meta))

    # every invocation extends the perf trajectory: one JSONL entry per
    # artifact with just the rows THIS run measured (regress renders the
    # table; `python -m repro.obs.regress` gates against the baseline).
    from repro.obs import regress as _regress
    history_dir = os.path.join(args.json_dir, _regress.DEFAULT_HISTORY)
    for artifact, arows in (("BENCH_spmv", spmv_rows),
                            ("BENCH_convert", convert_rows),
                            ("BENCH_dist", dist_rows),
                            ("BENCH_hpcg", hpcg_rows),
                            ("BENCH_obs", obs_rows),
                            ("BENCH_serve", serve_rows)):
        if arows:
            _regress.append_history(artifact, arows, meta,
                                    history_dir=history_dir)

    # roofline table pointer (if the dry-run has produced results)
    if not only or "roofline" in only:
        try:
            from benchmarks import roofline
            cells = roofline.load_cells("pod")
            if cells:
                print(f"roofline_cells_available,{len(cells)},see EXPERIMENTS.md")
        except Exception as e:  # noqa: BLE001
            print(f"roofline_FAILED,0,{e!r}")


if __name__ == "__main__":
    main()
