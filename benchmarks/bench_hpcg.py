"""HPCG solver suite: CG vs Jacobi-PCG vs MG-PCG (repro.mg).

The paper benchmarks HPCG with the preconditioner disabled (§IV-B) — the
reference SymGS sweep is sequential. ``repro.mg`` restores the multigrid
preconditioner with a multicolored (vector-parallel) SymGS smoother, so
this suite measures what that buys: iterations-to-tolerance and
wall-clock per solve for

  hpcg_cg_*             unpreconditioned CG (the paper's configuration)
  hpcg_pcg_jacobi_*     Jacobi (diag) PCG — the historical stand-in
  hpcg_pcg_mg_csr_*     MG-PCG, every level/color block uniform CSR
  hpcg_pcg_mg_multi_*   MG-PCG, per-level formats via FormatPolicy("ml")

plus ``hpcg_mg_build_*`` (hierarchy construction, cold). Rows land in
``BENCH_hpcg.json`` via ``python -m benchmarks.run --only hpcg``.
"""
from __future__ import annotations


def run(grids=((8, 8, 8), (16, 16, 16)), tol: float = 1e-8,
        maxiter: int = 400, iters: int = 3):
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Format, convert, extract_diagonal, hpcg, spmv
    from repro.core.solvers import cg, pcg
    from repro.mg import build_hierarchy
    from repro.tuning import FormatPolicy, time_fn

    rows = []
    for grid in grids:
        tag = "x".join(map(str, grid))
        prob = hpcg.generate_problem(*grid)
        A = convert(hpcg.to_coo(prob), Format.CSR)
        b = jnp.asarray(hpcg.rhs_for_ones(prob))
        apply_A = lambda v: spmv(A, v, backend="auto")  # noqa: E731
        diag = extract_diagonal(A)

        t0 = time.perf_counter()
        hier_csr = build_hierarchy(prob, fmt=Format.CSR)
        build_s = time.perf_counter() - t0
        rows.append((f"hpcg_mg_build_{tag}", build_s * 1e6,
                     f"levels={hier_csr.nlevels}"))
        hier_multi = build_hierarchy(prob, policy=FormatPolicy("ml"))
        lv_fmts = ">".join(r["A"] for r in hier_multi.formats())

        solvers = {
            "cg": jax.jit(lambda bb: cg(apply_A, bb, tol=tol,
                                        maxiter=maxiter)),
            "pcg_jacobi": jax.jit(lambda bb: pcg(apply_A, bb, diag, tol=tol,
                                                 maxiter=maxiter)),
            "pcg_mg_csr": jax.jit(lambda bb: pcg(
                apply_A, bb, tol=tol, maxiter=maxiter,
                apply_M=hier_csr.apply_M())),
            "pcg_mg_multi": jax.jit(lambda bb: pcg(
                apply_A, bb, tol=tol, maxiter=maxiter,
                apply_M=hier_multi.apply_M())),
        }
        for name, solve in solvers.items():
            res = jax.block_until_ready(solve(b))  # compile + warm
            t = time_fn(solve, b, iters=iters, warmup=0)
            k = int(res.iters)
            err = float(np.abs(np.asarray(res.x) - 1.0).max())
            derived = f"iters={k};max_err={err:.1e}"
            if name == "pcg_mg_multi":
                derived += f";levels={lv_fmts}"
            rows.append((f"hpcg_{name}_{tag}", t * 1e6, derived))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(",".join(str(c) for c in r))
