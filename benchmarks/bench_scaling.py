"""Paper Fig. 5: multi-shard scaling of the distributed build and SpMV.

Strong scaling (fixed global problem) over 1..32 simulated shards, for the
paper's versions: reference (CSR/CSR), Morpheus (DIA local / CSR remote),
Ghost (CSR local / COO remote) and Multi-Format (per-shard selection via
the cached policy — the production restart path). Three axes per shard
count:

  * ``scaling_build_*``   wall time of ``build_dist_matrix`` in multiformat
    mode — cold (first build: partition plan + switch plans + jit traces)
    and warm (rebuild with the DistPlan's memoised format plans and a hot
    jit cache: the device work only), plus ``ktune``: the once-per-problem
    kernel-config tuning pass on shard 0's containers (records are
    shape-bucketed, so one tune covers every shard). The batched
    partition/convert/select pipeline makes the warm rebuild ~flat in P,
    where the pre-plan host loop grew linearly.
  * ``scaling_spmv_*``    per-call distributed SpMV time for each version;
    the derived column reports the speedup over the uniform-CSR reference.
    The reference is built ``split=False`` and pinned ``backend="ref"`` —
    the paper's baseline issues the exchange against the whole local block
    with nothing reordered and reference kernels only — while the
    optimized versions run the interior/boundary split schedule with
    ``backend="auto"`` routing from the tuned records.
  * ``scaling_restart_first_spmv_*``  restart-to-first-SpMV: a *fresh*
    process whose ``build_dist_matrix(plan_cache=...)`` finds the
    persisted DistPlan (partition caps, split caps, per-candidate
    SwitchPlans) on disk and skips planning entirely, against an
    identical fresh process that re-plans from the triplets.

Subprocess environments are set up by ``repro.env.apply`` (backend-gated
XLA flags, forced host device count) so each shard count gets its own
device view.
"""
import json
import os
import subprocess
import sys
import tempfile

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = """
import os, sys, tempfile
sys.path.insert(0, %(src)r)
from repro import env
env.apply(host_devices=%(ndev)d)
os.environ.setdefault("REPRO_TUNING_CACHE",
                      os.path.join(tempfile.mkdtemp(), "selections.json"))
import time, json
import jax, jax.numpy as jnp, numpy as np
from repro.core import Format, hpcg
from repro.core.distributed import build_dist_matrix, dist_spmv, distribute_vector
from repro.tuning.cache import SelectionCache

mesh = jax.make_mesh((%(ndev)d,), ("rows",))
prob = hpcg.generate_problem(*%(grid)r)
x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
out = {"spmv": {}, "build": {}}
cache = SelectionCache()

build = lambda **kw: build_dist_matrix(prob.row, prob.col, prob.val,
                                       prob.shape, mesh, "rows", **kw)
t0 = time.perf_counter()
A = build(mode="multiformat", tune="cached", plan_cache=cache)
out["build"]["cold"] = time.perf_counter() - t0
t0 = time.perf_counter()
A = build(mode="multiformat", tune="cached", plan=A.plan)
out["build"]["warm"] = time.perf_counter() - t0

# Problem optimization, kernel layer (PR 4): measure the Pallas-vs-ref
# decision once per (format, shape bucket) on shard 0's containers —
# records are bucketed, so one tune covers every same-sized shard, and
# dist_spmv's backend="auto" then routes from measurement instead of
# defaulting to ref. The split interior/boundary containers sit in their
# own (smaller-cap) buckets, which is why the slices are tuned directly
# rather than a synthetic whole-slab block. The reference version never
# reads these records: it is pinned backend="ref" below, the paper's
# untouched baseline.
from repro.core import convert
from repro.tuning import kernel_tune
ghost0 = build(local_format=Format.CSR, remote_format=Format.COO)
xb = jnp.ones((ghost0.plan.mp,), jnp.float32)
t0 = time.perf_counter()
parts = (ghost0.local, ghost0.boundary) if ghost0.split else (ghost0.local,)
for part in parts:
    s0 = jax.tree_util.tree_map(lambda l: l[0], part)
    for fmt in (Format.CSR, Format.DIA, Format.ELL):
        blk = convert(s0, fmt) if Format(s0.format) != fmt else s0
        kernel_tune.tune_kernel(blk, xb, cache=cache, iters=3, inner=2)
out["build"]["ktune"] = time.perf_counter() - t0

for name, backend, kw in [
    # reference = the paper's non-overlapped baseline: whole local block,
    # no interior/boundary reordering, reference kernels only
    ("reference", "ref", dict(local_format=Format.CSR,
                              remote_format=Format.CSR, split=False)),
    ("morpheus", "auto", dict(local_format=Format.DIA,
                              remote_format=Format.CSR)),
    ("ghost", "auto", dict(local_format=Format.CSR,
                           remote_format=Format.COO)),
    ("multiformat", "auto", dict(mode="multiformat", tune="cached")),
]:
    A = build(**kw)
    f = jax.jit(lambda a, v, b=backend: dist_spmv(a, v, mesh, backend=b))
    jax.block_until_ready(f(A, x))
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(%(iters)d):
            jax.block_until_ready(f(A, x))
        best = min(best, (time.perf_counter() - t0) / %(iters)d)
    out["spmv"][name] = best
print("RESULT " + json.dumps(out))
"""

# Restart-to-first-SpMV: a fresh process, optionally finding the DistPlan
# persisted by a previous run in the shared SelectionCache store.
RESTART_SCRIPT = """
import os, sys
sys.path.insert(0, %(src)r)
from repro import env
env.apply(host_devices=%(ndev)d)
import time, json
import jax, numpy as np
from repro.core import hpcg
from repro.core.distributed import build_dist_matrix, dist_spmv, distribute_vector
from repro.obs import metrics
from repro.tuning.cache import SelectionCache

mesh = jax.make_mesh((%(ndev)d,), ("rows",))
prob = hpcg.generate_problem(*%(grid)r)
x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
kw = dict(mode="multiformat", tune="cached")
if %(use_cache)d:
    kw["plan_cache"] = SelectionCache()
with metrics.scope() as s:
    t0 = time.perf_counter()
    A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                          "rows", **kw)
    t1 = time.perf_counter()
    jax.block_until_ready(dist_spmv(A, x, mesh))
    t2 = time.perf_counter()
    hit = s.delta("distplan.cache_hit")
print("RESULT " + json.dumps({"build": t1 - t0, "spmv": t2 - t1,
                              "total": t2 - t0, "plan_cache_hit": int(hit)}))
"""


def _run(script: str, timeout: int = 1800, env_extra=None):
    env = dict(os.environ)
    if env_extra:
        env.update(env_extra)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")]
    if not line:
        return None, res.stderr[-200:]
    return json.loads(line[0][len("RESULT "):]), None


def _restart_rows(ndev, grid, src):
    """Two fresh processes sharing one on-disk cache: the first warms it,
    the timed pair then measures restart with vs. without the persisted
    plan (both pay identical jit-compile costs — only planning differs)."""
    rows = []
    with tempfile.TemporaryDirectory() as td:
        env_extra = {"REPRO_TUNING_CACHE": os.path.join(td, "selections.json")}
        warm = SCRIPT % {"ndev": ndev, "src": src, "grid": tuple(grid),
                         "iters": 1}
        out, err = _run(warm, env_extra=env_extra)
        if out is None:
            return [(f"scaling_restart_p{ndev}_FAILED", 0.0, err)]
        cached, err = _run(RESTART_SCRIPT % {
            "ndev": ndev, "src": src, "grid": tuple(grid), "use_cache": 1},
            env_extra=env_extra)
        replan, err2 = _run(RESTART_SCRIPT % {
            "ndev": ndev, "src": src, "grid": tuple(grid), "use_cache": 0},
            env_extra=env_extra)
        if cached is None or replan is None:
            return [(f"scaling_restart_p{ndev}_FAILED", 0.0,
                     (err or err2 or "")[-200:])]
        rows.append((
            f"scaling_restart_first_spmv_p{ndev}", cached["total"] * 1e6,
            f"build_us={cached['build'] * 1e6:.0f};"
            f"spmv_us={cached['spmv'] * 1e6:.0f};"
            f"plan_cache_hit={cached['plan_cache_hit']};"
            f"replan_total_us={replan['total'] * 1e6:.0f};"
            f"replan_build_us={replan['build'] * 1e6:.0f};"
            f"speedup_vs_replan={replan['total'] / max(cached['total'], 1e-9):.2f}"))
    return rows


def run(shards=(1, 2, 4, 8, 16, 32), grid=(16, 16, 32), iters=20,
        restart_shards=(8,)):
    src = os.path.abspath(SRC)
    rows = []
    for ndev in shards:
        script = SCRIPT % {"ndev": ndev, "src": src,
                           "grid": tuple(grid), "iters": iters}
        out, err = _run(script)
        if out is None:
            rows.append((f"scaling_p{ndev}_FAILED", 0.0, err))
            continue
        for phase, t in out["build"].items():
            rows.append((f"scaling_build_{phase}_p{ndev}", t * 1e6,
                         f"per_shard_us={t * 1e6 / ndev:.0f}"))
        ref = out["spmv"]["reference"]
        for name, t in out["spmv"].items():
            rows.append((f"scaling_spmv_{name}_p{ndev}", t * 1e6,
                         f"speedup_vs_ref={ref / t:.2f}"))
    for ndev in restart_shards:
        if ndev in shards:
            rows.extend(_restart_rows(ndev, grid, src))
    if rows and all(name.endswith("_FAILED") for name, _, _ in rows):
        # every shard count crashed: a *_FAILED-only artifact must not keep
        # CI green — surface the last stderr snippet instead
        raise RuntimeError(f"bench_scaling: all shard counts failed; "
                           f"last: {rows[-1]}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
