"""Paper Fig. 5: multi-shard scaling of the distributed SpMV.

Strong scaling (fixed global problem) over 1..8 simulated shards, for the
paper's versions: reference (CSR/CSR), Morpheus (DIA local / CSR remote),
Ghost (CSR local / COO remote) and Multi-Format (per-shard auto-tuned).
Runs in subprocesses so each shard count gets its own device view.
"""
import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
import sys, time, json
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp, numpy as np
from repro.core import Format, hpcg
from repro.core.distributed import build_dist_matrix, dist_spmv, distribute_vector

mesh = jax.make_mesh((%(ndev)d,), ("rows",))
prob = hpcg.generate_problem(16, 16, 32)
x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
out = {}
for name, kw in [
    ("reference", dict(local_format=Format.CSR, remote_format=Format.CSR)),
    ("morpheus", dict(local_format=Format.DIA, remote_format=Format.CSR)),
    ("ghost", dict(local_format=Format.CSR, remote_format=Format.COO)),
    ("multiformat", dict(mode="multiformat")),
]:
    A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                          "rows", **kw)
    f = jax.jit(lambda a, v: dist_spmv(a, v, mesh))
    jax.block_until_ready(f(A, x))
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(f(A, x))
    out[name] = (time.perf_counter() - t0) / 20
print("RESULT " + json.dumps(out))
"""


def run(shards=(1, 2, 4, 8)):
    rows = []
    for ndev in shards:
        script = SCRIPT % {"ndev": ndev, "src": os.path.abspath(SRC)}
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=900)
        line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")]
        if not line:
            rows.append((f"scaling_p{ndev}_FAILED", 0.0, res.stderr[-200:]))
            continue
        times = json.loads(line[0][len("RESULT "):])
        ref = times["reference"]
        for name, t in times.items():
            rows.append((f"scaling_{name}_p{ndev}", t * 1e6,
                         f"speedup_vs_ref={ref / t:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
