"""Paper Fig. 5: multi-shard scaling of the distributed build and SpMV.

Strong scaling (fixed global problem) over 1..8 simulated shards, for the
paper's versions: reference (CSR/CSR), Morpheus (DIA local / CSR remote),
Ghost (CSR local / COO remote) and Multi-Format (per-shard selection via
the cached policy — the production restart path). Two axes per shard count:

  * ``scaling_build_*``   wall time of ``build_dist_matrix`` in multiformat
    mode — cold (first build: partition plan + switch plans + jit traces)
    and warm (rebuild with the DistPlan's memoised format plans and a hot
    jit cache: the device work only). The batched partition/convert/select
    pipeline makes the warm rebuild ~flat in P, where the pre-plan host
    loop grew linearly.
  * ``scaling_spmv_*``    per-call distributed SpMV time for each version;
    the derived column reports the speedup over the uniform-CSR reference.

Runs in subprocesses so each shard count gets its own forced device view.
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
os.environ.setdefault("REPRO_TUNING_CACHE",
                      os.path.join(tempfile.mkdtemp(), "selections.json"))
import sys, time, json
sys.path.insert(0, %(src)r)
import jax, jax.numpy as jnp, numpy as np
from repro.core import Format, hpcg
from repro.core.distributed import build_dist_matrix, dist_spmv, distribute_vector

mesh = jax.make_mesh((%(ndev)d,), ("rows",))
prob = hpcg.generate_problem(*%(grid)r)
x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
out = {"spmv": {}, "build": {}}

build = lambda **kw: build_dist_matrix(prob.row, prob.col, prob.val,
                                       prob.shape, mesh, "rows", **kw)
t0 = time.perf_counter()
A = build(mode="multiformat", tune="cached")
out["build"]["cold"] = time.perf_counter() - t0
t0 = time.perf_counter()
A = build(mode="multiformat", tune="cached", plan=A.plan)
out["build"]["warm"] = time.perf_counter() - t0

for name, kw in [
    ("reference", dict(local_format=Format.CSR, remote_format=Format.CSR)),
    ("morpheus", dict(local_format=Format.DIA, remote_format=Format.CSR)),
    ("ghost", dict(local_format=Format.CSR, remote_format=Format.COO)),
    ("multiformat", dict(mode="multiformat", tune="cached")),
]:
    A = build(**kw)
    f = jax.jit(lambda a, v: dist_spmv(a, v, mesh))
    jax.block_until_ready(f(A, x))
    t0 = time.perf_counter()
    for _ in range(%(iters)d):
        jax.block_until_ready(f(A, x))
    out["spmv"][name] = (time.perf_counter() - t0) / %(iters)d
print("RESULT " + json.dumps(out))
"""


def run(shards=(1, 2, 4, 8), grid=(16, 16, 32), iters=20):
    rows = []
    for ndev in shards:
        script = SCRIPT % {"ndev": ndev, "src": os.path.abspath(SRC),
                           "grid": tuple(grid), "iters": iters}
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=900)
        line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")]
        if not line:
            rows.append((f"scaling_p{ndev}_FAILED", 0.0, res.stderr[-200:]))
            continue
        out = json.loads(line[0][len("RESULT "):])
        for phase, t in out["build"].items():
            rows.append((f"scaling_build_{phase}_p{ndev}", t * 1e6,
                         f"per_shard_us={t * 1e6 / ndev:.0f}"))
        ref = out["spmv"]["reference"]
        for name, t in out["spmv"].items():
            rows.append((f"scaling_spmv_{name}_p{ndev}", t * 1e6,
                         f"speedup_vs_ref={ref / t:.2f}"))
    if rows and all(name.endswith("_FAILED") for name, _, _ in rows):
        # every shard count crashed: a *_FAILED-only artifact must not keep
        # CI green — surface the last stderr snippet instead
        raise RuntimeError(f"bench_scaling: all shard counts failed; "
                           f"last: {rows[-1]}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
