"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results/dryrun.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments_md
Writes the tables to results/generated_tables.md for inclusion.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import roofline as rl

OUT = os.path.join(os.path.dirname(__file__), "..", "results", "generated_tables.md")


def dryrun_table(mesh: str) -> str:
    rows = ["| arch | shape | status | HBM GiB/dev | lower s | compile s | "
            "reported GFLOP/dev | collective GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(rl.RESULTS_DIR, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh or rec.get("tag"):
            continue
        if rec.get("skip"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | SKIP: {rec['skip'][:48]} "
                        "| - | - | - | - | - |")
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | compiled "
            f"| {rec['bytes_per_device'] / 2**30:.1f} "
            f"| {rec.get('lower_s', 0):.0f} | {rec.get('compile_s', 0):.0f} "
            f"| {rec['cost_reported']['flops'] / 1e9:.0f} "
            f"| {rec['collectives_reported'].get('total', 0) / 2**30:.2f} |")
    return "\n".join(rows)


def main():
    parts = ["## Generated tables (benchmarks/make_experiments_md.py)\n"]
    parts.append("### Dry-run, single pod (16x16 = 256 chips)\n")
    parts.append(dryrun_table("pod"))
    parts.append("\n### Dry-run, multi-pod (2x16x16 = 512 chips)\n")
    parts.append(dryrun_table("multipod"))
    parts.append("\n### Roofline (single pod, corrected costs)\n")
    parts.append(rl.table("pod"))
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        f.write("\n".join(parts) + "\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
