"""Generate EXPERIMENTS.md §Dry-run + §Roofline + §Distributed tables.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments_md
Reads results/dryrun (roofline), BENCH_dist.json (the ``scaling`` suite of
benchmarks/run.py), BENCH_hpcg.json (the ``hpcg`` solver suite) and
BENCH_obs.json (the ``obs`` overlap-decomposition suite); writes the
tables to results/generated_tables.md for inclusion.
"""
from __future__ import annotations

import glob
import json
import os
import re

from benchmarks import roofline as rl

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT = os.path.join(ROOT, "results", "generated_tables.md")


def dryrun_table(mesh: str) -> str:
    rows = ["| arch | shape | status | HBM GiB/dev | lower s | compile s | "
            "reported GFLOP/dev | collective GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for path in sorted(glob.glob(os.path.join(rl.RESULTS_DIR, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh or rec.get("tag"):
            continue
        if rec.get("skip"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | SKIP: {rec['skip'][:48]} "
                        "| - | - | - | - | - |")
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | compiled "
            f"| {rec['bytes_per_device'] / 2**30:.1f} "
            f"| {rec.get('lower_s', 0):.0f} | {rec.get('compile_s', 0):.0f} "
            f"| {rec['cost_reported']['flops'] / 1e9:.0f} "
            f"| {rec['collectives_reported'].get('total', 0) / 2**30:.2f} |")
    return "\n".join(rows)


def dist_table() -> str:
    """Pivot BENCH_dist.json's scaling rows: metric x shard count."""
    path = os.path.join(ROOT, "BENCH_dist.json")
    try:
        rows = json.load(open(path)).get("rows", [])
    except (OSError, ValueError):
        return "_no BENCH_dist.json — run `python -m benchmarks.run --only scaling`_"
    cells = {}  # metric -> {P: (us, derived)}
    for r in rows:
        m = re.fullmatch(r"scaling_(.+)_p(\d+)", r["name"])
        if not m:
            continue
        cells.setdefault(m.group(1), {})[int(m.group(2))] = (
            r["us_per_call"], r.get("derived", ""))
    if not cells:
        return "_BENCH_dist.json holds no scaling rows_"
    shards = sorted({p for v in cells.values() for p in v})
    out = ["| metric (µs) | " + " | ".join(f"P={p}" for p in shards) + " |",
           "|---|" + "---|" * len(shards)]
    for metric in sorted(cells):
        vals = []
        for p in shards:
            us, derived = cells[metric].get(p, (None, ""))
            vals.append("-" if us is None else
                        f"{us:.0f}" + (f" ({derived})" if derived else ""))
        out.append(f"| {metric} | " + " | ".join(vals) + " |")
    return "\n".join(out)


def hpcg_table() -> str:
    """Pivot BENCH_hpcg.json's solver rows: solver x grid."""
    path = os.path.join(ROOT, "BENCH_hpcg.json")
    try:
        rows = json.load(open(path)).get("rows", [])
    except (OSError, ValueError):
        return "_no BENCH_hpcg.json — run `python -m benchmarks.run --only hpcg`_"
    cells = {}  # solver -> {grid: (ms, derived)}
    for r in rows:
        m = re.fullmatch(r"hpcg_(.+?)_(\d+x\d+x\d+)", r["name"])
        if not m:
            continue
        cells.setdefault(m.group(1), {})[m.group(2)] = (
            r["us_per_call"] / 1e3, r.get("derived", ""))
    if not cells:
        return "_BENCH_hpcg.json holds no hpcg rows_"
    grids = sorted({g for v in cells.values() for g in v},
                   key=lambda g: [int(d) for d in g.split("x")])
    out = ["| solver (ms) | " + " | ".join(grids) + " |",
           "|---|" + "---|" * len(grids)]
    for solver in sorted(cells):
        vals = []
        for g in grids:
            ms, derived = cells[solver].get(g, (None, ""))
            vals.append("-" if ms is None else
                        f"{ms:.1f}" + (f" ({derived})" if derived else ""))
        out.append(f"| {solver} | " + " | ".join(vals) + " |")
    return "\n".join(out)


def powerlaw_table() -> str:
    """Pivot BENCH_spmv.json's power-law family: contender x matrix size.

    The SELL-C-sigma scoreboard: per-format SpMV, the tuned Pallas
    head-to-head, and the ``format_best_pow*`` auto-route pick."""
    path = os.path.join(ROOT, "BENCH_spmv.json")
    try:
        rows = json.load(open(path)).get("rows", [])
    except (OSError, ValueError):
        return "_no BENCH_spmv.json — run `python -m benchmarks.run --only formats`_"
    cells = {}  # contender -> {n: (us, derived)}
    for r in rows:
        m = re.fullmatch(r"(format|kernel_tuned)_(\w+?)_pow(\d+)", r["name"])
        if not m:
            continue
        label = (m.group(2) if m.group(1) == "format"
                 else f"{m.group(2)} (Pallas, tuned)")
        cells.setdefault(label, {})[int(m.group(3))] = (
            r["us_per_call"], r.get("derived", ""))
    if not cells:
        return ("_BENCH_spmv.json holds no *_pow rows — run "
                "`python -m benchmarks.run --only formats`_")
    sizes = sorted({n for v in cells.values() for n in v})
    out = ["| contender (µs) | " + " | ".join(f"n={n}" for n in sizes) + " |",
           "|---|" + "---|" * len(sizes)]
    for label in sorted(cells):
        vals = []
        for n in sizes:
            us, derived = cells[label].get(n, (None, ""))
            vals.append("-" if us is None else
                        f"{us:.0f}" + (f" ({derived})" if derived else ""))
        out.append(f"| {label} | " + " | ".join(vals) + " |")
    return "\n".join(out)


def obs_table() -> str:
    """Render BENCH_obs.json's overlap decomposition via repro.obs.report."""
    path = os.path.join(ROOT, "BENCH_obs.json")
    try:
        doc = json.load(open(path))
    except (OSError, ValueError):
        return "_no BENCH_obs.json — run `python -m benchmarks.run --only obs`_"
    from repro.obs import report
    rows = report.overlap_rows(doc)
    if not rows:
        return "_BENCH_obs.json holds no obs_overlap rows_"
    out = ["| version | P | local µs | exch µs | sum µs | full µs | "
           "hidden µs | hidden frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        loc, exc, full = r.get("local_us", 0.0), r.get("exch_us", 0.0), r["full_us"]
        if "hidden_frac" in r:  # absent at P=1 (remote part statically empty)
            hidden = loc + exc - full
            denom = min(loc, exc) or 1.0
            hid, frac = f"{hidden:.0f}", f"{max(0.0, hidden) / denom:.0%}"
        else:
            hid = frac = "-"
        out.append(f"| {r['version']} | {r['p']} | {loc:.0f} | {exc:.0f} "
                   f"| {loc + exc:.0f} | {full:.0f} | {hid} | {frac} |")
    out.append("")
    out.append(
        "`hidden = local + exchange - full` is the wall time XLA's scheduler "
        "overlapped when both phases run together; the fraction normalizes "
        "by `min(local, exchange)` (the most that pair could ever hide). "
        "A fraction near 100% means the halo exchange is fully hidden "
        "behind local compute; near 0% means the phases serialized — the "
        "shard count where the fraction collapses is where the ghost-mode "
        "p8 regression (`scaling_spmv_ghost_p8`) comes from. Produced by "
        "`benchmarks/bench_obs.py` via `dist_spmv_phase`; render from the "
        "artifact with `python -m repro.obs.report --bench BENCH_obs.json`.")
    return "\n".join(out)


def trajectory_table(max_runs: int = 8, max_rows: int = 12) -> str:
    """Perf-over-time pivot of results/history/trajectory.jsonl: one column
    per recorded run (newest ``max_runs``), one row per headline bench row.

    Headline = the rows the regression gate watches hardest: speedup-vs-ref
    and tokens/s rows. Values are the comparable metric ``repro.obs.regress``
    classifies each row into, so a column-to-column drift here is exactly
    what the gate would flag."""
    from repro.obs import regress

    entries = regress.load_history(os.path.join(ROOT, regress.DEFAULT_HISTORY))
    if not entries:
        return ("_no results/history/trajectory.jsonl — every "
                "`python -m benchmarks.run` invocation appends to it_")
    # group entries into runs by timestamp (one run writes several artifacts
    # within the same invocation; the ts string is per-artifact but close —
    # use (ts minute, git_rev) as the run key, newest last)
    runs: dict = {}
    for e in entries:
        key = (e.get("ts", "")[:16], e.get("git_rev"))
        run = runs.setdefault(key, {"ts": e.get("ts", ""), "rows": {}})
        for r in e.get("rows", []):
            cls, v = regress.classify(r)
            if cls in ("speedup", "throughput"):
                run["rows"][r["name"]] = (cls, v)
    keys = sorted(runs)[-max_runs:]
    names = sorted({n for k in keys for n in runs[k]["rows"]})[:max_rows]
    if not names:
        return "_trajectory.jsonl holds no speedup/throughput rows yet_"
    heads = [runs[k]["ts"][5:16].replace("T", " ") or "?" for k in keys]
    out = ["| row | " + " | ".join(heads) + " |",
           "|---|" + "---|" * len(keys)]
    for name in names:
        vals = []
        for k in keys:
            cv = runs[k]["rows"].get(name)
            vals.append("-" if cv is None else
                        (f"{cv[1]:.2f}x" if cv[0] == "speedup"
                         else f"{cv[1]:.0f} tok/s"))
        out.append(f"| `{name}` | " + " | ".join(vals) + " |")
    out.append("")
    out.append(f"Newest {len(keys)} recorded runs; speedup rows are "
               "vs-reference ratios, throughput rows tokens/s. Gate any "
               "run against the blessed baseline with "
               "`python -m repro.obs.regress`.")
    return "\n".join(out)


def main():
    parts = ["## Generated tables (benchmarks/make_experiments_md.py)\n"]
    parts.append("### Dry-run, single pod (16x16 = 256 chips)\n")
    parts.append(dryrun_table("pod"))
    parts.append("\n### Dry-run, multi-pod (2x16x16 = 512 chips)\n")
    parts.append(dryrun_table("multipod"))
    parts.append("\n### Roofline (single pod, corrected costs)\n")
    parts.append(rl.table("pod"))
    parts.append("\n### Distributed scaling (BENCH_dist.json, forced host devices)\n")
    parts.append(dist_table())
    parts.append("\n### HPCG solvers: CG vs Jacobi-PCG vs MG-PCG (BENCH_hpcg.json)\n")
    parts.append(hpcg_table())
    parts.append("\n### Power-law rows: the SELL-C-σ family (BENCH_spmv.json)\n")
    parts.append(powerlaw_table())
    parts.append("\n### Exchange/compute overlap per shard count (BENCH_obs.json)\n")
    parts.append(obs_table())
    parts.append("\n### Perf trajectory (results/history/trajectory.jsonl)\n")
    parts.append(trajectory_table())
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        f.write("\n".join(parts) + "\n")
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
