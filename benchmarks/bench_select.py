"""Selection-mode shoot-out: ml vs analytic vs cached vs profile.

Two questions the tuning subsystem must answer well:

* quality  — how close is each mode's pick to the profiling oracle, as the
  ratio of the chosen format's SpMV time to the best format's SpMV time
  (1.0 = picked the winner)?
* overhead — how long does selection itself take? This is what a restart
  pays per shard: profile reruns every candidate; ml is one feature pass +
  tree walk; a warm cache is a feature pass + dict hit.

Run: PYTHONPATH=src python benchmarks/bench_select.py [--samples 18]
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import convert, spmv
from repro.tuning import FormatPolicy, SelectionCache, profile_select, time_fn
from repro.tuning.corpus import DEFAULT_CANDIDATES, FAMILIES, make_matrix

MODES = ("analytic", "ml", "cached", "profile")


def run(samples: int = 18, seed: int = 42, iters: int = 8):
    rng = np.random.default_rng(seed)
    mats = [make_matrix(FAMILIES[i % len(FAMILIES)], rng) for i in range(samples)]

    # oracle: measured SpMV time of every candidate, per matrix
    oracle = []
    for A in mats:
        x = jnp.ones((A.shape[1],), A.dtype)
        rep = profile_select(A, x, candidates=DEFAULT_CANDIDATES, iters=iters)
        oracle.append(rep.times)

    cache_path = os.path.join(tempfile.mkdtemp(prefix="bench-select-"),
                              "selections.json")
    policies = {
        "analytic": FormatPolicy("analytic"),
        "ml": FormatPolicy("ml"),
        "cached": FormatPolicy("cached", cache=SelectionCache(cache_path)),
        "profile": FormatPolicy("profile", profile_iters=iters),
    }
    # warm the cache so "cached" measures the steady state, not first touch
    for A in mats:
        policies["cached"].select(A)

    rows = []
    for mode in MODES:
        pol = policies[mode]
        quality, sel_times, hits = [], [], 0
        for A, times in zip(mats, oracle):
            t0 = time.perf_counter()
            rep = pol.select(A)
            sel_times.append(time.perf_counter() - t0)
            best_t = min(times.values())
            chosen_t = times.get(rep.best)
            if chosen_t is None:  # pick outside the timed candidate set
                x = jnp.ones((A.shape[1],), A.dtype)
                fn = jax.jit(lambda a, v: spmv(a, v))
                chosen_t = time_fn(fn, convert(A, rep.best), x, iters=iters)
            quality.append(chosen_t / best_t)
            hits += int(rep.best == min(times, key=times.get))
        rows.append((
            f"select_{mode}_slowdown_geomean",
            float(np.exp(np.mean(np.log(quality)))),
            f"oracle_agreement={hits}/{len(mats)}",
        ))
        rows.append((
            f"select_{mode}_overhead_ms_median",
            float(np.median(sel_times) * 1e3),
            f"max={max(sel_times) * 1e3:.2f}ms",
        ))
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--samples", type=int, default=18)
    p.add_argument("--seed", type=int, default=42)
    p.add_argument("--iters", type=int, default=8)
    args = p.parse_args()
    for r in run(args.samples, args.seed, args.iters):
        print(",".join(str(c) for c in r))
