"""Roofline analysis (deliverable g): reads results/dryrun/*.json and emits
the per-(arch x shape x mesh) three-term roofline table.

Terms (TPU v5e): peak 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis values are per-device (the SPMD-partitioned program), so
  compute    = flops_dev / peak          (== global_flops / (chips * peak))
  memory     = bytes_dev / hbm_bw
  collective = coll_bytes_dev / link_bw
MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only serve cells);
the ratio MODEL_FLOPS / corrected-HLO-FLOPs exposes remat/redundant compute.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(mesh: str = "pod", tag: str = ""):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") == mesh and rec.get("tag", "") == tag:
            cells.append(rec)
    return cells


def roofline_terms(rec: dict) -> Optional[dict]:
    if rec.get("skip"):
        return None
    cost = rec.get("cost_corrected") or {
        "flops": rec["cost_reported"]["flops"],
        "bytes": rec["cost_reported"]["bytes accessed"],
        "coll": rec["collectives_reported"].get("total", 0),
    }
    chips = rec.get("chips", 256)
    t_comp = cost["flops"] / PEAK_FLOPS
    t_mem = cost["bytes"] / HBM_BW
    t_coll = cost["coll"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    # model flops (global) -> per chip
    n = rec.get("n_active_params") or rec.get("n_params") or 0
    toks = rec.get("tokens", 0)
    mult = 6 if rec["shape"].startswith("train") else 2
    model_flops_dev = mult * n * toks / chips
    bound = max(terms.values())
    frac = (model_flops_dev / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        **terms, "dominant": dom,
        "model_flops_dev": model_flops_dev,
        "hlo_flops_dev": cost["flops"],
        "useful_ratio": model_flops_dev / cost["flops"] if cost["flops"] else 0.0,
        "roofline_fraction": frac,
        "step_bound_s": bound,
        "mem_gib": rec.get("bytes_per_device", 0) / 2 ** 30,
    }


MOVE_DOWN = {
    "compute": "compute-bound: raise MFU via larger matmul tiles / fewer remat "
               "recomputes; already near the right regime",
    "memory": "memory-bound: cut HBM traffic (fuse elementwise chains, bf16 "
              "intermediates, bigger arithmetic intensity per pass)",
    "collective": "collective-bound: reduce cross-chip bytes (drop sequence-"
                  "parallel all-gathers, overlap FSDP gathers with compute, "
                  "or re-balance TP vs DP axes)",
}


def table(mesh: str = "pod", fmt: str = "md") -> str:
    rows = []
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "useful_ratio", "roofline_frac", "HBM_GiB", "note"]
    for rec in load_cells(mesh):
        name = rec["arch"]
        if rec.get("skip"):
            rows.append([name, rec["shape"], "-", "-", "-", "SKIP", "-", "-", "-",
                         rec["skip"][:60]])
            continue
        t = roofline_terms(rec)
        rows.append([
            name, rec["shape"], f"{t['compute']:.3f}", f"{t['memory']:.3f}",
            f"{t['collective']:.3f}", t["dominant"],
            f"{t['useful_ratio']:.2f}", f"{t['roofline_fraction']:.2f}",
            f"{t['mem_gib']:.1f}", MOVE_DOWN[t["dominant"]][:58]])
    if fmt == "md":
        out = ["| " + " | ".join(hdr) + " |",
               "|" + "---|" * len(hdr)]
        out += ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
        return "\n".join(out)
    return "\n".join(",".join(str(c) for c in r) for r in [hdr] + rows)


def main():
    print(table("pod"))


if __name__ == "__main__":
    main()
