"""Serving-path benchmark: batch-width-aware SpMM + measured decode loop.

Three row families, all landing in ``BENCH_serve.json``:

  serve_spmm_csr_b{B} / serve_spmm_t_csr_b{B}
      Tuned Pallas CSR SpMM (and the transposed-rhs variant LinearSparse
      actually calls) vs the jnp reference on a magnitude-pruned weight at
      rhs widths B in {1, 8, 64, 256}. Each width is tuned independently —
      the whole point of the rhs-width cache-key axis — so the winning tile
      config (``tn`` especially) legitimately differs across widths.

  serve_decision_b{B} / serve_layer_{name}_b{B}
      What the width-aware FormatPolicy records per width bucket: chosen
      format, pinned kernel backend and tile config for the engine-level
      decision; per-layer selected formats for a small stack of pruned
      weight layers (the per-layer table the README quotes).

  serve_decode_b{B}
      Steady-state greedy decode through ``launch.serve.DecodeEngine``
      (batched jit'd prefill + slot-static decode steps) on the smoke
      config, reported as us/token with tokens/s derived.

  serve_latency_b{B}
      Per-request end-to-end latency percentiles (p50 as the headline,
      p95/p99 and per-phase queue/prefill/decode p50s derived) from the
      DecodeEngine's own request telemetry, with the request queue
      oversubscribed 3x so admission waiting is actually measured.

Run: PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

WIDTHS = (1, 8, 64, 256)


def _cfg_str(cfg):
    return "/".join(f"{k}{v}" for k, v in sorted((cfg or {}).items()))


def run_spmm(widths=WIDTHS, quick: bool = False):
    """Ref-vs-tuned SpMM/SpMM_T on one pruned weight, per rhs width."""
    import jax
    import jax.numpy as jnp
    from repro.core import Format, convert, coo_from_dense_np
    from repro.core import ops as core_ops
    from repro.models.linear_sparse import prune_magnitude
    from repro.tuning import SelectionCache, kernel_tune, time_fn

    d = 512 if quick else 2048
    rng = np.random.default_rng(0)
    w = prune_magnitude(rng.standard_normal((d, d)).astype(np.float32), 0.05)
    A = convert(coo_from_dense_np(w.T), Format.CSR)  # stored (d_out, d_in)
    rows = []
    with tempfile.TemporaryDirectory() as td:
        kcache = SelectionCache(os.path.join(td, "kernels.json"))
        for b in widths:
            B = jnp.ones((d, b), jnp.float32)       # spmm rhs (N, B)
            X = jnp.ones((b, d), jnp.float32)       # spmm_t activations (B, N)
            for op, operand, name in (("spmm", B, "serve_spmm_csr"),
                                      ("spmm_t", X, "serve_spmm_t_csr")):
                ref = jax.jit(lambda v, op=op: getattr(core_ops, op)(
                    A, v, backend="ref"))
                t_ref = time_fn(ref, operand, iters=5, inner=2)
                rec = kernel_tune.tune_kernel(A, op=op, B_cols=b,
                                              cache=kcache, iters=3, inner=2)
                tuned = jax.jit(lambda v, op=op, cfg=dict(rec.cfg):
                                getattr(core_ops, op)(A, v, backend="pallas",
                                                      cfg=cfg))
                t_tuned = time_fn(tuned, operand, iters=5, inner=2)
                rows.append((f"{name}_b{b}", t_tuned * 1e6,
                             f"cfg={_cfg_str(rec.cfg)};"
                             f"ref_us={t_ref * 1e6:.0f};"
                             f"speedup_vs_ref={t_ref / t_tuned:.2f}"))
        rows += _decision_rows(A, kcache, widths)
    return rows


def _decision_rows(A, kcache, widths):
    """What the cached width-aware policy records per width bucket.

    The ml-picked format is kernel-tuned at every width FIRST (cached mode
    pins (backend, cfg) at miss time), so the recorded decision carries a
    real per-width measurement: the pin flips between pallas and ref-auto
    exactly where the speedup-vs-ref veto says it should, and the tile
    config's ``tn`` tracks the width bucket."""
    from repro.core import Format, convert, to_coo
    from repro.models.linear_sparse import WEIGHT_CANDIDATES
    from repro.tuning import kernel_tune
    from repro.tuning.policy import FormatPolicy

    fmt0 = FormatPolicy("ml", candidates=WEIGHT_CANDIDATES).select(A).best
    Af = A if Format(A.format) == fmt0 else convert(to_coo(A), fmt0)
    for b in widths:
        kernel_tune.tune_kernel(Af, op="spmm_t", B_cols=b, cache=kcache,
                                iters=3, inner=2)
    policy = FormatPolicy("cached", candidates=WEIGHT_CANDIDATES,
                          cache=kcache)
    rows = []
    for b in widths:
        rep = policy.select(A, op="spmm_t", ncols=b)
        rows.append((f"serve_decision_b{b}", 0.0,
                     f"fmt={Format(rep.best).name};"
                     f"backend={rep.backend or 'auto'};"
                     f"cfg={_cfg_str(rep.cfg)}"))
    return rows


def run_layers(widths=WIDTHS, quick: bool = False):
    """Per-layer selected formats for a small pruned-layer stack, per
    width (profile mode: the measurement at that width IS the decision)."""
    from repro.core import Format, banded_coo, coo_from_dense_np, to_dense_np
    from repro.models.linear_sparse import (WEIGHT_CANDIDATES,
                                            prune_magnitude)
    from repro.tuning.policy import FormatPolicy

    d = 256 if quick else 1024
    rng = np.random.default_rng(1)
    layers = {
        "ragged": prune_magnitude(
            rng.standard_normal((d, d)).astype(np.float32), 0.02),
        "banded": to_dense_np(banded_coo((d, d), [-2, -1, 0, 1, 2])),
        "uniform": np.where(rng.random((d, d)) < 0.05,
                            np.float32(1.0), np.float32(0.0)),
    }
    policy = FormatPolicy("profile", candidates=WEIGHT_CANDIDATES,
                          profile_iters=3)
    rows = []
    for name, w in layers.items():
        coo = coo_from_dense_np(np.asarray(w).T)
        for b in widths:
            rep = policy.select(coo, op="spmm_t", ncols=b)
            rows.append((f"serve_layer_{name}_b{b}",
                         rep.times[rep.best] * 1e6,
                         f"fmt={Format(rep.best).name}"))
    return rows


def run_sparse_mlp(widths=WIDTHS, quick: bool = False):
    """Decode-shaped tokens/s through a pruned LinearSparse MLP stack,
    each width served by layers retuned FOR that width (the paper's
    dynamic-format claim at the serving layer: the b=1 and b=256 builds
    may legitimately run different containers)."""
    import jax
    import jax.numpy as jnp
    from repro.core import Format
    from repro.models.linear_sparse import LinearSparse, prune_magnitude
    from repro.tuning import time_fn

    d, dff = (256, 512) if quick else (1024, 2816)
    rng = np.random.default_rng(2)
    up = LinearSparse.from_dense(prune_magnitude(
        rng.standard_normal((d, dff)).astype(np.float32), 0.1))
    down = LinearSparse.from_dense(prune_magnitude(
        rng.standard_normal((dff, d)).astype(np.float32), 0.1))
    rows = []
    for b in widths:
        ub = up.retune(ncols=b, tune="analytic")
        db = down.retune(ncols=b, tune="analytic")
        fn = jax.jit(lambda x, u=ub, dn=db: dn(jnp.maximum(u(x), 0.0)))
        x = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
        t = time_fn(fn, x, iters=5, inner=2)
        rows.append((f"serve_sparse_mlp_b{b}", t / b * 1e6,
                     f"tok_per_s={b / t:.1f};"
                     f"fmt_up={Format(ub.format).name};"
                     f"fmt_down={Format(db.format).name}"))
    return rows


def run_decode(widths=WIDTHS, quick: bool = False, arch="stablelm_1_6b"):
    """Steady-state decode tokens/s through the serving engine."""
    import jax
    from repro.configs import get_config
    from repro.launch.serve import DecodeEngine
    from repro.models import build_model

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    plen, steps = 8, (4 if quick else 16)
    rows = []
    for b in widths:
        engine = DecodeEngine(model, params, slots=b,
                              max_len=plen + steps + 8)
        for i in range(b):
            engine.submit(i, rng.integers(0, cfg.vocab, (plen,))
                          .astype(np.int32))
        engine.refill()                       # one batched jit'd prefill
        engine.step(max_new=1 << 30)          # compile the decode step
        t0 = time.perf_counter()
        for _ in range(steps):
            engine.step(max_new=1 << 30)
        dt = time.perf_counter() - t0
        ntok = b * steps
        rows.append((f"serve_decode_b{b}", dt / ntok * 1e6,
                     f"tok_per_s={ntok / dt:.1f};slots={b};"
                     f"prefills={engine.prefill_calls}"))
    return rows


def run_latency(widths=(1, 4), quick: bool = False, arch="stablelm_1_6b"):
    """Per-request latency percentiles from the engine's own telemetry.

    Drives a full submit->serve run per slot width with more requests than
    slots (so queueing is real), then reads the ``DecodeEngine`` request
    spans back out of ``request_log`` / the ``serve.*`` histograms:

      serve_latency_b{B}   us_per_call = p50 end-to-end request latency;
                           derived carries p95/p99, per-phase p50s
                           (queue/prefill/decode) and the peak queue wait.

    These are the rows the regression harness tracks for the serving
    loop — tokens/s alone hides admission stalls; the ROADMAP's serving
    item asks for latency explicitly."""
    import jax
    from repro.configs import get_config
    from repro.launch.serve import DecodeEngine, serve
    from repro.models import build_model
    from repro.obs import metrics

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    plen, max_new = 8, (4 if quick else 8)
    rows = []
    for b in widths:
        nreq = 3 * b  # oversubscribe so queue_us measures real waiting
        engine = DecodeEngine(model, params, slots=b,
                              max_len=plen + max_new + 8)
        reqs = [(i, rng.integers(0, cfg.vocab, (plen,)).astype(np.int32))
                for i in range(nreq)]
        metrics.reset(["serve.latency_us", "serve.queue_us",
                       "serve.prefill_us", "serve.decode_us",
                       "serve.queue_depth"])
        serve(engine, reqs, max_new=max_new)
        q = metrics.quantiles("serve.latency_us")
        depth_peak = max((r["queue_us"] for r in engine.request_log),
                        default=0.0)
        rows.append((
            f"serve_latency_b{b}", q["p50"] or 0.0,
            f"p95_us={q['p95']:.0f};p99_us={q['p99']:.0f};"
            f"queue_p50_us={metrics.quantile('serve.queue_us', 0.5):.0f};"
            f"prefill_p50_us={metrics.quantile('serve.prefill_us', 0.5):.0f};"
            f"decode_p50_us={metrics.quantile('serve.decode_us', 0.5):.0f};"
            f"requests={len(engine.request_log)};slots={b};"
            f"queue_peak_us={depth_peak:.0f}"))
    return rows


def run(widths=WIDTHS, quick: bool = False):
    rows = []
    rows += run_spmm(widths, quick=quick)
    rows += run_layers(widths, quick=quick)
    rows += run_sparse_mlp(widths, quick=quick)
    rows += run_decode(widths, quick=quick)
    rows += run_latency(quick=quick)
    return rows


if __name__ == "__main__":
    for r in run(quick=True):
        print(",".join(str(c) for c in r))
