"""Paper Fig. 4: single-node format comparison on the HPCG matrix.

SpMV runtime ratio of CSR (reference state) vs each candidate format over a
set of problem sizes, plus what the auto-tuner picks. Paper's expectation:
DIA wins on the regular stencil matrix except at small sizes; the ratio
flips with size — the motivation for runtime switching.
"""
import time

import jax
import jax.numpy as jnp

from repro.core import DynamicMatrix, Format, autotune, convert, hpcg, spmv


def _time(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


FORMATS = (Format.COO, Format.CSR, Format.DIA, Format.ELL)


def run(sizes=((8, 8, 8), (16, 16, 16), (32, 32, 32), (48, 48, 48))):
    rows = []
    f = jax.jit(lambda a, v: spmv(a, v))
    f_pallas = jax.jit(lambda a, v: spmv(a, v, backend="pallas"))
    for nx, ny, nz in sizes:
        prob = hpcg.generate_problem(nx, ny, nz)
        dm = DynamicMatrix(hpcg.to_coo(prob))
        x = jnp.ones((prob.shape[0],), jnp.float32)
        times = {}
        for fmt in FORMATS:
            times[fmt] = _time(f, dm.activate(fmt), x)
        n = prob.shape[0]
        ref = times[Format.CSR]
        for fmt in FORMATS:
            rows.append((f"format_{fmt.name}_n{n}", times[fmt] * 1e6,
                         f"speedup_vs_csr={ref / times[fmt]:.2f}"))
        # the reference format's Pallas kernel vs its pure-jnp path
        t_csr_pallas = _time(f_pallas, dm.activate(Format.CSR), x)
        rows.append((f"format_CSR_pallas_n{n}", t_csr_pallas * 1e6,
                     f"speedup_vs_csr_ref={ref / t_csr_pallas:.2f}"))
        best = min(times, key=times.get)
        tuned = autotune(dm, mode="analytic").best
        rows.append((f"format_best_n{n}", times[best] * 1e6,
                     f"measured={best.name};analytic_pick={tuned.name}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
