"""Paper Fig. 4: single-node format comparison on the HPCG matrix.

SpMV runtime ratio of CSR (reference state) vs each candidate format over a
set of problem sizes, plus what the auto-tuner picks. Paper's expectation:
DIA wins on the regular stencil matrix except at small sizes; the ratio
flips with size — the motivation for runtime switching.

The reference format also gets its Pallas kernel measured two ways:
``format_CSR_pallas_*`` runs the kernel with the *tuned* tile config
(``repro.tuning.kernel_tune`` over an ephemeral cache — the scoreboard for
"the Pallas path is actually fastest"), and ``kernel_tuned_CSR_*`` records
the tuner's own measurement of that winner, so the autotuner's effect is
visible in BENCH_spmv.json next to the untuned history.

A second family targets SELL-C-sigma: power-law row lengths (``*_pow{n}``
rows), where the sigma-sorted per-slice padding beats both ELL's global
kmax blowup and CSR's segmented reduction. The three contenders' *tuned*
Pallas kernels are measured head-to-head (``kernel_tuned_{fmt}_pow{n}``)
and ``format_best_pow{n}`` records what the auto route — profiling over
(format, backend) pairs reading the tuned cache — actually selects.
"""
import os
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (DynamicMatrix, Format, autotune, convert,
                        coo_from_arrays, hpcg, spmv)


def _time(fn, *args, iters=10, warmup=2):
    from repro.tuning import time_fn  # one timing harness for the repo
    return time_fn(fn, *args, iters=iters, warmup=warmup)


FORMATS = (Format.COO, Format.CSR, Format.DIA, Format.ELL, Format.SELL)

# DIA is pathological on unstructured power-law patterns (every diagonal
# occupied — the table would dwarf the matrix), so the irregular family
# compares the formats that can plausibly win it.
POW_FORMATS = (Format.COO, Format.CSR, Format.ELL, Format.SELL)


def powerlaw_coo(seed, n, shape_a=1.3, scale=4.0):
    """Power-law row lengths (pareto counts): the irregular-row family."""
    rng = np.random.default_rng(seed)
    counts = np.minimum(1 + (rng.pareto(shape_a, n) * scale).astype(np.int64),
                        n)
    rows = np.repeat(np.arange(n, dtype=np.int64), counts)
    cols = np.concatenate([rng.choice(n, k, replace=False) for k in counts])
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    vals = np.where(np.abs(vals) < 1e-3, 1e-3, vals)
    return coo_from_arrays(rows, cols, vals, (n, n))


def run(sizes=((8, 8, 8), (16, 16, 16), (32, 32, 32), (48, 48, 48)),
        pow_sizes=(4096,)):
    from benchmarks.run import _cfg_str
    from repro.tuning import SelectionCache, kernel_tune
    from repro.tuning.cache import CACHE_PATH_ENV
    from repro.tuning.engines import profile_select

    rows = []
    f = jax.jit(lambda a, v: spmv(a, v))
    with tempfile.TemporaryDirectory() as td:
        kcache = SelectionCache(os.path.join(td, "kernels.json"))
        for nx, ny, nz in sizes:
            prob = hpcg.generate_problem(nx, ny, nz)
            dm = DynamicMatrix(hpcg.to_coo(prob))
            x = jnp.ones((prob.shape[0],), jnp.float32)
            times = {}
            for fmt in FORMATS:
                times[fmt] = _time(f, dm.activate(fmt), x)
            n = prob.shape[0]
            ref = times[Format.CSR]
            for fmt in FORMATS:
                rows.append((f"format_{fmt.name}_n{n}", times[fmt] * 1e6,
                             f"speedup_vs_csr={ref / times[fmt]:.2f}"))
            # the reference format's Pallas kernel, tuned, vs its jnp path
            Ac = dm.activate(Format.CSR)
            rec = kernel_tune.tune_kernel(Ac.concrete, x, cache=kcache,
                                          iters=5, inner=2)
            f_pallas = jax.jit(lambda a, v, cfg=rec.cfg: spmv(
                a, v, backend="pallas", cfg=cfg))
            t_csr_pallas = _time(f_pallas, Ac, x)
            rows.append((f"format_CSR_pallas_n{n}", t_csr_pallas * 1e6,
                         f"speedup_vs_csr_ref={ref / t_csr_pallas:.2f};"
                         f"cfg={_cfg_str(rec.cfg)}"))
            rows.append((f"kernel_tuned_CSR_n{n}", rec.kernel_us,
                         f"cfg={_cfg_str(rec.cfg)};ref_us={rec.ref_us:.0f};"
                         f"speedup_vs_ref={rec.speedup:.2f}"))
            best = min(times, key=times.get)
            tuned = autotune(dm, mode="analytic").best
            rows.append((f"format_best_n{n}", times[best] * 1e6,
                         f"measured={best.name};analytic_pick={tuned.name}"))

        # ---- irregular power-law rows: the SELL-C-sigma target family ----
        # Point the process-default kernel cache at the ephemeral store so
        # the auto route (profile over (format, backend) pairs) reads the
        # records tuned right here.
        prev = os.environ.get(CACHE_PATH_ENV)
        os.environ[CACHE_PATH_ENV] = kcache.path
        try:
            for n in pow_sizes:
                A = powerlaw_coo(7, n)
                dm = DynamicMatrix(A)
                x = jnp.ones((n,), jnp.float32)
                times = {fmt: _time(f, dm.activate(fmt), x)
                         for fmt in POW_FORMATS}
                ref = times[Format.CSR]
                for fmt in POW_FORMATS:
                    rows.append((f"format_{fmt.name}_pow{n}",
                                 times[fmt] * 1e6,
                                 f"family=powerlaw;"
                                 f"speedup_vs_csr={ref / times[fmt]:.2f}"))
                # tuned Pallas contenders head-to-head on the same matrix
                for fmt in (Format.CSR, Format.ELL, Format.SELL):
                    Af = dm.activate(fmt).concrete
                    rec = kernel_tune.tune_kernel(Af, x, cache=kcache,
                                                  iters=5, inner=2)
                    rows.append((f"kernel_tuned_{fmt.name}_pow{n}",
                                 rec.kernel_us,
                                 f"family=powerlaw;cfg={_cfg_str(rec.cfg)};"
                                 f"ref_us={rec.ref_us:.0f};"
                                 f"speedup_vs_ref={rec.speedup:.2f}"))
                # what the auto route actually selects, given those records
                rep = profile_select(A, x, candidates=POW_FORMATS,
                                     backends=("ref", "pallas"),
                                     iters=3, inner=2)
                rows.append((f"format_best_pow{n}",
                             rep.times[rep.best] * 1e6,
                             f"family=powerlaw;selected={rep.best.name};"
                             f"backend={rep.backend};"
                             f"cfg={_cfg_str(rep.cfg)}"))
        finally:
            if prev is None:
                os.environ.pop(CACHE_PATH_ENV, None)
            else:
                os.environ[CACHE_PATH_ENV] = prev
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
