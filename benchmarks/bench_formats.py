"""Paper Fig. 4: single-node format comparison on the HPCG matrix.

SpMV runtime ratio of CSR (reference state) vs each candidate format over a
set of problem sizes, plus what the auto-tuner picks. Paper's expectation:
DIA wins on the regular stencil matrix except at small sizes; the ratio
flips with size — the motivation for runtime switching.

The reference format also gets its Pallas kernel measured two ways:
``format_CSR_pallas_*`` runs the kernel with the *tuned* tile config
(``repro.tuning.kernel_tune`` over an ephemeral cache — the scoreboard for
"the Pallas path is actually fastest"), and ``kernel_tuned_CSR_*`` records
the tuner's own measurement of that winner, so the autotuner's effect is
visible in BENCH_spmv.json next to the untuned history.
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.core import DynamicMatrix, Format, autotune, convert, hpcg, spmv


def _time(fn, *args, iters=10, warmup=2):
    from repro.tuning import time_fn  # one timing harness for the repo
    return time_fn(fn, *args, iters=iters, warmup=warmup)


FORMATS = (Format.COO, Format.CSR, Format.DIA, Format.ELL)


def run(sizes=((8, 8, 8), (16, 16, 16), (32, 32, 32), (48, 48, 48))):
    from benchmarks.run import _cfg_str
    from repro.tuning import SelectionCache, kernel_tune

    rows = []
    f = jax.jit(lambda a, v: spmv(a, v))
    with tempfile.TemporaryDirectory() as td:
        kcache = SelectionCache(os.path.join(td, "kernels.json"))
        for nx, ny, nz in sizes:
            prob = hpcg.generate_problem(nx, ny, nz)
            dm = DynamicMatrix(hpcg.to_coo(prob))
            x = jnp.ones((prob.shape[0],), jnp.float32)
            times = {}
            for fmt in FORMATS:
                times[fmt] = _time(f, dm.activate(fmt), x)
            n = prob.shape[0]
            ref = times[Format.CSR]
            for fmt in FORMATS:
                rows.append((f"format_{fmt.name}_n{n}", times[fmt] * 1e6,
                             f"speedup_vs_csr={ref / times[fmt]:.2f}"))
            # the reference format's Pallas kernel, tuned, vs its jnp path
            Ac = dm.activate(Format.CSR)
            rec = kernel_tune.tune_kernel(Ac.concrete, x, cache=kcache,
                                          iters=5, inner=2)
            f_pallas = jax.jit(lambda a, v, cfg=rec.cfg: spmv(
                a, v, backend="pallas", cfg=cfg))
            t_csr_pallas = _time(f_pallas, Ac, x)
            rows.append((f"format_CSR_pallas_n{n}", t_csr_pallas * 1e6,
                         f"speedup_vs_csr_ref={ref / t_csr_pallas:.2f};"
                         f"cfg={_cfg_str(rec.cfg)}"))
            rows.append((f"kernel_tuned_CSR_n{n}", rec.kernel_us,
                         f"cfg={_cfg_str(rec.cfg)};ref_us={rec.ref_us:.0f};"
                         f"speedup_vs_ref={rec.speedup:.2f}"))
            best = min(times, key=times.get)
            tuned = autotune(dm, mode="analytic").best
            rows.append((f"format_best_n{n}", times[best] * 1e6,
                         f"measured={best.name};analytic_pick={tuned.name}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
