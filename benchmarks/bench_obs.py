"""Observability deliverable: localize the distributed overlap loss.

``results/generated_tables.md`` shows ghost-mode distributed SpMV
regressing at P=8 (``scaling_spmv_ghost_p8`` ~0.78x vs reference) after
scaling fine at P=2/4 — the halo exchange stops overlapping with local
compute somewhere between 4 and 8 shards. This bench answers *where*
using :func:`repro.core.distributed.dist_spmv_phase`: per shard count it
times the production SpMV (``full``) against its two halves run alone —

  * ``local``     local SpMV only, no collective issued;
  * ``exchange``  halo exchange + remote SpMV only, no local SpMV —

and reports ``hidden_us = local + exchange - full``: the wall time XLA's
latency-hiding scheduler actually overlapped. ``hidden_frac`` normalizes
by ``min(local, exchange)`` (the most overlap that phase pair could ever
hide): ~1.0 means the exchange is fully hidden behind local compute, ~0
means the two phases serialized and the overlap is lost.

Runs in subprocesses (one forced host-device view per shard count), same
harness shape as ``bench_scaling``. Rows land in ``BENCH_obs.json`` via
``python -m benchmarks.run --only obs`` and render with
``python -m repro.obs.report --bench BENCH_obs.json``.
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
os.environ.setdefault("REPRO_TUNING_CACHE",
                      os.path.join(tempfile.mkdtemp(), "selections.json"))
import sys, time, json
sys.path.insert(0, %(src)r)
import jax, numpy as np
from repro.core import Format, hpcg
from repro.core.distributed import (build_dist_matrix, dist_spmv,
                                    dist_spmv_phase, distribute_vector)
from repro.obs import metrics

mesh = jax.make_mesh((%(ndev)d,), ("rows",))
prob = hpcg.generate_problem(*%(grid)r)
x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                      "rows", local_format=Format.CSR,
                      remote_format=Format.COO)  # the ghost config

fns = {
    "full": jax.jit(lambda a, v: dist_spmv(a, v, mesh)),
    "local": jax.jit(lambda a, v: dist_spmv_phase(a, v, mesh, phase="local")),
    "exchange": jax.jit(
        lambda a, v: dist_spmv_phase(a, v, mesh, phase="exchange")),
}
out = {"phases": {}, "halo_mode": A.halo_mode, "hw": int(A.hw),
       "remote_empty": bool(A.remote_empty)}
for name, f in fns.items():
    jax.block_until_ready(f(A, x))  # compile
    best = float("inf")
    for _ in range(3):  # min over repeats: shields against scheduler noise
        t0 = time.perf_counter()
        for _ in range(%(iters)d):
            jax.block_until_ready(f(A, x))
        best = min(best, (time.perf_counter() - t0) / %(iters)d)
    out["phases"][name] = best
out["halo_bytes"] = metrics.value("halo.bytes")
print("RESULT " + json.dumps(out))
"""


def run(shards=(1, 2, 4, 8), grid=(16, 16, 32), iters=20):
    rows = []
    for ndev in shards:
        script = SCRIPT % {"ndev": ndev, "src": os.path.abspath(SRC),
                           "grid": tuple(grid), "iters": iters}
        res = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=900)
        line = [l for l in res.stdout.splitlines() if l.startswith("RESULT ")]
        if not line:
            rows.append((f"obs_overlap_p{ndev}_FAILED", 0.0, res.stderr[-200:]))
            continue
        out = json.loads(line[0][len("RESULT "):])
        ph = out["phases"]
        full, loc, exc = ph["full"], ph["local"], ph["exchange"]
        derived = (f"local_us={loc * 1e6:.0f};exch_us={exc * 1e6:.0f};"
                   f"halo_mode={out['halo_mode']};hw={out['hw']};"
                   f"halo_bytes={out['halo_bytes']:.0f}")
        if not out["remote_empty"]:
            # overlap stats only when there is an exchange to hide (at P=1
            # the remote part is statically empty — full == local)
            hidden = loc + exc - full
            denom = min(loc, exc) or 1.0
            derived += (f";hidden_us={hidden * 1e6:.0f};"
                        f"hidden_frac={max(0.0, hidden) / denom:.3f}")
        rows.append((f"obs_overlap_ghost_p{ndev}", full * 1e6, derived))
    if rows and all(name.endswith("_FAILED") for name, _, _ in rows):
        raise RuntimeError(f"bench_obs: all shard counts failed; "
                           f"last: {rows[-1]}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
