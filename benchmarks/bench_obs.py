"""Observability deliverable: localize the distributed overlap loss.

``results/generated_tables.md`` showed ghost-mode distributed SpMV
regressing at P=8 (``scaling_spmv_ghost_p8`` ~0.78x vs reference) after
scaling fine at P=2/4 — the halo exchange stopped overlapping with local
compute somewhere between 4 and 8 shards. This bench answers *where*
using :func:`repro.core.distributed.dist_spmv_phase`: per shard count it
times the production SpMV (``full``) against its phases run alone —

  * ``local``     local SpMV only (interior + boundary), no collective;
  * ``exchange``  halo exchange + remote SpMV only, no local SpMV;
  * ``interior``/``boundary``  the split halves of the local block (the
    interior term is the dependency-free window the scheduler can hide
    the collective in) —

and reports ``hidden_us = local + exchange - full``: the wall time XLA's
latency-hiding scheduler actually overlapped. ``hidden_frac`` normalizes
the *positive* part by ``min(local, exchange)`` (the most overlap that
phase pair could ever hide): ~1.0 means the exchange is fully hidden
behind local compute, 0 means nothing was hidden. A *negative*
``hidden_us`` means composing the phases costs more than running them
separately — that overhead is reported explicitly as ``overhead_frac``
(``max(0, -hidden) / min(local, exchange)``) instead of being silently
floored into the 0.000 that used to hide the p8 regression.

Runs in subprocesses (one forced host-device view per shard count, set up
by ``repro.env``), same harness shape as ``bench_scaling``, and warms the
kernel-config cache on shard 0's containers first so the phases measure
the same ``backend="auto"`` schedule the scaling bench's ghost runs. Rows land in
``BENCH_obs.json`` via ``python -m benchmarks.run --only obs`` and render
with ``python -m repro.obs.report --bench BENCH_obs.json``.
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = """
import os, sys, tempfile
sys.path.insert(0, %(src)r)
from repro import env
env.apply(host_devices=%(ndev)d)
os.environ.setdefault("REPRO_TUNING_CACHE",
                      os.path.join(tempfile.mkdtemp(), "selections.json"))
import time, json
import jax, jax.numpy as jnp, numpy as np
from repro.core import Format, convert, hpcg
from repro.core.distributed import (build_dist_matrix, dist_spmv,
                                    dist_spmv_phase, distribute_vector)
from repro.obs import metrics
from repro.tuning import kernel_tune
from repro.tuning.cache import SelectionCache

mesh = jax.make_mesh((%(ndev)d,), ("rows",))
prob = hpcg.generate_problem(*%(grid)r)
x = distribute_vector(np.ones(prob.shape[0], np.float32), mesh, "rows")
A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                      "rows", local_format=Format.CSR,
                      remote_format=Format.COO)  # the ghost config

# production routing: tune the kernel decision on shard 0's containers so
# backend="auto" measures the same schedule bench_scaling's ghost runs
cache = SelectionCache()
xb = jnp.ones((A.plan.mp,), jnp.float32)
for part in ((A.local, A.boundary) if A.split else (A.local,)):
    s0 = jax.tree_util.tree_map(lambda l: l[0], part)
    kernel_tune.tune_kernel(s0 if Format(s0.format) == Format.CSR
                            else convert(s0, Format.CSR), xb, cache=cache,
                            iters=3, inner=2)

fns = {
    "full": jax.jit(lambda a, v: dist_spmv(a, v, mesh)),
    "local": jax.jit(lambda a, v: dist_spmv_phase(a, v, mesh, phase="local")),
    "exchange": jax.jit(
        lambda a, v: dist_spmv_phase(a, v, mesh, phase="exchange")),
}
if A.split:
    fns["interior"] = jax.jit(
        lambda a, v: dist_spmv_phase(a, v, mesh, phase="interior"))
    fns["boundary"] = jax.jit(
        lambda a, v: dist_spmv_phase(a, v, mesh, phase="boundary"))
out = {"phases": {}, "halo_mode": A.halo_mode, "hw": int(A.hw),
       "remote_empty": bool(A.remote_empty), "split": bool(A.split)}
for name, f in fns.items():
    jax.block_until_ready(f(A, x))  # compile
# round-robin repeats: timing each phase's repeats back-to-back lets
# slow allocator/cache drift within the process masquerade as a phase
# difference — interleaving exposes every phase to the same drift, and
# min-per-phase then shields against scheduler noise
for _ in range(5):
    for name, f in fns.items():
        t0 = time.perf_counter()
        for _ in range(%(iters)d):
            jax.block_until_ready(f(A, x))
        dt = (time.perf_counter() - t0) / %(iters)d
        out["phases"][name] = min(out["phases"].get(name, dt), dt)
out["halo_bytes"] = metrics.value("halo.bytes")
print("RESULT " + json.dumps(out))
"""


def run(shards=(1, 2, 4, 8, 16, 32), grid=(16, 16, 32), iters=20,
        attempts=3):
    rows = []
    for ndev in shards:
        script = SCRIPT % {"ndev": ndev, "src": os.path.abspath(SRC),
                           "grid": tuple(grid), "iters": iters}
        # process-level min: allocator layout and host load perturb a whole
        # process by more than the phase deltas being measured, so the
        # subprocess runs `attempts` times and the run with the fastest
        # production SpMV is kept — the same noise-shielding as the
        # min-over-repeats inside the process, one level up. All phases
        # come from that single process, so the decomposition stays
        # internally consistent (never a mix of best-ofs across runs).
        out, last_err = None, ""
        for _ in range(max(1, attempts)):
            res = subprocess.run([sys.executable, "-c", script],
                                 capture_output=True, text=True, timeout=1800)
            line = [l for l in res.stdout.splitlines()
                    if l.startswith("RESULT ")]
            if not line:
                last_err = res.stderr[-200:]
                continue
            cand = json.loads(line[0][len("RESULT "):])
            if out is None or cand["phases"]["full"] < out["phases"]["full"]:
                out = cand
        if out is None:
            rows.append((f"obs_overlap_p{ndev}_FAILED", 0.0, last_err))
            continue
        ph = out["phases"]
        full, loc, exc = ph["full"], ph["local"], ph["exchange"]
        derived = (f"local_us={loc * 1e6:.0f};exch_us={exc * 1e6:.0f};"
                   f"halo_mode={out['halo_mode']};hw={out['hw']};"
                   f"halo_bytes={out['halo_bytes']:.0f}")
        if out.get("split") and "interior" in ph:
            derived += (f";interior_us={ph['interior'] * 1e6:.0f};"
                        f"boundary_us={ph['boundary'] * 1e6:.0f}")
        if not out["remote_empty"]:
            # overlap stats only when there is an exchange to hide (at P=1
            # the remote part is statically empty — full == local). The
            # signed hidden_us is reported as-is; its negative part is the
            # phase-composition overhead, called out as overhead_frac.
            hidden = loc + exc - full
            denom = min(loc, exc) or 1.0
            derived += (f";hidden_us={hidden * 1e6:.0f};"
                        f"hidden_frac={max(0.0, hidden) / denom:.3f};"
                        f"overhead_frac={max(0.0, -hidden) / denom:.3f}")
        rows.append((f"obs_overlap_ghost_p{ndev}", full * 1e6, derived))
    if rows and all(name.endswith("_FAILED") for name, _, _ in rows):
        raise RuntimeError(f"bench_obs: all shard counts failed; "
                           f"last: {rows[-1]}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
