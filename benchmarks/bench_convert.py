"""Paper §III-B: conversion (format-switch) cost through the COO proxy.

The runtime cost of activate()/convert — the price of a format switch —
relative to one SpMV in the target format (i.e. how many SpMVs a switch
must win back; the paper's iterative solvers amortise over hundreds).
"""
import time

import jax
import jax.numpy as jnp

from repro.core import DynamicMatrix, Format, convert, hpcg, spmv


def _time(fn, iters=5, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(jax.tree.leaves(fn())[0])
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(jax.tree.leaves(fn())[0])
    return (time.perf_counter() - t0) / iters


def run(size=(16, 16, 16)):
    rows = []
    prob = hpcg.generate_problem(*size)
    A = hpcg.to_coo(prob)
    x = jnp.ones((prob.shape[0],), jnp.float32)
    f = jax.jit(lambda a, v: spmv(a, v))
    from repro.core import convert_execute, plan_switch
    ex = jax.jit(convert_execute, static_argnums=1)
    for fmt in (Format.CSR, Format.DIA, Format.ELL):
        t_conv = _time(lambda fmt=fmt: convert(A, fmt))
        plan = plan_switch(A, fmt)
        t_exec = _time(lambda plan=plan: ex(A, plan))
        Af = convert(A, fmt)
        t_spmv = _time(lambda Af=Af: f(Af, x))
        rows.append((f"convert_COO_to_{fmt.name}", t_conv * 1e6,
                     f"spmvs_to_amortize={t_conv / max(t_spmv, 1e-9):.1f}"))
        rows.append((f"convert_exec_COO_to_{fmt.name}", t_exec * 1e6,
                     f"spmvs_to_amortize={t_exec / max(t_spmv, 1e-9):.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
