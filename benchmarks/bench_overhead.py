"""Paper Fig. 3: dynamic-dispatch overhead.

Compares SpMV via (a) the concrete CSR container directly, (b) DynamicMatrix
with active state CSR (trace-time dispatch), (c) SwitchDynamicMatrix
(lax.switch runtime dispatch). The paper's claim: the abstraction adds no
significant overhead (ratio ~1). Repeated over HPCG per-core problem sizes.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DynamicMatrix, Format, SwitchDynamicMatrix, convert,
                        hpcg, spmv)


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(sizes=((8, 8, 8), (16, 16, 16), (24, 24, 24), (32, 32, 32))):
    rows = []
    f = jax.jit(lambda a, v: spmv(a, v))
    for nx, ny, nz in sizes:
        prob = hpcg.generate_problem(nx, ny, nz)
        A = convert(hpcg.to_coo(prob), Format.CSR)
        x = jnp.ones((prob.shape[0],), jnp.float32)
        t_concrete = _time(f, A, x)
        t_dynamic = _time(f, DynamicMatrix(A), x)
        sw = SwitchDynamicMatrix.from_matrix(A, active=Format.CSR)
        t_switch = _time(f, sw, x)
        n = prob.shape[0]
        rows.append((f"overhead_dynamic_n{n}", t_dynamic * 1e6,
                     f"ratio={t_dynamic / t_concrete:.3f}"))
        rows.append((f"overhead_switch_n{n}", t_switch * 1e6,
                     f"ratio={t_switch / t_concrete:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
