"""Paper Fig. 3: dynamic-dispatch overhead — plus format-*switch* overhead.

``run`` compares SpMV via (a) the concrete CSR container directly, (b)
DynamicMatrix with active state CSR (trace-time dispatch), (c)
SwitchDynamicMatrix (lax.switch runtime dispatch). The paper's claim: the
abstraction adds no significant overhead (ratio ~1). Repeated over HPCG
per-core problem sizes.

``run_switch`` measures the cost of the switch itself two ways:
  * host-sync     — ``convert(A, fmt)``: symbolic phase recomputed every
                    call (pattern analysis + host pulls), the pre-plan
                    ``activate()`` behaviour;
  * device-resident — symbolic phase done once (``plan_switch``), the
                    timed call is the jitted zero-sync numeric phase
                    (``convert_execute`` with the plan static).
The ratio is how many times cheaper a steady-state switch becomes, i.e.
how few SpMVs a switch must now win back to amortise.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DynamicMatrix, Format, SwitchDynamicMatrix, convert,
                        convert_execute, hpcg, plan_switch, spmv)


def _time(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(sizes=((8, 8, 8), (16, 16, 16), (24, 24, 24), (32, 32, 32))):
    rows = []
    f = jax.jit(lambda a, v: spmv(a, v))
    for nx, ny, nz in sizes:
        prob = hpcg.generate_problem(nx, ny, nz)
        A = convert(hpcg.to_coo(prob), Format.CSR)
        x = jnp.ones((prob.shape[0],), jnp.float32)
        t_concrete = _time(f, A, x)
        t_dynamic = _time(f, DynamicMatrix(A), x)
        sw = SwitchDynamicMatrix.from_matrix(A, active=Format.CSR)
        t_switch = _time(f, sw, x)
        n = prob.shape[0]
        rows.append((f"overhead_dynamic_n{n}", t_dynamic * 1e6,
                     f"ratio={t_dynamic / t_concrete:.3f}"))
        rows.append((f"overhead_switch_n{n}", t_switch * 1e6,
                     f"ratio={t_switch / t_concrete:.3f}"))
    return rows


def _time_tree(fn, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn()))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn()))
    return (time.perf_counter() - t0) / iters


SWITCH_FORMATS = (Format.CSR, Format.DIA, Format.ELL, Format.HYB)


def run_switch(sizes=((8, 8, 8), (16, 16, 16), (24, 24, 24))):
    rows = []
    ex = jax.jit(convert_execute, static_argnums=1)
    for nx, ny, nz in sizes:
        prob = hpcg.generate_problem(nx, ny, nz)
        A = hpcg.to_coo(prob)
        n = prob.shape[0]
        for fmt in SWITCH_FORMATS:
            t_host = _time_tree(lambda fmt=fmt: convert(A, fmt))
            plan = plan_switch(A, fmt)
            t_dev = _time_tree(lambda plan=plan: ex(A, plan))
            rows.append((f"switch_host_{fmt.name}_n{n}", t_host * 1e6,
                         "replan_every_call"))
            rows.append((f"switch_device_{fmt.name}_n{n}", t_dev * 1e6,
                         f"speedup_vs_host={t_host / max(t_dev, 1e-9):.1f}"))
    return rows


if __name__ == "__main__":
    for r in run() + run_switch():
        print(",".join(str(c) for c in r))
