"""Format auto-tuner — compatibility shims over ``repro.tuning``.

The selection engines historically lived here; they moved to the
``repro.tuning`` subsystem (features / engines / tree / cache / policy).
This module keeps the original public API stable for existing callers and
adds the ML and cached modes to ``autotune()``:

* ``profile``  — the paper's §V-E tuner (run candidates, pick fastest)
* ``analytic`` — bytes-touched / bandwidth model, no profiling runs
* ``ml``       — pre-trained decision tree over pattern features
* ``cached``   — persistent per-(pattern, backend, device) selection cache
"""
from __future__ import annotations

from repro.tuning.engines import (GATHER_PENALTY, HBM_BW, TuneReport,
                                  analytic_select, calibrate_gather_penalty,
                                  predicted_bytes, profile_select, time_fn)
from repro.tuning.features import PatternFeatures, PatternStats

# Historical private name, kept for callers that reached into it.
_time_fn = time_fn

__all__ = [
    "HBM_BW", "GATHER_PENALTY", "TuneReport", "PatternStats",
    "PatternFeatures", "analytic_select", "profile_select",
    "predicted_bytes", "calibrate_gather_penalty", "autotune", "time_fn",
]


def autotune(A, x=None, mode: str = "profile", **kwargs) -> TuneReport:
    """Select the best format for ``A`` (paper: per process; here: per shard).

    ``mode='profile'`` needs ``x``; every other mode needs only the pattern
    (pulled to host once). ``mode='ml'``/``'cached'`` delegate to a
    ``repro.tuning.FormatPolicy`` (kwargs: ``candidates``, ``tree``,
    ``cache``).
    """
    from repro.core.convert import to_coo as _to_coo_fn
    from repro.core.dynamic import DynamicMatrix
    from repro.tuning.policy import FormatPolicy

    if mode == "profile":
        if x is None:
            raise ValueError("profile mode requires x")
        return profile_select(A, x, **kwargs)
    if mode == "analytic":
        A = A.concrete if isinstance(A, DynamicMatrix) else A
        stats = PatternStats.from_coo(_to_coo_fn(A))
        return analytic_select(stats, **kwargs)
    if mode in ("ml", "cached"):
        return FormatPolicy(mode, **kwargs).select(A, x=x)
    raise ValueError(mode)
