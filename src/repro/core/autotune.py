"""Format auto-tuner (paper §V-E "naive auto-tuner" + beyond-paper analytic).

Two selection modes:

* ``profile`` — the paper's approach: run each candidate format's compiled
  SpMV a few times and pick the fastest (per matrix / per shard).
* ``analytic`` — beyond-paper (the paper's stated future work): SpMV is
  memory-bandwidth bound, so predicted time = bytes_touched / HBM_bw with an
  irregularity penalty on gathered x accesses. No profiling runs needed,
  works at trace time, and is what a 1000-node deployment would actually use
  (profiling 512 shards x 6 formats each restart is not viable).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convert import convert as _convert_fn, to_coo as _to_coo_fn
from repro.core import ops as _ops
from repro.core.dynamic import DynamicMatrix
from repro.core.formats import BSR, COO, CSR, DIA, ELL, Dense, Format, bytes_of

# v5e-class constants; overridable for other targets.
HBM_BW = 819e9  # bytes/s
GATHER_PENALTY = 4.0  # effective-bandwidth derate for data-dependent gathers

_CALIBRATED_PENALTY = None


def calibrate_gather_penalty(n: int = 1 << 18, iters: int = 5) -> float:
    """Measure the *actual* gather-vs-stream bandwidth ratio of the running
    backend and use it as the analytic model's penalty (beyond-paper: makes
    the no-profiling tuner performance-portable — the v5e default of 4.0 is
    wrong on e.g. CPU, where profiling and analytic modes then disagree).
    Cached per process."""
    global _CALIBRATED_PENALTY
    if _CALIBRATED_PENALTY is not None:
        return _CALIBRATED_PENALTY
    key = np.random.default_rng(0)
    x = jnp.asarray(key.standard_normal(n).astype(np.float32))
    idx = jnp.asarray(key.integers(0, n, n).astype(np.int32))
    stream = jax.jit(lambda v: v * 2.0 + 1.0)
    gather = jax.jit(lambda v, i: jnp.take(v, i, mode="clip"))
    t_s = _time_fn(stream, x, iters=iters)
    t_g = _time_fn(gather, x, idx, iters=iters)
    _CALIBRATED_PENALTY = float(max(1.0, t_g / max(t_s, 1e-9)))
    return _CALIBRATED_PENALTY


@dataclasses.dataclass
class TuneReport:
    best: Format
    times: Dict[Format, float]  # seconds (measured or predicted)
    mode: str

    def __repr__(self):
        rows = ", ".join(f"{f.name}={t:.3e}s" for f, t in self.times.items())
        return f"TuneReport(best={self.best.name}, mode={self.mode}, {rows})"


@dataclasses.dataclass
class PatternStats:
    """Host-side sparsity-pattern statistics driving the analytic model."""

    m: int
    n: int
    nnz: int
    max_row_nnz: int
    ndiag: int
    itemsize: int = 4

    @classmethod
    def from_coo(cls, A: COO) -> "PatternStats":
        r = np.asarray(A.row)
        c = np.asarray(A.col)
        d = np.asarray(A.data)
        live = d != 0
        r, c = r[live], c[live]
        nnz = int(live.sum())
        max_row = int(np.bincount(r, minlength=A.shape[0]).max()) if nnz else 1
        ndiag = int(np.unique(c.astype(np.int64) - r.astype(np.int64)).size) if nnz else 1
        return cls(A.shape[0], A.shape[1], nnz, max(1, max_row), max(1, ndiag),
                   np.dtype(A.dtype).itemsize)


def predicted_bytes(stats: PatternStats, fmt: Format,
                    gather_penalty: Optional[float] = None) -> float:
    """Bytes touched by one SpMV in ``fmt`` (matrix + x-access cost model)."""
    GATHER = gather_penalty if gather_penalty is not None else GATHER_PENALTY
    w, m, n = stats.itemsize, stats.m, stats.n
    ii = 4  # index itemsize
    if fmt == Format.COO:
        mat = stats.nnz * (2 * ii + w)
        x = stats.nnz * w * GATHER
    elif fmt == Format.CSR:
        mat = stats.nnz * (ii + w) + (m + 1) * ii
        x = stats.nnz * w * GATHER
    elif fmt == Format.DIA:
        mat = stats.ndiag * m * w + stats.ndiag * ii
        x = stats.ndiag * m * w  # contiguous shifted reads: NO penalty
    elif fmt == Format.ELL:
        mat = stats.max_row_nnz * m * (ii + w)
        x = stats.max_row_nnz * m * w * GATHER
    elif fmt == Format.BSR:
        bs = 128
        blocks = max(1, int(np.ceil(stats.nnz / (bs * bs))))  # lower bound
        mat = blocks * bs * bs * w + blocks * ii
        x = blocks * bs * w
    elif fmt == Format.HYB:
        k = min(stats.max_row_nnz, max(1, stats.nnz // max(1, stats.m)))
        ell_n = min(stats.nnz, k * stats.m)
        coo_n = stats.nnz - ell_n
        mat = ell_n * (ii + w) + coo_n * (2 * ii + w)
        x = (ell_n + coo_n) * w * GATHER
    elif fmt == Format.DENSE:
        mat = m * n * w
        x = n * w * max(1, m // 1024)
    else:
        raise ValueError(fmt)
    y = m * w
    return float(mat + x + y)


def analytic_select(stats: PatternStats,
                    candidates: Sequence[Format] = (Format.COO, Format.CSR, Format.DIA, Format.ELL),
                    hbm_bw: float = HBM_BW,
                    calibrate: bool = False) -> TuneReport:
    pen = calibrate_gather_penalty() if calibrate else None
    times = {Format(f): predicted_bytes(stats, Format(f), pen) / hbm_bw
             for f in candidates}
    best = min(times, key=times.get)
    return TuneReport(best, times, "analytic-calibrated" if calibrate else "analytic")


def _time_fn(fn, *args, iters: int = 10, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def profile_select(A, x,
                   candidates: Sequence[Format] = (Format.COO, Format.CSR, Format.DIA, Format.ELL),
                   iters: int = 10, backend: str = "ref",
                   conv_kwargs: Optional[dict] = None) -> TuneReport:
    """The paper's profiling auto-tuner: convert, compile, time, pick best."""
    A = A.concrete if isinstance(A, DynamicMatrix) else A
    conv_kwargs = conv_kwargs or {}
    times: Dict[Format, float] = {}
    for fmt in candidates:
        fmt = Format(fmt)
        try:
            Af = _convert_fn(A, fmt, **conv_kwargs.get(fmt, {}))
        except (ValueError, MemoryError):
            continue  # e.g. BSR on a non-block-aligned shape
        fn = jax.jit(lambda a, v: _ops.spmv(a, v, backend=backend))
        times[fmt] = _time_fn(fn, Af, x, iters=iters)
    best = min(times, key=times.get)
    return TuneReport(best, times, "profile")


def autotune(A, x=None, mode: str = "profile", **kwargs) -> TuneReport:
    """Select the best format for ``A`` (paper: per process; here: per shard).

    ``mode='profile'`` needs ``x``; ``mode='analytic'`` needs only the
    pattern (pulled to host once).
    """
    if mode == "profile":
        if x is None:
            raise ValueError("profile mode requires x")
        return profile_select(A, x, **kwargs)
    if mode == "analytic":
        A = A.concrete if isinstance(A, DynamicMatrix) else A
        stats = PatternStats.from_coo(_to_coo_fn(A))
        return analytic_select(stats, **kwargs)
    raise ValueError(mode)
