"""Iterative solvers over (dynamic, possibly distributed) sparse matrices.

CG is the paper's workload (HPCG — benchmarked there with the
preconditioner disabled, §IV-B; ``pcg(apply_M=...)`` restores it via the
``repro.mg`` multigrid V-cycle with the colored SymGS smoother). The
solvers are generic over an ``apply_A`` closure so the same loop runs:
  * single device, any concrete/dynamic format       (paper Fig. 4)
  * distributed local/remote split across a mesh     (paper Fig. 5)
Vector algebra goes through repro.core.ops (dot/waxpby/axpy/norm2), the
algorithms the paper exposes for DenseVector.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import ops as _ops


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    resnorm: jax.Array  # final ||r||_2
    # Fixed-size residual-norm history: ``history[k] = ||r_k||_2`` with
    # ``history[0]`` the initial residual; entries past the converged
    # iteration are NaN. The shape is ``(maxiter + 1,)`` regardless of
    # where the solve stopped, so the whole result is jit/vmap-friendly
    # (no data-dependent shapes). None for legacy constructions.
    history: Optional[jax.Array] = None


def operator(A, mesh=None, backend: str = "auto", cfg=None) -> Callable:
    """``apply_A`` closure for the solvers, over any matrix flavour.

    Accepts a concrete container, a (Switch)DynamicMatrix, or a
    ``DistSparseMatrix`` (then ``mesh`` is required and the closure is the
    overlapped distributed SpMV — including the interior/boundary overlap
    schedule when the matrix was built split, which every CG iteration's
    ``apply_A`` then inherits). ``backend="auto"`` routes every SpMV —
    per shard and per format — through the measured kernel-config cache
    (``repro.core.ops.kernel_route``): the Pallas kernels take the hot
    path exactly where a tuned tile config beat the reference path, so a
    distributed HPCG CG inherits tuned kernels on each shard by default.
    ``cfg`` pins an explicit kernel tile config instead (dict, forwarded
    to every SpMV the closure issues; None keeps the tuned/heuristic
    resolution per shard and format).
    """
    from repro.core.distributed import DistSparseMatrix, dist_spmv

    if isinstance(A, DistSparseMatrix):
        if mesh is None:
            raise ValueError("operator(DistSparseMatrix) requires mesh=")
        return lambda v: dist_spmv(A, v, mesh, backend=backend, cfg=cfg)
    return lambda v: _ops.spmv(A, v, backend=backend, cfg=cfg)


def _cg_step(apply_A: Callable, state):
    """One CG iteration (shared by :func:`cg` and :func:`cg_fixed_iters`):
    (x, r, p, rs) -> (x, r, p, rs). All reductions are global (XLA emits
    the cross-shard all-reduce when the vectors are sharded)."""
    x, r, p, rs = state
    Ap = apply_A(p)
    alpha = rs / jnp.maximum(_ops.dot(p, Ap), 1e-30)
    x = _ops.axpy(alpha, p, x)
    r = _ops.axpy(-alpha, Ap, r)
    rs_new = _ops.dot(r, r)
    beta = rs_new / jnp.maximum(rs, 1e-30)
    p = _ops.waxpby(1.0, r, beta, p)
    return x, r, p, rs_new


def cg(apply_A: Callable, b: jax.Array, x0: Optional[jax.Array] = None,
       tol: float = 1e-8, maxiter: int = 100) -> CGResult:
    """Unpreconditioned conjugate gradients (HPCG's optimized-phase solve).

    Runs a fixed-shape lax.while_loop over the shared :func:`_cg_step`.
    """
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - apply_A(x0)
    rs0 = _ops.dot(r0, r0)
    tol2 = jnp.asarray(tol, b.dtype) ** 2 * jnp.maximum(rs0, 1e-30)
    hist0 = jnp.full((maxiter + 1,), jnp.nan, b.dtype).at[0].set(jnp.sqrt(rs0))

    def cond(state):
        (_, _, _, rs), k, _ = state
        return (rs > tol2) & (k < maxiter)

    def body(state):
        s, k, hist = state
        s = _cg_step(apply_A, s)
        return s, k + 1, hist.at[k + 1].set(jnp.sqrt(s[3]))

    (x, r, p, rs), k, hist = jax.lax.while_loop(cond, body,
                                                ((x0, r0, r0, rs0), 0, hist0))
    return CGResult(x, k, jnp.sqrt(rs), hist)


def cg_fixed_iters(apply_A: Callable, b: jax.Array,
                   x0: Optional[jax.Array] = None, iters: int = 50) -> CGResult:
    """Fixed-iteration CG (benchmark timing variant: no early exit, the
    HPCG 'optimized problem timing' loop shape). Same :func:`_cg_step`
    body as :func:`cg`, under ``lax.scan``."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - apply_A(x0)
    rs0 = _ops.dot(r0, r0)

    def body(state, _):
        state = _cg_step(apply_A, state)
        return state, jnp.sqrt(state[3])

    (x, r, _, rs), norms = jax.lax.scan(body, (x0, r0, r0, rs0), None,
                                        length=iters)
    hist = jnp.concatenate([jnp.sqrt(rs0)[None], norms])
    return CGResult(x, jnp.asarray(iters), jnp.sqrt(rs), hist)


def pcg(apply_A: Callable, b: jax.Array,
        diag_A: Optional[jax.Array] = None,
        x0: Optional[jax.Array] = None, tol: float = 1e-8,
        maxiter: int = 100, *, apply_M: Optional[Callable] = None) -> CGResult:
    """Preconditioned CG, generic over the preconditioner ``z = M^{-1} r``.

    ``apply_M`` is any symmetric-positive-definite linear map — in
    particular ``repro.mg.MGHierarchy.apply_M()``, the multigrid V-cycle
    with the multicolored symmetric Gauss-Seidel smoother
    (``repro.mg.smoothers``). The coloring makes HPCG's reference SymGS
    sweep vector-parallel (per-color row-block SpMVs), so the
    preconditioner the paper had to disable (§IV-B: sequential triangular
    sweeps) runs on the same dynamic-format SpMV machinery as the
    operator itself.

    Without ``apply_M``, ``diag_A`` (from extract_diagonal() on any
    dynamic format) selects the classic Jacobi preconditioner
    M = diag(A) — the cheap fallback for operators with no usable
    coloring.
    """
    if apply_M is None:
        if diag_A is None:
            raise ValueError("pcg needs apply_M= (e.g. an MG V-cycle) or "
                             "diag_A= (Jacobi)")
        minv = jnp.where(jnp.abs(diag_A) > 1e-30, 1.0 / diag_A, 0.0)
        apply_M = lambda r: minv * r  # noqa: E731
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - apply_A(x0)
    z0 = apply_M(r0)
    p0 = z0
    rz0 = _ops.dot(r0, z0)
    rr0 = _ops.dot(r0, r0)
    tol2 = jnp.asarray(tol, b.dtype) ** 2 * jnp.maximum(rr0, 1e-30)
    hist0 = jnp.full((maxiter + 1,), jnp.nan, b.dtype).at[0].set(jnp.sqrt(rr0))

    # ||r||^2 is carried in the loop state: the convergence test reads it
    # instead of re-reducing r every cond evaluation, and computing it next
    # to dot(r, z) in the body lets XLA batch the two reductions into one
    # all-reduce under sharding — one fewer global reduction per iteration.
    def cond(state):
        _, _, _, _, rr, k, _ = state
        return (rr > tol2) & (k < maxiter)

    def body(state):
        x, r, p, rz, _, k, hist = state
        Ap = apply_A(p)
        alpha = rz / jnp.maximum(_ops.dot(p, Ap), 1e-30)
        x = _ops.axpy(alpha, p, x)
        r = _ops.axpy(-alpha, Ap, r)
        z = apply_M(r)
        rz_new = _ops.dot(r, z)
        rr_new = _ops.dot(r, r)
        beta = rz_new / jnp.maximum(rz, 1e-30)
        p = _ops.waxpby(1.0, z, beta, p)
        return (x, r, p, rz_new, rr_new, k + 1,
                hist.at[k + 1].set(jnp.sqrt(rr_new)))

    x, r, p, rz, rr, k, hist = jax.lax.while_loop(
        cond, body, (x0, r0, p0, rz0, rr0, 0, hist0))
    return CGResult(x, k, jnp.sqrt(rr), hist)
