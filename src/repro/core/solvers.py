"""Iterative solvers over (dynamic, possibly distributed) sparse matrices.

CG is the paper's workload (HPCG with the preconditioner disabled, §IV-B).
The solver is generic over an ``apply_A`` closure so the same loop runs:
  * single device, any concrete/dynamic format       (paper Fig. 4)
  * distributed local/remote split across a mesh     (paper Fig. 5)
Vector algebra goes through repro.core.ops (dot/waxpby/axpy/norm2), the
algorithms the paper exposes for DenseVector.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import ops as _ops


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    resnorm: jax.Array  # final ||r||_2


def operator(A, mesh=None, backend: str = "auto") -> Callable:
    """``apply_A`` closure for the solvers, over any matrix flavour.

    Accepts a concrete container, a (Switch)DynamicMatrix, or a
    ``DistSparseMatrix`` (then ``mesh`` is required and the closure is the
    overlapped distributed SpMV). ``backend="auto"`` routes every SpMV —
    per shard and per format — through the measured kernel-config cache
    (``repro.core.ops.kernel_route``): the Pallas kernels take the hot
    path exactly where a tuned tile config beat the reference path, so a
    distributed HPCG CG inherits tuned kernels on each shard by default.
    """
    from repro.core.distributed import DistSparseMatrix, dist_spmv

    if isinstance(A, DistSparseMatrix):
        if mesh is None:
            raise ValueError("operator(DistSparseMatrix) requires mesh=")
        return lambda v: dist_spmv(A, v, mesh, backend=backend)
    return lambda v: _ops.spmv(A, v, backend=backend)


def cg(apply_A: Callable, b: jax.Array, x0: Optional[jax.Array] = None,
       tol: float = 1e-8, maxiter: int = 100) -> CGResult:
    """Unpreconditioned conjugate gradients (HPCG's optimized-phase solve).

    Runs a fixed-shape lax.while_loop; all reductions are global (XLA emits
    the cross-shard all-reduce when b is sharded).
    """
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - apply_A(x0)
    p0 = r0
    rs0 = _ops.dot(r0, r0)
    tol2 = jnp.asarray(tol, b.dtype) ** 2 * jnp.maximum(rs0, 1e-30)

    def cond(state):
        _, _, _, rs, k = state
        return (rs > tol2) & (k < maxiter)

    def body(state):
        x, r, p, rs, k = state
        Ap = apply_A(p)
        alpha = rs / jnp.maximum(_ops.dot(p, Ap), 1e-30)
        x = _ops.axpy(alpha, p, x)
        r = _ops.axpy(-alpha, Ap, r)
        rs_new = _ops.dot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = _ops.waxpby(1.0, r, beta, p)
        return x, r, p, rs_new, k + 1

    x, r, p, rs, k = jax.lax.while_loop(cond, body, (x0, r0, p0, rs0, 0))
    return CGResult(x, k, jnp.sqrt(rs))


def cg_fixed_iters(apply_A: Callable, b: jax.Array,
                   x0: Optional[jax.Array] = None, iters: int = 50) -> CGResult:
    """Fixed-iteration CG (benchmark timing variant: no early exit, the
    HPCG 'optimized problem timing' loop shape)."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - apply_A(x0)
    rs0 = _ops.dot(r0, r0)

    def body(state, _):
        x, r, p, rs = state
        Ap = apply_A(p)
        alpha = rs / jnp.maximum(_ops.dot(p, Ap), 1e-30)
        x = _ops.axpy(alpha, p, x)
        r = _ops.axpy(-alpha, Ap, r)
        rs_new = _ops.dot(r, r)
        beta = rs_new / jnp.maximum(rs, 1e-30)
        p = _ops.waxpby(1.0, r, beta, p)
        return (x, r, p, rs_new), None

    (x, r, _, rs), _ = jax.lax.scan(body, (x0, r0, r0, rs0), None, length=iters)
    return CGResult(x, jnp.asarray(iters), jnp.sqrt(rs))


def pcg(apply_A: Callable, b: jax.Array, diag_A: jax.Array,
        x0: Optional[jax.Array] = None, tol: float = 1e-8,
        maxiter: int = 100) -> CGResult:
    """Jacobi-preconditioned CG.

    HPCG's reference preconditioner is a symmetric Gauss-Seidel sweep whose
    triangular solves are inherently sequential — hostile to every vector
    architecture (the paper disables preconditioning for the same reason,
    §IV-B). Jacobi (M = diag(A)) is the standard vector-friendly stand-in:
    one elementwise multiply, same convergence class on the HPCG operator.
    ``diag_A`` comes from extract_diagonal() on any (dynamic) format.
    """
    minv = jnp.where(jnp.abs(diag_A) > 1e-30, 1.0 / diag_A, 0.0)
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - apply_A(x0)
    z0 = minv * r0
    p0 = z0
    rz0 = _ops.dot(r0, z0)
    rr0 = _ops.dot(r0, r0)
    tol2 = jnp.asarray(tol, b.dtype) ** 2 * jnp.maximum(rr0, 1e-30)

    # ||r||^2 is carried in the loop state: the convergence test reads it
    # instead of re-reducing r every cond evaluation, and computing it next
    # to dot(r, z) in the body lets XLA batch the two reductions into one
    # all-reduce under sharding — one fewer global reduction per iteration.
    def cond(state):
        _, _, _, _, rr, k = state
        return (rr > tol2) & (k < maxiter)

    def body(state):
        x, r, p, rz, _, k = state
        Ap = apply_A(p)
        alpha = rz / jnp.maximum(_ops.dot(p, Ap), 1e-30)
        x = _ops.axpy(alpha, p, x)
        r = _ops.axpy(-alpha, Ap, r)
        z = minv * r
        rz_new = _ops.dot(r, z)
        rr_new = _ops.dot(r, r)
        beta = rz_new / jnp.maximum(rz, 1e-30)
        p = _ops.waxpby(1.0, z, beta, p)
        return x, r, p, rz_new, rr_new, k + 1

    x, r, p, rz, rr, k = jax.lax.while_loop(cond, body,
                                            (x0, r0, p0, rz0, rr0, 0))
    return CGResult(x, k, jnp.sqrt(rr))
