"""Format conversions (paper §III-B "Convert" copy concept).

Architecture: the classic sparse-library *symbolic/numeric* split, made
first-class as an explicit two-phase **plan/execute** API:

  * ``plan_switch`` (symbolic phase): analyse the sparsity *pattern* and
    produce a :class:`SwitchPlan` of static capacities / offset tables /
    block structure. The analysis runs on device (segment-sum / ``unique``
    / compare primitives); only the tiny plan artifacts — a handful of
    scalars, an offset list, a block map — cross to host, **once per
    plan**. The pre-plan pipeline shipped every index array to numpy on
    every ``DynamicMatrix.activate()``; that host round-trip was the
    dominant cost of a format switch.
  * ``convert_execute`` (numeric phase): a pure gather/scatter of values
    into the target layout. Fully jit-able with *zero* device->host
    transfers given a plan; plans are hashable and ride through
    ``jax.jit`` as static arguments, so a solver can re-switch formats
    inside a compiled step at memory-bandwidth cost.

As in the paper, COO acts as the proxy format: any -> COO -> any. Fast
paths exist where they fall out naturally (CSR<->COO order-preserving,
ELL->COO). The one-shot helpers (``coo_to_ell`` etc.) remain as thin
wrappers: hint missing -> plan on the fly; hint given -> validate +
execute.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import (BSR, COO, CSR, DIA, ELL, Dense, Format, HYB,
                                SELL, coo_from_arrays)
from repro.core.ops import csr_row_ids
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

# Sentinel pushed past every valid diagonal offset / block id during the
# device-side ``unique`` sweeps (offsets are < n <= int32 max; block grids
# are validated against int32 before use).
_SENTINEL = np.iinfo(np.int32).max

# Every device->host transfer the symbolic phase performs goes through
# ``_planned_pull`` below: the pull is executed under an explicit
# ``transfer_guard`` allowance (so builders can run with unplanned pulls
# *disallowed*) and counted (the ``planned_pulls`` metric), which is how
# tests assert that batched builds perform a constant number of host
# transfers independent of shard count.


def planned_pull_count() -> int:
    """Number of sanctioned symbolic-phase device->host pulls so far.

    Process-monotonic. For order-independent assertions use
    :func:`planned_pulls_scope` instead of before/after subtraction.
    """
    return int(_metrics.value("planned_pulls"))


class planned_pulls_scope:
    """``with planned_pulls_scope() as s: ...; s.count`` — the number of
    sanctioned pulls performed *inside* the scope, regardless of what ran
    before it in the process (the fix for order-dependent transfer-count
    assertions across a test suite). After exit, ``count`` freezes at the
    scope-closing value — pulls performed later never leak in."""

    _final: Optional[int] = None

    def __enter__(self):
        self._final = None
        self._scope = _metrics.scope()
        return self

    def __exit__(self, *exc):
        self._final = int(self._scope.delta("planned_pulls"))
        return False

    @property
    def count(self) -> int:
        if self._final is not None:
            return self._final
        return int(self._scope.delta("planned_pulls"))


def _planned_pull(x) -> np.ndarray:
    """Pull a small plan artifact (scalar / offset list) to host.

    This is the *only* sanctioned device->host transfer of the plan
    pipeline; it is exempted from any active ``transfer_guard`` and counted
    so callers can verify no O(shards) pulls sneak in.
    """
    _metrics.inc("planned_pulls")
    with jax.transfer_guard_device_to_host("allow"):
        return np.asarray(x)


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


# ---------------------------------------------------------------------------
# any -> COO (device-friendly where the source layout permits)
# ---------------------------------------------------------------------------


def csr_to_coo(A: CSR) -> COO:
    """CSR -> COO. jit-able: recover row ids from the row-pointer array."""
    rows = csr_row_ids(A.indptr, A.capacity, A.shape[0])
    return COO(rows, A.indices, A.data, A.shape, A.nnz)


def ell_to_coo(A: ELL) -> COO:
    """ELL -> COO. jit-able flatten; padding entries stay (0-valued)."""
    m, k = A.data.shape
    rows = jnp.repeat(jnp.arange(m, dtype=jnp.int32), k)
    return COO(rows, jnp.clip(A.cols.reshape(-1), 0, A.shape[1] - 1),
               A.data.reshape(-1), A.shape, A.nnz)


def dia_to_coo(A: DIA) -> COO:
    """DIA -> COO. jit-able; out-of-matrix diagonal tails become padding."""
    m, n = A.shape
    nd = A.ndiag
    i = jnp.arange(m, dtype=jnp.int32)[None, :]  # (1, M)
    offs = A.offsets[:, None].astype(jnp.int32)  # (nd, 1)
    cols = i + offs
    valid = (cols >= 0) & (cols < n)
    rows = jnp.broadcast_to(i, (nd, m))
    data = jnp.where(valid, A.data, 0)
    rows = jnp.where(valid, rows, 0)
    cols = jnp.where(valid, cols, 0)
    return COO(rows.reshape(-1), cols.reshape(-1), data.reshape(-1), A.shape, A.nnz)


def bsr_to_coo(A: BSR) -> COO:
    """BSR -> COO. jit-able block expansion."""
    bs = A.block_size
    nblk = A.nblocks
    k = jnp.arange(nblk, dtype=jnp.int32)
    brow = jnp.searchsorted(A.indptr, k, side="right").astype(jnp.int32) - 1
    brow = jnp.clip(brow, 0, A.shape[0] // bs - 1)
    bi = jnp.arange(bs, dtype=jnp.int32)
    rows = (brow[:, None, None] * bs + bi[None, :, None])
    cols = (A.indices[:, None, None] * bs + bi[None, None, :])
    rows = jnp.broadcast_to(rows, (nblk, bs, bs)).reshape(-1)
    cols = jnp.broadcast_to(cols, (nblk, bs, bs)).reshape(-1)
    return COO(rows, cols, A.data.reshape(-1), A.shape, A.nnz)


def hyb_to_coo(A: HYB) -> COO:
    """HYB -> COO. jit-able: concatenate the parts' COO views."""
    e = ell_to_coo(A.ell)
    c = A.coo
    return COO(jnp.concatenate([e.row, c.row]), jnp.concatenate([e.col, c.col]),
               jnp.concatenate([e.data, c.data]), A.shape, A.nnz)


def sell_to_coo(A: SELL) -> COO:
    """SELL -> COO. jit-able: recover (slice, lane) from each flat position
    via searchsorted on the slice pointers, then the original row through
    the permutation. Padding/ghost entries stay inert (row 0, val 0)."""
    m, n = A.shape
    c = A.c
    cap = A.capacity
    p = jnp.arange(cap, dtype=jnp.int32)
    s = jnp.searchsorted(A.slice_ptrs, p, side="right").astype(jnp.int32) - 1
    s = jnp.clip(s, 0, A.nslices - 1)
    lane = (p - A.slice_ptrs[s]) % c
    rows = jnp.clip(A.perm[s * c + lane], 0, m - 1).astype(jnp.int32)
    live = A.data != 0
    rows = jnp.where(live, rows, 0)
    cols = jnp.where(live, jnp.clip(A.cols, 0, n - 1), 0).astype(jnp.int32)
    return COO(rows, cols, A.data, A.shape, A.nnz)


def dense_to_coo(A: Dense, capacity: Optional[int] = None) -> COO:
    """Dense -> COO. With ``capacity`` (from a plan) the extraction is
    jit-able and sync-free via ``jnp.nonzero(size=...)`` — capacity
    validation is the plan phase's job; excess nonzeros truncate. Without
    one, the nonzero count is pulled from device first (one scalar
    sync)."""
    cnt = jnp.count_nonzero(A.data)
    if capacity is None:
        capacity = max(1, int(cnt))
    cap = int(capacity)
    r, c = jnp.nonzero(A.data, size=cap, fill_value=0)
    mask = jnp.arange(cap) < jnp.minimum(cnt, cap)
    val = jnp.where(mask, A.data[r, c], 0)
    r = jnp.where(mask, r, 0).astype(jnp.int32)
    c = jnp.where(mask, c, 0).astype(jnp.int32)
    return COO(r, c, val, A.shape, cap)


def to_coo(A, capacity: Optional[int] = None) -> COO:
    if isinstance(A, COO):
        return A
    if isinstance(A, CSR):
        return csr_to_coo(A)
    if isinstance(A, ELL):
        return ell_to_coo(A)
    if isinstance(A, DIA):
        return dia_to_coo(A)
    if isinstance(A, BSR):
        return bsr_to_coo(A)
    if isinstance(A, HYB):
        return hyb_to_coo(A)
    if isinstance(A, SELL):
        return sell_to_coo(A)
    if isinstance(A, Dense):
        return dense_to_coo(A, capacity)
    raise TypeError(f"not a sparse container: {type(A)}")


# ---------------------------------------------------------------------------
# The symbolic phase: SwitchPlan / plan_switch
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SwitchPlan:
    """Output of the symbolic phase of a format switch.

    Everything in here is *static* python data (ints and tuples), which
    makes a plan hashable — pass it through ``jax.jit`` as a static
    argument and the numeric phase compiles once per (shapes, plan) and
    never touches host again. Plans are produced by :func:`plan_switch`
    (or by the tuning policy via ``FormatPolicy.plan_for``) and consumed
    by :func:`convert_execute`.
    """

    target: Format
    ell_k: Optional[int] = None                       # ELL width / HYB split
    dia_offsets: Optional[Tuple[int, ...]] = None     # occupied diagonals
    block_size: Optional[int] = None                  # BSR block edge
    bsr_indptr: Optional[Tuple[int, ...]] = None      # BSR block-row ptrs
    bsr_indices: Optional[Tuple[int, ...]] = None     # BSR block columns
    hyb_coo_capacity: Optional[int] = None            # HYB overflow slots
    capacity: Optional[int] = None                    # Dense->COO extraction
    sell_c: Optional[int] = None                      # SELL slice height C
    sell_sigma: Optional[int] = None                  # SELL sort window
    sell_slice_ptrs: Optional[Tuple[int, ...]] = None  # SELL flat slice caps
    sell_perm: Optional[Tuple[int, ...]] = None       # SELL row permutation
    # (sell_perm is None for batch plans: each part derives its own sigma-
    # sort permutation on device in the numeric phase; the slice caps are
    # the elementwise max over parts and stay shared/static.)

    def __post_init__(self):
        object.__setattr__(self, "target", Format(self.target))

    def to_json(self) -> dict:
        """JSON-ready dict (Format by name, tuples as lists) — the on-disk
        half of persistent plan caching (``distplan:`` namespace)."""
        out = {"target": Format(self.target).name}
        for f in dataclasses.fields(self):
            if f.name == "target":
                continue
            v = getattr(self, f.name)
            if v is not None:
                out[f.name] = list(v) if isinstance(v, tuple) else v
        return out

    @classmethod
    def from_json(cls, doc: dict) -> "SwitchPlan":
        kw = {"target": Format[doc["target"]]}
        for f in dataclasses.fields(cls):
            if f.name == "target" or f.name not in doc:
                continue
            v = doc[f.name]
            kw[f.name] = tuple(v) if isinstance(v, list) else v
        return cls(**kw)


def _live_row_counts(C: COO, live) -> jax.Array:
    """Per-row count of live (non-zero) entries, on device."""
    return jax.ops.segment_sum(live.astype(jnp.int32), C.row,
                               num_segments=C.shape[0])


def _unique_small(values, sentinel=_SENTINEL) -> np.ndarray:
    """Device ``unique`` then pull only the compacted result to host.

    The transfer is O(#unique) — an offset list or a block map — not
    O(nnz) like the pre-plan host symbolic phase.
    """
    u = _planned_pull(jnp.unique(values))
    return u[u != sentinel]


def _sell_geometry(c: Optional[int], sigma: Optional[int], m: int):
    """Normalize (C, sigma) hints: C defaults to 32 lanes, sigma to 8*C
    (and is never smaller than C — a sub-slice sort window is meaningless)."""
    C = 32 if c is None else max(1, int(c))
    s = 8 * C if sigma is None else int(sigma)
    s = max(C, s)
    nslices = max(1, -(-m // C))
    return C, s, nslices


def _sell_perm(counts, sigma: int, m: int) -> jax.Array:
    """sigma-window sort permutation, on device: rows ordered by window,
    then by descending live-entry count (stable — ties keep matrix order).
    ``perm[p]`` is the original row stored at sorted position ``p``."""
    wid = jnp.arange(m, dtype=jnp.int32) // sigma
    return jnp.lexsort((-counts.astype(jnp.int32), wid)).astype(jnp.int32)


def _sell_widths(counts, perm, c: int, nslices: int) -> jax.Array:
    """Per-slice max live-row-count after the sigma-sort, on device."""
    mp = nslices * c
    m = counts.shape[0]
    sc = jnp.zeros((mp,), jnp.int32).at[:m].set(counts[perm].astype(jnp.int32))
    sids = jnp.arange(mp, dtype=jnp.int32) // c
    return jax.ops.segment_max(sc, sids, num_segments=nslices)


def _sell_ptrs(widths_np: np.ndarray, c: int) -> Tuple[int, ...]:
    """Static flat slice pointers from pulled per-slice widths. An all-empty
    matrix keeps one padding plane so the flat arrays are never zero-size."""
    widths_np = np.asarray(widths_np, np.int64).copy()
    if widths_np.sum() == 0:
        widths_np[0] = 1
    ptrs = np.concatenate([np.zeros(1, np.int64), np.cumsum(widths_np * c)])
    return tuple(int(x) for x in ptrs)


def plan_switch(A, fmt: Format, *, k: Optional[int] = None,
                offsets: Optional[Sequence[int]] = None,
                block_size: int = 128,
                capacity: Optional[int] = None,
                c: Optional[int] = None,
                sigma: Optional[int] = None,
                check: bool = True) -> SwitchPlan:
    """Symbolic phase: compute the :class:`SwitchPlan` for ``A`` -> ``fmt``.

    Pattern analysis (row counts, occupied diagonals, block structure)
    runs on device; only the plan artifacts are pulled to host. Explicit
    hints (``k=``, ``offsets=``, ``block_size=``) short-circuit the
    analysis — that is how the tuning policy or a distributed builder
    supplies a plan computed elsewhere.
    """
    fmt = Format(fmt)
    if isinstance(A, Dense):
        need = max(1, int(_planned_pull(jnp.count_nonzero(A.data))))
        if capacity is None:
            capacity = need
        elif int(capacity) < need:
            raise ValueError(f"capacity {capacity} < {need} nonzeros")
    if capacity is not None:
        capacity = int(capacity)

    if fmt in (Format.COO, Format.CSR, Format.DENSE):
        return SwitchPlan(fmt, capacity=capacity)

    C = to_coo(A, capacity=capacity)
    m, n = C.shape
    live = C.data != 0

    if fmt == Format.ELL:
        if k is None:
            k = max(1, int(_planned_pull(jnp.max(_live_row_counts(C, live)))))
        elif check and not _is_tracer(C.data):
            counts = _live_row_counts(C, live)
            probe = _planned_pull(jnp.stack([jnp.max(counts),
                                             jnp.argmax(counts).astype(jnp.int32)]))
            kmax, bad_row = int(probe[0]), int(probe[1])
            if kmax > int(k):
                raise ValueError(
                    f"coo_to_ell: k={int(k)} but row {bad_row} holds {kmax} "
                    f"live entries; the overflow would be silently dropped. "
                    f"Pass k>={kmax}, or use Format.HYB which spills "
                    f"overflow into its COO part.")
        return SwitchPlan(fmt, ell_k=int(k), capacity=capacity)

    if fmt == Format.SELL:
        C_, sig, nslices = _sell_geometry(c, sigma, m)
        counts = _live_row_counts(C, live)
        perm = _sell_perm(counts, sig, m)
        widths = _sell_widths(counts, perm, C_, nslices)
        # one planned pull for the whole geometry: widths then permutation
        probe = _planned_pull(jnp.concatenate([widths, perm]))
        ptrs = _sell_ptrs(probe[:nslices], C_)
        return SwitchPlan(fmt, sell_c=C_, sell_sigma=sig,
                          sell_slice_ptrs=ptrs,
                          sell_perm=tuple(int(x) for x in probe[nslices:]),
                          capacity=capacity)

    if fmt == Format.DIA:
        if offsets is None:
            diffs = jnp.where(live, C.col.astype(jnp.int32) - C.row.astype(jnp.int32),
                              _SENTINEL)
            offs = _unique_small(diffs)
            offsets = offs if offs.size else np.array([0])
        # the numeric phase routes entries with searchsorted, which needs
        # ascending *unique* offsets: a duplicated offset would leave its
        # second slot permanently unreachable, and the historical distributed
        # builder's duplicate-offset padding could alias a live diagonal —
        # dedupe here so every plan is canonical.
        offsets = tuple(int(o) for o in np.unique(np.asarray(offsets).ravel()))
        return SwitchPlan(fmt, dia_offsets=offsets, capacity=capacity)

    if fmt == Format.BSR:
        bs = int(block_size)
        if m % bs or n % bs:
            raise ValueError(f"shape {C.shape} not a multiple of block size {bs}")
        nbr, nbc = m // bs, n // bs
        if nbr * nbc >= np.iinfo(np.int32).max:
            raise ValueError("block grid too large for int32 block ids")
        gid = jnp.where(live, (C.row // bs) * nbc + (C.col // bs), _SENTINEL)
        blk = _unique_small(gid).astype(np.int64)
        if blk.size == 0:
            blk = np.zeros(1, np.int64)  # single inert zero block at (0, 0)
        pbr, pbc = blk // nbc, blk % nbc
        indptr = np.zeros(nbr + 1, np.int64)
        np.add.at(indptr, pbr + 1, 1)
        indptr = np.cumsum(indptr)
        return SwitchPlan(fmt, block_size=bs,
                          bsr_indptr=tuple(int(i) for i in indptr),
                          bsr_indices=tuple(int(c) for c in pbc),
                          capacity=capacity)

    if fmt == Format.HYB:
        counts = _live_row_counts(C, live)
        if k is None:
            k = _median_positive(counts, m)
        k = max(1, int(k))
        coo_cap = max(1, int(jnp.sum(jnp.maximum(counts - k, 0))))
        return SwitchPlan(fmt, ell_k=k, hyb_coo_capacity=coo_cap,
                          capacity=capacity)

    raise ValueError(f"unknown format {fmt}")


def _median_positive(counts, m: int) -> int:
    """Median of the positive row counts, computed on device (one scalar
    sync). Mirrors the historical ``np.median(counts[counts > 0])``."""
    npos = int(_planned_pull(jnp.sum(counts > 0)))
    if npos == 0:
        return 1
    s = jnp.sort(counts)
    nz = m - npos
    lo = min(nz + (npos - 1) // 2, m - 1)
    hi = min(nz + npos // 2, m - 1)
    return max(1, int(_planned_pull(s[lo] + s[hi])) // 2)


# ---------------------------------------------------------------------------
# Batched symbolic/numeric phases (stacked shard containers)
# ---------------------------------------------------------------------------


def _batch_row_counts(C: COO) -> jax.Array:
    """(P, M) live-entry row counts of a stacked COO batch, one device pass."""
    m = C.shape[0]

    def one(row, data):
        return jax.ops.segment_sum((data != 0).astype(jnp.int32), row,
                                   num_segments=m)

    return jax.vmap(one)(C.row, C.data)


def plan_switch_batch(A: COO, fmt: Format, *, k: Optional[int] = None,
                      offsets: Optional[Sequence[int]] = None,
                      block_size: int = 128,
                      capacity: Optional[int] = None,
                      c: Optional[int] = None,
                      sigma: Optional[int] = None,
                      check: bool = True) -> SwitchPlan:
    """Shared symbolic phase over a *stacked* batch of same-shape COO parts.

    ``A`` is a COO container whose arrays carry a leading batch (shard)
    axis: ``row/col/data`` of shape ``(P, capacity)`` with ``shape`` the
    per-part matrix shape — exactly what the distributed partitioner emits.
    One device pass analyses every part at once and produces a single
    :class:`SwitchPlan` valid for the whole batch (shared ELL width = max
    over parts, DIA offsets = deduped union over parts, shared HYB split,
    union BSR block map), so the numeric phase can ``vmap`` under one
    static plan — see :func:`convert_execute_batch`. Host traffic is a
    handful of :func:`_planned_pull` artifacts, independent of P.
    """
    fmt = Format(fmt)
    if not isinstance(A, COO) or getattr(A.data, "ndim", 1) != 2:
        raise TypeError("plan_switch_batch expects a stacked COO container "
                        "with (P, capacity) arrays")
    m, n = A.shape

    if fmt in (Format.COO, Format.CSR, Format.DENSE):
        return SwitchPlan(fmt, capacity=capacity)

    live = A.data != 0

    if fmt == Format.ELL:
        if k is None:
            k = max(1, int(_planned_pull(jnp.max(_batch_row_counts(A)))))
        elif check and not _is_tracer(A.data):
            counts = _batch_row_counts(A)
            probe = _planned_pull(jnp.stack([jnp.max(counts),
                                             jnp.argmax(counts).astype(jnp.int32)]))
            kmax, flat = int(probe[0]), int(probe[1])
            part, bad_row = divmod(flat, m)
            if kmax > int(k):
                raise ValueError(
                    f"plan_switch_batch: k={int(k)} but row {bad_row} of "
                    f"part {part} holds {kmax} live entries; the overflow "
                    f"would be silently dropped. Pass k>={kmax}, or use "
                    f"Format.HYB which spills overflow into its COO part.")
        return SwitchPlan(fmt, ell_k=int(k), capacity=capacity)

    if fmt == Format.SELL:
        C_, sig, nslices = _sell_geometry(c, sigma, m)
        counts = _batch_row_counts(A)  # (P, M)

        def one(cnt):
            return _sell_widths(cnt, _sell_perm(cnt, sig, m), C_, nslices)

        # shared static slice caps = elementwise max over parts: a part's
        # i-th-largest count inside any sigma window is <= the max over
        # parts, so every part's own sigma-sort fits under the shared caps.
        widths = jnp.max(jax.vmap(one)(counts), axis=0)
        ptrs = _sell_ptrs(_planned_pull(widths), C_)
        return SwitchPlan(fmt, sell_c=C_, sell_sigma=sig,
                          sell_slice_ptrs=ptrs, sell_perm=None,
                          capacity=capacity)

    if fmt == Format.DIA:
        if offsets is None:
            diffs = jnp.where(live, A.col.astype(jnp.int32) - A.row.astype(jnp.int32),
                              _SENTINEL)
            offs = _unique_small(diffs.ravel())  # deduped union over parts
            offsets = offs if offs.size else np.array([0])
        offsets = tuple(int(o) for o in np.unique(np.asarray(offsets).ravel()))
        return SwitchPlan(fmt, dia_offsets=offsets, capacity=capacity)

    if fmt == Format.HYB:
        counts = _batch_row_counts(A)
        if k is None:
            k = _median_positive(counts.ravel(), int(counts.size))
        k = max(1, int(k))
        overflow = jnp.sum(jnp.maximum(counts - k, 0), axis=1)  # per part
        coo_cap = max(1, int(_planned_pull(jnp.max(overflow))))
        return SwitchPlan(fmt, ell_k=k, hyb_coo_capacity=coo_cap,
                          capacity=capacity)

    if fmt == Format.BSR:
        bs = int(block_size)
        if m % bs or n % bs:
            raise ValueError(f"shape {A.shape} not a multiple of block size {bs}")
        nbr, nbc = m // bs, n // bs
        if nbr * nbc >= np.iinfo(np.int32).max:
            raise ValueError("block grid too large for int32 block ids")
        gid = jnp.where(live, (A.row // bs) * nbc + (A.col // bs), _SENTINEL)
        blk = _unique_small(gid.ravel()).astype(np.int64)  # union over parts
        if blk.size == 0:
            blk = np.zeros(1, np.int64)
        pbr, pbc = blk // nbc, blk % nbc
        indptr = np.zeros(nbr + 1, np.int64)
        np.add.at(indptr, pbr + 1, 1)
        indptr = np.cumsum(indptr)
        return SwitchPlan(fmt, block_size=bs,
                          bsr_indptr=tuple(int(i) for i in indptr),
                          bsr_indices=tuple(int(c) for c in pbc),
                          capacity=capacity)

    raise ValueError(f"unknown format {fmt}")


@functools.partial(jax.jit, static_argnums=1)
def convert_execute_batch(A, plan: SwitchPlan):
    """Batched numeric phase: ``vmap`` of :func:`convert_execute` over the
    leading (shard) axis under one shared static plan. Jit-compiled once
    per (shapes, plan), zero device->host transfers — the distributed
    builder's conversion is one call of this per candidate format, never a
    per-shard Python loop.
    """
    return jax.vmap(lambda part: convert_execute(part, plan))(A)


# ---------------------------------------------------------------------------
# The numeric phase: convert_execute (fully jit-able given a plan)
# ---------------------------------------------------------------------------


def _row_slots(C: COO):
    """Stable row sort + within-row slot of every *live* entry (device).

    Slots rank live (non-zero) entries only: dead entries — capacity
    padding, or explicit zeros interleaved with data as ``dia_to_coo``
    emits for partially-filled diagonals — must not inflate the rank of
    the live entries behind them, or ELL widths and HYB split capacities
    (both derived from live counts) silently drop data. Dead entries get
    a meaningless (possibly colliding) slot; callers mask them out.
    """
    m = C.shape[0]
    order = jnp.argsort(C.row, stable=True)
    rows, cols, data = C.row[order], C.col[order], C.data[order]
    live = data != 0
    live_counts = jax.ops.segment_sum(live.astype(jnp.int32), rows,
                                      num_segments=m)
    live_starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(live_counts).astype(jnp.int32)])[:-1]
    slot = jnp.cumsum(live.astype(jnp.int32)) - 1 - live_starts[rows]
    return rows, cols, data, slot, live


def coo_to_csr(A: COO) -> CSR:
    """COO -> CSR. jit-able: stable sort by row, bincount row pointers.

    Padding entries (row 0, val 0) sort to the front of row 0 — harmless.
    """
    m = A.shape[0]
    order = jnp.argsort(A.row, stable=True)
    rows = A.row[order]
    counts = jnp.bincount(rows, length=m)
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    return CSR(indptr, A.col[order], A.data[order], A.shape, A.nnz)


def _coo_to_ell_exec(A: COO, k: int) -> ELL:
    """ELL numeric phase: jit-able scatter into the (M, K) planes."""
    m = A.shape[0]
    k = int(k)
    rows, cols, data, slot, live = _row_slots(A)
    # zero-valued (dead) entries carry meaningless slots; park them in the
    # guard column dropped below. ELL padding sentinel is col=-1 (gathers
    # clip to 0, data=0 keeps it inert; -1 can never collide with a real
    # diagonal position).
    dead = ~live
    slot = jnp.where(dead, k, slot)
    cols_plane = jnp.full((m, k + 1), -1, jnp.int32).at[rows, jnp.clip(slot, 0, k)].set(jnp.where(dead, -1, cols))
    data_plane = jnp.zeros((m, k + 1), A.dtype).at[rows, jnp.clip(slot, 0, k)].add(jnp.where(dead, 0, data))
    return ELL(cols_plane[:, :k], data_plane[:, :k], A.shape, A.nnz)


def coo_to_ell(A: COO, k: Optional[int] = None, *, check: bool = True) -> ELL:
    """COO -> ELL. ``k`` missing -> planned on the fly; ``k`` given ->
    validated (live entries beyond slot ``k`` would otherwise be silently
    dropped) unless ``check=False`` or the data is a tracer (a jitted
    caller must pass a validated plan/width)."""
    plan = plan_switch(A, Format.ELL, k=k, check=check)
    return _coo_to_ell_exec(A, plan.ell_k)


def _coo_to_dia_exec(A: COO, offsets: Sequence[int]) -> DIA:
    """DIA numeric phase: jit-able scatter into the (ndiag, M) table."""
    m, n = A.shape
    offsets_arr = jnp.asarray(np.asarray(offsets, np.int32))
    nd = int(offsets_arr.shape[0])
    k = (A.col - A.row).astype(jnp.int32)
    slot = jnp.searchsorted(offsets_arr, k).astype(jnp.int32)
    slot = jnp.clip(slot, 0, nd - 1)
    hit = offsets_arr[slot] == k  # entries on non-listed diagonals are dropped
    data = jnp.zeros((nd, m), A.dtype).at[slot, A.row].add(jnp.where(hit, A.data, 0))
    return DIA(offsets_arr, data, A.shape, A.nnz)


def coo_to_dia(A: COO, offsets: Optional[Sequence[int]] = None) -> DIA:
    """COO -> DIA. Symbolic: the set of occupied diagonals (planned unless
    given, sorted ascending); numeric: jit-able scatter."""
    plan = plan_switch(A, Format.DIA, offsets=offsets)
    return _coo_to_dia_exec(A, plan.dia_offsets)


def _coo_to_bsr_exec(A: COO, plan: SwitchPlan) -> BSR:
    """BSR numeric phase: jit scatter of entries into their blocks. The
    block map rides in the plan and lowers to on-device constants."""
    m, n = A.shape
    bs = plan.block_size
    nbc = n // bs
    bcol_np = np.asarray(plan.bsr_indices, np.int32)
    indptr_np = np.asarray(plan.bsr_indptr, np.int32)
    brow_np = np.repeat(np.arange(len(indptr_np) - 1, dtype=np.int64),
                        np.diff(indptr_np))
    blk_sorted = brow_np * nbc + bcol_np.astype(np.int64)
    nblk = max(1, len(bcol_np))
    blk_lut = jnp.asarray(blk_sorted.astype(np.int32))
    gid = (A.row // bs) * nbc + A.col // bs
    slot = jnp.searchsorted(blk_lut, gid).astype(jnp.int32)
    slot = jnp.clip(slot, 0, nblk - 1)
    hit = blk_lut[slot] == gid
    bi = (A.row % bs).astype(jnp.int32)
    bj = (A.col % bs).astype(jnp.int32)
    data = jnp.zeros((nblk, bs, bs), A.dtype).at[slot, bi, bj].add(jnp.where(hit, A.data, 0))
    return BSR(jnp.asarray(indptr_np), jnp.asarray(bcol_np), data, A.shape,
               A.nnz, bs)


def coo_to_bsr(A: COO, block_size: int = 128, plan=None) -> BSR:
    """COO -> BSR. ``plan`` may be a :class:`SwitchPlan` or the legacy
    ``(indptr, bcol, blk)`` numpy triple."""
    if plan is None:
        plan = plan_switch(A, Format.BSR, block_size=block_size)
    elif not isinstance(plan, SwitchPlan):
        indptr_np, bcol_np, _blk = plan
        plan = SwitchPlan(Format.BSR, block_size=int(block_size),
                          bsr_indptr=tuple(int(i) for i in np.asarray(indptr_np)),
                          bsr_indices=tuple(int(c) for c in np.asarray(bcol_np)))
    return _coo_to_bsr_exec(A, plan)


def _coo_to_hyb_exec(A: COO, k: int, coo_cap: int) -> HYB:
    """HYB numeric phase: one stable row sort, then jit-able scatters into
    the ELL planes (within-row rank < k) and the COO overflow arrays.

    The overflow capacity is static (from the plan); overflow entries are
    compacted with a cumsum and any excess past ``coo_cap`` lands in a
    dropped guard slot.
    """
    m, n = A.shape
    k, coo_cap = int(k), int(coo_cap)
    rows, cols, data, slot, live = _row_slots(A)
    in_ell = (slot < k) & live
    in_coo = (~in_ell) & live
    ell_slot = jnp.where(in_ell, slot, k)
    cols_plane = jnp.full((m, k + 1), -1, jnp.int32).at[rows, jnp.clip(ell_slot, 0, k)].set(jnp.where(in_ell, cols, -1))
    data_plane = jnp.zeros((m, k + 1), A.dtype).at[rows, jnp.clip(ell_slot, 0, k)].add(jnp.where(in_ell, data, 0))
    ell = ELL(cols_plane[:, :k], data_plane[:, :k], A.shape, A.nnz)
    pos = jnp.cumsum(in_coo.astype(jnp.int32)) - 1
    pos = jnp.clip(jnp.where(in_coo, pos, coo_cap), 0, coo_cap)
    crow = jnp.zeros((coo_cap + 1,), jnp.int32).at[pos].set(jnp.where(in_coo, rows, 0))[:coo_cap]
    ccol = jnp.zeros((coo_cap + 1,), jnp.int32).at[pos].set(jnp.where(in_coo, cols, 0))[:coo_cap]
    cdat = jnp.zeros((coo_cap + 1,), A.dtype).at[pos].set(jnp.where(in_coo, data, 0))[:coo_cap]
    coo = COO(crow, ccol, cdat, A.shape, coo_cap)
    return HYB(ell, coo, A.shape, A.nnz)


def coo_to_hyb(A: COO, k: Optional[int] = None) -> HYB:
    """COO -> HYB. Symbolic: split each row at k entries (planned; default
    k = median positive row length); numeric: jit-able scatters."""
    plan = plan_switch(A, Format.HYB, k=k)
    return _coo_to_hyb_exec(A, plan.ell_k, plan.hyb_coo_capacity)


def _coo_to_sell_exec(A: COO, plan: SwitchPlan) -> SELL:
    """SELL numeric phase: jit-able scatter into the flat column-major
    slice storage. When the plan carries ``sell_perm`` (single-matrix
    plans) the permutation lowers to an on-device constant; batch plans
    ship ``sell_perm=None`` and each part re-derives its own sigma-sort on
    device — sort/segment/scatter all ``vmap`` cleanly and the shared
    static slice caps are guaranteed to fit every part.
    """
    m, n = A.shape
    cs = int(plan.sell_c)
    ptrs_np = np.asarray(plan.sell_slice_ptrs, np.int32)
    nslices = len(ptrs_np) - 1
    cap = int(ptrs_np[-1])
    mp = nslices * cs
    rows, cols, data, slot, live = _row_slots(A)
    if plan.sell_perm is not None:
        perm = jnp.asarray(np.asarray(plan.sell_perm, np.int32))
    else:
        counts = jax.ops.segment_sum((A.data != 0).astype(jnp.int32), A.row,
                                     num_segments=m)
        perm = _sell_perm(counts, int(plan.sell_sigma), m)
    # sorted position of each original row; ghost lanes past M map to row M
    inv = jnp.zeros((m,), jnp.int32).at[perm].set(
        jnp.arange(m, dtype=jnp.int32))
    perm_p = jnp.concatenate(
        [perm, jnp.full((mp - m,), m, jnp.int32)]) if mp > m else perm
    ptrs = jnp.asarray(ptrs_np)
    p = inv[rows]
    sl = p // cs
    lane = p % cs
    width = (ptrs[sl + 1] - ptrs[sl]) // cs
    # a live entry whose within-row rank exceeds its slice cap can only
    # mean a stale plan; park it in the dropped guard slot at ``cap``.
    ok = live & (slot < width)
    pos = jnp.where(ok, ptrs[sl] + slot * cs + lane, cap)
    # padding sentinel col=-1 (as in ELL): gathers clip to 0 with data=0
    # inert, and -1 never collides with a real diagonal position.
    cols_flat = jnp.full((cap + 1,), -1, jnp.int32).at[pos].set(
        jnp.where(ok, cols, -1))[:cap]
    data_flat = jnp.zeros((cap + 1,), A.dtype).at[pos].add(
        jnp.where(ok, data, 0))[:cap]
    return SELL(cols_flat, data_flat, perm_p, ptrs, A.shape, A.nnz,
                cs, int(plan.sell_sigma))


def coo_to_sell(A: COO, c: Optional[int] = None,
                sigma: Optional[int] = None) -> SELL:
    """COO -> SELL-C-sigma. Symbolic: sigma-window sort permutation and
    per-slice caps (planned); numeric: jit-able flat scatter."""
    plan = plan_switch(A, Format.SELL, c=c, sigma=sigma)
    return _coo_to_sell_exec(A, plan)


def coo_to_dense(A: COO) -> Dense:
    """COO -> Dense. jit-able scatter-add."""
    m, n = A.shape
    out = jnp.zeros((m, n), A.dtype).at[A.row, A.col].add(A.data)
    return Dense(out, A.shape, A.nnz)


def convert_execute(A, plan: SwitchPlan):
    """Numeric phase of the paper's convert(): any -> ``plan.target`` via
    the COO proxy, with every shape-determining quantity taken from the
    plan. jit-able with ``plan`` as a static argument; performs zero
    device->host transfers.
    """
    fmt = Format(plan.target)
    C = to_coo(A, capacity=plan.capacity)
    if fmt == Format.COO:
        return C
    if fmt == Format.CSR:
        return coo_to_csr(C)
    if fmt == Format.ELL:
        return _coo_to_ell_exec(C, plan.ell_k)
    if fmt == Format.DIA:
        return _coo_to_dia_exec(C, plan.dia_offsets)
    if fmt == Format.BSR:
        return _coo_to_bsr_exec(C, plan)
    if fmt == Format.HYB:
        return _coo_to_hyb_exec(C, plan.ell_k, plan.hyb_coo_capacity)
    if fmt == Format.SELL:
        return _coo_to_sell_exec(C, plan)
    if fmt == Format.DENSE:
        return coo_to_dense(C)
    raise ValueError(f"unknown format {fmt}")


# ---------------------------------------------------------------------------
# The paper's convert(): any -> any via the COO proxy
# ---------------------------------------------------------------------------


def convert(A, fmt: Format, plan: Optional[SwitchPlan] = None, **kwargs):
    """Element-wise conversion between any two formats via the COO proxy.

    With ``plan`` (a precomputed :class:`SwitchPlan`) the call is the pure
    numeric phase — jit-able, zero host syncs. Without one, the symbolic
    hints in ``kwargs`` (``k=`` for ELL/HYB, ``offsets=`` for DIA,
    ``block_size=`` for BSR, ``capacity=`` for Dense sources) seed
    :func:`plan_switch` and the plan is computed on the fly.
    """
    fmt = Format(fmt)
    if plan is not None:
        if not isinstance(plan, SwitchPlan):
            if fmt == Format.BSR:  # legacy (indptr, bcol, blk) triple
                return coo_to_bsr(to_coo(A), kwargs.get("block_size", 128),
                                  plan=plan)
            raise TypeError(f"plan must be a SwitchPlan, got {type(plan)}")
        if Format(plan.target) != fmt:
            raise ValueError(f"plan targets {Format(plan.target).name}, not {fmt.name}")
        return convert_execute(A, plan)
    if getattr(A, "format", None) == fmt and not kwargs:
        return A
    with _trace.span("convert.any", target=fmt.name):
        return convert_execute(A, plan_switch(A, fmt, **kwargs))


# ---------------------------------------------------------------------------
# Observability: plan/execute spans + padding-waste histograms
# ---------------------------------------------------------------------------
# Spans here wrap *host-side* symbolic work (plan_switch) or the dispatch
# of the numeric phase; when a wrapped function is itself being traced by
# jax (tracer inputs), the span measures trace/compile time, which the
# attribution report counts once per compilation rather than per call.
# Padding-waste histograms cost two static-int divisions — every input
# to them (shape, nnz, plan fields) is host metadata, never device data.


def _observe_plan_waste(A, plan: SwitchPlan) -> None:
    try:
        m = int(A.shape[0])
        nnz = int(A.nnz)
    except (TypeError, AttributeError):  # duck-typed inputs without nnz
        return
    if m <= 0:
        return
    if Format(plan.target) == Format.SELL and plan.sell_slice_ptrs:
        slots = int(plan.sell_slice_ptrs[-1])
        if slots > 0:
            _metrics.observe("sell.padding_waste",
                             min(1.0, max(0.0, 1.0 - nnz / slots)))
        return
    if plan.ell_k is None:
        return
    slots = m * int(plan.ell_k)
    if slots <= 0:
        return
    if Format(plan.target) == Format.ELL:
        _metrics.observe("ell.padding_waste",
                         min(1.0, max(0.0, 1.0 - nnz / slots)))
    elif Format(plan.target) == Format.HYB:
        # ELL-part occupancy estimate: nnz minus (at most) the planned COO
        # overflow capacity lands in the k-wide slots.
        ell_nnz = max(0, nnz - int(plan.hyb_coo_capacity or 0))
        _metrics.observe("hyb.padding_waste",
                         min(1.0, max(0.0, 1.0 - ell_nnz / slots)))


def _traced_plan(fn, name: str):
    @functools.wraps(fn)
    def wrapper(A, fmt, **kwargs):
        fmt = Format(fmt)
        if _trace.mode() == "off":
            plan = fn(A, fmt, **kwargs)
        else:
            with _trace.span(name, fmt=fmt.name) as sp:
                plan = fn(A, fmt, **kwargs)
                if plan.ell_k is not None:
                    sp.set(ell_k=plan.ell_k)
                if plan.dia_offsets is not None:
                    sp.set(n_offsets=len(plan.dia_offsets))
        _observe_plan_waste(A, plan)
        return plan
    return wrapper


# Rebind so internal callers (convert, coo_to_*, the tuning policy, the
# distributed builders) all go through the instrumented entry points.
plan_switch = _traced_plan(plan_switch, "plan.switch")
plan_switch_batch = _traced_plan(plan_switch_batch, "plan.switch_batch")


def _traced_execute(fn):
    @functools.wraps(fn)
    def wrapper(A, plan: SwitchPlan):
        if _trace.mode() == "off":
            return fn(A, plan)
        with _trace.span("convert.execute", target=Format(plan.target).name):
            return fn(A, plan)
    return wrapper


convert_execute = _traced_execute(convert_execute)
