"""Format conversions (paper §III-B "Convert" copy concept).

Architecture: the classic sparse-library *symbolic/numeric* split, which is
also the honest TPU adaptation of the paper's element-wise convert:

  * symbolic phase (host, numpy): analyse the sparsity *pattern* and produce
    static capacities / offset tables / block structure;
  * numeric phase (device, jit-able): pure gather/scatter of values into the
    target layout.

As in the paper, COO acts as the proxy format: any -> COO -> any. Fast paths
exist where they fall out naturally (CSR<->COO order-preserving, ELL->COO).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import (BSR, COO, CSR, DIA, ELL, Dense, Format, HYB,
                                coo_from_arrays)

# ---------------------------------------------------------------------------
# any -> COO (device-friendly where the source layout permits)
# ---------------------------------------------------------------------------


def csr_to_coo(A: CSR) -> COO:
    """CSR -> COO. jit-able: recover row ids from the row-pointer array."""
    cap = A.capacity
    k = jnp.arange(cap, dtype=jnp.int32)
    rows = jnp.searchsorted(A.indptr, k, side="right").astype(jnp.int32) - 1
    rows = jnp.clip(rows, 0, A.shape[0] - 1)  # padded tail -> row 0-ish, val 0
    return COO(rows, A.indices, A.data, A.shape, A.nnz)


def ell_to_coo(A: ELL) -> COO:
    """ELL -> COO. jit-able flatten; padding entries stay (0-valued)."""
    m, k = A.data.shape
    rows = jnp.repeat(jnp.arange(m, dtype=jnp.int32), k)
    return COO(rows, A.cols.reshape(-1), A.data.reshape(-1), A.shape, A.nnz)


def dia_to_coo(A: DIA) -> COO:
    """DIA -> COO. jit-able; out-of-matrix diagonal tails become padding."""
    m, n = A.shape
    nd = A.ndiag
    i = jnp.arange(m, dtype=jnp.int32)[None, :]  # (1, M)
    offs = A.offsets[:, None].astype(jnp.int32)  # (nd, 1)
    cols = i + offs
    valid = (cols >= 0) & (cols < n)
    rows = jnp.broadcast_to(i, (nd, m))
    data = jnp.where(valid, A.data, 0)
    rows = jnp.where(valid, rows, 0)
    cols = jnp.where(valid, cols, 0)
    return COO(rows.reshape(-1), cols.reshape(-1), data.reshape(-1), A.shape, A.nnz)


def bsr_to_coo(A: BSR) -> COO:
    """BSR -> COO. jit-able block expansion."""
    bs = A.block_size
    nblk = A.nblocks
    k = jnp.arange(nblk, dtype=jnp.int32)
    brow = jnp.searchsorted(A.indptr, k, side="right").astype(jnp.int32) - 1
    brow = jnp.clip(brow, 0, A.shape[0] // bs - 1)
    bi = jnp.arange(bs, dtype=jnp.int32)
    rows = (brow[:, None, None] * bs + bi[None, :, None])
    cols = (A.indices[:, None, None] * bs + bi[None, None, :])
    rows = jnp.broadcast_to(rows, (nblk, bs, bs)).reshape(-1)
    cols = jnp.broadcast_to(cols, (nblk, bs, bs)).reshape(-1)
    return COO(rows, cols, A.data.reshape(-1), A.shape, A.nnz)


def hyb_to_coo(A: HYB) -> COO:
    """HYB -> COO. jit-able: concatenate the parts' COO views."""
    e = ell_to_coo(A.ell)
    c = A.coo
    return COO(jnp.concatenate([e.row, c.row]), jnp.concatenate([e.col, c.col]),
               jnp.concatenate([e.data, c.data]), A.shape, A.nnz)


def coo_to_hyb(A: COO, k: Optional[int] = None) -> HYB:
    """COO -> HYB. Symbolic: split each row at k entries (host); numeric:
    jit-able scatters into the two parts. Default k = median row length."""
    m, n = A.shape
    r = np.asarray(A.row)
    d = np.asarray(A.data)
    live = d != 0
    counts = np.bincount(r[live], minlength=m) if live.any() else np.zeros(m, int)
    if k is None:
        k = max(1, int(np.median(counts[counts > 0])) if (counts > 0).any() else 1)
    # rank of each entry within its row (host, by first-seen order)
    order = np.argsort(r, kind="stable")
    rank = np.zeros(len(r), np.int64)
    seen = {}
    for pos in order:
        rr = r[pos]
        rank[pos] = seen.get(rr, 0)
        seen[rr] = rank[pos] + 1
    in_ell = (rank < k) & live
    in_coo = (~in_ell) & live
    ell = coo_to_ell(COO(A.row, A.col, jnp.where(jnp.asarray(in_ell), A.data, 0),
                         A.shape, A.nnz), k=k)
    coo_cap = max(1, int(in_coo.sum()))
    idx = np.nonzero(in_coo)[0]
    pad = np.zeros(coo_cap - len(idx), np.int64)
    sel = jnp.asarray(np.concatenate([idx, pad]).astype(np.int32))
    mask = jnp.asarray(np.concatenate([np.ones(len(idx)), np.zeros(len(pad))]).astype(bool))
    coo = COO(jnp.where(mask, A.row[sel], 0), jnp.where(mask, A.col[sel], 0),
              jnp.where(mask, A.data[sel], 0), A.shape, coo_cap)
    return HYB(ell, coo, A.shape, A.nnz)


def dense_to_coo(A: Dense, capacity: Optional[int] = None) -> COO:
    """Dense -> COO. Host symbolic (nonzero is data-dependent)."""
    a = np.asarray(A.data)
    r, c = np.nonzero(a)
    return coo_from_arrays(r, c, a[r, c], A.shape, capacity, a.dtype)


def to_coo(A, capacity: Optional[int] = None) -> COO:
    if isinstance(A, COO):
        return A
    if isinstance(A, CSR):
        return csr_to_coo(A)
    if isinstance(A, ELL):
        return ell_to_coo(A)
    if isinstance(A, DIA):
        return dia_to_coo(A)
    if isinstance(A, BSR):
        return bsr_to_coo(A)
    if isinstance(A, HYB):
        return hyb_to_coo(A)
    if isinstance(A, Dense):
        return dense_to_coo(A, capacity)
    raise TypeError(f"not a sparse container: {type(A)}")


# ---------------------------------------------------------------------------
# COO -> any
# ---------------------------------------------------------------------------


def _coo_host(A: COO):
    """Pull the (tiny) index pattern to host for the symbolic phase."""
    return np.asarray(A.row), np.asarray(A.col), np.asarray(A.data)


def coo_to_csr(A: COO) -> CSR:
    """COO -> CSR. jit-able: stable sort by row, bincount row pointers.

    Padding entries (row 0, val 0) sort to the front of row 0 — harmless.
    """
    m = A.shape[0]
    order = jnp.argsort(A.row, stable=True)
    rows = A.row[order]
    counts = jnp.bincount(rows, length=m)
    indptr = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    return CSR(indptr, A.col[order], A.data[order], A.shape, A.nnz)


def coo_to_ell(A: COO, k: Optional[int] = None) -> ELL:
    """COO -> ELL. Symbolic: max row length K (host unless given); numeric:
    jit-able scatter into the (M, K) planes."""
    m = A.shape[0]
    if k is None:
        r, _, d = _coo_host(A)
        live = np.asarray(d) != 0
        k = int(np.bincount(r[live], minlength=m).max()) if live.any() else 1
        k = max(k, 1)
    order = jnp.argsort(A.row, stable=True)
    rows, cols, data = A.row[order], A.col[order], A.data[order]
    # slot within row = position - start of row
    counts = jnp.bincount(rows, length=m)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])[:-1]
    slot = jnp.arange(rows.shape[0], dtype=jnp.int32) - starts[rows]
    # zero-valued (padding) entries all map to row 0; push them out of range.
    # ELL padding sentinel is col=-1 (gathers clip to 0, data=0 keeps it
    # inert; -1 can never collide with a real diagonal position).
    dead = data == 0
    slot = jnp.where(dead, k, slot)  # row-0 overflow guard, dropped below
    cols_plane = jnp.full((m, k + 1), -1, jnp.int32).at[rows, jnp.clip(slot, 0, k)].set(jnp.where(dead, -1, cols))
    data_plane = jnp.zeros((m, k + 1), A.dtype).at[rows, jnp.clip(slot, 0, k)].add(jnp.where(dead, 0, data))
    return ELL(cols_plane[:, :k], data_plane[:, :k], A.shape, A.nnz)


def coo_to_dia(A: COO, offsets: Optional[Sequence[int]] = None) -> DIA:
    """COO -> DIA. Symbolic: the set of occupied diagonals (host unless
    given); numeric: jit-able scatter into the (ndiag, M) table."""
    m, n = A.shape
    if offsets is None:
        r, c, d = _coo_host(A)
        live = np.asarray(d) != 0
        offs = np.unique((c - r)[live]) if live.any() else np.array([0])
        offsets = offs.astype(np.int64)
    offsets_arr = jnp.asarray(np.asarray(offsets, np.int32))
    nd = int(offsets_arr.shape[0])
    k = (A.col - A.row).astype(jnp.int32)
    slot = jnp.searchsorted(offsets_arr, k).astype(jnp.int32)
    slot = jnp.clip(slot, 0, nd - 1)
    hit = offsets_arr[slot] == k  # entries on non-listed diagonals are dropped
    data = jnp.zeros((nd, m), A.dtype).at[slot, A.row].add(jnp.where(hit, A.data, 0))
    return DIA(offsets_arr, data, A.shape, A.nnz)


def coo_to_bsr(A: COO, block_size: int = 128, plan=None) -> BSR:
    """COO -> BSR. Symbolic: block structure on host; numeric: jit scatter."""
    m, n = A.shape
    bs = block_size
    if m % bs or n % bs:
        raise ValueError(f"shape {A.shape} not a multiple of block size {bs}")
    if plan is None:
        r, c, d = _coo_host(A)
        live = np.asarray(d) != 0
        br, bc = r[live] // bs, c[live] // bs
        blk = np.unique(br.astype(np.int64) * (n // bs) + bc)
        pbr, pbc = blk // (n // bs), blk % (n // bs)
        indptr = np.zeros(m // bs + 1, np.int32)
        np.add.at(indptr, pbr + 1, 1)
        indptr = np.cumsum(indptr).astype(np.int32)
        plan = (indptr, pbc.astype(np.int32), blk)
    indptr_np, bcol_np, blk_np = plan
    nblk = max(1, len(bcol_np))
    # host map: global block id -> slot
    blk_sorted = np.asarray(blk_np, np.int64)
    if blk_sorted.size and blk_sorted.max() >= np.iinfo(np.int32).max:
        raise ValueError("block grid too large for int32 block ids")
    blk_lut = jnp.asarray(blk_sorted.astype(np.int32))
    gid = (A.row // bs) * (n // bs) + A.col // bs
    slot = jnp.searchsorted(blk_lut, gid).astype(jnp.int32)
    slot = jnp.clip(slot, 0, nblk - 1)
    hit = blk_lut[slot] == gid
    bi = (A.row % bs).astype(jnp.int32)
    bj = (A.col % bs).astype(jnp.int32)
    data = jnp.zeros((nblk, bs, bs), A.dtype).at[slot, bi, bj].add(jnp.where(hit, A.data, 0))
    indptr = jnp.asarray(indptr_np if len(bcol_np) else np.zeros(m // bs + 1, np.int32))
    bcol = jnp.asarray(bcol_np if len(bcol_np) else np.zeros(1, np.int32))
    return BSR(indptr, bcol, data, A.shape, A.nnz, bs)


def coo_to_dense(A: COO) -> Dense:
    """COO -> Dense. jit-able scatter-add."""
    m, n = A.shape
    out = jnp.zeros((m, n), A.dtype).at[A.row, A.col].add(A.data)
    return Dense(out, A.shape, A.nnz)


# ---------------------------------------------------------------------------
# The paper's convert(): any -> any via the COO proxy
# ---------------------------------------------------------------------------


def convert(A, fmt: Format, **kwargs):
    """Element-wise conversion between any two formats via the COO proxy.

    ``kwargs`` forward symbolic hints (``k=`` for ELL, ``offsets=`` for DIA,
    ``block_size=`` for BSR, ``capacity=`` for COO) so the call can be made
    fully jit-able when the plan is known.
    """
    fmt = Format(fmt)
    if getattr(A, "format", None) == fmt and not kwargs:
        return A
    C = to_coo(A, capacity=kwargs.pop("capacity", None))
    if fmt == Format.COO:
        return C
    if fmt == Format.CSR:
        return coo_to_csr(C)
    if fmt == Format.ELL:
        return coo_to_ell(C, k=kwargs.get("k"))
    if fmt == Format.DIA:
        return coo_to_dia(C, offsets=kwargs.get("offsets"))
    if fmt == Format.BSR:
        return coo_to_bsr(C, block_size=kwargs.get("block_size", 128), plan=kwargs.get("plan"))
    if fmt == Format.HYB:
        return coo_to_hyb(C, k=kwargs.get("k"))
    if fmt == Format.DENSE:
        return coo_to_dense(C)
    raise ValueError(f"unknown format {fmt}")
