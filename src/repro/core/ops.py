"""Algorithms over sparse containers (the paper's §III-D algorithm layer).

Every algorithm has one generic entry point that dispatches on the container
type at *trace* time — the JAX analogue of the paper's compile-time
introspection dispatch. The implementations here are the pure-jnp "reference
backend" (the paper's Serial/OpenMP backends); `repro.kernels` provides the
Pallas TPU backend for the hot formats, selected via ``backend=``.

SpMV is the paper's evaluated hot spot; we also provide SpMM (needed by the
block-sparse / MoE integration) and the dense-vector algorithms used by CG
(dot, waxpby, axpy, norm2) plus diagonal extract/update (HPCG's TestCG).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formats import BSR, COO, CSR, DIA, ELL, Dense, HYB, SELL
from repro.obs import ledger as _ledger
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

# ---------------------------------------------------------------------------
# SpMV: y = A @ x
# ---------------------------------------------------------------------------


def csr_row_ids(indptr, capacity: int, m: int):
    """Per-entry row ids of a capacity-padded CSR layout (jit-able).

    The TPU replacement for a warp-per-row walk: recover every stored
    entry's row from the row-pointer array in one vectorised searchsorted.
    Padding entries past ``indptr[-1]`` clip to row ``m - 1`` (their values
    are zero, so they are inert under accumulate semantics). Shared by the
    reference SpMV/SpMM, the CSR Pallas wrapper, and CSR -> COO conversion.
    """
    k = jnp.arange(capacity, dtype=jnp.int32)
    rows = jnp.searchsorted(indptr, k, side="right").astype(jnp.int32) - 1
    return jnp.clip(rows, 0, m - 1)


def resolve_backend(backend: str, A=None) -> str:
    """Resolve the ``"auto"`` backend name to a concrete one.

    With a matrix, ``auto`` answers from *measurement*: it routes to the
    Pallas kernels iff the kernel-config cache
    (``repro.tuning.kernel_tune``) holds a winner for ``A``'s (format,
    shape bucket, backend, device) whose measured time beats the reference
    path — a kernel that merely compiles, or that was measured slower,
    never takes the hot path. Without a matrix (legacy callers) it falls
    back to the coarse compile test: ``pallas`` when the kernels lower
    natively (TPU, or ``REPRO_FORCE_INTERPRET=0``), else ``ref``.
    Concrete names pass through unchanged.
    """
    if backend != "auto":
        return backend
    if A is not None:
        return kernel_route(A)[0]
    from repro.kernels import ops as kops  # lazy: keep core import-light
    return kops.auto_backend()


def kernel_route(A, op: str = "spmv", cache=None, ncols=None):
    """The measured ``"auto"`` decision for a concrete container.

    Returns ``("pallas", cfg)`` when a cached kernel-tune record for
    ``A``'s shape bucket beat the reference path (``cfg`` is the winning
    tile config), else ``("ref", None)`` — including when no record
    exists: an unmeasured kernel is never presumed faster. Host dict
    lookups only; safe at trace time (the decision is baked into the
    jitted program, so retune-then-retrace to pick up new winners).
    ``ncols`` is the rhs batch width for the spmm ops — lookups hit the
    matching rhs-width bucket only.
    """
    if isinstance(A, _DYN_TYPES):
        A = getattr(A, "concrete", A)
    if not hasattr(A, "format"):
        _metrics.inc("kernel.route.ref")
        if _ledger.enabled():
            _ledger.record("kernel.route", op=op, fmt=type(A).__name__,
                           route="ref", reason="not a sparse container — "
                           "no kernel exists for it")
        return "ref", None
    from repro.tuning import kernel_tune  # lazy: tuning imports core
    fmt_name = getattr(A.format, "name", str(A.format))
    rec = kernel_tune.best_config(A, op=op, ncols=ncols, cache=cache)
    if rec is not None and rec.speedup >= 1.0:
        _metrics.inc("kernel.route.pallas")
        if _trace.mode() != "off":
            _trace.event("kernel.route", op=op, route="pallas",
                         fmt=fmt_name, cfg=str(dict(rec.cfg)))
        if _ledger.enabled():
            _ledger.record("kernel.route", op=op, fmt=fmt_name,
                           route="pallas", kernel=_route_kernel_dict(rec),
                           bucket=_route_bucket(A, op, ncols))
        return "pallas", dict(rec.cfg)
    # distinguish "no record" from "a record exists but measured slower"
    _metrics.inc("kernel.route.veto" if rec is not None else "kernel.route.ref")
    if _trace.mode() != "off":
        _trace.event("kernel.route", op=op,
                     route="veto" if rec is not None else "ref",
                     fmt=fmt_name)
    if _ledger.enabled():
        if rec is not None:
            _ledger.record("kernel.route", op=op, fmt=fmt_name, route="veto",
                           kernel=_route_kernel_dict(rec),
                           bucket=_route_bucket(A, op, ncols),
                           reason=f"cached kernel measured {rec.speedup:.2f}x "
                                  "vs ref (< 1.0) — reference path kept")
        else:
            _ledger.record("kernel.route", op=op, fmt=fmt_name, route="ref",
                           bucket=_route_bucket(A, op, ncols),
                           reason="no tuned record for this bucket — an "
                                  "unmeasured kernel is never presumed faster")
    return "ref", None


def _route_kernel_dict(rec) -> dict:
    return {"fmt": rec.fmt, "op": rec.op, "cfg": dict(rec.cfg),
            "kernel_us": float(rec.kernel_us), "ref_us": float(rec.ref_us),
            "speedup": float(rec.speedup)}


def _route_bucket(A, op: str, ncols) -> str:
    """The cache key ``kernel_route`` consulted (ledger context only)."""
    from repro.tuning import kernel_tune
    try:
        return kernel_tune.kernel_key(
            A.format, A.shape[0], A.shape[1],
            max(1, int(getattr(A, "nnz", 1))), op=op, ncols=ncols)
    except Exception:
        return "?"


def _spmv_coo(A: COO, x):
    contrib = A.data * jnp.take(x, A.col, mode="clip")
    return jax.ops.segment_sum(contrib, A.row, num_segments=A.shape[0])


def _spmv_csr(A: CSR, x):
    # TPU adaptation: no warp-per-row — recover row ids from indptr and use a
    # vectorised gather + segment reduction (see DESIGN.md §2).
    rows = csr_row_ids(A.indptr, A.capacity, A.shape[0])
    contrib = A.data * jnp.take(x, A.indices, mode="clip")
    return jax.ops.segment_sum(contrib, rows, num_segments=A.shape[0])


# Beyond this many diagonals the per-diagonal code duplication of a fully
# unrolled scan stops paying for itself (and DIA is the wrong format anyway).
_DIA_UNROLL_MAX = 64


def _spmv_dia(A: DIA, x):
    # The format's whole point: one *contiguous* shifted multiply-add per
    # diagonal, zero gathers. x is zero-padded by M on both sides so the
    # shifted window x[i + off] is a plain dynamic_slice for any offset in
    # [-(M-1), N-1], with out-of-matrix reads landing on the zero padding
    # (container invariant: data is zero wherever the diagonal leaves the
    # matrix, so no validity masking is needed).
    m, n = A.shape
    xp = jnp.pad(x, (m, m))

    def one_diag(acc, od):
        off, drow = od
        w = jax.lax.dynamic_slice(xp, (off + m,), (m,))
        return acc + drow * w, None

    acc0 = jnp.zeros((m,), jnp.result_type(A.dtype, x.dtype))
    acc, _ = jax.lax.scan(one_diag, acc0,
                          (A.offsets.astype(jnp.int32), A.data),
                          unroll=min(A.ndiag, _DIA_UNROLL_MAX))
    return acc


def _spmv_ell(A: ELL, x):
    return jnp.sum(A.data * jnp.take(x, A.cols, mode="clip"), axis=1)


def _spmv_bsr(A: BSR, x):
    bs = A.block_size
    m, n = A.shape
    xb = x.reshape(n // bs, bs)
    gathered = jnp.take(xb, A.indices, axis=0, mode="clip")  # (nblk, bs)
    prod = jnp.einsum("nij,nj->ni", A.data, gathered)
    k = jnp.arange(A.nblocks, dtype=jnp.int32)
    brow = jnp.searchsorted(A.indptr, k, side="right").astype(jnp.int32) - 1
    brow = jnp.clip(brow, 0, m // bs - 1)
    yb = jax.ops.segment_sum(prod, brow, num_segments=m // bs)
    return yb.reshape(m)


def _spmv_dense(A: Dense, x):
    return A.data @ x


def _spmv_hyb(A: HYB, x):
    return _spmv_ell(A.ell, x) + _spmv_coo(A.coo, x)


def sell_sorted_ids(slice_ptrs, c: int, capacity: int, nslices: int):
    """Per-entry *sorted row position* of a flat SELL layout (jit-able).

    The SELL analogue of :func:`csr_row_ids`: recover each stored entry's
    (slice, lane) from the slice-pointer array in one vectorised
    searchsorted — column-major within a slice means position ``q`` of
    slice ``s`` sits on lane ``(q - slice_ptrs[s]) % C``. Used by the
    diagonal update/extract paths; the reference SpMV/SpMM reduce over
    whole planes instead (:func:`_sell_plane_ids` — one searchsorted per
    *plane*, not per entry).
    """
    q = jnp.arange(capacity, dtype=jnp.int32)
    s = jnp.searchsorted(slice_ptrs, q, side="right").astype(jnp.int32) - 1
    s = jnp.clip(s, 0, nslices - 1)
    lane = (q - slice_ptrs[s]) % c
    return s * c + lane


def _sell_plane_ids(A: SELL):
    """Slice id of each width *plane* (capacity is always a multiple of C,
    so the flat arrays are exactly ``capacity // C`` planes of C lanes)."""
    t = A.capacity // A.c
    sid = jnp.searchsorted(A.slice_ptrs,
                           jnp.arange(t, dtype=jnp.int32) * A.c,
                           side="right").astype(jnp.int32) - 1
    return jnp.clip(sid, 0, A.nslices - 1)


def _spmv_sell(A: SELL, x):
    # plane-wise: one (planes, C) gather + a segment reduction over planes
    # grouped by slice — far cheaper than per-entry segment ids over the
    # padded capacity.
    m = A.shape[0]
    c = A.c
    t = A.capacity // c
    contrib = A.data.reshape(t, c) * jnp.take(x, A.cols.reshape(t, c),
                                              mode="clip")
    y_sorted = jax.ops.segment_sum(contrib, _sell_plane_ids(A),
                                   num_segments=A.nslices).reshape(-1)
    # ghost lanes carry perm == m and are dropped by the OOB scatter
    return jnp.zeros((m,), y_sorted.dtype).at[A.perm].add(y_sorted)


_SPMV = {COO: _spmv_coo, CSR: _spmv_csr, DIA: _spmv_dia, ELL: _spmv_ell,
         BSR: _spmv_bsr, Dense: _spmv_dense, HYB: _spmv_hyb,
         SELL: _spmv_sell}


def spmv(A, x, backend: str = "ref", cfg=None):
    """y = A @ x. ``backend='ref'`` pure-jnp; ``'pallas'`` TPU kernels where
    available (CSR/DIA/ELL/BSR/HYB), falling back to ref otherwise;
    ``'auto'`` picks pallas exactly when a measured kernel config beats the
    reference path (see :func:`kernel_route`) and threads that config.
    ``cfg`` overrides the kernel tile config (dict, e.g. ``{"tm": 256,
    "tk": 2048}``); None uses the tuned winner (auto) or the density
    heuristic (pallas)."""
    if isinstance(A, _DYN_TYPES):
        return A.spmv(x, backend=backend, cfg=cfg)
    if backend == "auto":
        backend, auto_cfg = kernel_route(A)
        cfg = cfg if cfg is not None else auto_cfg
    if backend == "pallas":
        from repro.kernels import ops as kops  # lazy: keep core import-light
        fn = kops.SPMV_PALLAS.get(type(A))
        if fn is not None:
            return fn(A, x, cfg=cfg)
    return _SPMV[type(A)](A, x)


# ---------------------------------------------------------------------------
# SpMM: Y = A @ B (B dense, column-major tiles on TPU)
# ---------------------------------------------------------------------------


def _spmm_coo(A: COO, B):
    contrib = A.data[:, None] * jnp.take(B, A.col, axis=0, mode="clip")
    return jax.ops.segment_sum(contrib, A.row, num_segments=A.shape[0])


def _spmm_csr(A: CSR, B):
    rows = csr_row_ids(A.indptr, A.capacity, A.shape[0])
    contrib = A.data[:, None] * jnp.take(B, A.indices, axis=0, mode="clip")
    return jax.ops.segment_sum(contrib, rows, num_segments=A.shape[0])


def _spmm_dia(A: DIA, B):
    m, n = A.shape
    i = jnp.arange(m, dtype=jnp.int32)[None, :]
    cols = i + A.offsets[:, None].astype(jnp.int32)
    valid = (cols >= 0) & (cols < n)
    bv = jnp.take(B, jnp.clip(cols, 0, n - 1), axis=0, mode="clip")  # (nd, M, K)
    return jnp.sum(jnp.where(valid[..., None], A.data[..., None] * bv, 0), axis=0)


def _spmm_ell(A: ELL, B):
    bv = jnp.take(B, A.cols, axis=0, mode="clip")  # (M, K, Kb)
    return jnp.sum(A.data[..., None] * bv, axis=1)


def _spmm_bsr(A: BSR, B):
    # The MXU path: every stored block is a (bs x bs) x (bs x Kb) matmul.
    bs = A.block_size
    m, n = A.shape
    kb = B.shape[1]
    Bb = B.reshape(n // bs, bs, kb)
    gathered = jnp.take(Bb, A.indices, axis=0, mode="clip")  # (nblk, bs, Kb)
    prod = jnp.einsum("nij,njk->nik", A.data, gathered)
    k = jnp.arange(A.nblocks, dtype=jnp.int32)
    brow = jnp.searchsorted(A.indptr, k, side="right").astype(jnp.int32) - 1
    brow = jnp.clip(brow, 0, m // bs - 1)
    yb = jax.ops.segment_sum(prod, brow, num_segments=m // bs)
    return yb.reshape(m, kb)


def _spmm_dense(A: Dense, B):
    return A.data @ B


def _spmm_hyb(A: HYB, B):
    return _spmm_ell(A.ell, B) + _spmm_coo(A.coo, B)


def _spmm_sell(A: SELL, B):
    m = A.shape[0]
    kb = B.shape[1]
    c = A.c
    t = A.capacity // c
    bv = jnp.take(B, A.cols.reshape(t, c), axis=0, mode="clip")  # (t, c, Kb)
    contrib = A.data.reshape(t, c)[..., None] * bv
    y_sorted = jax.ops.segment_sum(contrib, _sell_plane_ids(A),
                                   num_segments=A.nslices)
    y_sorted = y_sorted.reshape(A.nslices * c, kb)
    return jnp.zeros((m, kb), y_sorted.dtype).at[A.perm].add(y_sorted)


_SPMM = {COO: _spmm_coo, CSR: _spmm_csr, DIA: _spmm_dia, ELL: _spmm_ell,
         BSR: _spmm_bsr, Dense: _spmm_dense, HYB: _spmm_hyb,
         SELL: _spmm_sell}


def spmm(A, B, backend: str = "ref", cfg=None):
    """Y = A @ B with dense B of shape (N, K). ``backend``/``cfg`` as in
    :func:`spmv` (auto routing keys on the ``op="spmm"`` records, bucketed
    by the rhs width K — a winner measured at one batch width never
    routes another)."""
    if isinstance(A, _DYN_TYPES):
        return A.spmm(B, backend=backend, cfg=cfg)
    if backend == "auto":
        backend, auto_cfg = kernel_route(A, op="spmm", ncols=B.shape[1])
        cfg = cfg if cfg is not None else auto_cfg
    if backend == "pallas":
        from repro.kernels import ops as kops
        fn = kops.SPMM_PALLAS.get(type(A))
        if fn is not None:
            return fn(A, B, cfg=cfg)
    return _SPMM[type(A)](A, B)


def spmm_t(A, X, backend: str = "ref", cfg=None):
    """Y = X @ A^T for activations X of shape (T, N); returns (T, M).

    The serving orientation: ``LinearSparse`` keeps its weight transposed
    ((d_out, d_in)) and activations row-major, so this is the layer
    matmul with **no activation transposes** on the Pallas path. The
    reference path *is* the classic double transpose
    (``spmm(A, X.T).T``) — the baseline the equivalence tests compare
    against, and what the fused-transpose kernels must beat to route.
    Auto routing keys on ``op="spmm_t"`` records bucketed by T.
    """
    if isinstance(A, _DYN_TYPES):
        return A.spmm_t(X, backend=backend, cfg=cfg)
    if backend == "auto":
        backend, auto_cfg = kernel_route(A, op="spmm_t", ncols=X.shape[0])
        cfg = cfg if cfg is not None else auto_cfg
    if backend == "pallas":
        from repro.kernels import ops as kops
        fn = kops.SPMM_T_PALLAS.get(type(A))
        if fn is not None:
            return fn(A, X, cfg=cfg)
    return _SPMM[type(A)](A, X.T).T


# ---------------------------------------------------------------------------
# Diagonal extract / update (HPCG's TestCG mutates the diagonal)
# ---------------------------------------------------------------------------


def extract_diagonal(A):
    m, n = A.shape
    d = min(m, n)
    if isinstance(A, HYB):
        return extract_diagonal(A.ell) + extract_diagonal(A.coo)
    if isinstance(A, COO):
        on = (A.row == A.col) & (A.row < d)
        return jax.ops.segment_sum(jnp.where(on, A.data, 0), jnp.clip(A.row, 0, d - 1), num_segments=d)
    if isinstance(A, CSR):
        from repro.core.convert import csr_to_coo
        return extract_diagonal(csr_to_coo(A))
    if isinstance(A, DIA):
        slot = jnp.argmax(A.offsets == 0)
        has = jnp.any(A.offsets == 0)
        return jnp.where(has, A.data[slot, :d], 0)
    if isinstance(A, ELL):
        i = jnp.arange(A.shape[0], dtype=jnp.int32)[:, None]
        on = A.cols == i
        return jnp.sum(jnp.where(on, A.data, 0), axis=1)[:d]
    if isinstance(A, BSR):
        from repro.core.convert import bsr_to_coo
        return extract_diagonal(bsr_to_coo(A))
    if isinstance(A, SELL):
        from repro.core.convert import sell_to_coo
        return extract_diagonal(sell_to_coo(A))
    if isinstance(A, Dense):
        return jnp.diagonal(A.data)[:d]
    raise TypeError(type(A))


def update_diagonal(A, new_diag):
    """Replace the main diagonal values (pattern must already contain it)."""
    if isinstance(A, COO):
        on = (A.row == A.col)
        return COO(A.row, A.col, jnp.where(on, jnp.take(new_diag, jnp.clip(A.row, 0, new_diag.shape[0] - 1), mode="clip"), A.data), A.shape, A.nnz)
    if isinstance(A, CSR):
        rows = csr_row_ids(A.indptr, A.capacity, A.shape[0])
        on = A.indices == rows
        return CSR(A.indptr, A.indices, jnp.where(on, jnp.take(new_diag, rows, mode="clip"), A.data), A.shape, A.nnz)
    if isinstance(A, DIA):
        slot = jnp.argmax(A.offsets == 0)
        row = jnp.zeros((A.data.shape[1],), A.dtype).at[:new_diag.shape[0]].set(new_diag.astype(A.dtype))
        return DIA(A.offsets, A.data.at[slot].set(row), A.shape, A.nnz)
    if isinstance(A, ELL):
        i = jnp.arange(A.shape[0], dtype=jnp.int32)[:, None]
        on = A.cols == i
        vals = jnp.take(new_diag, jnp.clip(i[:, 0], 0, new_diag.shape[0] - 1), mode="clip")[:, None]
        return ELL(A.cols, jnp.where(on, vals, A.data), A.shape, A.nnz)
    if isinstance(A, SELL):
        p = sell_sorted_ids(A.slice_ptrs, A.c, A.capacity, A.nslices)
        rows = jnp.take(A.perm, p, mode="clip")
        on = A.cols == rows  # padding col=-1 never matches a row id
        vals = jnp.take(new_diag,
                        jnp.clip(rows, 0, new_diag.shape[0] - 1), mode="clip")
        return SELL(A.cols, jnp.where(on, vals, A.data), A.perm,
                    A.slice_ptrs, A.shape, A.nnz, A.c, A.sigma)
    if isinstance(A, Dense):
        d = min(A.shape)
        i = jnp.arange(d)
        return Dense(A.data.at[i, i].set(new_diag[:d].astype(A.dtype)), A.shape, A.nnz)
    raise TypeError(type(A))


# ---------------------------------------------------------------------------
# Dense-vector algorithms (paper §III-D: dot, WAXPBY, reduction, assign)
# ---------------------------------------------------------------------------


def dot(x, y):
    return jnp.dot(x, y)


def waxpby(alpha, x, beta, y):
    """w = alpha*x + beta*y (HPCG's vector update)."""
    return alpha * x + beta * y


def axpy(alpha, x, y):
    return alpha * x + y


def norm2(x):
    return jnp.sqrt(jnp.dot(x, x))


def assign(x, value):
    """Morpheus::assign — fill (ZeroVector when value == 0)."""
    return jnp.full_like(x, value)


def reduction(x):
    return jnp.sum(x)


def scan(x):
    return jnp.cumsum(x)


# populated by repro.core.dynamic to avoid a circular import
_DYN_TYPES: tuple = ()


def _register_dynamic(*types):
    global _DYN_TYPES
    _DYN_TYPES = tuple(set(_DYN_TYPES) | set(types))
