"""Sparse-matrix storage-format containers (the paper's "concrete formats").

Each container is a registered JAX pytree dataclass, uniformly parameterized
by value/index dtype and carrying *static* shape/capacity metadata so that
format switches are jit-stable (the TPU analogue of the paper's
"containers resolved at compile time").

Padding convention: containers are capacity-padded; padding entries are
(row=0, col=0, val=0) which contribute nothing under SpMV accumulate
semantics. `nnz` (the *logical* number of stored entries) is static metadata.

Formats:
  COO    - coordinate list; the conversion proxy format (paper §III-B).
  CSR    - compressed sparse row; the paper's reference format.
  DIA    - diagonal; the paper's winner for stencil matrices; ideal on TPU
           (contiguous shifted vector ops, zero gathers).
  ELL    - ELLPACK padded rows; TPU-friendly gather + dense reduce.
  BSR    - block CSR with MXU-aligned blocks (beyond-paper, TPU-native).
  Dense  - dense fallback for the near-dense small-problem regime.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Format(enum.IntEnum):
    """Enum of supported storage formats (paper's `formats_e`)."""

    COO = 0
    CSR = 1
    DIA = 2
    ELL = 3
    BSR = 4
    DENSE = 5
    HYB = 6
    SELL = 7


def _register(cls):
    """Register a dataclass container as a pytree (data vs. meta fields)."""
    data_fields = [f.name for f in dataclasses.fields(cls) if f.metadata.get("pytree_node", True)]
    meta_fields = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("pytree_node", True)]
    return jax.tree_util.register_dataclass(cls, data_fields, meta_fields)


def static_field():
    return dataclasses.field(metadata={"pytree_node": False})


@_register
@dataclasses.dataclass(frozen=True)
class COO:
    """Coordinate format: explicit (row, col, val) triplets, no ordering."""

    row: jax.Array  # (capacity,) int32
    col: jax.Array  # (capacity,) int32
    data: jax.Array  # (capacity,) values
    shape: Tuple[int, int] = static_field()
    nnz: int = static_field()  # logical nnz (<= capacity)

    format = Format.COO

    @property
    def capacity(self) -> int:
        return int(self.data.shape[0])

    @property
    def dtype(self):
        return self.data.dtype

    def tree_flatten(self):  # pragma: no cover - convenience
        return (self.row, self.col, self.data), (self.shape, self.nnz)


@_register
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row: row-pointer array + (col, val) pairs.

    Entries are sorted by row (CSR's intrinsic ordering); padding lives past
    ``indptr[-1]`` with val=0/col=0 and is dropped by segment-sum.
    """

    indptr: jax.Array  # (M+1,) int32
    indices: jax.Array  # (capacity,) int32 column indices
    data: jax.Array  # (capacity,) values
    shape: Tuple[int, int] = static_field()
    nnz: int = static_field()

    format = Format.CSR

    @property
    def capacity(self) -> int:
        return int(self.data.shape[0])

    @property
    def dtype(self):
        return self.data.dtype


@_register
@dataclasses.dataclass(frozen=True)
class DIA:
    """Diagonal format.

    ``data[d, i]`` holds A[i, i + offsets[d]] (cusp convention, padded with
    zeros where the diagonal leaves the matrix). Rectangular matrices are
    supported: offsets range over [-(M-1), N-1].
    """

    offsets: jax.Array  # (ndiag,) int32 diagonal offsets (k = col - row)
    data: jax.Array  # (ndiag, M) values
    shape: Tuple[int, int] = static_field()
    nnz: int = static_field()

    format = Format.DIA

    @property
    def ndiag(self) -> int:
        return int(self.data.shape[0])

    @property
    def dtype(self):
        return self.data.dtype


@_register
@dataclasses.dataclass(frozen=True)
class ELL:
    """ELLPACK: every row padded to K entries; column-index + value planes."""

    cols: jax.Array  # (M, K) int32
    data: jax.Array  # (M, K) values
    shape: Tuple[int, int] = static_field()
    nnz: int = static_field()

    format = Format.ELL

    @property
    def k(self) -> int:
        return int(self.data.shape[1])

    @property
    def dtype(self):
        return self.data.dtype


@_register
@dataclasses.dataclass(frozen=True)
class BSR:
    """Block CSR: (bs x bs) dense blocks addressed CSR-style by block row.

    TPU-native: each stored block feeds the MXU directly. Capacity-padded
    with zero blocks pointing at block-column 0.
    """

    indptr: jax.Array  # (Mb+1,) int32 block-row pointers
    indices: jax.Array  # (blk_capacity,) int32 block-column indices
    data: jax.Array  # (blk_capacity, bs, bs) values
    shape: Tuple[int, int] = static_field()  # element shape (multiple of bs)
    nnz: int = static_field()  # logical element nnz
    block_size: int = static_field()

    format = Format.BSR

    @property
    def nblocks(self) -> int:
        return int(self.data.shape[0])

    @property
    def dtype(self):
        return self.data.dtype


@_register
@dataclasses.dataclass(frozen=True)
class Dense:
    """Dense matrix container (paper's DenseMatrix)."""

    data: jax.Array  # (M, N)
    shape: Tuple[int, int] = static_field()
    nnz: int = static_field()

    format = Format.DENSE

    @property
    def dtype(self):
        return self.data.dtype


@_register
@dataclasses.dataclass(frozen=True)
class HYB:
    """Hybrid ELL + COO (Bell & Garland; cited by the paper as HYB [15]).

    The regular part of each row (up to k entries) lives in the ELL planes;
    the irregular overflow lives in COO — the classic fix for ELL's
    worst-case padding on power-law row lengths. Demonstrates the paper's
    extensibility claim: added without touching DynamicMatrix/algorithms.
    """

    ell: "ELL"
    coo: "COO"
    shape: Tuple[int, int] = static_field()
    nnz: int = static_field()

    format = Format.HYB

    @property
    def dtype(self):
        return self.ell.data.dtype

    @property
    def k(self) -> int:
        return self.ell.k


@_register
@dataclasses.dataclass(frozen=True)
class SELL:
    """SELL-C-sigma: sliced ELLPACK with sigma-window row sorting
    (Kreutzer et al., arXiv:1307.6209).

    Rows are sorted by descending length within sigma-row windows, then
    grouped into slices of C consecutive sorted rows; each slice is padded
    only to its *own* max width — the fix for ELL's global-kmax padding
    blowup on irregular (e.g. power-law) row lengths.

    Storage is flat and column-major within a slice: the entry at lane
    ``r`` (0 <= r < C) and plane ``j`` of slice ``s`` lives at
    ``slice_ptrs[s] + j*C + r``, so every plane is C contiguous lanes —
    SpMV is a dense gather+FMA over contiguous vectors per plane, with one
    output element per lane and no segmented reduction. ``perm[p]`` is the
    original row index stored at sorted position ``p``; ghost lanes past M
    map to row index M (dropped by the out-of-bounds scatter), and padding
    entries carry col=0/val=0 (inert under accumulate).
    """

    cols: jax.Array  # (capacity,) int32, column-major within each slice
    data: jax.Array  # (capacity,) values
    perm: jax.Array  # (nslices*C,) int32 original row at sorted position
    slice_ptrs: jax.Array  # (nslices+1,) int32 flat offset of each slice
    shape: Tuple[int, int] = static_field()
    nnz: int = static_field()
    c: int = static_field()  # slice height C
    sigma: int = static_field()  # sort-window height (multiple of C)

    format = Format.SELL

    @property
    def nslices(self) -> int:
        return int(self.slice_ptrs.shape[-1]) - 1

    @property
    def capacity(self) -> int:
        return int(self.data.shape[-1])

    @property
    def dtype(self):
        return self.data.dtype


SparseMatrix = (COO, CSR, DIA, ELL, BSR, Dense, HYB, SELL)

FORMAT_TO_CLS = {
    Format.COO: COO,
    Format.CSR: CSR,
    Format.DIA: DIA,
    Format.ELL: ELL,
    Format.BSR: BSR,
    Format.DENSE: Dense,
    Format.HYB: HYB,
    Format.SELL: SELL,
}


# ---------------------------------------------------------------------------
# Host-side builders (setup phase; numeric data may later be updated on device)
# ---------------------------------------------------------------------------

def coo_from_arrays(row, col, val, shape, capacity=None, dtype=jnp.float32) -> COO:
    """Build a COO container from host triplets, padding to ``capacity``."""
    row = np.asarray(row, dtype=np.int32)
    col = np.asarray(col, dtype=np.int32)
    val = np.asarray(val)
    nnz = int(row.shape[0])
    cap = int(capacity) if capacity is not None else nnz
    if cap < nnz:
        raise ValueError(f"capacity {cap} < nnz {nnz}")
    r = np.zeros((cap,), np.int32)
    c = np.zeros((cap,), np.int32)
    v = np.zeros((cap,), np.dtype(dtype))
    r[:nnz], c[:nnz], v[:nnz] = row, col, val
    return COO(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v), tuple(shape), nnz)


def dense_from_array(a, dtype=None) -> Dense:
    a = jnp.asarray(a, dtype=dtype)
    return Dense(a, tuple(a.shape), int(a.shape[0] * a.shape[1]))


def coo_from_dense_np(a: np.ndarray, capacity=None, dtype=None) -> COO:
    """Host helper: extract non-zeros of a dense numpy matrix into COO."""
    a = np.asarray(a)
    row, col = np.nonzero(a)
    order = np.lexsort((col, row))
    row, col = row[order], col[order]
    val = a[row, col]
    return coo_from_arrays(row, col, val, a.shape, capacity, dtype or a.dtype)


def random_coo(key, shape, density=0.05, capacity=None, dtype=jnp.float32) -> COO:
    """Random sparse matrix for tests/benchmarks (host-side)."""
    m, n = shape
    rng = np.random.default_rng(int(key) if not hasattr(key, "shape") else int(jax.random.randint(key, (), 0, 2**31 - 1)))
    nnz = max(1, int(density * m * n))
    lin = rng.choice(m * n, size=nnz, replace=False)
    lin.sort()
    row, col = lin // n, lin % n
    val = rng.standard_normal(nnz).astype(np.dtype(dtype))
    # Avoid exact zeros so nnz is meaningful.
    val = np.where(np.abs(val) < 1e-3, 1e-3, val)
    return coo_from_arrays(row, col, val, shape, capacity, dtype)


def banded_coo(shape, offsets, fill=None, dtype=jnp.float32, capacity=None) -> COO:
    """Banded (multi-diagonal) matrix — the stencil-like regular pattern."""
    m, n = shape
    rows, cols, vals = [], [], []
    for d_i, off in enumerate(offsets):
        r = np.arange(max(0, -off), min(m, n - off), dtype=np.int64)
        c = r + off
        rows.append(r)
        cols.append(c)
        if fill is None:
            vals.append(np.full(r.shape, float(len(offsets) - d_i), np.dtype(dtype)))
        else:
            vals.append(np.full(r.shape, fill[d_i], np.dtype(dtype)))
    row = np.concatenate(rows)
    col = np.concatenate(cols)
    val = np.concatenate(vals)
    order = np.lexsort((col, row))
    return coo_from_arrays(row[order], col[order], val[order], shape, capacity, dtype)


def to_dense_np(A) -> np.ndarray:
    """Host-side densification (oracle for tests)."""
    if isinstance(A, HYB):
        return to_dense_np(A.ell) + to_dense_np(A.coo)
    m, n = A.shape
    out = np.zeros((m, n), dtype=np.asarray(A.data).dtype)
    if isinstance(A, COO):
        r, c, v = np.asarray(A.row), np.asarray(A.col), np.asarray(A.data)
        np.add.at(out, (r, c), v)
    elif isinstance(A, CSR):
        indptr = np.asarray(A.indptr)
        idx, v = np.asarray(A.indices), np.asarray(A.data)
        for i in range(m):
            sl = slice(indptr[i], indptr[i + 1])
            np.add.at(out, (np.full(indptr[i + 1] - indptr[i], i), idx[sl]), v[sl])
    elif isinstance(A, DIA):
        offs, d = np.asarray(A.offsets), np.asarray(A.data)
        for k in range(d.shape[0]):
            off = int(offs[k])
            i = np.arange(max(0, -off), min(m, n - off))
            out[i, i + off] += d[k, i]
    elif isinstance(A, ELL):
        cols, v = np.asarray(A.cols), np.asarray(A.data)
        for i in range(m):
            np.add.at(out[i], cols[i], v[i])
    elif isinstance(A, BSR):
        bs = A.block_size
        indptr = np.asarray(A.indptr)
        idx, v = np.asarray(A.indices), np.asarray(A.data)
        for bi in range(len(indptr) - 1):
            for p in range(indptr[bi], indptr[bi + 1]):
                bj = idx[p]
                out[bi * bs:(bi + 1) * bs, bj * bs:(bj + 1) * bs] += v[p]
    elif isinstance(A, SELL):
        cols, v = np.asarray(A.cols), np.asarray(A.data)
        perm, ptrs = np.asarray(A.perm), np.asarray(A.slice_ptrs)
        C = A.c
        for s in range(ptrs.shape[0] - 1):
            w = (int(ptrs[s + 1]) - int(ptrs[s])) // C
            for r in range(C):
                i = int(perm[s * C + r])
                if i >= m:
                    continue  # ghost lane past the last row
                sl = int(ptrs[s]) + r + C * np.arange(w)
                np.add.at(out[i], np.clip(cols[sl], 0, n - 1), v[sl])
    elif isinstance(A, Dense):
        out = np.asarray(A.data).copy()
    else:
        raise TypeError(type(A))
    return out


# ---------------------------------------------------------------------------
# Copy semantics (paper §III-B)
# ---------------------------------------------------------------------------

def shallow_copy(A):
    """Shallow copy: JAX arrays are immutable — aliasing is free and safe.

    Mirrors the paper's same-type requirement: the result *is* the same
    container type with the same buffers.
    """
    return A


def deep_copy(A, sharding=None):
    """Deep (bitwise) copy; with ``sharding`` this is the mirroring interface
    (HostMirror/device transfer analogue): a cross-memory-space memcpy."""
    if sharding is None:
        return jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), A)
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, sharding), A)


def bytes_of(A) -> int:
    """Total payload bytes of a container (for the analytic autotuner)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(A))
