"""HPCG problem substrate (paper §II-B, §IV-B).

Synthetic Poisson problem on a regular 3D grid, 27-point stencil — the
matrix whose regular, diagonal-dominated pattern makes DIA the winning
format on a single node, and whose MPI local/remote split creates the
irregular remote part motivating per-part/per-shard format selection.

Grid ordering is x-fastest (idx = x + nx*(y + ny*z)); partitioning along z
in whole planes makes every remote column fall in the neighbouring slab's
boundary plane => halo width = nx*ny per side (neighbor exchange).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.formats import COO, coo_from_arrays


@dataclasses.dataclass(frozen=True)
class HPCGProblem:
    nx: int
    ny: int
    nz: int
    row: np.ndarray
    col: np.ndarray
    val: np.ndarray
    shape: Tuple[int, int]

    @property
    def nrows(self) -> int:
        return self.nx * self.ny * self.nz


def generate_problem(nx: int, ny: int, nz: int, dtype=np.float32) -> HPCGProblem:
    """27-point stencil: diag = 26, off-diag = -1 (HPCG's synthetic system)."""
    n = nx * ny * nz
    x, y, z = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij")
    # row index, x-fastest ordering
    idx = (x + nx * (y + ny * z)).ravel()
    xs, ys, zs = x.ravel(), y.ravel(), z.ravel()

    rows, cols, vals = [], [], []
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                nxp, nyp, nzp = xs + dx, ys + dy, zs + dz
                ok = ((nxp >= 0) & (nxp < nx) & (nyp >= 0) & (nyp < ny)
                      & (nzp >= 0) & (nzp < nz))
                r = idx[ok]
                c = (nxp + nx * (nyp + ny * nzp))[ok]
                v = np.where(r == c, 26.0, -1.0).astype(dtype)
                rows.append(r)
                cols.append(c)
                vals.append(v)
    row = np.concatenate(rows)
    col = np.concatenate(cols)
    val = np.concatenate(vals)
    order = np.lexsort((col, row))
    return HPCGProblem(nx, ny, nz, row[order].astype(np.int64),
                       col[order].astype(np.int64), val[order], (n, n))


def to_coo(prob: HPCGProblem, capacity: Optional[int] = None,
           dtype=jnp.float32) -> COO:
    return coo_from_arrays(prob.row, prob.col, prob.val, prob.shape,
                           capacity=capacity, dtype=dtype)


def rhs_for_ones(prob: HPCGProblem, dtype=np.float32) -> np.ndarray:
    """b = A @ 1 — HPCG's exact solution is the all-ones vector."""
    b = np.zeros(prob.shape[0], dtype=np.float64)
    np.add.at(b, prob.row, prob.val.astype(np.float64))
    return b.astype(dtype)


def exact_solution(prob: HPCGProblem, dtype=np.float32) -> np.ndarray:
    return np.ones(prob.shape[0], dtype=dtype)
