"""HPCG problem substrate (paper §II-B, §IV-B).

Synthetic Poisson problem on a regular 3D grid, 27-point stencil — the
matrix whose regular, diagonal-dominated pattern makes DIA the winning
format on a single node, and whose MPI local/remote split creates the
irregular remote part motivating per-part/per-shard format selection.

Grid ordering is x-fastest (idx = x + nx*(y + ny*z)); partitioning along z
in whole planes makes every remote column fall in the neighbouring slab's
boundary plane => halo width = nx*ny per side (neighbor exchange).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.formats import COO, coo_from_arrays


@dataclasses.dataclass(frozen=True)
class HPCGProblem:
    nx: int
    ny: int
    nz: int
    row: np.ndarray
    col: np.ndarray
    val: np.ndarray
    shape: Tuple[int, int]

    @property
    def nrows(self) -> int:
        return self.nx * self.ny * self.nz


def generate_problem(nx: int, ny: int, nz: int, dtype=np.float32) -> HPCGProblem:
    """27-point stencil: diag = 26, off-diag = -1 (HPCG's synthetic system)."""
    n = nx * ny * nz
    x, y, z = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij")
    # row index, x-fastest ordering
    idx = (x + nx * (y + ny * z)).ravel()
    xs, ys, zs = x.ravel(), y.ravel(), z.ravel()

    rows, cols, vals = [], [], []
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                nxp, nyp, nzp = xs + dx, ys + dy, zs + dz
                ok = ((nxp >= 0) & (nxp < nx) & (nyp >= 0) & (nyp < ny)
                      & (nzp >= 0) & (nzp < nz))
                r = idx[ok]
                c = (nxp + nx * (nyp + ny * nzp))[ok]
                v = np.where(r == c, 26.0, -1.0).astype(dtype)
                rows.append(r)
                cols.append(c)
                vals.append(v)
    row = np.concatenate(rows)
    col = np.concatenate(cols)
    val = np.concatenate(vals)
    order = np.lexsort((col, row))
    return HPCGProblem(nx, ny, nz, row[order].astype(np.int64),
                       col[order].astype(np.int64), val[order], (n, n))


def to_coo(prob: HPCGProblem, capacity: Optional[int] = None,
           dtype=jnp.float32) -> COO:
    return coo_from_arrays(prob.row, prob.col, prob.val, prob.shape,
                           capacity=capacity, dtype=dtype)


def slab_plan(prob: HPCGProblem, nshards: int) -> "DistPlan":
    """Analytic :class:`~repro.core.distributed.DistPlan` for the z-slab
    partition of the stencil problem.

    The partition structure is known a priori — slabs of ``nz/P`` whole x-y
    planes, every remote column in the neighbouring slab's boundary plane,
    halo width ``nx*ny`` per side — so no reach scan over the global
    triplets is needed; the only data-dependent metadata (per-shard
    capacities) comes from one vectorised bincount. Feed the plan to
    ``build_dist_matrix(..., plan=..., check_plan=False)`` (the plan is
    correct by construction) and the global triplets are touched exactly
    once, by the on-device ``partition_execute`` scatter; with the default
    ``check_plan=True`` the builder additionally runs its one-pass
    stale-plan validation scan on host.
    """
    from repro.core.distributed import DistPlan, _split_caps

    n = prob.shape[0]
    if nshards <= 0 or prob.nz % nshards:
        raise ValueError(
            f"z-slab partition needs nz % P == 0, got nz={prob.nz} / {nshards}")
    mp = n // nshards
    shard = prob.row // mp
    local_mask = (prob.col // mp) == shard
    lcounts = np.bincount(shard[local_mask], minlength=nshards)
    rcounts = np.bincount(shard[~local_mask], minlength=nshards)
    remote_empty = nshards == 1
    # interior/boundary overlap caps (boundary = the slab's first/last x-y
    # planes): computed here so a split build skips its own host scan.
    icap, bcap = (None, None) if remote_empty else _split_caps(
        prob.row, prob.col, prob.val, mp, nshards)
    return DistPlan(nshards=nshards, mp=mp,
                    hw=0 if remote_empty else prob.nx * prob.ny,
                    halo_mode="neighbor", shape=prob.shape,
                    local_cap=max(1, int(lcounts.max())),
                    remote_cap=max(1, int(rcounts.max())),
                    remote_empty=remote_empty,
                    interior_cap=icap, boundary_cap=bcap)


def partition_problem(prob: HPCGProblem, nshards: int, dtype=jnp.float32):
    """Slab-aware problem partitioner: ``(local, remote, plan)``.

    Returns the stacked per-shard local/remote COO containers directly on
    device — the global triplets are never re-materialised into per-shard
    host copies (the pre-plan builder's second materialisation).
    """
    from repro.core.distributed import partition_execute_jit

    plan = slab_plan(prob, nshards)
    local, remote = partition_execute_jit(prob.row, prob.col, prob.val,
                                          plan=plan, dtype=dtype)
    return local, remote, plan


def rhs_for_ones(prob: HPCGProblem, dtype=np.float32) -> np.ndarray:
    """b = A @ 1 — HPCG's exact solution is the all-ones vector."""
    b = np.zeros(prob.shape[0], dtype=np.float64)
    np.add.at(b, prob.row, prob.val.astype(np.float64))
    return b.astype(dtype)


def exact_solution(prob: HPCGProblem, dtype=np.float32) -> np.ndarray:
    return np.ones(prob.shape[0], dtype=dtype)
