"""repro.core — Morpheus-JAX: dynamic sparse matrices (the paper's library).

Public API:
    Format, COO, CSR, DIA, ELL, BSR, Dense      containers
    convert, to_coo                             format conversion (COO proxy)
    DynamicMatrix, SwitchDynamicMatrix          dynamic abstractions
    spmv, spmm, dot, waxpby, axpy, norm2        algorithms
    autotune                                    per-matrix/shard format tuner
"""
from repro.core.autotune import PatternStats, TuneReport, analytic_select, autotune, profile_select
from repro.core.convert import (SwitchPlan, convert, convert_execute,
                                convert_execute_batch, coo_to_sell,
                                plan_switch, plan_switch_batch, sell_to_coo,
                                to_coo)
from repro.core.dynamic import DEFAULT_CANDIDATES, DynamicMatrix, SwitchDynamicMatrix
from repro.core.formats import (BSR, COO, CSR, DIA, ELL, SELL, Dense, Format,
                                HYB, banded_coo, bytes_of, coo_from_arrays,
                                coo_from_dense_np, deep_copy, dense_from_array,
                                random_coo, shallow_copy, to_dense_np)
from repro.core.ops import (assign, axpy, dot, extract_diagonal, norm2,
                            reduction, spmm, spmm_t, spmv, update_diagonal,
                            waxpby)

__all__ = [
    "Format", "COO", "CSR", "DIA", "ELL", "BSR", "Dense", "HYB", "SELL",
    "convert", "convert_execute", "convert_execute_batch", "plan_switch",
    "plan_switch_batch", "SwitchPlan", "to_coo", "coo_to_sell", "sell_to_coo",
    "DynamicMatrix", "SwitchDynamicMatrix",
    "DEFAULT_CANDIDATES", "spmv", "spmm", "spmm_t", "dot", "waxpby", "axpy",
    "norm2",
    "assign", "reduction", "extract_diagonal", "update_diagonal",
    "autotune", "profile_select", "analytic_select", "TuneReport",
    "PatternStats", "banded_coo", "random_coo", "coo_from_arrays",
    "coo_from_dense_np", "dense_from_array", "to_dense_np", "bytes_of",
    "shallow_copy", "deep_copy",
]
