"""JAX version-compat resolvers and tiny sharding helpers.

The repo targets the modern `jax.shard_map` / varying-axes API but must run
on JAX 0.4.x, where shard_map still lives in `jax.experimental.shard_map`
and `jax.lax.pcast` does not exist. Resolve once at import time; callers use
``compat.shard_map`` / ``compat.pcast`` and never touch the version split.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec


def leading_axis_spec(axis, ndim: int) -> PartitionSpec:
    """``P(axis, None, ...)`` — shard the leading axis, replicate the rest.

    The one spec every stacked shard container and batch tensor uses; shared
    by ``repro.core.distributed`` and ``repro.launch.sharding`` so the
    distributed layer and the model launcher agree on the convention.
    """
    return PartitionSpec(axis, *(None,) * (ndim - 1))


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, True
    from jax.experimental.shard_map import shard_map as fn  # JAX 0.4.x
    return fn, False


_SHARD_MAP, _NATIVE_SHARD_MAP = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs):
    """`jax.shard_map` where available, else the 0.4.x experimental one.

    The experimental version is called with ``check_rep=False``: its
    replication checker predates the pcast/varying API that the bodies here
    rely on to annotate device-varying carries, and rejects valid programs
    (ppermute carried through lax.scan).
    """
    if _NATIVE_SHARD_MAP:
        return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def pcast(x, axis_names, to: str = "varying"):
    """`jax.lax.pcast` where available; identity on 0.4.x (where shard_map
    runs with check_rep=False and needs no varying annotations)."""
    fn = getattr(jax.lax, "pcast", None)
    if fn is None:
        return x
    return fn(x, axis_names, to=to)
