"""Distributed dynamic sparse matrices (paper §V-E, DESIGN.md §5).

The paper's MPI design, mapped to JAX SPMD:

  * the global matrix is row-partitioned into P contiguous slabs, one per
    shard of a (possibly multi-axis) mesh partition;
  * each shard's rows split into a **local** square block (columns it owns —
    the regular part) and a **remote** rectangular block (columns owned by
    neighbours — the irregular part), each an independently-formatted
    dynamic matrix (the paper's key distributed observation);
  * SpMV = local SpMV + remote SpMV over halo values obtained by
    ``ExchangeHalo`` — here a ``ppermute`` neighbour exchange (slab
    partitions: stencil matrices) or an ``all_gather`` (general fallback);
  * per-shard format selection ("Multi-Format") uses ``SwitchDynamicMatrix``:
    one SPMD program, ``lax.switch`` on a per-shard format id.

Containers are *stacked*: every array gains a leading P axis which is
sharded over the mesh partition axes; inside ``shard_map`` each shard sees
its own slab (leading dim 1) and unstacks it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core.convert import convert as _convert_fn
from repro.core import ops as _ops
from repro.core.dynamic import DynamicMatrix, SwitchDynamicMatrix
from repro.core.formats import (BSR, COO, CSR, DIA, ELL, Dense, Format,
                                coo_from_arrays)

AxisNames = Union[str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# Stacking / unstacking shard containers
# ---------------------------------------------------------------------------


def stack_parts(parts: Sequence):
    """Stack P same-structure containers into one with a leading P axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *parts)


def _unstack(part):
    """Inside shard_map: strip the leading (length-1) shard axis."""
    return jax.tree.map(lambda a: a[0], part)


def _pad_coo(A: COO, capacity: int) -> COO:
    pad = capacity - A.capacity
    if pad <= 0:
        return A
    z = lambda a: jnp.pad(a, (0, pad))
    return COO(z(A.row), z(A.col), z(A.data), A.shape, A.nnz)


def uniform_capacity(parts: Sequence[COO]) -> Sequence[COO]:
    cap = max(p.capacity for p in parts)
    return [_pad_coo(p, cap) for p in parts]


# ---------------------------------------------------------------------------
# The distributed container
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class DistSparseMatrix:
    """Row-partitioned sparse matrix with local/remote split per shard.

    ``local``/``remote`` are stacked containers (or stacked
    SwitchDynamicMatrix for Multi-Format). ``halo_mode`` is ``"neighbor"``
    (remote columns renumbered into a [prev_tail | next_head] halo of width
    ``hw`` per side) or ``"gather"`` (remote columns are global ids).
    """

    def __init__(self, local, remote, *, nshards: int, mp: int, shape,
                 axis: AxisNames, halo_mode: str, hw: int):
        self.local = local
        self.remote = remote
        self.nshards = nshards
        self.mp = mp
        self.shape = tuple(shape)
        self.axis = axis
        self.halo_mode = halo_mode
        self.hw = hw

    def tree_flatten(self):
        meta = (self.nshards, self.mp, self.shape, self.axis, self.halo_mode, self.hw)
        return (self.local, self.remote), meta

    @classmethod
    def tree_unflatten(cls, meta, children):
        nshards, mp, shape, axis, halo_mode, hw = meta
        return cls(children[0], children[1], nshards=nshards, mp=mp,
                   shape=shape, axis=axis, halo_mode=halo_mode, hw=hw)

    def __repr__(self):
        lf = type(self.local).__name__
        rf = type(self.remote).__name__
        return (f"DistSparseMatrix(shape={self.shape}, P={self.nshards}, "
                f"local={lf}, remote={rf}, halo={self.halo_mode}:{self.hw})")


# ---------------------------------------------------------------------------
# Halo exchange (the paper's ExchangeHalo)
# ---------------------------------------------------------------------------


def _exchange_neighbor(x_blk, hw: int, axis: AxisNames, nshards: int):
    """[prev shard's last hw | next shard's first hw] via ppermute."""
    fwd = [(i, i + 1) for i in range(nshards - 1)]
    bwd = [(i + 1, i) for i in range(nshards - 1)]
    prev_tail = jax.lax.ppermute(x_blk[-hw:], axis, fwd)   # from p-1
    next_head = jax.lax.ppermute(x_blk[:hw], axis, bwd)    # from p+1
    return jnp.concatenate([prev_tail, next_head])


def _shard_spmv(local, remote, x_blk, hw: int, axis: AxisNames, nshards: int,
                halo_mode: str, backend: str):
    """Per-shard SpMV body: y = A_local x_local + A_remote x_halo."""
    y = _ops.spmv(local, x_blk, backend=backend)
    if halo_mode == "neighbor":
        halo = _exchange_neighbor(x_blk, hw, axis, nshards)
    elif halo_mode == "gather":
        halo = jax.lax.all_gather(x_blk, axis, tiled=True)
    else:
        raise ValueError(halo_mode)
    return y + _ops.spmv(remote, halo, backend=backend)


def dist_spmv(A: DistSparseMatrix, x, mesh: Mesh, backend: str = "ref"):
    """Global SpMV. ``x`` is the global vector sharded P(axis)."""
    axis = A.axis
    part_spec = lambda t: jax.tree.map(lambda a: P(axis, *(None,) * (a.ndim - 1)), t)

    def body(local_s, remote_s, x_blk):
        return _shard_spmv(_unstack(local_s), _unstack(remote_s), x_blk,
                           A.hw, axis, A.nshards, A.halo_mode, backend)

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(part_spec(A.local), part_spec(A.remote), P(axis)),
        out_specs=P(axis))
    return fn(A.local, A.remote, x)


def distribute_vector(x, mesh: Mesh, axis: AxisNames):
    return jax.device_put(jnp.asarray(x), NamedSharding(mesh, P(axis)))


# ---------------------------------------------------------------------------
# Partitioner (host, setup phase — the paper's problem-setup analogue)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PartitionedCOO:
    """Host-side per-shard COO triplets (intermediate symbolic product)."""

    local: list  # [(row, col, val)] per shard, columns shard-local
    remote: list  # [(row, col, val)] per shard, columns halo-renumbered
    mp: int
    hw: int
    halo_mode: str
    shape: Tuple[int, int]


def partition_coo(row, col, val, shape, nshards: int,
                  halo_mode: str = "auto") -> PartitionedCOO:
    """Split global COO triplets into per-shard local/remote parts.

    Rows are divided into ``nshards`` equal slabs (M must divide evenly; pad
    upstream with identity rows otherwise). The halo mode is chosen
    automatically: ``neighbor`` when every remote column lies within one
    slab-width of the owning slab (stencil matrices), else ``gather``.
    """
    m, n = shape
    if m % nshards or m != n:
        raise ValueError(f"square matrix with M % P == 0 required, got {shape} / {nshards}")
    mp = m // nshards
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    val = np.asarray(val)

    shard = row // mp
    local_mask = (col // mp) == shard
    # maximum reach of remote columns beyond slab boundaries
    reach_lo = np.where(~local_mask, shard * mp - col, 0).max(initial=0)
    reach_hi = np.where(~local_mask, col - ((shard + 1) * mp - 1), 0).max(initial=0)
    reach = int(max(reach_lo, reach_hi))
    if halo_mode == "auto":
        halo_mode = "neighbor" if 0 < reach <= mp else ("neighbor" if reach == 0 else "gather")
    hw = max(1, int(reach)) if halo_mode == "neighbor" else mp

    locals_, remotes = [], []
    for p in range(nshards):
        in_shard = shard == p
        lm = in_shard & local_mask
        rm = in_shard & ~local_mask
        lr, lc, lv = row[lm] - p * mp, col[lm] - p * mp, val[lm]
        rr = row[rm] - p * mp
        if halo_mode == "neighbor":
            gc = col[rm]
            start, end = p * mp, (p + 1) * mp
            below = gc < start
            rc = np.where(below, gc - (start - hw), hw + (gc - end))
            if rm.any() and ((rc < 0).any() or (rc >= 2 * hw).any()):
                raise ValueError("neighbor halo violated; use halo_mode='gather'")
        else:
            rc = col[rm]
        locals_.append((lr, lc, lv))
        remotes.append((rr, rc, val[rm]))
    return PartitionedCOO(locals_, remotes, mp, hw, halo_mode, shape)


def _shard_coos(parts, shape, dtype):
    """Uniform-capacity COO containers from per-shard triplets.

    Static metadata (capacity AND logical nnz) must match across shards so
    the containers stack into one pytree; nnz is set to the shared capacity
    (zero-padding keeps the extra entries inert).
    """
    cap = max(1, max(len(t[0]) for t in parts))
    coos = [coo_from_arrays(r, c, v, shape, capacity=cap, dtype=dtype)
            for (r, c, v) in parts]
    return [dataclasses.replace(c, nnz=cap) for c in coos]


def _convert_uniform(coos, fmt: Format, **kw):
    """Convert shard COOs to ``fmt`` with *uniform* static metadata so the
    results can be stacked (shared ELL width / DIA offset count / etc.)."""
    if fmt == Format.ELL:
        k = kw.get("k")
        if k is None:
            k = 1
            for c in coos:
                r = np.asarray(c.row)[np.asarray(c.data) != 0]
                if r.size:
                    k = max(k, int(np.bincount(r, minlength=c.shape[0]).max()))
        return [_convert_fn(c, fmt, k=k) for c in coos]
    if fmt == Format.DIA:
        # per-shard offsets padded to a common count (offset 0, zero data)
        offs = []
        for c in coos:
            live = np.asarray(c.data) != 0
            o = np.unique((np.asarray(c.col, np.int64) - np.asarray(c.row, np.int64))[live])
            offs.append(o if o.size else np.zeros(1, np.int64))
        nd = max(o.size for o in offs)
        out = []
        for c, o in zip(coos, offs):
            o = np.concatenate([o, np.full(nd - o.size, o[-1] if o.size else 0)])
            out.append(_convert_fn(c, fmt, offsets=np.sort(o)))
        return out
    return [_convert_fn(c, fmt, **kw) for c in coos]


def build_dist_matrix(row, col, val, shape, mesh: Mesh, axis: AxisNames,
                      local_format: Format = Format.CSR,
                      remote_format: Format = Format.CSR,
                      mode: str = "uniform",
                      candidates: Sequence[Format] = (Format.COO, Format.CSR, Format.DIA, Format.ELL),
                      tune: str = "calibrated",
                      halo_mode: str = "auto",
                      dtype=jnp.float32) -> DistSparseMatrix:
    """Build a distributed dynamic matrix (the paper's three versions).

    mode='uniform'      local/remote formats fixed (Morpheus & Ghost configs)
    mode='multiformat'  per-shard formats chosen by the auto-tuner, dispatched
                        via SwitchDynamicMatrix (paper's Multi-Format).

    ``tune`` names the per-shard selection strategy: a
    ``repro.tuning.FormatPolicy`` mode ("ml" | "cached" | "analytic" |
    "profile"), a FormatPolicy instance, or the historical alias
    "calibrated" (= profile). At production shard counts use "cached": a
    warm cache selects every shard's format without a single profiling run.
    """
    sizes = mesh.shape
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    nshards = int(np.prod([sizes[a] for a in names]))
    axis = names if len(names) > 1 else names[0]

    pc = partition_coo(row, col, val, shape, nshards, halo_mode=halo_mode)
    lshape = (pc.mp, pc.mp)
    rshape = (pc.mp, 2 * pc.hw if pc.halo_mode == "neighbor" else shape[1])
    lcoos = _shard_coos(pc.local, lshape, dtype)
    rcoos = _shard_coos(pc.remote, rshape, dtype)

    if mode == "uniform":
        local = stack_parts(_convert_uniform(lcoos, Format(local_format)))
        remote = stack_parts(_convert_uniform(rcoos, Format(remote_format)))
    elif mode == "multiformat":
        # per-shard selection, paper §V-E, via the unified FormatPolicy
        from repro.tuning.policy import FormatPolicy

        if isinstance(tune, FormatPolicy):
            policy = tune
            if not set(policy.candidates) <= set(Format(c) for c in candidates):
                raise ValueError(
                    f"tune policy candidates {[f.name for f in policy.candidates]} "
                    f"must be a subset of the build candidates "
                    f"{[Format(c).name for c in candidates]}: every pick has "
                    f"to map onto a resident union variant")
        else:
            pmode = "profile" if tune == "calibrated" else tune
            policy = FormatPolicy(pmode, candidates=tuple(candidates),
                                  profile_iters=3)

        def select(coos):
            ids = []
            for c in coos:
                rep = policy.select(c, x=jnp.ones((c.shape[1],), dtype))
                ids.append(list(candidates).index(rep.best))
            return np.asarray(ids, np.int32)

        lids, rids = select(lcoos), select(rcoos)
        lvars = [stack_parts(_convert_uniform(lcoos, f)) for f in candidates]
        rvars = [stack_parts(_convert_uniform(rcoos, f)) for f in candidates]
        local = SwitchDynamicMatrix(lvars, jnp.asarray(lids))
        remote = SwitchDynamicMatrix(rvars, jnp.asarray(rids))
    else:
        raise ValueError(mode)

    A = DistSparseMatrix(local, remote, nshards=nshards, mp=pc.mp, shape=shape,
                         axis=axis, halo_mode=pc.halo_mode, hw=pc.hw)
    return _shard_containers(A, mesh)


def _shard_containers(A: DistSparseMatrix, mesh: Mesh) -> DistSparseMatrix:
    """Place stacked shard arrays with their leading axis on the mesh."""
    axis = A.axis

    def put(t):
        return jax.tree.map(
            lambda a: jax.device_put(a, NamedSharding(mesh, P(axis, *(None,) * (a.ndim - 1)))), t)

    return DistSparseMatrix(put(A.local), put(A.remote), nshards=A.nshards,
                            mp=A.mp, shape=A.shape, axis=axis,
                            halo_mode=A.halo_mode, hw=A.hw)


def activate_dist(A: DistSparseMatrix, part: str, fmt_or_ids) -> DistSparseMatrix:
    """Runtime format switch of the local or remote part (paper activate())."""
    tgt = getattr(A, part)
    if isinstance(tgt, SwitchDynamicMatrix):
        if isinstance(fmt_or_ids, Format):
            new = tgt.activate(fmt_or_ids)
        else:
            new = tgt.activate_id(jnp.asarray(fmt_or_ids, jnp.int32))
    else:
        raise TypeError("uniform-mode parts switch via build (conversion); "
                        "use mode='multiformat' for runtime switching")
    kw = dict(nshards=A.nshards, mp=A.mp, shape=A.shape, axis=A.axis,
              halo_mode=A.halo_mode, hw=A.hw)
    return (DistSparseMatrix(new, A.remote, **kw) if part == "local"
            else DistSparseMatrix(A.local, new, **kw))
