"""Distributed dynamic sparse matrices (paper §V-E, DESIGN.md §5).

The paper's MPI design, mapped to JAX SPMD:

  * the global matrix is row-partitioned into P contiguous slabs, one per
    shard of a (possibly multi-axis) mesh partition;
  * each shard's rows split into a **local** square block (columns it owns —
    the regular part) and a **remote** rectangular block (columns owned by
    neighbours — the irregular part), each an independently-formatted
    dynamic matrix (the paper's key distributed observation);
  * the local block optionally splits further into **interior** rows (no
    live remote entry — their results never touch the halo) and
    **boundary** rows (the classic MPI overlap decomposition): the
    interior SpMV is the compute the scheduler can run while the halo
    collective is in flight, because *nothing* in it waits on the
    exchange;
  * SpMV = interior SpMV + boundary SpMV + remote SpMV over halo values
    obtained by ``ExchangeHalo`` — here a ``ppermute`` neighbour exchange
    (slab partitions: stencil matrices) or an ``all_gather`` (general
    fallback), issued *before* the interior SpMV so the collective
    overlaps compute;
  * per-shard format selection ("Multi-Format") uses ``SwitchDynamicMatrix``:
    one SPMD program, ``lax.switch`` on a per-shard format id.

Architecture (the PR-2 plan/execute split, applied end-to-end):

  * ``plan_partition`` (symbolic) scans the global triplets once — counts,
    halo reach — and emits a :class:`DistPlan` of static host metadata
    (slab size, halo width/mode, per-shard capacities, and once computed,
    the per-format :class:`SwitchPlan`\\ s).
  * ``partition_execute`` (numeric) is jit-able with the plan static: one
    stable ``argsort`` over the global triplets scatters every entry into
    its shard-local slot of the stacked, uniform-capacity local/remote COO
    containers. Zero device->host transfers.
  * conversion/selection are batched: ``plan_switch_batch`` produces one
    shared plan per candidate format, ``convert_execute_batch`` vmaps the
    numeric phase over the shard axis, and ``FormatPolicy.select_batch``
    featurises every shard in one device pass — build cost no longer has a
    Python-loop factor of P.

Containers are *stacked*: every array gains a leading P axis which is
sharded over the mesh partition axes; inside ``shard_map`` each shard sees
its own slab (leading dim 1) and unstacks it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core import compat
from repro.core.compat import leading_axis_spec
from repro.core.convert import (SwitchPlan, convert_execute_batch,
                                plan_switch_batch)
from repro.core import ops as _ops
from repro.core.dynamic import SwitchDynamicMatrix
from repro.core.formats import COO, Format
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

AxisNames = Union[str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# Stacking / unstacking shard containers
# ---------------------------------------------------------------------------


def stack_parts(parts: Sequence):
    """Stack P same-structure containers into one with a leading P axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *parts)


def _unstack(part):
    """Inside shard_map: strip the leading (length-1) shard axis."""
    return jax.tree.map(lambda a: a[0], part)


def _part_spec(t, axis: AxisNames):
    """Stacked-container PartitionSpec tree: leading shard axis on ``axis``."""
    return jax.tree.map(lambda a: leading_axis_spec(axis, a.ndim), t)


# ---------------------------------------------------------------------------
# The distributed container
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class DistSparseMatrix:
    """Row-partitioned sparse matrix with local/remote split per shard.

    ``local``/``remote`` are stacked containers (or stacked
    SwitchDynamicMatrix for Multi-Format). ``halo_mode`` is ``"neighbor"``
    (remote columns renumbered into a [prev_tail | next_head] halo of width
    ``hw`` per side) or ``"gather"`` (remote columns are global ids).
    ``remote_empty`` marks a statically block-diagonal partition: the
    remote part carries no entries, so SpMV skips both the exchange and
    the remote term entirely.

    With the overlap split (``build_dist_matrix(split=...)``), ``local``
    holds only the **interior** rows (no live remote entry) and
    ``boundary`` holds the rest of the local block — both (mp, mp), their
    entry sets disjoint and together exactly the unsplit local block.
    ``boundary is None`` means the matrix is unsplit and ``local`` is the
    whole local block.
    """

    def __init__(self, local, remote, *, nshards: int, mp: int, shape,
                 axis: AxisNames, halo_mode: str, hw: int,
                 remote_empty: bool = False, boundary=None):
        self.local = local
        self.remote = remote
        self.boundary = boundary
        self.nshards = nshards
        self.mp = mp
        self.shape = tuple(shape)
        self.axis = axis
        self.halo_mode = halo_mode
        self.hw = hw
        self.remote_empty = remote_empty

    @property
    def split(self) -> bool:
        """True when local is interior-only and ``boundary`` carries the
        halo-coupled rows (the overlap decomposition)."""
        return self.boundary is not None

    def tree_flatten(self):
        meta = (self.nshards, self.mp, self.shape, self.axis, self.halo_mode,
                self.hw, self.remote_empty)
        return (self.local, self.remote, self.boundary), meta

    @classmethod
    def tree_unflatten(cls, meta, children):
        nshards, mp, shape, axis, halo_mode, hw, remote_empty = meta
        return cls(children[0], children[1], boundary=children[2],
                   nshards=nshards, mp=mp,
                   shape=shape, axis=axis, halo_mode=halo_mode, hw=hw,
                   remote_empty=remote_empty)

    def _replace_parts(self, local, remote, boundary=None) -> "DistSparseMatrix":
        return DistSparseMatrix(
            local, remote, boundary=self.boundary if boundary is None else boundary,
            nshards=self.nshards, mp=self.mp, shape=self.shape,
            axis=self.axis, halo_mode=self.halo_mode, hw=self.hw,
            remote_empty=self.remote_empty)

    def __repr__(self):
        lf = type(self.local).__name__
        rf = type(self.remote).__name__
        halo = "empty" if self.remote_empty else f"{self.halo_mode}:{self.hw}"
        parts = f"local={lf}"
        if self.split:
            parts += f", boundary={type(self.boundary).__name__}"
        return (f"DistSparseMatrix(shape={self.shape}, P={self.nshards}, "
                f"{parts}, remote={rf}, halo={halo})")


# ---------------------------------------------------------------------------
# Halo exchange (the paper's ExchangeHalo)
# ---------------------------------------------------------------------------


def _exchange_neighbor(x_blk, hw: int, axis: AxisNames, nshards: int):
    """[prev shard's last hw | next shard's first hw] via ppermute."""
    fwd = [(i, i + 1) for i in range(nshards - 1)]
    bwd = [(i + 1, i) for i in range(nshards - 1)]
    prev_tail = jax.lax.ppermute(x_blk[-hw:], axis, fwd)   # from p-1
    next_head = jax.lax.ppermute(x_blk[:hw], axis, bwd)    # from p+1
    return jnp.concatenate([prev_tail, next_head])


def _shard_spmv(local, remote, x_blk, hw: int, axis: AxisNames, nshards: int,
                halo_mode: str, backend: str, remote_empty: bool, cfg=None,
                boundary=None):
    """Per-shard SpMV body: y = A_local x_local + A_remote x_halo.

    The halo collective is issued *before* the local SpMV: it has no data
    dependency on it, so XLA's latency-hiding scheduler overlaps the
    exchange with the local compute (the paper's communication/computation
    overlap). A statically-empty remote part skips both entirely.

    With the interior/boundary split (``boundary is not None``), ``local``
    is the interior part: its entire SpMV — compute *and* result rows — is
    independent of the collective, so the scheduler has a dependency-free
    region exactly as wide as the interior work to hide the exchange in.
    The boundary and remote terms, whose result rows genuinely wait on the
    halo, are summed last.
    """
    if remote_empty:
        y = _ops.spmv(local, x_blk, backend=backend, cfg=cfg)
        if boundary is not None:
            y = y + _ops.spmv(boundary, x_blk, backend=backend, cfg=cfg)
        return y
    if halo_mode == "neighbor":
        halo = _exchange_neighbor(x_blk, hw, axis, nshards)
    elif halo_mode == "gather":
        halo = jax.lax.all_gather(x_blk, axis, tiled=True)
    else:
        raise ValueError(halo_mode)
    y = _ops.spmv(local, x_blk, backend=backend, cfg=cfg)
    if boundary is not None:
        y = y + _ops.spmv(boundary, x_blk, backend=backend, cfg=cfg)
    return y + _ops.spmv(remote, halo, backend=backend, cfg=cfg)


def dist_spmv(A: DistSparseMatrix, x, mesh: Mesh, backend: str = "auto",
              cfg=None):
    """Global SpMV. ``x`` is the global vector sharded P(axis).

    ``backend="auto"`` flows *into* the shard bodies unresolved: every
    shard-local per-format SpMV routes itself through the measured
    kernel-config cache (``repro.core.ops.kernel_route``), so a
    multiformat distributed matrix inherits each format's tuned Pallas
    tiles where they beat the reference path — per (format, shard-shape
    bucket), not one coarse process-wide pick. The routing is a
    trace-time host lookup; inside ``shard_map`` all shards share one
    program, so the decision is identical across shards of the same
    format branch. An explicit ``cfg`` (kernel tile-config dict) applies
    uniformly to every shard's SpMVs instead.

    A split matrix (``A.boundary is not None``) runs the overlap schedule:
    halo collective issued first, interior SpMV (``A.local``) while it is
    in flight, boundary + remote last.
    """
    axis = A.axis
    if not A.remote_empty:
        # Exchange accounting. ``dist_spmv`` may run under an outer jit, in
        # which case this host-side bookkeeping executes once at trace time
        # (per compilation), not per device call — documented semantics of
        # the ``halo.bytes`` counter.
        itemsize = jnp.dtype(getattr(x, "dtype", jnp.float32)).itemsize
        halo_elems = (2 * A.hw if A.halo_mode == "neighbor"
                      else A.shape[1])
        _metrics.inc("halo.bytes", A.nshards * halo_elems * itemsize)
        if _trace.mode() != "off":
            _trace.event("exchange.issue", mode=A.halo_mode, p=A.nshards,
                         bytes=A.nshards * halo_elems * itemsize,
                         split=A.split)

    if A.split:
        def body(local_s, boundary_s, remote_s, x_blk):
            return _shard_spmv(_unstack(local_s), _unstack(remote_s), x_blk,
                               A.hw, axis, A.nshards, A.halo_mode, backend,
                               A.remote_empty, cfg=cfg,
                               boundary=_unstack(boundary_s))
        in_specs = (_part_spec(A.local, axis), _part_spec(A.boundary, axis),
                    _part_spec(A.remote, axis), leading_axis_spec(axis, 1))
        operands = (A.local, A.boundary, A.remote, x)
    else:
        def body(local_s, remote_s, x_blk):
            return _shard_spmv(_unstack(local_s), _unstack(remote_s), x_blk,
                               A.hw, axis, A.nshards, A.halo_mode, backend,
                               A.remote_empty, cfg=cfg)
        in_specs = (_part_spec(A.local, axis), _part_spec(A.remote, axis),
                    leading_axis_spec(axis, 1))
        operands = (A.local, A.remote, x)

    fn = compat.shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=leading_axis_spec(axis, 1))
    if _trace.mode() == "off":
        return fn(*operands)
    with _trace.span("exchange.dist_spmv", p=A.nshards,
                     halo="empty" if A.remote_empty else A.halo_mode) as sp:
        y = fn(*operands)
        sp.sync(y)
    return y


def dist_spmv_phase(A: DistSparseMatrix, x, mesh: Mesh, phase: str = "full",
                    backend: str = "auto", cfg=None):
    """Phase-decomposed distributed SpMV — the overlap diagnostic.

    ``phase``:
      * ``"full"``      the production path (:func:`dist_spmv`);
      * ``"local"``     local SpMV only (interior + boundary when split) —
                        no halo collective is issued;
      * ``"exchange"``  halo exchange + remote SpMV only — no local SpMV;
      * ``"interior"``  interior rows only (split matrices);
      * ``"boundary"``  boundary rows only (split matrices).

    Timing the phases independently and comparing ``t_local + t_exchange``
    against ``t_full`` measures how much of the exchange XLA's scheduler
    actually hid behind local compute (``hidden = local + exchange -
    full``); the per-shard-count sweep in ``benchmarks/bench_obs.py`` uses
    this to localize where the ghost-mode p8 overlap is lost. The
    ``interior``/``boundary`` phases further attribute the local side of a
    split matrix: the interior term is the overlap window's width.
    """
    if phase == "full":
        return dist_spmv(A, x, mesh, backend=backend, cfg=cfg)
    if phase not in ("local", "exchange", "interior", "boundary"):
        raise ValueError(f"phase {phase!r} not in ('full', 'local', "
                         f"'exchange', 'interior', 'boundary')")
    if phase in ("interior", "boundary") and not A.split:
        raise ValueError(f"phase {phase!r} needs a split matrix "
                         "(build_dist_matrix(split=True))")
    axis = A.axis

    def body(local_s, boundary_s, remote_s, x_blk):
        local, remote = _unstack(local_s), _unstack(remote_s)
        boundary = _unstack(boundary_s) if boundary_s is not None else None
        if phase == "interior":
            return _ops.spmv(local, x_blk, backend=backend, cfg=cfg)
        if phase == "boundary":
            return _ops.spmv(boundary, x_blk, backend=backend, cfg=cfg)
        if phase == "local":
            y = _ops.spmv(local, x_blk, backend=backend, cfg=cfg)
            if boundary is not None:
                y = y + _ops.spmv(boundary, x_blk, backend=backend, cfg=cfg)
            return y
        if A.remote_empty:
            return jnp.zeros_like(x_blk)
        if A.halo_mode == "neighbor":
            halo = _exchange_neighbor(x_blk, A.hw, axis, A.nshards)
        else:
            halo = jax.lax.all_gather(x_blk, axis, tiled=True)
        return _ops.spmv(remote, halo, backend=backend, cfg=cfg)

    if A.split:
        def body3(local_s, boundary_s, remote_s, x_blk):
            return body(local_s, boundary_s, remote_s, x_blk)
        in_specs = (_part_spec(A.local, axis), _part_spec(A.boundary, axis),
                    _part_spec(A.remote, axis), leading_axis_spec(axis, 1))
        fn = compat.shard_map(body3, mesh=mesh, in_specs=in_specs,
                              out_specs=leading_axis_spec(axis, 1))
        return fn(A.local, A.boundary, A.remote, x)

    def body2(local_s, remote_s, x_blk):
        return body(local_s, None, remote_s, x_blk)
    fn = compat.shard_map(
        body2, mesh=mesh,
        in_specs=(_part_spec(A.local, axis), _part_spec(A.remote, axis),
                  leading_axis_spec(axis, 1)),
        out_specs=leading_axis_spec(axis, 1))
    return fn(A.local, A.remote, x)


def distribute_vector(x, mesh: Mesh, axis: AxisNames):
    return jax.device_put(jnp.asarray(x),
                          NamedSharding(mesh, leading_axis_spec(axis, 1)))


# ---------------------------------------------------------------------------
# The partition plan (symbolic phase — static host metadata only)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Static metadata of a slab partition — the distributed symbolic phase.

    Everything here is small host data (ints, strings, plan tuples):
    hashable, so the numeric phases (``partition_execute``,
    ``convert_execute_batch``) ride through ``jax.jit`` as static
    arguments. ``local_plans``/``remote_plans`` memoise the per-candidate
    :class:`SwitchPlan`\\ s once a multiformat build has computed them, so
    a rebuild (e.g. after a numeric update with the same pattern) performs
    zero symbolic device->host pulls.
    """

    nshards: int
    mp: int                       # rows per slab
    hw: int                       # halo width per side (0: remote empty)
    halo_mode: str                # "neighbor" | "gather"
    shape: Tuple[int, int]
    local_cap: int                # shared local COO capacity across shards
    remote_cap: int               # shared remote COO capacity across shards
    remote_empty: bool = False
    candidates: Optional[Tuple[Format, ...]] = None
    local_plans: Optional[Tuple[SwitchPlan, ...]] = None
    remote_plans: Optional[Tuple[SwitchPlan, ...]] = None
    # live-pattern fingerprint: the memoised format plans above are valid
    # only for triplets with the same live (val != 0) pattern; the builder
    # drops them and re-plans when the fingerprint no longer matches.
    pattern_sig: Optional[str] = None
    # overlap split: shared capacities of the interior/boundary halves of
    # the local block (live entries only), plus their memoised per-candidate
    # format plans. None until a split build computes them.
    interior_cap: Optional[int] = None
    boundary_cap: Optional[int] = None
    interior_plans: Optional[Tuple[SwitchPlan, ...]] = None
    boundary_plans: Optional[Tuple[SwitchPlan, ...]] = None

    @property
    def remote_width(self) -> int:
        if self.remote_empty:
            return 1  # inert 1-column placeholder part
        return 2 * self.hw if self.halo_mode == "neighbor" else self.shape[1]

    @property
    def local_shape(self) -> Tuple[int, int]:
        return (self.mp, self.mp)

    @property
    def remote_shape(self) -> Tuple[int, int]:
        return (self.mp, self.remote_width)

    # -- persistence (the ``distplan:`` SelectionCache namespace) ----------

    def to_json(self) -> str:
        """Serialise the whole plan — partition caps, split caps, memoised
        per-candidate SwitchPlans, pattern fingerprint — to one JSON
        string, so a restarted job rebuilds with zero symbolic work."""
        import json

        doc = {"nshards": self.nshards, "mp": self.mp, "hw": self.hw,
               "halo_mode": self.halo_mode, "shape": list(self.shape),
               "local_cap": self.local_cap, "remote_cap": self.remote_cap,
               "remote_empty": self.remote_empty,
               "pattern_sig": self.pattern_sig,
               "interior_cap": self.interior_cap,
               "boundary_cap": self.boundary_cap}
        if self.candidates is not None:
            doc["candidates"] = [Format(f).name for f in self.candidates]
        for name in ("local_plans", "remote_plans", "interior_plans",
                     "boundary_plans"):
            plans = getattr(self, name)
            if plans is not None:
                doc[name] = [p.to_json() for p in plans]
        return json.dumps(doc, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "DistPlan":
        import json

        doc = json.loads(s)
        kw = {k: doc[k] for k in ("nshards", "mp", "hw", "halo_mode",
                                  "local_cap", "remote_cap", "remote_empty",
                                  "pattern_sig", "interior_cap",
                                  "boundary_cap")}
        kw["shape"] = tuple(doc["shape"])
        if "candidates" in doc:
            kw["candidates"] = tuple(Format[n] for n in doc["candidates"])
        for name in ("local_plans", "remote_plans", "interior_plans",
                     "boundary_plans"):
            if name in doc:
                kw[name] = tuple(SwitchPlan.from_json(p) for p in doc[name])
        return cls(**kw)


def plan_partition(row, col, val, shape, nshards: int,
                   halo_mode: str = "auto") -> DistPlan:
    """Symbolic phase of the slab partitioner: one vectorised host scan.

    Rows are divided into ``nshards`` equal slabs (M must divide evenly;
    pad upstream with identity rows otherwise). The halo mode is chosen
    automatically: ``neighbor`` when every remote column lies within one
    slab-width of the owning slab (stencil matrices), else ``gather``; a
    block-diagonal matrix (no remote entries at all) gets ``hw=0`` and a
    statically-empty remote part — no exchange is ever issued for it.
    """
    m, n = shape
    if nshards <= 0 or m % nshards or m != n:
        raise ValueError(
            f"square matrix with M % P == 0 required, got {shape} / {nshards}")
    mp = m // nshards
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)

    shard = row // mp
    local_mask = (col // mp) == shard
    remote_mask = ~local_mask
    remote_empty = not bool(remote_mask.any())
    # maximum reach of remote columns beyond slab boundaries
    reach_lo = np.where(remote_mask, shard * mp - col, 0).max(initial=0)
    reach_hi = np.where(remote_mask, col - ((shard + 1) * mp - 1), 0).max(initial=0)
    reach = int(max(reach_lo, reach_hi))
    if halo_mode == "auto":
        halo_mode = "neighbor" if reach <= mp else "gather"
    if halo_mode == "neighbor":
        if reach > mp:
            raise ValueError("neighbor halo violated; use halo_mode='gather'")
        hw = 0 if remote_empty else max(1, reach)
    elif halo_mode == "gather":
        hw = 0 if remote_empty else mp
    else:
        raise ValueError(halo_mode)

    lcounts = np.bincount(shard[local_mask], minlength=nshards)
    rcounts = np.bincount(shard[remote_mask], minlength=nshards)
    return DistPlan(nshards=nshards, mp=mp, hw=hw, halo_mode=halo_mode,
                    shape=(m, n), local_cap=max(1, int(lcounts.max())),
                    remote_cap=max(1, int(rcounts.max())),
                    remote_empty=remote_empty)


def partition_execute(row, col, val, plan: DistPlan,
                      dtype=jnp.float32) -> Tuple[COO, COO]:
    """Numeric phase of the slab partitioner (jit-able, ``plan`` static).

    One stable ``argsort`` over the global triplets orders entries by
    (shard, local/remote); a rank-within-group scatter then drops every
    entry into its slot of the stacked uniform-capacity containers. Local
    columns are renumbered shard-relative, remote columns halo-relative
    (neighbor mode) or kept global (gather mode). Zero device->host
    transfers.
    """
    nshards, mp, hw = plan.nshards, plan.mp, plan.hw
    row = jnp.asarray(row).astype(jnp.int32)
    col = jnp.asarray(col).astype(jnp.int32)
    val = jnp.asarray(val).astype(dtype)
    nent = row.shape[0]

    shard = row // mp
    is_remote = (col // mp) != shard
    key = shard * 2 + is_remote.astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    k_s, r_s, c_s, v_s = key[order], row[order], col[order], val[order]
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(key, length=2 * nshards)).astype(jnp.int32)])
    rank = jnp.arange(nent, dtype=jnp.int32) - starts[k_s]
    p = k_s // 2
    rem = (k_s % 2) == 1

    lrow = r_s - p * mp
    lcol = c_s - p * mp
    if plan.halo_mode == "neighbor" and not plan.remote_empty:
        below = c_s < p * mp
        rcol = jnp.where(below, c_s - (p * mp - hw), hw + (c_s - (p + 1) * mp))
    else:
        rcol = c_s

    def scatter(select, cap, cols, vals):
        # in-capacity entries land at p*cap + rank; everything else (the
        # other part's entries, or overflow under a stale plan) goes to a
        # dropped guard slot past the end.
        ok = select & (rank < cap)
        dest = jnp.where(ok, p * cap + jnp.minimum(rank, cap - 1),
                         nshards * cap)
        out = []
        for x in (lrow, cols, vals):
            buf = jnp.zeros((nshards * cap + 1,), x.dtype).at[dest].set(
                jnp.where(ok, x, jnp.zeros((), x.dtype)))
            out.append(buf[:nshards * cap].reshape(nshards, cap))
        return out

    lr, lc, lv = scatter(~rem, plan.local_cap, lcol, v_s)
    rr, rc, rv = scatter(rem, plan.remote_cap, rcol, v_s)
    local = COO(lr, lc, lv, plan.local_shape, plan.local_cap)
    remote = COO(rr, rc, rv, plan.remote_shape, plan.remote_cap)
    return local, remote


# One process-wide trace cache: rebuilds with the same plan/shapes are pure
# dispatch (jit wrappers created per call would retrace every build).
partition_execute_jit = jax.jit(partition_execute,
                                static_argnames=("plan", "dtype"))


# ---------------------------------------------------------------------------
# Interior/boundary overlap split of the local block
# ---------------------------------------------------------------------------


def _split_caps(row, col, val, mp: int, nshards: int) -> Tuple[int, int]:
    """Shared (interior, boundary) capacities — one vectorised host scan.

    A row is *boundary* when it has at least one live remote entry (its
    SpMV result waits on the halo); every other local row is *interior*.
    Counting is over live (val != 0) local entries, matching the device
    split, which drops dead entries.
    """
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    live = np.asarray(val) != 0
    shard = row // mp
    local_mask = (col // mp) == shard
    brow = np.zeros((mp * nshards,), bool)
    brow[row[live & ~local_mask]] = True
    loc_live = live & local_mask
    is_b = brow[row] & loc_live
    icounts = np.bincount(shard[loc_live & ~is_b], minlength=nshards)
    bcounts = np.bincount(shard[is_b], minlength=nshards)
    return (max(1, int(icounts.max(initial=0))),
            max(1, int(bcounts.max(initial=0))))


def split_local_execute(local: COO, remote: COO, mp: int, icap: int,
                        bcap: int) -> Tuple[COO, COO]:
    """Numeric phase of the overlap split (jit-able, caps static).

    One extra stacked scatter over the already-partitioned local block:
    per shard, rows with a live remote entry are flagged (one scatter-max
    over the remote triplets), then every live local entry lands in the
    interior or boundary container by a rank-within-mask scatter — the
    same guard-slot pattern as :func:`partition_execute`. Dead (val == 0)
    entries are dropped; both outputs keep the (mp, mp) local shape. Zero
    device->host transfers.
    """
    def one(lrow, lcol, lval, rrow, rdata):
        bflag = jnp.zeros((mp,), bool).at[rrow].max(rdata != 0)
        live = lval != 0
        outs = []
        for mask, cap in (((~bflag[lrow]) & live, icap),
                          (bflag[lrow] & live, bcap)):
            rank = jnp.cumsum(mask.astype(jnp.int32)) - 1
            ok = mask & (rank < cap)
            dest = jnp.where(ok, jnp.minimum(rank, cap - 1), cap)
            for x in (lrow, lcol, lval):
                buf = jnp.zeros((cap + 1,), x.dtype).at[dest].set(
                    jnp.where(ok, x, jnp.zeros((), x.dtype)))
                outs.append(buf[:cap])
        return tuple(outs)

    ir, ic, iv, br, bc, bv = jax.vmap(one)(local.row, local.col, local.data,
                                           remote.row, remote.data)
    return (COO(ir, ic, iv, (mp, mp), icap), COO(br, bc, bv, (mp, mp), bcap))


split_local_execute_jit = jax.jit(split_local_execute,
                                  static_argnames=("mp", "icap", "bcap"))


def plan_dist_formats(local: COO, remote: COO, plan: DistPlan,
                      candidates: Sequence[Format],
                      boundary: Optional[COO] = None) -> DistPlan:
    """Attach the per-candidate :class:`SwitchPlan`\\ s to a DistPlan.

    One :func:`plan_switch_batch` pass per candidate per part; a plan that
    already carries matching format plans is returned unchanged (rebuilds
    perform no symbolic pulls at all). With ``boundary`` (the overlap
    split), ``local`` is the interior part and the plan memoises
    ``interior_plans``/``boundary_plans`` instead of ``local_plans`` —
    per-split multiformat selection needs per-split conversion plans.
    """
    candidates = tuple(Format(c) for c in candidates)
    if boundary is None:
        if plan.candidates == candidates and plan.local_plans is not None:
            return plan
        with _trace.span("plan.dist_formats",
                         candidates=",".join(f.name for f in candidates)):
            lplans = tuple(plan_switch_batch(local, f) for f in candidates)
            rplans = tuple(plan_switch_batch(remote, f) for f in candidates)
        return dataclasses.replace(plan, candidates=candidates,
                                   local_plans=lplans, remote_plans=rplans)
    if plan.candidates == candidates and plan.interior_plans is not None:
        return plan
    with _trace.span("plan.dist_formats", split=True,
                     candidates=",".join(f.name for f in candidates)):
        iplans = tuple(plan_switch_batch(local, f) for f in candidates)
        bplans = tuple(plan_switch_batch(boundary, f) for f in candidates)
        rplans = tuple(plan_switch_batch(remote, f) for f in candidates)
    return dataclasses.replace(plan, candidates=candidates,
                               interior_plans=iplans, boundary_plans=bplans,
                               remote_plans=rplans)


def _pattern_sig(row, col, val) -> str:
    """Fingerprint of the *live* sparsity pattern (host, one O(nnz) pass)."""
    import hashlib

    live = np.asarray(val) != 0
    h = hashlib.sha1()
    h.update(np.ascontiguousarray(np.asarray(row, np.int64)[live]).tobytes())
    h.update(np.ascontiguousarray(np.asarray(col, np.int64)[live]).tobytes())
    return h.hexdigest()


def _check_plan_fits(row, col, plan: DistPlan, val=None) -> None:
    """A reused plan must still fit the triplets.

    ``partition_execute``'s guard-slot scatter silently drops entries whose
    rank exceeds the planned capacity, and a halo reach beyond the planned
    width would store out-of-range remote columns — both would corrupt the
    matrix with no error. One vectorised host scan (same cost class as
    ``plan_partition``) turns a stale plan into a loud failure instead.
    With ``val`` and a plan carrying split capacities, the
    interior/boundary scatter of :func:`split_local_execute` is validated
    the same way (its counting is live-entry based, hence the values).
    """
    if val is not None and plan.interior_cap is not None:
        icap, bcap = _split_caps(row, col, val, plan.mp, plan.nshards)
        if icap > plan.interior_cap or bcap > plan.boundary_cap:
            raise ValueError(
                f"stale DistPlan: split capacities (interior "
                f"{plan.interior_cap}, boundary {plan.boundary_cap}) too "
                f"small for these triplets (need {icap}/{bcap}); re-plan "
                f"with plan_partition")
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    mp = plan.mp
    shard = row // mp
    local_mask = (col // mp) == shard
    remote_mask = ~local_mask
    lmax = int(np.bincount(shard[local_mask], minlength=plan.nshards).max(initial=0))
    rmax = int(np.bincount(shard[remote_mask], minlength=plan.nshards).max(initial=0))
    if lmax > plan.local_cap or rmax > plan.remote_cap:
        raise ValueError(
            f"stale DistPlan: capacities (local {plan.local_cap}, remote "
            f"{plan.remote_cap}) too small for these triplets (need "
            f"{lmax}/{rmax}); re-plan with plan_partition")
    if rmax and plan.remote_empty:
        raise ValueError("stale DistPlan: marked remote-empty but the "
                         "triplets have remote entries; re-plan")
    if plan.halo_mode == "neighbor" and not plan.remote_empty:
        reach_lo = np.where(remote_mask, shard * mp - col, 0).max(initial=0)
        reach_hi = np.where(remote_mask, col - ((shard + 1) * mp - 1), 0).max(initial=0)
        if int(max(reach_lo, reach_hi)) > plan.hw:
            raise ValueError(
                f"stale DistPlan: halo width {plan.hw} smaller than the "
                f"triplets' reach {int(max(reach_lo, reach_hi))}; re-plan")


# ---------------------------------------------------------------------------
# Legacy host partitioner (reference implementation, kept for tooling)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PartitionedCOO:
    """Host-side per-shard COO triplets (reference symbolic product).

    The batched device path (``plan_partition`` + ``partition_execute``)
    supersedes this for building; it remains the easy-to-inspect oracle.
    """

    local: list  # [(row, col, val)] per shard, columns shard-local
    remote: list  # [(row, col, val)] per shard, columns halo-renumbered
    mp: int
    hw: int
    halo_mode: str
    shape: Tuple[int, int]
    remote_empty: bool = False


def partition_coo(row, col, val, shape, nshards: int,
                  halo_mode: str = "auto") -> PartitionedCOO:
    """Split global COO triplets into per-shard local/remote host triplets.

    Reference (per-shard loop) counterpart of :func:`partition_execute`;
    halo-mode selection and capacities come from :func:`plan_partition`.
    """
    plan = plan_partition(row, col, val, shape, nshards, halo_mode=halo_mode)
    mp, hw = plan.mp, plan.hw
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    val = np.asarray(val)
    shard = row // mp
    local_mask = (col // mp) == shard

    locals_, remotes = [], []
    for p in range(nshards):
        in_shard = shard == p
        lm = in_shard & local_mask
        rm = in_shard & ~local_mask
        locals_.append((row[lm] - p * mp, col[lm] - p * mp, val[lm]))
        rr = row[rm] - p * mp
        gc = col[rm]
        if plan.halo_mode == "neighbor" and not plan.remote_empty:
            start, end = p * mp, (p + 1) * mp
            rc = np.where(gc < start, gc - (start - hw), hw + (gc - end))
        else:
            rc = gc
        remotes.append((rr, rc, val[rm]))
    return PartitionedCOO(locals_, remotes, mp, hw, plan.halo_mode, plan.shape,
                          remote_empty=plan.remote_empty)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


def build_dist_matrix(row, col, val, shape, mesh: Mesh, axis: AxisNames,
                      local_format: Format = Format.CSR,
                      remote_format: Format = Format.CSR,
                      mode: str = "uniform",
                      candidates: Sequence[Format] = (Format.COO, Format.CSR, Format.DIA, Format.ELL, Format.SELL),
                      tune: str = "calibrated",
                      halo_mode: str = "auto",
                      dtype=jnp.float32,
                      plan: Optional[DistPlan] = None,
                      check_plan: bool = True,
                      parts: Optional[Tuple[COO, COO]] = None,
                      split: Union[str, bool] = "auto",
                      plan_cache=None) -> DistSparseMatrix:
    """Build a distributed dynamic matrix (the paper's three versions).

    mode='uniform'      local/remote formats fixed (Morpheus & Ghost configs)
    mode='multiformat'  per-shard formats chosen by the auto-tuner, dispatched
                        via SwitchDynamicMatrix (paper's Multi-Format).

    The build is the plan/execute pipeline end-to-end: one host scan (or a
    caller-supplied :class:`DistPlan`, e.g. ``repro.core.hpcg.slab_plan``'s
    analytic one) plans the partition; one jitted ``partition_execute``
    scatters the triplets into stacked shard containers on device; one
    shared ``plan_switch_batch`` plan + one vmapped ``convert_execute_batch``
    per candidate format builds the variants; and in multiformat mode
    ``FormatPolicy.select_batch`` picks every shard's format from a single
    batched featurisation pass. No per-shard Python loops anywhere on the
    cached/ml/analytic paths.

    ``tune`` names the per-shard selection strategy: a
    ``repro.tuning.FormatPolicy`` mode ("ml" | "cached" | "analytic" |
    "profile"), a FormatPolicy instance, or the historical alias
    "calibrated" (= profile). At production shard counts use "cached": a
    warm cache selects every shard's format without a single profiling run.

    ``parts`` short-circuits the partition scatter with an already
    partitioned ``(local, remote)`` stacked-COO pair produced from the
    *same* plan (e.g. by ``hpcg.partition_problem``) — callers that need
    the stacked containers anyway (the MG hierarchy builder feeds them to
    the colored smoother) avoid running the device scatter twice.
    ``parts`` requires an explicit ``plan``.

    ``split`` controls the interior/boundary overlap decomposition of the
    local block: ``True`` forces it, ``False`` keeps the historical
    two-part matrix, ``"auto"`` (default) splits exactly when a halo
    exchange will actually be issued (``not remote_empty`` — a
    block-diagonal matrix has nothing to hide the collective behind).

    ``plan_cache`` (a ``repro.tuning.SelectionCache``) persists the fully
    enriched :class:`DistPlan` under a ``distplan:`` key derived from the
    live-pattern fingerprint, so a *restarted* process skips both the
    partition host scan and all per-candidate symbolic conversion
    planning: consulted only when ``plan`` is None, stored after every
    planning build. Hits/misses count as ``distplan.cache_hit`` /
    ``distplan.cache_miss``.
    """
    sizes = mesh.shape
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    nshards = int(np.prod([sizes[a] for a in names]))
    axis = names if len(names) > 1 else names[0]

    cache_key = None
    if plan is None and plan_cache is not None:
        sig = _pattern_sig(row, col, val)
        m, n = shape
        cache_key = f"distplan:{sig}|{m}x{n}|P{nshards}|{halo_mode}"
        rec = plan_cache.get_raw(cache_key)
        if rec is not None:
            try:
                plan = DistPlan.from_json(rec)
            except (KeyError, ValueError):
                plan = None  # unreadable/old record: fall through to planning
        _metrics.inc("distplan.cache_hit" if plan is not None
                     else "distplan.cache_miss")
        if plan is not None:
            _trace.event("plan.cache_hit", key=cache_key)

    if plan is None:
        plan = plan_partition(row, col, val, shape, nshards,
                              halo_mode=halo_mode)
    else:
        if plan.nshards != nshards or plan.shape != tuple(shape):
            raise ValueError(f"plan is for P={plan.nshards} shape={plan.shape}, "
                             f"build asked for P={nshards} shape={tuple(shape)}")
        if check_plan:
            # one vectorised host scan: a stale plan must fail loudly (or,
            # for the memoised format plans, fall back to re-planning)
            # rather than silently drop entries. check_plan=False skips it
            # for trusted analytic plans (e.g. hpcg.slab_plan) so the
            # triplets are touched only by the device scatter.
            _check_plan_fits(row, col, plan, val=val)
            if ((plan.local_plans is not None
                 or plan.interior_plans is not None)
                    and plan.pattern_sig != _pattern_sig(row, col, val)):
                # live pattern changed: the memoised format plans are void
                _metrics.inc("replan.pattern_sig")
                _trace.event("plan.replan", reason="pattern_sig")
                plan = dataclasses.replace(plan, candidates=None,
                                           local_plans=None,
                                           remote_plans=None,
                                           interior_plans=None,
                                           boundary_plans=None,
                                           pattern_sig=None)
    if split == "auto":
        split = not plan.remote_empty
    if split and plan.interior_cap is None:
        icap, bcap = _split_caps(row, col, val, plan.mp, plan.nshards)
        plan = dataclasses.replace(plan, interior_cap=icap, boundary_cap=bcap)
    if parts is not None:
        lcoos, rcoos = parts
        if (lcoos.shape != plan.local_shape
                or rcoos.shape != plan.remote_shape):
            raise ValueError(
                f"parts shapes {lcoos.shape}/{rcoos.shape} do not match the "
                f"plan's {plan.local_shape}/{plan.remote_shape}")
    else:
        # strip the format plans / fingerprint / split metadata for the
        # partition jit key: a plan enriched by plan_dist_formats or the
        # split-cap scan must hit the same partition_execute trace
        part_plan = dataclasses.replace(plan, candidates=None,
                                        local_plans=None, remote_plans=None,
                                        interior_plans=None,
                                        boundary_plans=None,
                                        interior_cap=None, boundary_cap=None,
                                        pattern_sig=None)
        with _trace.span("build.partition_execute", p=plan.nshards) as sp:
            lcoos, rcoos = partition_execute_jit(np.asarray(row),
                                                 np.asarray(col),
                                                 np.asarray(val),
                                                 plan=part_plan, dtype=dtype)
            sp.sync(lcoos.data, rcoos.data)

    bcoos = None
    if split:
        with _trace.span("build.split_execute", p=plan.nshards) as sp:
            lcoos, bcoos = split_local_execute_jit(
                lcoos, rcoos, mp=plan.mp, icap=plan.interior_cap,
                bcap=plan.boundary_cap)
            sp.sync(lcoos.data, bcoos.data)

    boundary = None
    if mode == "uniform":
        local = convert_execute_batch(
            lcoos, plan_switch_batch(lcoos, Format(local_format)))
        if bcoos is not None:
            boundary = convert_execute_batch(
                bcoos, plan_switch_batch(bcoos, Format(local_format)))
        remote = convert_execute_batch(
            rcoos, plan_switch_batch(rcoos, Format(remote_format)))
    elif mode == "multiformat":
        # per-shard selection, paper §V-E, via the unified FormatPolicy
        from repro.tuning.policy import FormatPolicy

        candidates = tuple(Format(c) for c in candidates)
        if isinstance(tune, FormatPolicy):
            policy = tune
            if not set(policy.candidates) <= set(candidates):
                raise ValueError(
                    f"tune policy candidates {[f.name for f in policy.candidates]} "
                    f"must be a subset of the build candidates "
                    f"{[f.name for f in candidates]}: every pick has "
                    f"to map onto a resident union variant")
        else:
            pmode = "profile" if tune == "calibrated" else tune
            policy = FormatPolicy(pmode, candidates=candidates,
                                  profile_iters=3)

        plan = plan_dist_formats(lcoos, rcoos, plan, candidates,
                                 boundary=bcoos)
        if plan.pattern_sig is None:
            # stamp the live pattern the memoised format plans are valid for
            plan = dataclasses.replace(
                plan, pattern_sig=_pattern_sig(row, col, val))
        # policy-candidate indices -> build-candidate (variant) indices
        remap = np.asarray([candidates.index(f) for f in policy.candidates],
                           np.int32)
        lplans = plan.interior_plans if split else plan.local_plans
        lids, rids = remap[policy.select_batch(lcoos)], remap[policy.select_batch(rcoos)]
        local = SwitchDynamicMatrix.build_batched(
            lcoos, candidates, plans=lplans, active_ids=lids)
        if bcoos is not None:
            bids = remap[policy.select_batch(bcoos)]
            boundary = SwitchDynamicMatrix.build_batched(
                bcoos, candidates, plans=plan.boundary_plans, active_ids=bids)
        remote = SwitchDynamicMatrix.build_batched(
            rcoos, candidates, plans=plan.remote_plans, active_ids=rids)
    else:
        raise ValueError(mode)

    A = DistSparseMatrix(local, remote, boundary=boundary, nshards=nshards,
                         mp=plan.mp, shape=shape, halo_mode=plan.halo_mode,
                         axis=axis, hw=plan.hw, remote_empty=plan.remote_empty)
    A = _shard_containers(A, mesh)
    # Build artifact (not pytree state): pass back via build(plan=...) and a
    # rebuild performs zero symbolic pulls — partition caps, split caps and
    # per-format SwitchPlans are all memoised.
    A.plan = plan
    if cache_key is not None and plan_cache is not None:
        if plan.pattern_sig is None:
            plan = dataclasses.replace(plan, pattern_sig=sig)
            A.plan = plan
        plan_cache.put_raw(cache_key, plan.to_json())
    return A


def _shard_containers(A: DistSparseMatrix, mesh: Mesh) -> DistSparseMatrix:
    """Place stacked shard arrays with their leading axis on the mesh."""
    axis = A.axis

    def put(t):
        # a planned *placement*, not a symbolic pull: resharding a committed
        # single-device array across the mesh may stage through host on CPU
        # backends, which must not trip a build-time transfer guard.
        with jax.transfer_guard("allow"):
            return jax.tree.map(
                lambda a: jax.device_put(
                    a, NamedSharding(mesh, leading_axis_spec(axis, a.ndim))), t)

    return A._replace_parts(put(A.local), put(A.remote),
                            boundary=put(A.boundary) if A.split else None)


def activate_dist(A: DistSparseMatrix, part: str, fmt_or_ids) -> DistSparseMatrix:
    """Runtime format switch of the local, boundary or remote part
    (paper activate())."""
    if part not in ("local", "boundary", "remote"):
        raise ValueError(f"part {part!r} not in ('local', 'boundary', "
                         f"'remote')")
    if part == "boundary" and not A.split:
        raise ValueError("matrix has no boundary part "
                         "(build_dist_matrix(split=True))")
    tgt = getattr(A, part)
    if isinstance(tgt, SwitchDynamicMatrix):
        if isinstance(fmt_or_ids, Format):
            idx = list(tgt.candidates).index(Format(fmt_or_ids))
            ids = jnp.full((A.nshards,), idx, jnp.int32)
        else:
            # scalar ids broadcast to the per-shard vector the stacked
            # union's shard axis expects
            ids = jnp.broadcast_to(jnp.asarray(fmt_or_ids, jnp.int32),
                                   (A.nshards,))
        new = tgt.activate_id(ids)
    else:
        raise TypeError("uniform-mode parts switch via build (conversion); "
                        "use mode='multiformat' for runtime switching")
    if part == "local":
        return A._replace_parts(new, A.remote)
    if part == "boundary":
        return A._replace_parts(A.local, A.remote, boundary=new)
    return A._replace_parts(A.local, new)


# ---------------------------------------------------------------------------
# Observability wrappers (spans on the host-side build pipeline)
# ---------------------------------------------------------------------------


def _traced_plan_partition(fn):
    @functools.wraps(fn)
    def wrapper(row, col, val, shape, nshards, **kwargs):
        if _trace.mode() == "off":
            return fn(row, col, val, shape, nshards, **kwargs)
        with _trace.span("plan.partition", p=int(nshards)) as sp:
            plan = fn(row, col, val, shape, nshards, **kwargs)
            sp.set(halo=plan.halo_mode, hw=plan.hw,
                   remote_empty=plan.remote_empty)
        return plan
    return wrapper


def _traced_build_dist(fn):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        if _trace.mode() == "off":
            return fn(*args, **kwargs)
        with _trace.span("build.dist",
                         mode=kwargs.get("mode", "uniform")) as sp:
            A = fn(*args, **kwargs)
            sp.set(p=A.nshards, halo=A.halo_mode, hw=A.hw)
        return A
    return wrapper


# Rebind so internal callers (partition_coo, build_dist_matrix, the MG
# hierarchy builder) and importers all get the instrumented entry points.
plan_partition = _traced_plan_partition(plan_partition)
build_dist_matrix = _traced_build_dist(build_dist_matrix)
