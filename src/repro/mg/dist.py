"""Distributed MG-PCG: per-level slab partitions over the same mesh.

Every level of the geometric hierarchy is an independent HPCG stencil
system, so every level gets its own analytic
:class:`~repro.core.distributed.DistPlan` from ``hpcg.slab_plan`` (z-slab
partition, correct by construction -> ``check_plan=False``, triplets
touched once by the device scatter) and its own
:func:`~repro.core.distributed.build_dist_matrix` — including
``mode="multiformat"``, where the tuning policy picks each level's
per-shard local/remote formats exactly as for the top-level operator.

The smoother is the standard distributed adaptation of HPCG's SymGS:
halo values are exchanged once per sweep and *frozen* during it (hybrid
block-Jacobi across shards, colored symmetric Gauss-Seidel within each
shard's local block). Folding the frozen halo term into the right-hand
side (``b_eff = b - A_remote x_halo``) reduces the per-shard work to the
single-device colored sweep over the local block — the same
``(NCOLORS, cap)`` stacked split, built here with one vmapped device
scatter over the shard axis. Grid transfers are injection and z-slabs
align across levels (fine z = 2 * coarse z lands in the same shard), so
restriction/prolongation are shard-local gathers/scatters — no collective.

A V-cycle therefore issues collectives only where the operator itself
does: the per-sweep halo exchange and the residual's overlapped
``dist_spmv``. Each level's operator is built with the default
``split="auto"``, so the residual SpMV runs the interior/boundary overlap
schedule (interior compute while the halo collective is in flight); the
colored smoother keeps working off the *full* local stacked COO the
partition scatter already produced — the split never touches it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.core import compat
from repro.core import ops as _ops
from repro.core.compat import leading_axis_spec
from repro.core.convert import (_planned_pull, convert_execute_batch,
                                plan_switch_batch)
from repro.core.distributed import (DistSparseMatrix, _exchange_neighbor,
                                    _part_spec, _unstack, build_dist_matrix,
                                    dist_spmv)
from repro.core.dynamic import DEFAULT_CANDIDATES, SwitchDynamicMatrix
from repro.core.formats import COO, Format
from repro.core.hpcg import HPCGProblem, generate_problem, partition_problem
from repro.mg.cycle import MIN_COARSE_ROWS
from repro.obs import trace as _trace
from repro.mg.smoothers import (NCOLORS, _split_colors_device, color_grid,
                                color_ranks, color_rows_padded)


@dataclasses.dataclass(frozen=True)
class DistColoredSystem:
    """Stacked per-shard color split of the local blocks.

    ``blocks[c]`` is a stacked ``(P, ...)`` container of shape
    ``(rmax, mp)`` (every shard's slab has identical geometry, so the
    color structure — ``rows``, ranks, counts — is shared host metadata);
    ``diag`` is the stacked ``(P, mp)`` local diagonal.
    """

    blocks: Tuple
    rows: Tuple[np.ndarray, ...]
    diag: jax.Array

    @property
    def formats(self):
        out = []
        for b in self.blocks:
            out.append([f.name for f in b.candidates]
                       if isinstance(b, SwitchDynamicMatrix)
                       else Format(b.format).name)
        return out


@dataclasses.dataclass(frozen=True)
class DistMGLevel:
    A: DistSparseMatrix
    colored: DistColoredSystem
    f2c_local: Optional[np.ndarray]     # (mp_coarse,) — None on coarsest
    dims: Tuple[int, int, int]
    slab_dims: Tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class DistMGHierarchy:
    levels: Tuple[DistMGLevel, ...]
    mesh: Mesh
    pre: int = 1
    post: int = 1
    coarse_sweeps: int = 4
    backend: str = "auto"

    @property
    def nlevels(self) -> int:
        return len(self.levels)

    def apply_M(self) -> Callable:
        return lambda r: v_cycle_dist(self, r)

    def formats(self):
        """Per-level distributed selection summary (A's per-shard active
        ids in multiformat mode + smoother block formats)."""
        out = []
        for i, lev in enumerate(self.levels):
            rec = {"level": i, "dims": lev.dims,
                   "colors": lev.colored.formats}
            parts = (("local", "boundary", "remote") if lev.A.split
                     else ("local", "remote"))
            for part in parts:
                t = getattr(lev.A, part)
                if isinstance(t, SwitchDynamicMatrix):
                    names = [f.name for f in t.candidates]
                    ids = np.asarray(t.active_id)
                    rec[part] = [names[j] for j in ids]
                else:
                    rec[part] = Format(t.format).name
            out.append(rec)
        return out

    def __repr__(self):
        dims = " > ".join("x".join(map(str, lev.dims)) for lev in self.levels)
        return (f"DistMGHierarchy({dims}; P={self.levels[0].A.nshards}, "
                f"pre={self.pre}, post={self.post})")


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


def _shard_put(t, mesh: Mesh, axis):
    with jax.transfer_guard("allow"):
        return jax.tree.map(
            lambda a: jax.device_put(
                a, NamedSharding(mesh, leading_axis_spec(axis, a.ndim))), t)


def _diag_batched(local: COO) -> jax.Array:
    """(P, mp) local-block diagonal in one vmapped device pass."""
    mp = local.shape[0]

    def one(row, col, data):
        on = row == col
        return jax.ops.segment_sum(jnp.where(on, data, 0), row,
                                   num_segments=mp)

    return jax.vmap(one)(local.row, local.col, local.data)


def _build_dist_colored(local: COO, slab_dims, mesh: Mesh, axis,
                        fmt: Format = Format.CSR,
                        policy=None,
                        candidates: Sequence[Format] = DEFAULT_CANDIDATES
                        ) -> DistColoredSystem:
    """Color-split every shard's local block in one vmapped device scatter.

    With a ``FormatPolicy``, each color's stacked shard batch goes through
    ``select_batch`` and becomes a stacked ``SwitchDynamicMatrix`` with
    per-shard active ids (the Multi-Format smoother); otherwise every
    block converts uniformly to ``fmt`` via the batched plan/execute.
    """
    mp = local.shape[0]
    colors = color_grid(*slab_dims)
    counts = np.bincount(colors, minlength=NCOLORS)
    rmax = max(1, int(counts.max()))
    colors_d = jnp.asarray(colors)
    rank_d = jnp.asarray(color_ranks(colors))

    # shared per-color capacity: one vmapped count + one planned pull
    def _counts(row, data):
        key = jnp.where(data != 0, colors_d[row], NCOLORS)
        return jnp.bincount(key, length=NCOLORS + 1)[:NCOLORS]

    cap = max(1, int(_planned_pull(jnp.max(jax.vmap(_counts)(
        local.row, local.data)))))

    split = jax.vmap(
        lambda r, c, v: _split_colors_device(r, c, v, colors_d, rank_d, cap))
    rr, cc, vv = split(local.row, local.col, local.data)  # (P, NCOLORS, cap)

    blocks = []
    for c in range(NCOLORS):
        Cc = COO(rr[:, c], cc[:, c], vv[:, c], (rmax, mp), cap)
        if policy is not None:
            ids = policy.select_batch(Cc)
            blk = SwitchDynamicMatrix.build_batched(
                Cc, candidates=tuple(policy.candidates), active_ids=ids)
        else:
            blk = convert_execute_batch(Cc, plan_switch_batch(Cc, Format(fmt)))
        blocks.append(_shard_put(blk, mesh, axis))
    rows_np = color_rows_padded(colors, mp, rmax)
    rows = tuple(rows_np[c] for c in range(NCOLORS))
    diag = _shard_put(_diag_batched(local), mesh, axis)
    return DistColoredSystem(tuple(blocks), rows, diag)


def build_dist_hierarchy(prob: HPCGProblem, mesh: Mesh, axis,
                         nlevels: Optional[int] = None,
                         mode: str = "uniform",
                         tune="cached",
                         local_format: Format = Format.DIA,
                         remote_format: Format = Format.COO,
                         candidates: Sequence[Format] = DEFAULT_CANDIDATES,
                         smoother_format: Format = Format.CSR,
                         smoother_policy=None,
                         pre: int = 1, post: int = 1, coarse_sweeps: int = 4,
                         backend: str = "auto",
                         dtype=jnp.float32) -> DistMGHierarchy:
    """Per-level slab-partitioned hierarchy on ``mesh``.

    Coarsening continues while the grid dims stay even, the coarse slab
    height divides the shard count (``(nz/2) % P == 0`` — each level's
    ``hpcg.slab_plan`` must exist) and the level keeps at least
    ``MIN_COARSE_ROWS`` rows. ``mode``/``tune``/``*_format`` flow into
    every level's ``build_dist_matrix``; ``smoother_policy`` upgrades the
    colored smoother blocks to per-(shard, color) Multi-Format selection.
    """
    sizes = mesh.shape
    names = (axis,) if isinstance(axis, str) else tuple(axis)
    nshards = int(np.prod([sizes[a] for a in names]))

    dims = (prob.nx, prob.ny, prob.nz)
    if prob.nz % nshards:
        raise ValueError(f"nz={prob.nz} not divisible by P={nshards}")
    levels = []
    prob_l = prob
    while True:
        nx, ny, nz = dims
        last = ((nlevels is not None and len(levels) + 1 >= nlevels)
                or any(d % 2 for d in dims)
                or (nz // 2) % nshards
                or (nx * ny * nz) // 8 < MIN_COARSE_ROWS)
        # one device scatter per level: the stacked (local, remote) parts
        # feed both the matrix builder (parts=) and the colored smoother
        with _trace.span("build.mg_dist_level", level=len(levels),
                         dims="x".join(map(str, dims)), p=nshards):
            local, remote, plan = partition_problem(prob_l, nshards,
                                                    dtype=dtype)
            A = build_dist_matrix(prob_l.row, prob_l.col, prob_l.val,
                                  prob_l.shape, mesh, axis,
                                  local_format=local_format,
                                  remote_format=remote_format, mode=mode,
                                  tune=tune, candidates=candidates,
                                  plan=plan, check_plan=False, dtype=dtype,
                                  parts=(local, remote))
            slab_dims = (nx, ny, nz // nshards)
            colored = _build_dist_colored(local, slab_dims, mesh, axis,
                                          fmt=smoother_format,
                                          policy=smoother_policy,
                                          candidates=candidates)
        f2c_local = None
        if not last:
            # coarse slab -> fine slab injection map (shard-local: fine
            # z = 2 * coarse z stays inside the same z-slab)
            from repro.mg.coarsen import f2c_map, plan_coarsen

            cplan = plan_coarsen(nx, ny, nz // nshards)
            f2c_local = np.asarray(f2c_map(cplan))
        levels.append(DistMGLevel(A, colored, f2c_local, dims, slab_dims))
        if last:
            break
        dims = (nx // 2, ny // 2, nz // 2)
        prob_l = generate_problem(*dims)
    return DistMGHierarchy(tuple(levels), mesh, pre=pre, post=post,
                           coarse_sweeps=coarse_sweeps, backend=backend)


# ---------------------------------------------------------------------------
# The distributed V-cycle
# ---------------------------------------------------------------------------


def _dist_smooth(hier: DistMGHierarchy, lev: DistMGLevel, b, x,
                 sweeps: int, x_is_zero: bool):
    """``sweeps`` distributed SymGS sweeps: per sweep, one halo exchange
    (skipped when ``x`` is statically zero — the halo term vanishes) then
    the frozen-halo colored forward+backward sweep on the local block."""
    if sweeps <= 0:
        return x if x is not None else jnp.zeros_like(b)
    A, cs = lev.A, lev.colored
    axis = A.axis
    backend = hier.backend
    rows_np = cs.rows

    def body(blocks_s, diag_s, remote_s, b_blk, x_blk):
        blocks = [_unstack(blk) for blk in blocks_s]
        diag_l = diag_s[0]
        remote = _unstack(remote_s)
        x = x_blk
        for s in range(int(sweeps)):
            if A.remote_empty or (x_is_zero and s == 0):
                beff = b_blk
            else:
                if A.halo_mode == "neighbor":
                    halo = _exchange_neighbor(x, A.hw, axis, A.nshards)
                else:
                    halo = jax.lax.all_gather(x, axis, tiled=True)
                beff = b_blk - _ops.spmv(remote, halo, backend=backend)
            for order in (range(NCOLORS), range(NCOLORS - 1, -1, -1)):
                for c in order:
                    y = _ops.spmv(blocks[c], x, backend=backend)
                    rws = jnp.asarray(rows_np[c])
                    bc = jnp.take(beff, rws, mode="clip")
                    dc = jnp.take(diag_l, rws, mode="clip")
                    x = x.at[rws].add((bc - y) / jnp.where(dc != 0, dc, 1.0))
        return x

    if x is None:
        x = jnp.zeros_like(b)
    fn = compat.shard_map(
        body, mesh=hier.mesh,
        in_specs=(_part_spec(cs.blocks, axis), leading_axis_spec(axis, 2),
                  _part_spec(A.remote, axis), leading_axis_spec(axis, 1),
                  leading_axis_spec(axis, 1)),
        out_specs=leading_axis_spec(axis, 1))
    return fn(cs.blocks, cs.diag, A.remote, b, x)


def _dist_restrict(hier: DistMGHierarchy, lev: DistMGLevel, r):
    axis = lev.A.axis
    f2c = lev.f2c_local
    fn = compat.shard_map(
        lambda rf: jnp.take(rf, jnp.asarray(f2c), mode="clip"),
        mesh=hier.mesh, in_specs=(leading_axis_spec(axis, 1),),
        out_specs=leading_axis_spec(axis, 1))
    return fn(r)


def _dist_prolong(hier: DistMGHierarchy, lev: DistMGLevel, xc):
    axis = lev.A.axis
    f2c = lev.f2c_local
    mp = lev.A.mp

    fn = compat.shard_map(
        lambda xb: jnp.zeros((mp,), xb.dtype).at[jnp.asarray(f2c)].set(xb),
        mesh=hier.mesh, in_specs=(leading_axis_spec(axis, 1),),
        out_specs=leading_axis_spec(axis, 1))
    return fn(xc)


def v_cycle_dist(hier: DistMGHierarchy, r: jax.Array,
                 level: int = 0) -> jax.Array:
    """One distributed V-cycle from a zero guess (jit-able; collectives:
    halo exchanges in the smoother + the overlapped residual SpMV)."""
    with _trace.span("mg.vcycle_dist", level=level):
        lev = hier.levels[level]
        if level == hier.nlevels - 1:
            return _dist_smooth(hier, lev, r, None, hier.coarse_sweeps, True)
        x = _dist_smooth(hier, lev, r, None, hier.pre, True)
        res = r - dist_spmv(lev.A, x, hier.mesh, backend=hier.backend)
        rc = _dist_restrict(hier, lev, res)
        xc = v_cycle_dist(hier, rc, level + 1)
        x = x + _dist_prolong(hier, lev, xc)
        return _dist_smooth(hier, lev, r, x, hier.post, False)
