"""Geometric 2:1 coarsening of the HPCG grid hierarchy (plan/execute).

The multigrid analogue of ``core.convert``'s symbolic/numeric split:

  * :func:`plan_coarsen` (symbolic) is pure integer arithmetic over the
    grid dimensions — no device work, no data. It emits a
    :class:`CoarsenPlan` of static python ints/strings, hashable so the
    numeric phase rides through ``jax.jit`` as a static argument.
  * :func:`coarsen_execute` (numeric) materialises the level-transfer
    machinery **on device**: the injection map ``f2c`` (coarse point i ->
    fine grid index), the trilinear-prolongation corner tables, and (for
    the default rediscretized coarse operator) the 27-point-stencil COO
    triplets of the coarse grid — all from ``jnp.arange`` index
    arithmetic, fully jit-able, zero device->host transfers.

Transfer operators (paper HPCG §3.3 conventions):

  * restriction: **injection** (``rc[i] = rf[f2c[i]]``, HPCG's choice)
    paired with injection prolongation, or **full weighting**
    (``R = P^T / 8``) paired with trilinear prolongation — both pairings
    keep ``P = c R^T`` so the V-cycle preconditioner stays symmetric.
  * coarse operator: **rediscretize** (the 27-point stencil regenerated on
    the coarse grid — HPCG's choice, device-resident here) or **galerkin**
    (``Ac = R Af P``, a host triple product via padded-neighbour joins;
    setup-phase only, kept as the algebraic cross-check).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import COO
from repro.core.hpcg import HPCGProblem

PROLONG_MODES = ("injection", "trilinear")
COARSE_OPS = ("rediscretize", "galerkin")


@dataclasses.dataclass(frozen=True)
class CoarsenPlan:
    """Static metadata of one 2:1 coarsening step (hashable, jit-static).

    ``fine``/``coarse`` are the grid dims; ``prolong`` fixes the transfer
    pair (injection/injection or trilinear/full-weighting); ``coarse_op``
    picks how the coarse operator is built.
    """

    fine: Tuple[int, int, int]
    coarse: Tuple[int, int, int]
    prolong: str = "injection"
    coarse_op: str = "rediscretize"

    @property
    def nf(self) -> int:
        return int(np.prod(self.fine))

    @property
    def nc(self) -> int:
        return int(np.prod(self.coarse))


def plan_coarsen(nx: int, ny: int, nz: int, prolong: str = "injection",
                 coarse_op: str = "rediscretize") -> CoarsenPlan:
    """Symbolic phase: validate the 2:1 step and fix its static metadata."""
    if prolong not in PROLONG_MODES:
        raise ValueError(f"prolong {prolong!r} not in {PROLONG_MODES}")
    if coarse_op not in COARSE_OPS:
        raise ValueError(f"coarse_op {coarse_op!r} not in {COARSE_OPS}")
    if coarse_op == "galerkin" and prolong == "injection":
        # R A P with injection R/P just samples A at the even points: for a
        # reach-1 stencil every sampled off-diagonal vanishes and Ac
        # degenerates to a diagonal — pair galerkin with trilinear instead.
        raise ValueError("coarse_op='galerkin' requires prolong='trilinear' "
                         "(injection Galerkin degenerates to diag sampling)")
    for d in (nx, ny, nz):
        if d < 2 or d % 2:
            raise ValueError(
                f"2:1 coarsening needs even dims >= 2, got {(nx, ny, nz)}")
    return CoarsenPlan((nx, ny, nz), (nx // 2, ny // 2, nz // 2),
                       prolong=prolong, coarse_op=coarse_op)


# ---------------------------------------------------------------------------
# Device index arithmetic (all jit-able; grid ordering is x-fastest,
# idx = x + nx*(y + ny*z), matching core.hpcg.generate_problem)
# ---------------------------------------------------------------------------


def _grid_xyz(n: int, nx: int, ny: int):
    idx = jnp.arange(n, dtype=jnp.int32)
    return idx % nx, (idx // nx) % ny, idx // (nx * ny)


def f2c_map(plan: CoarsenPlan) -> jax.Array:
    """(nc,) fine-grid index of every coarse point (fine = 2 * coarse)."""
    nxc, nyc, _ = plan.coarse
    nxf, nyf, _ = plan.fine
    xc, yc, zc = _grid_xyz(plan.nc, nxc, nyc)
    return 2 * xc + nxf * (2 * yc + nyf * 2 * zc)


def trilinear_corners(plan: CoarsenPlan) -> Tuple[jax.Array, jax.Array]:
    """Per-fine-point coarse interpolation corners.

    Returns ``(cols, wts)`` of shape ``(nf, 8)``: the up-to-8 coarse
    points each fine point interpolates from and their trilinear weights
    (1 per even coordinate, 1/2 per odd-coordinate neighbour pair). Corners
    falling outside the coarse grid (the odd top boundary) carry weight 0
    and a column id of ``nc`` — the scatter-drop / masked-gather sentinel.
    """
    nxf, nyf, _ = plan.fine
    nxc, nyc, nzc = plan.coarse
    xf, yf, zf = _grid_xyz(plan.nf, nxf, nyf)
    cols, wts = [], []
    for dz in (0, 1):
        for dy in (0, 1):
            for dx in (0, 1):
                xc, yc, zc = xf // 2 + dx, yf // 2 + dy, zf // 2 + dz
                # weight per axis: even coord -> only the d=0 corner (w=1);
                # odd coord -> both corners at w=1/2 each
                w = jnp.ones((plan.nf,), jnp.float32)
                dup = jnp.zeros((plan.nf,), bool)
                for coord, d in ((xf, dx), (yf, dy), (zf, dz)):
                    odd = (coord % 2) == 1
                    w = w * jnp.where(odd, 0.5, 1.0)
                    dup = dup | (~odd & (d == 1))  # even coord has no d=1 corner
                ok = (~dup) & (xc < nxc) & (yc < nyc) & (zc < nzc)
                cid = xc + nxc * (yc + nyc * zc)
                cols.append(jnp.where(ok, cid, plan.nc))
                wts.append(jnp.where(ok, w, 0.0))
    return jnp.stack(cols, axis=1), jnp.stack(wts, axis=1)


def stencil27_coo(nx: int, ny: int, nz: int, dtype=jnp.float32) -> COO:
    """The HPCG 27-point stencil (diag 26, off-diag -1) as device COO.

    jit-able twin of ``core.hpcg.generate_problem``: capacity ``27*n`` with
    out-of-grid neighbours stored as inert padding (row kept, val 0) so the
    shape is static for any grid.
    """
    n = nx * ny * nz
    x, y, z = _grid_xyz(n, nx, ny)
    rows, cols, vals = [], [], []
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                xp, yp, zp = x + dx, y + dy, z + dz
                ok = ((xp >= 0) & (xp < nx) & (yp >= 0) & (yp < ny)
                      & (zp >= 0) & (zp < nz))
                c = xp + nx * (yp + ny * zp)
                v = jnp.where(dx == 0 and dy == 0 and dz == 0, 26.0, -1.0)
                rows.append(x + nx * (y + ny * z))
                cols.append(jnp.where(ok, c, 0).astype(jnp.int32))
                vals.append(jnp.where(ok, v, 0.0).astype(dtype))
    row = jnp.concatenate(rows).astype(jnp.int32)
    col = jnp.concatenate(cols)
    val = jnp.concatenate(vals)
    return COO(row, col, val, (n, n), 27 * n)


# ---------------------------------------------------------------------------
# The numeric phase: Coarsening (device-resident level-transfer machinery)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Coarsening:
    """Device artifacts of one coarsening step (output of
    :func:`coarsen_execute`). ``tri_cols``/``tri_wts`` are only populated
    for trilinear plans; ``Ac`` only when the plan's coarse operator is
    device-buildable (rediscretize)."""

    plan: CoarsenPlan
    f2c: jax.Array                       # (nc,) injection map
    tri_cols: Optional[jax.Array] = None  # (nf, 8) coarse corner ids
    tri_wts: Optional[jax.Array] = None   # (nf, 8) trilinear weights
    Ac: Optional[COO] = None              # coarse operator (rediscretized)


@functools.partial(jax.jit, static_argnums=0)
def _coarsen_execute_jit(plan: CoarsenPlan, dummy=None):
    f2c = f2c_map(plan)
    tc = tw = None
    if plan.prolong == "trilinear":
        tc, tw = trilinear_corners(plan)
    Ac = None
    if plan.coarse_op == "rediscretize":
        Ac = stencil27_coo(*plan.coarse)
    return f2c, tc, tw, Ac


def coarsen_execute(plan: CoarsenPlan, Af: Optional[COO] = None) -> Coarsening:
    """Numeric phase: build the level-transfer artifacts for ``plan``.

    Device-resident and jit-compiled (one trace per plan) for the
    injection/trilinear maps and the rediscretized coarse stencil. A
    ``galerkin`` plan additionally needs the fine operator ``Af`` and runs
    the host triple product (:func:`galerkin_coarse`) — setup-phase only.
    """
    f2c, tc, tw, Ac = _coarsen_execute_jit(plan)
    if plan.coarse_op == "galerkin":
        if Af is None:
            raise ValueError("coarse_op='galerkin' needs the fine operator "
                             "Af (host triple product)")
        Ac = galerkin_coarse(Af, plan)
    return Coarsening(plan, f2c, tri_cols=tc, tri_wts=tw, Ac=Ac)


def restrict(c: Coarsening, rf: jax.Array) -> jax.Array:
    """rc = R rf: injection gather, or full weighting ``P^T rf / 8`` for
    trilinear plans (scatter-add over the corner tables; the ``nc``
    sentinel columns drop)."""
    if c.plan.prolong == "injection":
        return jnp.take(rf, c.f2c, mode="clip")
    contrib = (c.tri_wts * rf[:, None]).reshape(-1)
    return jnp.zeros((c.plan.nc,), rf.dtype).at[
        c.tri_cols.reshape(-1)].add(contrib) / 8.0


def prolong(c: Coarsening, xc: jax.Array) -> jax.Array:
    """xf = P xc: injection scatter (zeros elsewhere), or trilinear
    interpolation over the corner tables."""
    if c.plan.prolong == "injection":
        return jnp.zeros((c.plan.nf,), xc.dtype).at[c.f2c].set(xc)
    gathered = jnp.take(xc, jnp.clip(c.tri_cols, 0, c.plan.nc - 1),
                        mode="clip")
    return jnp.sum(c.tri_wts * gathered, axis=1)


# ---------------------------------------------------------------------------
# Galerkin triple product (host; the algebraic cross-check of rediscretize)
# ---------------------------------------------------------------------------


def _coalesce(r, c, v):
    order = np.lexsort((c, r))
    r, c, v = r[order], c[order], v[order]
    first = np.ones(len(r), bool)
    first[1:] = (r[1:] != r[:-1]) | (c[1:] != c[:-1])
    idx = np.cumsum(first) - 1
    out = np.zeros(int(first.sum()), v.dtype)
    np.add.at(out, idx, v)
    return r[first], c[first], out


def _p_padded(plan: CoarsenPlan):
    """Host (nf, 8) padded form of the prolongation P (cols=-1 padding)."""
    if plan.prolong == "injection":
        cols = np.full((plan.nf, 1), -1, np.int64)
        wts = np.zeros((plan.nf, 1))
        f2c = np.asarray(f2c_map(plan))
        cols[f2c, 0] = np.arange(plan.nc)
        wts[f2c, 0] = 1.0
        return cols, wts
    tc_d, tw_d = trilinear_corners(plan)
    tc = np.asarray(tc_d).astype(np.int64)
    tw = np.asarray(tw_d).astype(np.float64)
    return np.where(tw > 0, tc, -1), tw


def galerkin_coarse(Af: COO, plan: CoarsenPlan, dtype=jnp.float32) -> COO:
    """Ac = R Af P on host via two padded-neighbour joins.

    ``R`` is the adjoint pairing of the plan's prolongation (``P^T`` for
    injection, ``P^T / 8`` full weighting for trilinear), so ``Ac`` is
    symmetric whenever ``Af`` is. O(nnz(Af) * 8^2) intermediate entries —
    a setup-phase cost, matching the symbolic phase's transfer class.
    """
    pc, pw = _p_padded(plan)
    k = pc.shape[1]
    ar = np.asarray(Af.row, np.int64)
    ac = np.asarray(Af.col, np.int64)
    av = np.asarray(Af.data, np.float64)
    live = av != 0
    ar, ac, av = ar[live], ac[live], av[live]
    # join 1: (A P)[i, kc] = sum_j A[i, j] P[j, kc]
    jr = np.repeat(ar, k)
    jc = pc[ac].reshape(-1)
    jv = (av[:, None] * pw[ac]).reshape(-1)
    ok = jc >= 0
    jr, jc, jv = _coalesce(jr[ok], jc[ok], jv[ok])
    # join 2: Ac[kr, kc] = sum_i P[i, kr] (A P)[i, kc]   (R = P^T [/8])
    gr = pc[jr].reshape(-1)
    gc = np.repeat(jc, k)
    gv = (pw[jr] * jv[:, None]).reshape(-1)
    ok = gr >= 0
    gr, gc, gv = _coalesce(gr[ok], gc[ok], gv[ok])
    if plan.prolong == "trilinear":
        gv = gv / 8.0
    return COO(jnp.asarray(gr, jnp.int32), jnp.asarray(gc, jnp.int32),
               jnp.asarray(gv.astype(np.dtype(dtype))), (plan.nc, plan.nc),
               len(gv))


def coarse_problem(prob: HPCGProblem) -> HPCGProblem:
    """Rediscretized coarse :class:`HPCGProblem` (host twin of
    :func:`stencil27_coo`, used by the distributed per-level builder)."""
    from repro.core.hpcg import generate_problem

    plan = plan_coarsen(prob.nx, prob.ny, prob.nz)
    return generate_problem(*plan.coarse)
