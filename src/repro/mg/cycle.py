"""MGHierarchy — geometric multigrid V-cycle over DynamicMatrix levels.

Each level is an *independent* sparse operator with its own sparsity
structure — exactly the scenario where runtime format selection pays
(Morpheus unleashed, arXiv:2304.09511): the fine stencil favours DIA, the
small coarse systems favour whatever the policy measures/predicts for
their shape bucket. ``build_hierarchy`` therefore routes every level's
operator *and* every smoother color block through one
``FormatPolicy`` (``select`` for the level operator, one batched
``select_batch`` pass per level for its stacked color blocks) when a
policy is given.

``apply_M()`` returns a jit-able closure ``r -> z`` (the level loop
unrolls at trace time; level data lowers to on-device constants) that
plugs straight into ``repro.core.solvers.pcg(apply_A, b, apply_M=...)``.
The default configuration — SymGS pre/post smoothing with equal sweep
counts, injection transfer pair ``P = R^T``, a symmetric coarse solve
(SymGS sweeps) — keeps M symmetric positive definite, which plain
(non-flexible) PCG requires; ``tests/test_mg.py`` checks both properties
against the densified operator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ops as _ops
from repro.core.convert import convert_execute, plan_switch
from repro.core.formats import COO, Format
from repro.core.hpcg import HPCGProblem, to_coo as hpcg_to_coo
from repro.mg.coarsen import (Coarsening, coarsen_execute, plan_coarsen,
                              prolong, restrict)
from repro.mg.smoothers import ColoredSystem, build_colored, jacobi, symgs
from repro.obs import trace as _trace

# Coarsening stops once a level has this few rows (the coarse solve —
# SymGS sweeps — handles the rest).
MIN_COARSE_ROWS = 8


@dataclasses.dataclass(frozen=True)
class MGLevel:
    """One level: operator + smoother + (except coarsest) the coarsening."""

    A: object                      # level operator, any concrete format
    diag: jax.Array                # diag(A) for the Jacobi fallback
    smoother: Optional[ColoredSystem]   # None -> weighted Jacobi
    coarsen: Optional[Coarsening]       # None on the coarsest level
    dims: Tuple[int, int, int]

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def format(self) -> Format:
        return Format(self.A.format)


@dataclasses.dataclass(frozen=True)
class MGHierarchy:
    """The V-cycle preconditioner M^{-1} ~ A^{-1} over a level stack."""

    levels: Tuple[MGLevel, ...]
    pre: int = 1
    post: int = 1
    coarse_sweeps: int = 4
    backend: str = "auto"

    @property
    def nlevels(self) -> int:
        return len(self.levels)

    def apply_M(self) -> Callable:
        """``r -> z = M^{-1} r``: one V-cycle, jit-able (close over the
        hierarchy; level containers lower to on-device constants)."""
        return lambda r: v_cycle(self, r)

    def formats(self):
        """Per-level (operator format, color-block formats) — the
        introspection hook the selection tests/benchmarks read."""
        return [{
            "level": i, "dims": lev.dims, "n": lev.n,
            "A": lev.format.name,
            "colors": ([f.name for f in lev.smoother.formats]
                       if lev.smoother is not None else None),
        } for i, lev in enumerate(self.levels)]

    def __repr__(self):
        dims = " > ".join("x".join(map(str, lev.dims)) for lev in self.levels)
        return (f"MGHierarchy({dims}; pre={self.pre}, post={self.post}, "
                f"coarse_sweeps={self.coarse_sweeps})")


def _smooth(hier: MGHierarchy, lev: MGLevel, b, x, sweeps: int):
    if sweeps <= 0:
        return x if x is not None else jnp.zeros_like(b)
    if lev.smoother is not None:
        return symgs(lev.smoother, b, x, sweeps=sweeps, backend=hier.backend)
    return jacobi(lev.diag, lambda v: _ops.spmv(lev.A, v, backend=hier.backend),
                  b, x, sweeps=sweeps)


def v_cycle(hier: MGHierarchy, r: jax.Array, level: int = 0) -> jax.Array:
    """One V-cycle on ``A_level z = r`` from a zero initial guess.

    The ``mg.vcycle`` span fires per *trace* of the level recursion (the
    cycle is usually jitted inside pcg's while_loop), so it attributes
    trace/compile structure, not per-iteration device time — the
    per-iteration cost shows up in the enclosing ``solver.*`` span.
    """
    with _trace.span("mg.vcycle", level=level):
        lev = hier.levels[level]
        if level == hier.nlevels - 1:
            return _smooth(hier, lev, r, None, hier.coarse_sweeps)
        x = _smooth(hier, lev, r, None, hier.pre)
        res = r - _ops.spmv(lev.A, x, backend=hier.backend)
        rc = restrict(lev.coarsen, res)
        xc = v_cycle(hier, rc, level + 1)
        x = x + prolong(lev.coarsen, xc)
        return _smooth(hier, lev, r, x, hier.post)


def _pick_format(C: COO, policy, fmt: Format):
    best = policy.select(C).best if policy is not None else Format(fmt)
    return convert_execute(C, plan_switch(C, best))


def build_hierarchy(prob: HPCGProblem, nlevels: Optional[int] = None,
                    fmt: Format = Format.CSR, policy=None,
                    smoother: str = "symgs",
                    pre: int = 1, post: int = 1, coarse_sweeps: int = 4,
                    prolong: str = "injection",
                    coarse_op: str = "rediscretize",
                    backend: str = "auto",
                    dtype=jnp.float32) -> MGHierarchy:
    """Construct the geometric hierarchy for an HPCG stencil problem.

    Levels coarsen 2:1 while every grid dim stays even and the level keeps
    at least ``MIN_COARSE_ROWS`` rows (or until ``nlevels``). Each level's
    operator format comes from ``policy.select`` (falling back to ``fmt``
    without a policy); each level's smoother color blocks come from one
    ``policy.select_batch`` pass over the stacked blocks. ``smoother`` is
    ``"symgs"`` (colored symmetric Gauss-Seidel) or ``"jacobi"``.

    Hierarchy construction is the plan/execute pipeline: per step one
    static :class:`~repro.mg.coarsen.CoarsenPlan` plus the jit-compiled
    device :func:`~repro.mg.coarsen.coarsen_execute` (rediscretized coarse
    stencil, injection/trilinear tables) — index arrays never round-trip
    through host.
    """
    if smoother not in ("symgs", "jacobi"):
        raise ValueError(f"unknown smoother {smoother!r}")
    dims = (prob.nx, prob.ny, prob.nz)
    C = hpcg_to_coo(prob, dtype=dtype)

    levels = []
    while True:
        last = ((nlevels is not None and len(levels) + 1 >= nlevels)
                or any(d % 2 for d in dims)
                or (C.shape[0] // 8) < MIN_COARSE_ROWS)
        with _trace.span("build.mg_level", level=len(levels),
                         dims="x".join(map(str, dims))) as sp:
            cz = None
            if not last:
                plan = plan_coarsen(*dims, prolong=prolong,
                                    coarse_op=coarse_op)
                cz = coarsen_execute(plan, Af=C)
            A = _pick_format(C, policy, fmt)
            cs = (build_colored(C, dims=dims, fmt=fmt, policy=policy)
                  if smoother == "symgs" else None)
            diag = cs.diag if cs is not None else _ops.extract_diagonal(C)
            sp.set(fmt=Format(A.format).name).sync(diag)
        levels.append(MGLevel(A, diag, cs, cz, dims))
        if last:
            break
        C = cz.Ac
        dims = plan.coarse
    return MGHierarchy(tuple(levels), pre=pre, post=post,
                       coarse_sweeps=coarse_sweeps, backend=backend)
