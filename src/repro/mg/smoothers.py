"""Vector-friendly smoothers: multicolored SymGS and weighted Jacobi.

HPCG's reference symmetric Gauss-Seidel sweeps rows in lexicographic
order — each update reads the previous one, which serialises the sweep and
is why the paper benchmarks with the preconditioner disabled (§IV-B). The
classic cure is a **grid coloring**: under the 2x2x2 (8-color) coloring of
a 3D grid, same-color points are at distance >= 2 along every axis, so the
27-point stencil never couples two points of one color. Gauss-Seidel in
*color order* then updates each color's rows simultaneously:

    for color c (ascending = forward, descending = backward):
        x[c] += (b[c] - (A x)[c]) / diag[c]

Each per-color partial ``(A x)[c]`` is one SpMV of the color's **row
block** — an ordinary (rows_c, n) sparse matrix stored in any of the
library's formats, so the sweep runs on the existing CSR/ELL Pallas
kernels through ``repro.core.ops.spmv`` and the measured ``backend="auto"``
routing. The sweep is *exactly* sequential Gauss-Seidel over the
color-permuted row ordering (the permutation is applied implicitly: blocks
carry their global row ids and updates scatter back through them).

Build path mirrors the distributed multiformat pipeline: the 8 row blocks
are extracted as ONE stacked ``(ncolors, cap)`` COO batch (a single device
scatter), featurised in one ``FormatPolicy.select_batch`` pass when a
policy is given, and converted per color through the plan/execute numeric
phase — so every color block can live in its own format.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ops as _ops
from repro.core.convert import (_planned_pull, convert_execute, plan_switch,
                                to_coo)
from repro.core.formats import COO, Format

NCOLORS = 8


def color_grid(nx: int, ny: int, nz: int) -> np.ndarray:
    """2x2x2 parity coloring of the x-fastest-ordered grid: color =
    (x%2) + 2*(y%2) + 4*(z%2). Proper for any stencil of reach <= 1 per
    axis (the 27-point stencil): no two same-color points are coupled."""
    idx = np.arange(nx * ny * nz)
    x, y, z = idx % nx, (idx // nx) % ny, idx // (nx * ny)
    return ((x % 2) + 2 * (y % 2) + 4 * (z % 2)).astype(np.int32)


def check_coloring(C: COO, colors: np.ndarray) -> None:
    """Raise if ``colors`` is not a proper coloring of ``C``'s live
    off-diagonal pattern (same-color coupling would silently turn the
    parallel sweep into chaotic relaxation)."""
    r = np.asarray(C.row)
    c = np.asarray(C.col)
    live = (np.asarray(C.data) != 0) & (r != c)
    bad = colors[r[live]] == colors[c[live]]
    if bad.any():
        i = int(np.argmax(bad))
        rr, cc = r[live][i], c[live][i]
        raise ValueError(
            f"improper coloring: rows {rr} and {cc} share color "
            f"{int(colors[rr])} but are coupled; a colored sweep would not "
            f"match sequential Gauss-Seidel")


@dataclasses.dataclass(frozen=True)
class ColoredSystem:
    """Color-permuted view of a square system for parallel Gauss-Seidel.

    ``blocks[c]`` is the (rmax, n) row block of color ``c`` (any format;
    inert padding rows when colors are unevenly sized); ``rows[c]`` holds
    the blocks' global row ids, padded with ``n`` so padded lanes clip on
    gather and drop on scatter; ``diag`` is the full diagonal of A.
    """

    blocks: Tuple
    rows: Tuple[jax.Array, ...]
    diag: jax.Array
    shape: Tuple[int, int]

    @property
    def ncolors(self) -> int:
        return len(self.blocks)

    @property
    def formats(self) -> Tuple[Format, ...]:
        return tuple(Format(b.format) for b in self.blocks)


def color_ranks(colors: np.ndarray) -> np.ndarray:
    """(n,) rank of every row within its color (host; shared metadata)."""
    order = np.argsort(colors, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order)) - np.concatenate(
        [[0], np.cumsum(np.bincount(colors, minlength=NCOLORS))])[colors[order]]
    return rank.astype(np.int32)


def _split_colors_device(row, col, data, colors_d, rank_d, cap: int):
    """Pure device core of the color split: one stable argsort scatters the
    entries of a (cap0,) COO part into ``(NCOLORS, cap)`` planes. Entry
    (i, j, v) lands in plane ``colors[i]`` at row ``rank_of_i_within_color``;
    dead entries and per-color overflow land in a dropped guard slot.
    jit/vmap-able — the distributed builder vmaps it over the shard axis.
    The same scatter shape as ``distributed.partition_execute``, with the
    color id in place of the shard id.
    """
    cap0 = row.shape[0]
    key = jnp.where(data != 0, colors_d[row], NCOLORS)
    order_e = jnp.argsort(key, stable=True)
    k_s = key[order_e]
    r_s, c_s, v_s = row[order_e], col[order_e], data[order_e]
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(key, length=NCOLORS + 1)).astype(jnp.int32)])
    erank = jnp.arange(cap0, dtype=jnp.int32) - starts[k_s]
    ok = (k_s < NCOLORS) & (erank < cap)
    dest = jnp.where(ok, k_s * cap + jnp.minimum(erank, cap - 1), NCOLORS * cap)
    lrow = rank_d[r_s]
    out = []
    for xs in (lrow, c_s, v_s):
        buf = jnp.zeros((NCOLORS * cap + 1,), xs.dtype).at[dest].set(
            jnp.where(ok, xs, jnp.zeros((), xs.dtype)))
        out.append(buf[:NCOLORS * cap].reshape(NCOLORS, cap))
    return out[0], out[1], out[2]


def split_colors_stacked(C: COO, colors: np.ndarray,
                         rmax: int, cap: int) -> COO:
    """One device scatter: (cap0,) COO -> stacked (ncolors, cap) row blocks
    (``cap`` must come from a prior count — see :func:`build_colored`)."""
    colors_d = jnp.asarray(colors)
    rank_d = jnp.asarray(color_ranks(colors))
    r, c, v = _split_colors_device(C.row, C.col, C.data, colors_d, rank_d, cap)
    return COO(r, c, v, (rmax, C.shape[1]), cap)


def color_rows_padded(colors: np.ndarray, n: int, rmax: int) -> np.ndarray:
    """(ncolors, rmax) global row ids per color, padded with ``n``."""
    rows = np.full((NCOLORS, rmax), n, np.int32)
    for c in range(NCOLORS):
        ids = np.nonzero(colors == c)[0]
        rows[c, :len(ids)] = ids
    return rows


def build_colored(A, colors: Optional[np.ndarray] = None,
                  dims: Optional[Tuple[int, int, int]] = None,
                  fmt: Format = Format.CSR, policy=None,
                  check: bool = False) -> ColoredSystem:
    """Build the per-color row blocks of a square operator ``A``.

    ``colors`` (or ``dims``, from which the 2x2x2 grid coloring is
    derived) assigns every row a color. With a ``FormatPolicy`` each color
    block picks its own format from ONE batched ``select_batch`` pass over
    the stacked blocks; otherwise all blocks use ``fmt``. ``check=True``
    verifies the coloring is proper (host scan).
    """
    C = to_coo(A.concrete if hasattr(A, "concrete") else A)
    n = C.shape[0]
    if colors is None:
        if dims is None:
            raise ValueError("build_colored needs colors= or dims=")
        colors = color_grid(*dims)
    colors = np.asarray(colors, np.int32)
    if len(colors) != n:
        raise ValueError(f"{len(colors)} colors for {n} rows")
    if check:
        check_coloring(C, colors)

    counts = np.bincount(colors, minlength=NCOLORS)
    rmax = max(1, int(counts.max()))
    # per-color entry capacity: one device pass + one planned pull
    live = C.data != 0
    ecnt = jnp.bincount(jnp.where(live, jnp.asarray(colors)[C.row], NCOLORS),
                        length=NCOLORS + 1)[:NCOLORS]
    cap = max(1, int(_planned_pull(jnp.max(ecnt))))

    stacked = split_colors_stacked(C, colors, rmax, cap)
    if policy is not None:
        ids = policy.select_batch(stacked)
        fmts = [policy.candidates[i] for i in ids]
    else:
        fmts = [Format(fmt)] * NCOLORS
    blocks = []
    for c in range(NCOLORS):
        blk = jax.tree.map(lambda a, c=c: a[c], stacked)
        blk = COO(blk.row, blk.col, blk.data, (rmax, n), cap)
        blocks.append(convert_execute(blk, plan_switch(blk, fmts[c])))
    rows_np = color_rows_padded(colors, n, rmax)
    rows = tuple(jnp.asarray(rows_np[c]) for c in range(NCOLORS))
    diag = _ops.extract_diagonal(C)
    return ColoredSystem(tuple(blocks), rows, diag, (n, n))


# ---------------------------------------------------------------------------
# Sweeps (jit-able; the color loop unrolls at trace time)
# ---------------------------------------------------------------------------


def gs_sweep(cs: ColoredSystem, b: jax.Array, x: jax.Array,
             forward: bool = True, backend: str = "auto",
             cfg=None) -> jax.Array:
    """One Gauss-Seidel sweep in color order (exact GS over the color
    permutation). Each color is one row-block SpMV + a masked scatter."""
    n = cs.shape[0]
    order = range(cs.ncolors) if forward else range(cs.ncolors - 1, -1, -1)
    for c in order:
        y = _ops.spmv(cs.blocks[c], x, backend=backend, cfg=cfg)
        rows = cs.rows[c]
        bc = jnp.take(b, rows, mode="clip")
        dc = jnp.take(cs.diag, rows, mode="clip")
        delta = (bc - y) / jnp.where(dc != 0, dc, 1.0)
        x = x.at[rows].add(delta)  # padded lanes (id n) drop
    return x


def symgs(cs: ColoredSystem, b: jax.Array, x: Optional[jax.Array] = None,
          sweeps: int = 1, backend: str = "auto", cfg=None) -> jax.Array:
    """Symmetric Gauss-Seidel: forward then backward color sweep,
    ``sweeps`` times. Self-adjoint in the A-inner product — the V-cycle
    smoother that keeps ``apply_M`` a symmetric preconditioner."""
    if x is None:
        x = jnp.zeros_like(b)
    for _ in range(int(sweeps)):
        x = gs_sweep(cs, b, x, forward=True, backend=backend, cfg=cfg)
        x = gs_sweep(cs, b, x, forward=False, backend=backend, cfg=cfg)
    return x


def jacobi(diag: jax.Array, apply_A, b: jax.Array,
           x: Optional[jax.Array] = None, sweeps: int = 1,
           omega: float = 2.0 / 3.0) -> jax.Array:
    """Weighted-Jacobi fallback smoother (for operators without a proper
    coloring): x += omega * (b - A x) / diag."""
    minv = jnp.where(jnp.abs(diag) > 1e-30, omega / diag, 0.0)
    if x is None:
        x = minv * b
        start = 1
    else:
        start = 0
    for _ in range(start, int(sweeps)):
        x = x + minv * (b - apply_A(x))
    return x


def symgs_reference_np(row, col, val, colors: np.ndarray, b: np.ndarray,
                       x: np.ndarray, sweeps: int = 1) -> np.ndarray:
    """Sequential NumPy SymGS oracle over the color-permuted row ordering.

    Processes rows one at a time in (color, row) order — forward then
    reverse — always reading the latest x. With a proper coloring the
    parallel :func:`symgs` matches this exactly (up to float summation
    order).
    """
    row = np.asarray(row)
    col = np.asarray(col)
    val = np.asarray(val, np.float64)
    x = np.asarray(x, np.float64).copy()
    b = np.asarray(b, np.float64)
    n = len(x)
    diag = np.zeros(n)
    np.add.at(diag, row[row == col], val[row == col])
    perm = np.lexsort((np.arange(n), colors))  # rows in (color, id) order
    by_row = [[] for _ in range(n)]
    for r, c, v in zip(row, col, val):
        if v != 0:
            by_row[r].append((c, v))
    for _ in range(sweeps):
        for ordering in (perm, perm[::-1]):
            for r in ordering:
                s = sum(v * x[c] for c, v in by_row[r])
                x[r] += (b[r] - s) / diag[r]
    return x
