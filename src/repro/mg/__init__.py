"""repro.mg — geometric multigrid over dynamic sparse matrices.

The preconditioner the paper's HPCG port leaves on the table (§IV-B
benchmarks with preconditioning disabled because reference SymGS is
sequential): a V-cycle of 2:1-coarsened stencil levels, smoothed by a
multicolored (vector-parallel) symmetric Gauss-Seidel, with every level —
an independent sparsity pattern — routed through the runtime
format-selection machinery.

    coarsen    plan/execute 2:1 grid coarsening (injection / trilinear,
               rediscretized / Galerkin coarse operators)
    smoothers  8-color SymGS as per-color row-block SpMVs + Jacobi fallback
    cycle      MGHierarchy + jit-able V-cycle apply_M for solvers.pcg
    dist       per-level slab-partitioned hierarchy (DistPlan per level)
"""
from repro.mg.coarsen import (CoarsenPlan, Coarsening, coarsen_execute,
                              f2c_map, galerkin_coarse, plan_coarsen,
                              prolong, restrict, stencil27_coo,
                              trilinear_corners)
from repro.mg.cycle import MGHierarchy, MGLevel, build_hierarchy, v_cycle
from repro.mg.dist import (DistMGHierarchy, DistMGLevel,
                           build_dist_hierarchy, v_cycle_dist)
from repro.mg.smoothers import (ColoredSystem, build_colored, check_coloring,
                                color_grid, gs_sweep, jacobi, symgs,
                                symgs_reference_np)

__all__ = [
    "CoarsenPlan", "Coarsening", "plan_coarsen", "coarsen_execute",
    "f2c_map", "trilinear_corners", "stencil27_coo", "galerkin_coarse",
    "restrict", "prolong",
    "ColoredSystem", "color_grid", "build_colored", "check_coloring",
    "gs_sweep", "symgs", "jacobi", "symgs_reference_np",
    "MGHierarchy", "MGLevel", "build_hierarchy", "v_cycle",
    "DistMGHierarchy", "DistMGLevel", "build_dist_hierarchy", "v_cycle_dist",
]
