"""Data pipeline: deterministic, stateless-seekable token streams.

Fault-tolerance property (DESIGN.md §5): ``batch_at(step)`` is a pure
function of (seed, step) — after a restart at step k the pipeline resumes
at exactly batch k with no replay and no skip, on any number of hosts.

Two sources:
  * SyntheticLM  — hash-based pseudo-token stream (benchmarks, smoke)
  * MemmapTokens — binary token file (np.memmap), strided per step
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Deterministic synthetic next-token data with learnable structure
    (token t+1 = f(token t) mixture + noise) so smoke training can show a
    decreasing loss."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        start = rng.integers(0, self.vocab, (b, 1))
        # affine walk mod vocab => learnable bigram structure
        mult = 31 % self.vocab or 1
        steps = np.arange(s, dtype=np.int64)[None, :]
        toks = (start * pow(mult, 1, self.vocab) + 17 * steps) % self.vocab
        noise = rng.integers(0, self.vocab, (b, s))
        mask = rng.random((b, s)) < 0.05
        toks = np.where(mask, noise, toks).astype(np.int32)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1).astype(np.int32)
        return {"tokens": toks, "labels": labels}


@dataclasses.dataclass(frozen=True)
class SyntheticFrames:
    """Audio-family stand-in: frame embeddings + frame labels."""

    dim: int
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step, 1))
        b, s = self.global_batch, self.seq_len
        frames = rng.standard_normal((b, s, self.dim)).astype(np.float32)
        labels = rng.integers(0, self.vocab, (b, s)).astype(np.int32)
        return {"frames": frames, "labels": labels}


@dataclasses.dataclass(frozen=True)
class MemmapTokens:
    """Token file source: flat int32 binary, strided deterministically."""

    path: str
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "_data",
                           np.memmap(self.path, dtype=np.int32, mode="r"))

    @property
    def n_tokens(self) -> int:
        return int(self._data.shape[0])

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b, s = self.global_batch, self.seq_len
        n_seq = max(1, (self.n_tokens - 1) // s)
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, n_seq, (b,))
        toks = np.stack([self._data[i * s:(i + 1) * s] for i in idx]).astype(np.int32)
        labels = np.stack([self._data[i * s + 1:(i + 1) * s + 1] for i in idx]).astype(np.int32)
        return {"tokens": toks, "labels": labels}


def make_source(cfg, seq_len: int, global_batch: int, seed: int = 0,
                path: Optional[str] = None):
    if path:
        return MemmapTokens(path, seq_len, global_batch, seed)
    if cfg.frontend == "audio":
        return SyntheticFrames(cfg.frontend_dim, cfg.vocab, seq_len, global_batch, seed)
    return SyntheticLM(cfg.vocab, seq_len, global_batch, seed)


def shard_batch(batch: Dict[str, np.ndarray], mesh, pspec_fn):
    """Place a host batch onto the mesh with per-array PartitionSpecs."""
    from jax.sharding import NamedSharding
    return {k: jax.device_put(v, NamedSharding(mesh, pspec_fn(k, v)))
            for k, v in batch.items()}
