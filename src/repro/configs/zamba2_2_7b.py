"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

The shared transformer block (attention + MLP, one parameter set) is
applied after every 6 Mamba2 blocks (9 applications over 54 layers).
Zamba2's concatenated-embedding input to the shared block is simplified to
a standard residual application (DESIGN.md §8).
"""
import dataclasses

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv=32, d_ff=10240, vocab=32000,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=160, vocab=256,
    ssm_state=16, ssm_head_dim=16, attn_every=2, ssm_chunk=32,
)
