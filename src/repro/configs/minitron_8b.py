"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron. [arXiv:2407.14679; hf]

Pruned-model note (DESIGN.md §4): Minitron is the arch where the paper's
technique applies to *weights* — serving its pruned linears as dynamic
sparse matrices (LinearSparse / BSR) is supported by the model stack.
"""
import dataclasses

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=16384, vocab=256000, head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=160, vocab=256,
    head_dim=16,
)
