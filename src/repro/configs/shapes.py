"""Assigned input-shape cells and abstract input specs for the dry-run.

  train_4k      seq 4096,    global_batch 256   -> train_step
  prefill_32k   seq 32768,   global_batch 32    -> prefill (serve)
  decode_32k    seq 32768,   global_batch 128   -> serve_step (1 token, KV cache)
  long_500k     seq 524288,  global_batch 1     -> serve_step, sub-quadratic only

``input_specs`` returns ShapeDtypeStruct stand-ins only (no allocation).
``skip_reason`` encodes the assignment's skip rules (recorded in DESIGN.md
and the dry-run table).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def skip_reason(cfg: ArchConfig, shape: str) -> Optional[str]:
    cell = SHAPES[shape]
    if cell.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return "long_500k needs sub-quadratic attention; pure full-attention arch (per assignment)"
    if cfg.encoder_only and cell.kind == "decode":
        return "encoder-only arch has no decode step (per assignment)"
    return None


def token_input_specs(cfg: ArchConfig, cell: ShapeCell,
                      with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cfg.frontend == "audio":
        out = {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.bfloat16)}
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return out
    if cfg.frontend == "vision":
        np_ = cfg.n_patches
        out = {"patches": jax.ShapeDtypeStruct((b, np_, cfg.frontend_dim), jnp.bfloat16),
               "tokens": jax.ShapeDtypeStruct((b, s - np_), i32)}
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return out
    out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return out


def input_specs(cfg: ArchConfig, shape: str, model=None) -> dict:
    """Abstract inputs for the (arch x shape) cell.

    train:   {batch: {tokens/frames/patches, labels}}
    prefill: {batch: {tokens/...}}
    decode:  {cache, tokens (B,), pos (B,)}
    """
    cell = SHAPES[shape]
    if cell.kind == "train":
        return {"batch": token_input_specs(cfg, cell, with_labels=True)}
    if cell.kind == "prefill":
        return {"batch": token_input_specs(cfg, cell, with_labels=False)}
    # decode: one new token against a seq_len cache
    from repro.models.model import build_model
    model = model or build_model(cfg)
    b = cell.global_batch
    return {
        "cache": model.cache_specs(b, cell.seq_len),
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
    }
