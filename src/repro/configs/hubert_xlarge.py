"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504,
encoder-only (w2v2-style backbone). [arXiv:2106.07447; unverified]

Per the assignment the modality frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, 512); the conv feature extractor is out
of scope. Encoder-only => no decode shapes (skip recorded in DESIGN.md).
Positional encoding uses RoPE in place of HuBERT's conv-pos embedding
(modernisation; noted in DESIGN.md §8).
"""
import dataclasses

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv=16, d_ff=5120, vocab=504, mlp_act="gelu",
    encoder_only=True, frontend="audio", frontend_dim=512,
    vocab_pad=8,  # 504 -> 504 (tiny head; replicated under TP anyway)
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_ff=160, vocab=32,
    frontend_dim=24,
)
