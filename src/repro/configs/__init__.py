"""Architecture configs (--arch <id>) + assigned shape cells."""
from repro.configs.registry import ALIASES, ARCH_IDS, ArchConfig, get_config, list_archs
from repro.configs.shapes import SHAPES, input_specs, skip_reason
