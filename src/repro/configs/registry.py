"""Architecture config schema + registry (``--arch <id>`` selection)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | audio | hybrid | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int  # 0 => attention-free
    n_kv: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    head_dim: Optional[int] = None
    mlp_act: str = "swiglu"  # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "sort"  # dense | sort | bsr (dynamic-format selectable)
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- hybrid (zamba2): one shared attention block every k SSM blocks ---
    attn_every: int = 0
    # --- modality frontends (stubs per assignment) ---
    frontend: Optional[str] = None  # audio | vision
    frontend_dim: int = 0
    n_patches: int = 0
    encoder_only: bool = False
    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    vocab_pad: int = 256  # pad vocab to a multiple (TP divisibility)
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, self.vocab_pad)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Analytic parameter count (for 6·N·D roofline checks)."""
        d, L = self.d_model, self.n_layers
        emb = self.padded_vocab * d * (1 if self.encoder_only else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm"):
            hd = self.hd
            attn = d * self.n_heads * hd + 2 * d * self.n_kv * hd + self.n_heads * hd * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv) * hd
            if self.family == "moe":
                e_ff = 3 * d * self.d_ff
                mlp = (self.n_experts + self.n_shared_experts) * e_ff + d * self.n_experts
            elif self.mlp_act == "swiglu":
                mlp = 3 * d * self.d_ff
            else:
                mlp = 2 * d * self.d_ff
            per_layer = attn + mlp + 2 * d
        elif self.family == "ssm":
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = (d * (2 * di + 2 * ds + nh)  # in_proj (z,x,B,C,dt)
                         + (di + 2 * ds) * self.ssm_conv + di * d + 2 * d + 3 * nh)
        elif self.family == "hybrid":
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = (d * (2 * di + 2 * ds + nh)
                         + (di + 2 * ds) * self.ssm_conv + di * d + 2 * d + 3 * nh)
            hd = self.hd
            shared_attn = (d * self.n_heads * hd + 2 * d * self.n_kv * hd
                           + self.n_heads * hd * d + 3 * d * self.d_ff + 2 * d)
            return emb + L * per_layer + shared_attn + d
        if self.frontend:
            emb += self.frontend_dim * d
        return emb + L * per_layer + d  # final norm

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed-in experts count)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        e_ff = 3 * d * self.d_ff
        inactive = (self.n_experts - self.top_k) * e_ff * self.n_layers
        return full - inactive


ARCH_IDS = [
    "qwen1_5_32b", "command_r_plus_104b", "stablelm_1_6b", "minitron_8b",
    "llama4_scout_17b_a16e", "deepseek_moe_16b", "hubert_xlarge",
    "zamba2_2_7b", "mamba2_2_7b", "internvl2_26b",
]

# canonical external names (--arch accepts both)
ALIASES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "command-r-plus-104b": "command_r_plus_104b",
    "stablelm-1.6b": "stablelm_1_6b",
    "minitron-8b": "minitron_8b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-2.7b": "zamba2_2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "internvl2-26b": "internvl2_26b",
    "hpcg": "hpcg",
}


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def list_archs():
    return list(ARCH_IDS)
