"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no bias. [hf:CohereForAI/c4ai-command-r-v01;
unverified]"""
import dataclasses

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense", n_layers=64, d_model=12288,
    n_heads=96, n_kv=8, d_ff=33792, vocab=256000,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv=2, d_ff=256, vocab=256,
)
