"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 backbone. [arXiv:2404.16821; hf]

Per the assignment the ViT frontend is a STUB: input_specs() provides
precomputed patch embeddings (B, 256, 1024) which are projected and
prepended to the token sequence. vocab padded 92553 -> 92672 (x256) for TP.
"""
import dataclasses

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv=8, d_ff=16384, vocab=92553,
    frontend="vision", frontend_dim=1024, n_patches=256,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv=2, d_ff=256, vocab=256,
    frontend_dim=32, n_patches=8,
)
