"""mamba2-2.7b [ssm] — 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

§Arch-applicability: attention-format selection is inapplicable (attention
free); the SSD scan is dense. Runs long_500k natively (O(1) decode state).
"""
import dataclasses

from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab=256, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=32,
)
