"""Pallas TPU kernel: CSR-format SpMM — Y = A @ B for a dense rhs batch.

SpMM is SpMV whose computation changed: a request batch widens the
right-hand side from a vector to (N, K), and the winning schedule moves
with K (the paper's runtime-selection thesis applied to the *operation*,
not just the pattern — Stylianou et al., arXiv:2303.05098). This kernel
extends the row x nnz tiling of ``csr_spmv.py`` (the segmented-prefix-sum
schedule that made CSR SpMV 2.5x vs ref) with a third **rhs tile axis**:

  * grid over (row tiles of ``tm`` rows) x (rhs tiles of ``tn`` columns);
    the row-pointer array rides in SMEM via scalar prefetch and bounds
    each row tile's nnz window exactly as in SpMV;
  * the window streams in ``tk``-entry chunks; per chunk the gather of B
    becomes a *row* gather — ``B[cols]`` is (tk, tn), tn lanes wide, so
    every stored entry now feeds tn MACs instead of one (the arithmetic
    intensity jump that makes wide-batch SpMM compute-bound where SpMV
    was bandwidth-bound);
  * the segmented prefix sum (Hillis-Steele, resets at row boundaries)
    runs unchanged along the nnz axis, broadcast over the tn lanes; each
    row's chunk partial reads out at its last position as a (tm, tn) tile.

Two rhs orientations, because the serving stack hands activations over
row-major:

  * :func:`csr_spmm` — B is (N, K) (columns of the classic SpMM); output
    (M, K). The rhs tile is a ``(N, tn)`` VMEM-resident slab.
  * :func:`csr_spmm_t` — X is (T, N): a batch of T row-vector activations
    (``LinearSparse``'s layout — one jit'd call computes ``X @ A^T`` with
    **no transposes of the activations on either side**). The scan runs
    along the minor axis; the output tile is (tb, tm) with rows on the
    lanes.

Tile sizes ``(tm, tk, tn)`` are the tuning space — searched per
(shape bucket, **rhs-width bucket**, backend, device) by
``repro.tuning.kernel_tune``: a config tuned at K=1 is never replayed at
K=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segmented_cumsum(v: jax.Array, flags: jax.Array, axis: int = 0) -> jax.Array:
    """Inclusive prefix sum of ``v`` along ``axis`` that restarts wherever
    ``flags`` (1-D along that axis) is True. Hillis-Steele, statically
    unrolled — vector shifts and adds only, no scatter; the flag vector is
    broadcast over the other (rhs-lane) axis."""
    n = v.shape[axis]
    f = flags
    d = 1

    def shift(a, by, ax):
        pad = [(0, 0)] * a.ndim
        pad[ax] = (by, 0)
        sl = [slice(None)] * a.ndim
        sl[ax] = slice(None, -by)
        return jnp.pad(a[tuple(sl)], pad)

    while d < n:
        vs = shift(v, d, axis)
        fs = jnp.concatenate([jnp.zeros((d,), jnp.bool_), f[:-d]])
        mask = f if v.ndim == 1 else jnp.expand_dims(f, 1 - axis)
        v = v + jnp.where(mask, jnp.zeros((), v.dtype), vs)
        f = f | fs
        d *= 2
    return v


def _spmm_kernel(indptr_ref, starts_ref, ends_ref, rows_ref, indices_ref,
                 data_ref, b_ref, y_ref, *, tm: int, tk: int, tn: int):
    """One (row tile i, rhs tile j) output block; B tile is (N, tn)."""
    i = pl.program_id(0)
    row0 = i * tm
    w0 = indptr_ref[row0]
    wend = indptr_ref[row0 + tm]
    starts = starts_ref[...]
    ends = ends_ref[...]
    b = b_ref[...]                      # (N, tn) rhs slab for this j

    def window(w, acc):
        base = w0 + w * tk
        cols = pl.load(indices_ref, (pl.ds(base, tk),))
        vals = pl.load(data_ref, (pl.ds(base, tk),))
        rws = pl.load(rows_ref, (pl.ds(base, tk),))
        gathered = jnp.take(b, cols, axis=0, mode="clip")      # (tk, tn)
        contrib = vals.astype(jnp.float32)[:, None] * gathered.astype(jnp.float32)
        flags = jnp.concatenate(
            [jnp.zeros((1,), jnp.bool_), rws[1:] != rws[:-1]])
        seg = _segmented_cumsum(contrib, flags, axis=0)
        lo = jnp.clip(starts - base, 0, tk)
        hi = jnp.clip(ends - base, 0, tk)
        part = jnp.take(seg, jnp.maximum(hi - 1, 0), axis=0)   # (tm, tn)
        return acc + jnp.where((hi > lo)[:, None], part, 0.0)

    nwin = (wend - w0 + tk - 1) // tk
    acc = jax.lax.fori_loop(0, nwin, window,
                            jnp.zeros((tm, tn), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


def _spmm_t_kernel(indptr_ref, starts_ref, ends_ref, rows_ref, indices_ref,
                   data_ref, x_ref, y_ref, *, tm: int, tk: int, tn: int):
    """Transposed-rhs orientation: X tile is (tn, N) activations; the
    segmented scan runs along the minor (nnz) axis and the output tile is
    (tn, tm) — activations never transpose on either side."""
    i = pl.program_id(0)
    row0 = i * tm
    w0 = indptr_ref[row0]
    wend = indptr_ref[row0 + tm]
    starts = starts_ref[...]
    ends = ends_ref[...]
    x = x_ref[...]                      # (tn, N) activation rows

    def window(w, acc):
        base = w0 + w * tk
        cols = pl.load(indices_ref, (pl.ds(base, tk),))
        vals = pl.load(data_ref, (pl.ds(base, tk),))
        rws = pl.load(rows_ref, (pl.ds(base, tk),))
        gathered = jnp.take(x, jnp.clip(cols, 0, x.shape[1] - 1), axis=1)
        contrib = vals.astype(jnp.float32)[None, :] * gathered.astype(jnp.float32)
        flags = jnp.concatenate(
            [jnp.zeros((1,), jnp.bool_), rws[1:] != rws[:-1]])
        seg = _segmented_cumsum(contrib, flags, axis=1)        # (tn, tk)
        lo = jnp.clip(starts - base, 0, tk)
        hi = jnp.clip(ends - base, 0, tk)
        part = jnp.take(seg, jnp.maximum(hi - 1, 0), axis=1)   # (tn, tm)
        return acc + jnp.where((hi > lo)[None, :], part, 0.0)

    nwin = (wend - w0 + tk - 1) // tk
    acc = jax.lax.fori_loop(0, nwin, window,
                            jnp.zeros((tn, tm), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


def _pad_csr(indptr, rows, indices, data, m, cap, tm, tk):
    """Shared row/nnz padding: rows pad to a tm multiple with empty
    windows, entry arrays pad so any ``pl.ds`` chunk start stays in
    bounds (padding past ``indptr[-1]`` is never read out)."""
    mp = ((m + tm - 1) // tm) * tm
    indptr = indptr.astype(jnp.int32)
    if mp != m:
        indptr = jnp.concatenate(
            [indptr, jnp.broadcast_to(indptr[-1], (mp - m,))])
    capp = ((cap + tk - 1) // tk) * tk + tk
    rows = jnp.pad(rows, (0, capp - cap))
    indices = jnp.pad(indices, (0, capp - cap))
    data = jnp.pad(data, (0, capp - cap))
    return indptr, rows, indices, data, mp


@functools.partial(jax.jit,
                   static_argnames=("tm", "tk", "tn", "interpret"))
def csr_spmm(indptr: jax.Array, rows: jax.Array, indices: jax.Array,
             data: jax.Array, B: jax.Array, tm: int = 256, tk: int = 512,
             tn: int = 128, interpret: bool = True) -> jax.Array:
    """Y = A @ B for CSR A and dense B of shape (N, K); returns (M, K).

    ``rows`` is the precomputed per-entry row id array
    (``repro.core.ops.csr_row_ids``). K pads to a ``tn`` multiple; the
    pad columns are sliced off before returning.
    """
    m = indptr.shape[0] - 1
    cap = data.shape[0]
    n, kb = B.shape
    indptr, rows, indices, data, mp = _pad_csr(
        indptr, rows, indices, data, m, cap, tm, tk)
    kp = ((kb + tn - 1) // tn) * tn
    if kp != kb:
        B = jnp.pad(B, ((0, 0), (0, kp - kb)))

    grid = (mp // tm, kp // tn)
    kernel = functools.partial(_spmm_kernel, tm=tm, tk=tk, tn=tn)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm,), lambda i, j, *_: (i,)),
                pl.BlockSpec((tm,), lambda i, j, *_: (i,)),
                pl.BlockSpec(rows.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec(indices.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec(data.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec((n, tn), lambda i, j, *_: (0, j)),
            ],
            out_specs=pl.BlockSpec((tm, tn), lambda i, j, *_: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((mp, kp), B.dtype),
        interpret=interpret,
    )(indptr, starts_of(indptr), ends_of(indptr), rows, indices, data, B)
    return y[:m, :kb]


def starts_of(indptr: jax.Array) -> jax.Array:
    return indptr[:-1]


def ends_of(indptr: jax.Array) -> jax.Array:
    return indptr[1:]


@functools.partial(jax.jit,
                   static_argnames=("tm", "tk", "tn", "interpret"))
def csr_spmm_t(indptr: jax.Array, rows: jax.Array, indices: jax.Array,
               data: jax.Array, X: jax.Array, tm: int = 256, tk: int = 512,
               tn: int = 8, interpret: bool = True) -> jax.Array:
    """Y = X @ A^T for CSR A and activations X of shape (T, N); returns
    (T, M) — the serving layout, no activation transposes."""
    m = indptr.shape[0] - 1
    cap = data.shape[0]
    t, n = X.shape
    indptr, rows, indices, data, mp = _pad_csr(
        indptr, rows, indices, data, m, cap, tm, tk)
    tp = ((t + tn - 1) // tn) * tn
    if tp != t:
        X = jnp.pad(X, ((0, tp - t), (0, 0)))

    grid = (mp // tm, tp // tn)
    kernel = functools.partial(_spmm_t_kernel, tm=tm, tk=tk, tn=tn)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm,), lambda i, j, *_: (i,)),
                pl.BlockSpec((tm,), lambda i, j, *_: (i,)),
                pl.BlockSpec(rows.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec(indices.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec(data.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec((tn, n), lambda i, j, *_: (j, 0)),
            ],
            out_specs=pl.BlockSpec((tn, tm), lambda i, j, *_: (j, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((tp, mp), X.dtype),
        interpret=interpret,
    )(indptr, starts_of(indptr), ends_of(indptr), rows, indices, data, X)
    return y[:t, :m]
