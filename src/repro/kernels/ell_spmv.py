"""Pallas TPU kernel: ELL-format SpMV.

ELL pads every row to K entries, turning CSR's serial row walk into dense
(rows x K) vector arithmetic — the TPU-idiomatic replacement for the GPU's
warp-per-row CSR tricks (DESIGN.md §2, §8). The single data-dependent step
is the gather of x at the stored column indices, which maps to the VPU's
dynamic-gather path; everything else is dense multiply-reduce.

Two layouts, selected by ``layout`` (part of the kernel's tuning space):

  * ``"row"`` — the container's native (tm, K) tiles; the reduction runs
    across the minor axis. Wins where the gather dominates and K is the
    contiguous axis (measured fastest on CPU/interpret).
  * ``"col"`` — the same (tm, K) tiles, transposed *per tile inside the
    kernel* (a VMEM-register reshape, never a materialized (K, M) copy —
    a whole-array transpose would add O(nnz) HBM traffic to every call),
    so rows map onto the 128-lane minor axis and the K-loop walks
    contiguous row-vectors: each of the K planes is one lane-aligned
    gather + multiply-accumulate. This is the TPU-friendly orientation.

Blocking: grid over row tiles of ``tm`` rows; x resident in VMEM (ops
wrapper falls back to ref when it would not fit). ``(tm, layout)`` are
searched per (shape bucket, backend, device) by
``repro.tuning.kernel_tune``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel_row(cols_ref, data_ref, x_ref, y_ref):
    cols = cols_ref[...]                       # (tm, K)
    vals = data_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols, mode="clip")  # VPU dynamic gather
    acc = jnp.sum(vals.astype(jnp.float32) * gathered.astype(jnp.float32),
                  axis=1)
    y_ref[...] = acc.astype(y_ref.dtype)


def _ell_kernel_col(cols_ref, data_ref, x_ref, y_ref):
    cols = cols_ref[...].T                     # (K, tm): rows on the lanes,
    vals = data_ref[...].T                     # transposed per tile in VMEM
    x = x_ref[...]
    gathered = jnp.take(x, cols, mode="clip")
    acc = jnp.sum(vals.astype(jnp.float32) * gathered.astype(jnp.float32),
                  axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "layout", "interpret"))
def ell_spmv(cols: jax.Array, data: jax.Array, x: jax.Array,
             tm: int = 256, layout: str = "row",
             interpret: bool = True) -> jax.Array:
    """y = A @ x for ELL A given as (cols[M, K], data[M, K])."""
    if layout not in ("row", "col"):
        raise ValueError(f"layout {layout!r} not in ('row', 'col')")
    m, k = data.shape
    if k == 0:  # every row empty: nothing to stream, nothing to launch
        return jnp.zeros((m,), x.dtype)
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        cols = jnp.pad(cols, ((0, mp - m), (0, 0)))
        data = jnp.pad(data, ((0, mp - m), (0, 0)))

    grid = (mp // tm,)
    in_specs = [
        pl.BlockSpec((tm, k), lambda i: (i, 0)),
        pl.BlockSpec((tm, k), lambda i: (i, 0)),
        pl.BlockSpec(x.shape, lambda i: (0,)),
    ]
    kernel = _ell_kernel_col if layout == "col" else _ell_kernel_row
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), x.dtype),
        interpret=interpret,
    )(cols, data, x)
    return y[:m]
