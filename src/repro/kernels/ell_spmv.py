"""Pallas TPU kernel: ELL-format SpMV.

ELL pads every row to K entries, turning CSR's serial row walk into dense
(rows x K) vector arithmetic — the TPU-idiomatic replacement for the GPU's
warp-per-row CSR tricks (DESIGN.md §2, §8). The single data-dependent step
is the gather of x at the stored column indices, which maps to the VPU's
dynamic-gather path; everything else is dense multiply-reduce.

Blocking strategy:
  * grid over row tiles of ``tm`` rows;
  * the (tm, K) column-index and value planes stream through VMEM;
  * x resident in VMEM (ops wrapper falls back to ref when it would not fit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ell_kernel(cols_ref, data_ref, x_ref, y_ref):
    cols = cols_ref[...]
    vals = data_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols, mode="clip")  # (tm, K) dynamic gather
    acc = jnp.sum(vals.astype(jnp.float32) * gathered.astype(jnp.float32), axis=1)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def ell_spmv(cols: jax.Array, data: jax.Array, x: jax.Array,
             tm: int = 256, interpret: bool = True) -> jax.Array:
    """y = A @ x for ELL A given as (cols[M, K], data[M, K])."""
    m, k = data.shape
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        cols = jnp.pad(cols, ((0, mp - m), (0, 0)))
        data = jnp.pad(data, ((0, mp - m), (0, 0)))

    grid = (mp // tm,)
    y = pl.pallas_call(
        _ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec((tm, k), lambda i: (i, 0)),
            pl.BlockSpec(x.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), x.dtype),
        interpret=interpret,
    )(cols, data, x)
    return y[:m]
