"""Pallas TPU kernel: ELL-format SpMV.

ELL pads every row to K entries, turning CSR's serial row walk into dense
(rows x K) vector arithmetic — the TPU-idiomatic replacement for the GPU's
warp-per-row CSR tricks (DESIGN.md §2, §8). The single data-dependent step
is the gather of x at the stored column indices, which maps to the VPU's
dynamic-gather path; everything else is dense multiply-reduce.

Two layouts, selected by ``layout`` (part of the kernel's tuning space):

  * ``"row"`` — the container's native (tm, K) tiles; the reduction runs
    across the minor axis. Wins where the gather dominates and K is the
    contiguous axis (measured fastest on CPU/interpret).
  * ``"col"`` — the same (tm, K) tiles, transposed *per tile inside the
    kernel* (a VMEM-register reshape, never a materialized (K, M) copy —
    a whole-array transpose would add O(nnz) HBM traffic to every call),
    so rows map onto the 128-lane minor axis and the K-loop walks
    contiguous row-vectors: each of the K planes is one lane-aligned
    gather + multiply-accumulate. This is the TPU-friendly orientation.

Blocking: grid over row tiles of ``tm`` rows; x resident in VMEM (ops
wrapper falls back to ref when it would not fit). ``(tm, layout)`` are
searched per (shape bucket, backend, device) by
``repro.tuning.kernel_tune``.

SpMM (:func:`ell_spmm` / :func:`ell_spmm_t`) reuses the same lane-aligned
layouts with an rhs tile axis ``tn``: ``"row"`` materialises the full
(tm, K, tn) gather (one wide VPU pass — wins for small K), ``"col"``
streams K planes of (tm, tn) gather-FMA through a ``fori_loop`` so the
transient footprint stays (tm, tn) no matter how long the rows are (the
pruned-weight case, K in the hundreds). The ``_t`` variant takes
activations (T, N) row-major and scans planes of (tn, tm) gathers along
the minor axis — no activation transposes (see ``csr_spmm.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_kernel_row(cols_ref, data_ref, x_ref, y_ref):
    cols = cols_ref[...]                       # (tm, K)
    vals = data_ref[...]
    x = x_ref[...]
    gathered = jnp.take(x, cols, mode="clip")  # VPU dynamic gather
    acc = jnp.sum(vals.astype(jnp.float32) * gathered.astype(jnp.float32),
                  axis=1)
    y_ref[...] = acc.astype(y_ref.dtype)


def _ell_kernel_col(cols_ref, data_ref, x_ref, y_ref):
    cols = cols_ref[...].T                     # (K, tm): rows on the lanes,
    vals = data_ref[...].T                     # transposed per tile in VMEM
    x = x_ref[...]
    gathered = jnp.take(x, cols, mode="clip")
    acc = jnp.sum(vals.astype(jnp.float32) * gathered.astype(jnp.float32),
                  axis=0)
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "layout", "interpret"))
def ell_spmv(cols: jax.Array, data: jax.Array, x: jax.Array,
             tm: int = 256, layout: str = "row",
             interpret: bool = True) -> jax.Array:
    """y = A @ x for ELL A given as (cols[M, K], data[M, K])."""
    if layout not in ("row", "col"):
        raise ValueError(f"layout {layout!r} not in ('row', 'col')")
    m, k = data.shape
    if k == 0:  # every row empty: nothing to stream, nothing to launch
        return jnp.zeros((m,), x.dtype)
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        cols = jnp.pad(cols, ((0, mp - m), (0, 0)))
        data = jnp.pad(data, ((0, mp - m), (0, 0)))

    grid = (mp // tm,)
    in_specs = [
        pl.BlockSpec((tm, k), lambda i: (i, 0)),
        pl.BlockSpec((tm, k), lambda i: (i, 0)),
        pl.BlockSpec(x.shape, lambda i: (0,)),
    ]
    kernel = _ell_kernel_col if layout == "col" else _ell_kernel_row
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), x.dtype),
        interpret=interpret,
    )(cols, data, x)
    return y[:m]


# ---------------------------------------------------------------------------
# SpMM: Y = A @ B (and the transposed-rhs serving orientation)
# ---------------------------------------------------------------------------


def _ell_spmm_kernel_row(cols_ref, data_ref, b_ref, y_ref):
    cols = cols_ref[...]                       # (tm, K)
    vals = data_ref[...]
    b = b_ref[...]                             # (N, tn)
    gathered = jnp.take(b, cols, axis=0, mode="clip")   # (tm, K, tn)
    acc = jnp.sum(vals.astype(jnp.float32)[..., None]
                  * gathered.astype(jnp.float32), axis=1)
    y_ref[...] = acc.astype(y_ref.dtype)


def _ell_spmm_kernel_col(cols_ref, data_ref, b_ref, y_ref, *, tn: int):
    cols = cols_ref[...]                       # (tm, K)
    vals = data_ref[...]
    b = b_ref[...]                             # (N, tn)
    tm, k = cols.shape

    def plane(kk, acc):
        c = jax.lax.dynamic_index_in_dim(cols, kk, 1, keepdims=False)
        v = jax.lax.dynamic_index_in_dim(vals, kk, 1, keepdims=False)
        g = jnp.take(b, c, axis=0, mode="clip")          # (tm, tn)
        return acc + v.astype(jnp.float32)[:, None] * g.astype(jnp.float32)

    acc = jax.lax.fori_loop(0, k, plane, jnp.zeros((tm, tn), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


def _ell_spmm_t_kernel_row(cols_ref, data_ref, x_ref, y_ref):
    cols = cols_ref[...]                       # (tm, K)
    vals = data_ref[...]
    x = x_ref[...]                             # (tn, N)
    safe = jnp.clip(cols, 0, x.shape[1] - 1)
    gathered = jnp.take(x, safe, axis=1)       # (tn, tm, K)
    acc = jnp.sum(vals.astype(jnp.float32)[None, ...]
                  * gathered.astype(jnp.float32), axis=2)
    y_ref[...] = acc.astype(y_ref.dtype)       # (tn, tm)


def _ell_spmm_t_kernel_col(cols_ref, data_ref, x_ref, y_ref, *, tn: int):
    cols = cols_ref[...]                       # (tm, K)
    vals = data_ref[...]
    x = x_ref[...]                             # (tn, N)
    tm, k = cols.shape

    def plane(kk, acc):
        c = jax.lax.dynamic_index_in_dim(cols, kk, 1, keepdims=False)
        v = jax.lax.dynamic_index_in_dim(vals, kk, 1, keepdims=False)
        g = jnp.take(x, jnp.clip(c, 0, x.shape[1] - 1), axis=1)  # (tn, tm)
        return acc + v.astype(jnp.float32)[None, :] * g.astype(jnp.float32)

    acc = jax.lax.fori_loop(0, k, plane, jnp.zeros((tn, tm), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tn", "layout", "interpret"))
def ell_spmm(cols: jax.Array, data: jax.Array, B: jax.Array,
             tm: int = 256, tn: int = 128, layout: str = "col",
             interpret: bool = True) -> jax.Array:
    """Y = A @ B for ELL A (cols[M, K], data[M, K]) and dense B (N, Kb)."""
    if layout not in ("row", "col"):
        raise ValueError(f"layout {layout!r} not in ('row', 'col')")
    m, k = data.shape
    n, kb = B.shape
    if k == 0:
        return jnp.zeros((m, kb), B.dtype)
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        cols = jnp.pad(cols, ((0, mp - m), (0, 0)))
        data = jnp.pad(data, ((0, mp - m), (0, 0)))
    kp = ((kb + tn - 1) // tn) * tn
    if kp != kb:
        B = jnp.pad(B, ((0, 0), (0, kp - kb)))

    grid = (mp // tm, kp // tn)
    in_specs = [
        pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((n, tn), lambda i, j: (0, j)),
    ]
    kernel = (functools.partial(_ell_spmm_kernel_col, tn=tn)
              if layout == "col" else _ell_spmm_kernel_row)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), B.dtype),
        interpret=interpret,
    )(cols, data, B)
    return y[:m, :kb]


@functools.partial(jax.jit, static_argnames=("tm", "tn", "layout", "interpret"))
def ell_spmm_t(cols: jax.Array, data: jax.Array, X: jax.Array,
               tm: int = 256, tn: int = 8, layout: str = "col",
               interpret: bool = True) -> jax.Array:
    """Y = X @ A^T for ELL A and activations X (T, N); returns (T, M)."""
    if layout not in ("row", "col"):
        raise ValueError(f"layout {layout!r} not in ('row', 'col')")
    m, k = data.shape
    t, n = X.shape
    if k == 0:
        return jnp.zeros((t, m), X.dtype)
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        cols = jnp.pad(cols, ((0, mp - m), (0, 0)))
        data = jnp.pad(data, ((0, mp - m), (0, 0)))
    tp = ((t + tn - 1) // tn) * tn
    if tp != t:
        X = jnp.pad(X, ((0, tp - t), (0, 0)))

    grid = (mp // tm, tp // tn)
    in_specs = [
        pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((tn, n), lambda i, j: (j, 0)),
    ]
    kernel = (functools.partial(_ell_spmm_t_kernel_col, tn=tn)
              if layout == "col" else _ell_spmm_t_kernel_row)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tn, tm), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((tp, mp), X.dtype),
        interpret=interpret,
    )(cols, data, X)
    return y[:t, :m]
