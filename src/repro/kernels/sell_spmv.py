"""Pallas TPU kernel: SELL-C-sigma SpMV / SpMM / SpMM_T.

SELL-C-sigma (Kreutzer et al., arXiv:1307.6209) stores sigma-window
length-sorted rows in slices of C, each padded only to its own width, flat
and column-major within the slice — so every width *plane* of a slice is C
contiguous lanes holding one entry of C consecutive sorted rows. That is
exactly the lane-aligned orientation the ELL "col" layout manufactures per
tile with an in-VMEM transpose (``ell_spmv.py``), except here the layout
is native and the padded width is per-slice instead of the global kmax:

  * grid over *slice tiles* of ``ts`` slices; the slice-pointer array
    rides in SMEM via scalar prefetch (the CSR kernel's idiom) and bounds
    each slice's flat window ``[ptrs[s], ptrs[s+1])``;
  * per slice, a ``fori_loop`` whose trip count is the slice's *own*
    width streams C-entry planes via ``pl.ds`` dynamic-start loads: VPU
    gather of x at the stored columns, f32 multiply-accumulate onto a
    (C,) lane accumulator — one output element per lane, no segmented
    reduction at all (the sort guarantees a lane is one row);
  * the kernel computes y in *sorted row order*; the wrapper scatters it
    back through the container's permutation (ghost lanes carry row id M
    and are dropped by the out-of-bounds scatter).

Work is ``sum_s C * width_s`` — nnz plus the per-slice padding the
sigma-sort minimizes — vs ELL's ``M * kmax`` blowup and CSR's log-depth
segmented scan per chunk. ``(c, sigma)`` reshape the container itself and
``ts`` the launch geometry; all three are searched by
``repro.tuning.kernel_tune`` per (shape bucket, backend, device).

SpMM streams (C, tn) gather-FMA planes per rhs tile; SpMM_T takes
activations (T, N) row-major and accumulates (tn, C) planes along the
minor axis — no activation transposes (see ``csr_spmm.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad_ptrs(slice_ptrs: jax.Array, ts: int):
    """Pad the slice-pointer array so the grid covers whole slice tiles;
    padded slices are empty (zero-width windows at the capacity end)."""
    nslices = slice_ptrs.shape[0] - 1
    nsp = (nslices + ts - 1) // ts
    ptrs = slice_ptrs.astype(jnp.int32)
    pad = nsp * ts - nslices
    if pad:
        ptrs = jnp.concatenate([ptrs, jnp.broadcast_to(ptrs[-1], (pad,))])
    return ptrs, nsp


def _sell_kernel(ptrs_ref, cols_ref, data_ref, x_ref, y_ref, *, c: int,
                 ts: int):
    i = pl.program_id(0)
    s0 = i * ts
    x = x_ref[...]
    for j in range(ts):  # static unroll over the tile's slices
        w0 = ptrs_ref[s0 + j]
        w1 = ptrs_ref[s0 + j + 1]

        def plane(t, acc, w0=w0):
            base = w0 + t * c
            cc = pl.load(cols_ref, (pl.ds(base, c),))
            vv = pl.load(data_ref, (pl.ds(base, c),))
            g = jnp.take(x, cc, mode="clip").astype(jnp.float32)
            return acc + vv.astype(jnp.float32) * g

        acc = jax.lax.fori_loop(0, (w1 - w0) // c, plane,
                                jnp.zeros((c,), jnp.float32))
        pl.store(y_ref, (pl.ds(j * c, c),), acc.astype(y_ref.dtype))


@functools.partial(jax.jit, static_argnames=("m", "c", "ts", "interpret"))
def sell_spmv(slice_ptrs: jax.Array, cols: jax.Array, data: jax.Array,
              perm: jax.Array, x: jax.Array, m: int, c: int,
              ts: int = 8, interpret: bool = True) -> jax.Array:
    """y = A @ x for SELL A given as flat (slice_ptrs, cols, data, perm)."""
    nslices = slice_ptrs.shape[0] - 1
    ptrs, nsp = _pad_ptrs(slice_ptrs, ts)
    grid = (nsp,)
    kernel = functools.partial(_sell_kernel, c=c, ts=ts)
    y_sorted = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(cols.shape, lambda i, *_: (0,)),
                pl.BlockSpec(data.shape, lambda i, *_: (0,)),
                pl.BlockSpec(x.shape, lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((ts * c,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((nsp * ts * c,), x.dtype),
        interpret=interpret,
    )(ptrs, cols, data, x)
    # back to matrix row order; ghost lanes (perm == m) drop out of bounds
    return jnp.zeros((m,), x.dtype).at[perm].set(y_sorted[:nslices * c])


# ---------------------------------------------------------------------------
# SpMM: Y = A @ B (and the transposed-rhs serving orientation)
# ---------------------------------------------------------------------------


def _sell_spmm_kernel(ptrs_ref, cols_ref, data_ref, b_ref, y_ref, *, c: int,
                      ts: int, tn: int):
    i = pl.program_id(0)
    s0 = i * ts
    b = b_ref[...]                             # (N, tn)
    for j in range(ts):
        w0 = ptrs_ref[s0 + j]
        w1 = ptrs_ref[s0 + j + 1]

        def plane(t, acc, w0=w0):
            base = w0 + t * c
            cc = pl.load(cols_ref, (pl.ds(base, c),))
            vv = pl.load(data_ref, (pl.ds(base, c),))
            g = jnp.take(b, cc, axis=0, mode="clip").astype(jnp.float32)
            return acc + vv.astype(jnp.float32)[:, None] * g

        acc = jax.lax.fori_loop(0, (w1 - w0) // c, plane,
                                jnp.zeros((c, tn), jnp.float32))
        pl.store(y_ref, (pl.ds(j * c, c), slice(None)),
                 acc.astype(y_ref.dtype))


@functools.partial(jax.jit,
                   static_argnames=("m", "c", "ts", "tn", "interpret"))
def sell_spmm(slice_ptrs: jax.Array, cols: jax.Array, data: jax.Array,
              perm: jax.Array, B: jax.Array, m: int, c: int,
              ts: int = 8, tn: int = 128, interpret: bool = True
              ) -> jax.Array:
    """Y = A @ B for SELL A and dense B (N, Kb)."""
    n, kb = B.shape
    nslices = slice_ptrs.shape[0] - 1
    ptrs, nsp = _pad_ptrs(slice_ptrs, ts)
    kp = ((kb + tn - 1) // tn) * tn
    if kp != kb:
        B = jnp.pad(B, ((0, 0), (0, kp - kb)))
    grid = (nsp, kp // tn)
    kernel = functools.partial(_sell_spmm_kernel, c=c, ts=ts, tn=tn)
    y_sorted = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(cols.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec(data.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec((n, tn), lambda i, j, *_: (0, j)),
            ],
            out_specs=pl.BlockSpec((ts * c, tn), lambda i, j, *_: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((nsp * ts * c, kp), B.dtype),
        interpret=interpret,
    )(ptrs, cols, data, B)
    return jnp.zeros((m, kb), B.dtype).at[perm].set(
        y_sorted[:nslices * c, :kb])


def _sell_spmm_t_kernel(ptrs_ref, cols_ref, data_ref, x_ref, y_ref, *,
                        c: int, ts: int, tn: int):
    i = pl.program_id(0)
    s0 = i * ts
    x = x_ref[...]                             # (tn, N)
    for j in range(ts):
        w0 = ptrs_ref[s0 + j]
        w1 = ptrs_ref[s0 + j + 1]

        def plane(t, acc, w0=w0):
            base = w0 + t * c
            cc = pl.load(cols_ref, (pl.ds(base, c),))
            vv = pl.load(data_ref, (pl.ds(base, c),))
            g = jnp.take(x, jnp.clip(cc, 0, x.shape[1] - 1),
                         axis=1).astype(jnp.float32)  # (tn, c)
            return acc + vv.astype(jnp.float32)[None, :] * g

        acc = jax.lax.fori_loop(0, (w1 - w0) // c, plane,
                                jnp.zeros((tn, c), jnp.float32))
        pl.store(y_ref, (slice(None), pl.ds(j * c, c)),
                 acc.astype(y_ref.dtype))


@functools.partial(jax.jit,
                   static_argnames=("m", "c", "ts", "tn", "interpret"))
def sell_spmm_t(slice_ptrs: jax.Array, cols: jax.Array, data: jax.Array,
                perm: jax.Array, X: jax.Array, m: int, c: int,
                ts: int = 8, tn: int = 8, interpret: bool = True
                ) -> jax.Array:
    """Y = X @ A^T for SELL A and activations X (T, N); returns (T, M)."""
    t, n = X.shape
    nslices = slice_ptrs.shape[0] - 1
    ptrs, nsp = _pad_ptrs(slice_ptrs, ts)
    tp = ((t + tn - 1) // tn) * tn
    if tp != t:
        X = jnp.pad(X, ((0, tp - t), (0, 0)))
    grid = (nsp, tp // tn)
    kernel = functools.partial(_sell_spmm_t_kernel, c=c, ts=ts, tn=tn)
    y_sorted = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(cols.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec(data.shape, lambda i, j, *_: (0,)),
                pl.BlockSpec((tn, n), lambda i, j, *_: (j, 0)),
            ],
            out_specs=pl.BlockSpec((tn, ts * c), lambda i, j, *_: (j, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((tp, nsp * ts * c), X.dtype),
        interpret=interpret,
    )(ptrs, cols, data, X)
    return jnp.zeros((t, m), X.dtype).at[:, perm].set(
        y_sorted[:t, :nslices * c])
