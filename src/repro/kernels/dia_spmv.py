"""Pallas TPU kernel: DIA-format SpMV.

The paper's winning format for stencil matrices, re-derived for TPU
(DESIGN.md §2): every diagonal contributes one *contiguous, shifted*
multiply-add — pure VPU work, zero gathers, zero index arithmetic per
element. This is the access pattern vector machines were built for, and the
reason DIA transfers so well from the paper's GPUs to the TPU's VPU.

Blocking strategy:
  * grid over row tiles of size ``tm`` (multiple of 128 lanes);
  * the diagonal table ``data[ndiag, M]`` streams through VMEM one
    ``(ndiag, tm)`` tile per grid step;
  * ``x`` is pre-padded by ``pad`` zeros on both sides so every shifted
    window load is in-bounds and mask-free (zero padding in the table makes
    out-of-matrix lanes contribute 0); the padded vector is resident in VMEM;
  * ``offsets`` ride in SMEM via scalar prefetch and drive dynamic-start
    (``pl.ds``) window loads — the TPU analogue of the diagonal walk.

VMEM budget per step: ndiag*tm*4 + (N + 2*pad)*4 bytes; the ops wrapper
falls back to the reference implementation when x would not fit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dia_kernel(offsets_ref, data_ref, x_ref, y_ref, *, pad: int, tm: int):
    i = pl.program_id(0)
    ndiag = data_ref.shape[0]
    row0 = i * tm

    def body(d, acc):
        off = offsets_ref[d]
        # contiguous shifted window: x_pad[pad + row0 + off : ... + tm]
        start = pad + row0 + off
        window = pl.load(x_ref, (pl.ds(start, tm),))
        dline = pl.load(data_ref, (pl.ds(d, 1), slice(None)))[0]
        return acc + dline * window

    acc = jax.lax.fori_loop(0, ndiag, body, jnp.zeros((tm,), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "tm", "interpret"))
def dia_spmv(offsets: jax.Array, data: jax.Array, x: jax.Array, n: int,
             tm: int = 512, interpret: bool = True) -> jax.Array:
    """y = A @ x for DIA A given as (offsets[ndiag], data[ndiag, M]).

    ``x`` has length ``n`` (rectangular matrices supported). ``data`` rows
    follow the cusp convention data[d, i] = A[i, i + offsets[d]] with zeros
    where the diagonal leaves the matrix.
    """
    ndiag, m = data.shape
    mp = ((m + tm - 1) // tm) * tm
    if mp != m:
        data = jnp.pad(data, ((0, 0), (0, mp - m)))
    # pad so every window load [row0+off, row0+off+tm) lands in-bounds:
    # row0+off spans [-(pad), mp-tm+pad] => left pad >= max|off|+0, right pad
    # >= max|off| + (mp - n) + tm slack. Static bound: pad to a safe superset.
    pad = mp + tm  # static, covers any int32 offset clamped below
    offsets = jnp.clip(offsets.astype(jnp.int32), -(m + tm), n + tm)
    x_pad = jnp.pad(x, (pad, pad + (mp - min(n, mp)) + tm))

    grid = (mp // tm,)
    kernel = functools.partial(_dia_kernel, pad=pad, tm=tm)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((ndiag, tm), lambda i, *_: (0, i)),
                pl.BlockSpec(x_pad.shape, lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((tm,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((mp,), x.dtype),
        interpret=interpret,
    )(offsets, data, x_pad)
    return y[:m]
