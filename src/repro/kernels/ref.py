"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical specification the kernel must reproduce;
tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dia_spmv_ref(offsets: jax.Array, data: jax.Array, x: jax.Array, n: int) -> jax.Array:
    """y[i] = sum_d data[d, i] * x[i + offsets[d]]  (zero outside [0, n)).

    Accumulates in f32 (the kernels' accumulator dtype — the accurate spec).
    """
    m = data.shape[1]
    i = jnp.arange(m, dtype=jnp.int32)[None, :]
    cols = i + offsets[:, None].astype(jnp.int32)
    valid = (cols >= 0) & (cols < n)
    xv = jnp.take(x, jnp.clip(cols, 0, n - 1), mode="clip").astype(jnp.float32)
    acc = jnp.sum(jnp.where(valid, data.astype(jnp.float32) * xv, 0), axis=0)
    return acc.astype(x.dtype)


def ell_spmv_ref(cols: jax.Array, data: jax.Array, x: jax.Array) -> jax.Array:
    """y[i] = sum_k data[i, k] * x[cols[i, k]] (f32 accumulation)."""
    acc = jnp.sum(data.astype(jnp.float32)
                  * jnp.take(x, cols, mode="clip").astype(jnp.float32), axis=1)
    return acc.astype(x.dtype)


def csr_spmv_ref(indptr: jax.Array, indices: jax.Array, data: jax.Array,
                 x: jax.Array, m: int) -> jax.Array:
    """y[i] = sum_{p in [indptr[i], indptr[i+1])} data[p] * x[indices[p]]
    (f32 accumulation; capacity padding past indptr[-1] is inert)."""
    cap = data.shape[0]
    k = jnp.arange(cap, dtype=jnp.int32)
    rows = jnp.searchsorted(indptr, k, side="right").astype(jnp.int32) - 1
    live = (rows >= 0) & (k < indptr[-1])
    rows = jnp.clip(rows, 0, m - 1)
    contrib = data.astype(jnp.float32) * jnp.take(x, indices, mode="clip").astype(jnp.float32)
    acc = jax.ops.segment_sum(jnp.where(live, contrib, 0.0), rows, num_segments=m)
    return acc.astype(x.dtype)


def bsr_spmm_ref(indptr: jax.Array, indices: jax.Array, blocks: jax.Array,
                 B: jax.Array, m: int) -> jax.Array:
    """Y = A @ B for block-CSR A with (bs x bs) blocks; B is (N, K)."""
    bs = blocks.shape[1]
    nblk = blocks.shape[0]
    kb = B.shape[1]
    Bb = B.reshape(B.shape[0] // bs, bs, kb)
    gathered = jnp.take(Bb, indices, axis=0, mode="clip")
    prod = jnp.einsum("nij,njk->nik", blocks.astype(jnp.float32),
                      gathered.astype(jnp.float32))
    k = jnp.arange(nblk, dtype=jnp.int32)
    brow = jnp.searchsorted(indptr, k, side="right").astype(jnp.int32) - 1
    brow = jnp.clip(brow, 0, m // bs - 1)
    yb = jax.ops.segment_sum(prod, brow, num_segments=m // bs)
    return yb.reshape(m, kb).astype(B.dtype)
