"""Pallas TPU kernel: block-CSR (BSR) SpMM — Y = A @ B.

The flagship TPU-native kernel (DESIGN.md §2): every stored (bs x bs) block
is one MXU matmul against a (bs, tn) tile of B. This is the format/kernel
pair that carries the paper's "switch to the format the hardware loves"
thesis onto the MXU, and the compute path for the block-sparse / MoE
integration in the model stack.

Blocking strategy (output-revisiting accumulation):
  * grid = (N/tn, nblk) with the B-column tile j OUTER and the stored-block
    index k INNER: for a fixed j, ``block_row[k]`` is non-decreasing, so all
    k belonging to one output tile (row, j) are *consecutive* grid steps —
    Pallas keeps the out tile resident in VMEM across them and only writes
    back on the row change (the TPU revisiting idiom; non-consecutive
    revisits would be read-modify-write-incorrect on real hardware);
  * ``indptr``/``block_row``/``block_col`` ride in SMEM via scalar prefetch
    and drive the BlockSpec index maps (data-dependent tiling);
  * the out tile is zero-initialised on the first block of each row.

Requirement: every block row must own >= 1 block (the ops wrapper verifies
and falls back to ref otherwise; conversion can pad empty rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bsr_kernel(indptr_ref, brow_ref, bcol_ref, blocks_ref, b_ref, y_ref, acc_ref):
    k = pl.program_id(1)
    row = brow_ref[k]

    @pl.when(k == indptr_ref[row])  # first block of this output row
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    block = blocks_ref[0]  # (bs, bs)
    btile = b_ref[...]  # (bs, tn)
    acc_ref[...] += jnp.dot(block.astype(jnp.float32), btile.astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == indptr_ref[row + 1] - 1)  # last block: single write-back
    def _():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "tn", "interpret"))
def bsr_spmm(indptr: jax.Array, brow: jax.Array, bcol: jax.Array,
             blocks: jax.Array, B: jax.Array, m: int,
             tn: int = 128, interpret: bool = True) -> jax.Array:
    """Y = A @ B.

    A is block-CSR: ``blocks[nblk, bs, bs]``, ``bcol[nblk]`` block columns,
    ``indptr[Mb+1]`` block-row pointers and ``brow[nblk]`` the (precomputed,
    non-decreasing) block row of every stored block. B is (N, K); K is padded
    to a multiple of ``tn`` by the wrapper. Every block row must be non-empty.
    """
    nblk, bs, _ = blocks.shape
    n, kb = B.shape
    kbp = ((kb + tn - 1) // tn) * tn
    if kbp != kb:
        B = jnp.pad(B, ((0, 0), (0, kbp - kb)))

    grid = (kbp // tn, nblk)  # j outer, k inner => consecutive accumulation
    y = pl.pallas_call(
        _bsr_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                # one stored block per step
                pl.BlockSpec((1, bs, bs), lambda j, k, ptr, br, bc: (k, 0, 0)),
                # the B tile addressed by the block's column (data-dependent)
                pl.BlockSpec((bs, tn), lambda j, k, ptr, br, bc: (bc[k], j)),
            ],
            out_specs=pl.BlockSpec((bs, tn), lambda j, k, ptr, br, bc: (br[k], j)),
            scratch_shapes=[pltpu.VMEM((bs, tn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((m, kbp), B.dtype),
        interpret=interpret,
    )(indptr, brow, bcol, blocks, B)
    return y[:, :kb]
