"""jit'd wrappers binding the Pallas kernels to the core containers.

``INTERPRET`` is True off-TPU: the kernel bodies execute in Python on CPU
(correctness validation); on TPU the same code lowers through Mosaic. The
``REPRO_FORCE_INTERPRET=0|1`` environment variable overrides the TPU
detection in either direction — re-read on every call, so tests/CI can
exercise the compiled-path plumbing (or pin interpret mode on a TPU host)
without monkeypatching module state.

Every SpMV/SpMM entry point takes ``cfg=`` — a kernel tile-config dict
(e.g. ``{"tm": 256, "tk": 2048}`` for CSR, ``{"tm": 1024, "layout":
"col"}`` for ELL). Explicit keyword arguments win over ``cfg`` entries,
which win over :func:`default_config`'s density heuristic (tile sizes
derived from the matrix's shape and average row nnz). Measured winning
configs come from ``repro.tuning.kernel_tune`` and are threaded here by
``repro.core.ops.spmv(backend="auto")``.

Wrappers enforce each kernel's structural preconditions and fall back to the
pure-jnp reference path when they do not hold (e.g. x too large for VMEM
residency, empty BSR block rows) — the dynamic-format machinery guarantees a
correct answer either way.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR, CSR, DIA, ELL, HYB
from repro.kernels import bsr_spmm as _bsr
from repro.kernels import csr_spmv as _csr
from repro.kernels import dia_spmv as _dia
from repro.kernels import ell_spmv as _ell


def _env_interpret():
    v = os.environ.get("REPRO_FORCE_INTERPRET", "").strip()
    if v in ("0", "1"):
        return v == "1"
    return None


_DETECTED = jax.default_backend() != "tpu"
INTERPRET = _env_interpret() if _env_interpret() is not None else _DETECTED


def interpret_mode() -> bool:
    """Effective interpret flag: ``REPRO_FORCE_INTERPRET`` (if set) wins
    over the import-time TPU detection baked into ``INTERPRET``."""
    env = _env_interpret()
    return INTERPRET if env is None else env


def auto_backend() -> str:
    """Backend the kernels would *compile* to right now: ``"pallas"`` when
    they lower natively (TPU, or the interpret override is forced off),
    ``"ref"`` when they would run interpreted. NOTE: ``"auto"`` SpMV
    routing no longer uses this compile test alone — it requires a
    measured kernel config that beats the reference path (see
    ``repro.core.ops.resolve_backend``); this predicate remains for
    callers that only care whether native lowering is available."""
    return "ref" if interpret_mode() else "pallas"


# VMEM residency budget for the x vector (bytes); beyond this the wrappers
# fall back to the reference path (v5e has ~16 MiB VMEM per core).
X_VMEM_BUDGET = 6 * 1024 * 1024


# ---------------------------------------------------------------------------
# Default tile configs: the per-matrix density heuristic
# ---------------------------------------------------------------------------


def _pow2_clamp(v: float, lo: int, hi: int) -> int:
    """Smallest power of two >= v, clamped into [lo, hi]."""
    p = 1 << max(0, int(np.ceil(np.log2(max(1.0, float(v))))))
    return int(min(max(p, lo), hi))


def _csr_tiles(m: int, nnz: int, cfg: Optional[dict],
               tm: Optional[int] = None, tk: Optional[int] = None):
    """(tm, tk) for the CSR kernel: explicit args > cfg > density heuristic.

    Heuristic: tm rides the VPU sweet spot (256 rows, or the whole matrix
    when smaller); tk sizes each nnz chunk to roughly a quarter of the
    average tile's window (avg row nnz x tm / 4) so sparse tiles take one
    cheap chunk while dense tiles stream several full ones.
    """
    cfg = cfg or {}
    tm = int(tm if tm is not None else cfg.get("tm") or _pow2_clamp(min(m, 256), 8, 8192))
    avg = max(1.0, nnz / max(1, m))
    tk = int(tk if tk is not None else cfg.get("tk") or _pow2_clamp(avg * tm / 4, 256, 4096))
    return tm, tk


def resolve_config(A, cfg: Optional[dict], op: str = "spmv") -> dict:
    """The tile config a wrapper should run with: an explicit ``cfg``
    wins; otherwise the *tuned* winner cached for ``A``'s shape bucket
    (host dict lookup, trace-time only); otherwise the density heuristic.

    Consulting the tuned cache here — not just on the ``"auto"`` route —
    means resolve-then-dispatch callers (``resolve_backend("auto", A)``
    followed by ``spmv(backend="pallas")``) also run the measured winner
    rather than silently falling back to an untuned default.
    """
    if cfg is not None:
        return cfg
    try:
        from repro.tuning import kernel_tune  # lazy: tuning imports kernels
        rec = kernel_tune.best_config(A, op=op)
        if rec is not None:
            return dict(rec.cfg)
    except ImportError:  # pragma: no cover - partial installs
        pass
    return default_config(A)


def _pick(explicit, cfg: dict, key: str, A):
    """The one precedence rule for kernel params: explicit kwarg > ``cfg``
    entry > density-heuristic default (guards tuned records that predate a
    newly added key)."""
    if explicit is not None:
        return explicit
    v = cfg.get(key)
    return v if v is not None else default_config(A)[key]


def default_config(A) -> dict:
    """Density-heuristic tile config for ``A`` (the no-tuning default).

    ``repro.tuning.kernel_tune.best_config`` supersedes this with a
    measured winner when one is cached for the matrix's shape bucket
    (see :func:`resolve_config`).
    """
    m = A.shape[0]
    nnz = max(1, int(getattr(A, "nnz", 1)))
    if isinstance(A, CSR):
        tm, tk = _csr_tiles(m, nnz, None)
        return {"tm": tm, "tk": tk}
    if isinstance(A, ELL):
        # interpret mode pays per grid step: prefer one big tile; native
        # Mosaic wants lane-aligned (K, tm) tiles in VMEM.
        if interpret_mode():
            return {"tm": _pow2_clamp(m, 8, 8192), "layout": "row"}
        return {"tm": 256, "layout": "col"}
    if isinstance(A, DIA):
        return {"tm": _pow2_clamp(min(m, 512), 8, 2048)}
    if isinstance(A, BSR):
        return {"tn": 128}
    if isinstance(A, HYB):
        return {"ell": default_config(A.ell)}
    return {}


# ---------------------------------------------------------------------------
# SpMV / SpMM entry points (all take cfg=)
# ---------------------------------------------------------------------------


def dia_spmv(A: DIA, x: jax.Array, tm: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    cfg = resolve_config(A, cfg)
    tm = int(_pick(tm, cfg, "tm", A))
    n = A.shape[1]
    if (n + 2 * (A.data.shape[1] + tm)) * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_dia(A, x)
    return _dia.dia_spmv(A.offsets, A.data, x, n, tm=tm,
                         interpret=interpret_mode())


def ell_spmv(A: ELL, x: jax.Array, tm: Optional[int] = None,
             layout: Optional[str] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    cfg = resolve_config(A, cfg)
    tm = int(_pick(tm, cfg, "tm", A))
    layout = _pick(layout, cfg, "layout", A)
    if x.size * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_ell(A, x)
    return _ell.ell_spmv(A.cols, A.data, x, tm=tm, layout=layout,
                         interpret=interpret_mode())


def csr_spmv(A: CSR, x: jax.Array, tm: Optional[int] = None,
             tk: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    """CSR SpMV via the 2-D row x nnz tiled Pallas kernel; the
    (rows, indices, data) arrays plus x must fit the VMEM residency
    budget, else ref fallback."""
    from repro.core import ops as core_ops
    resident = (3 * A.capacity + x.size) * 4
    if resident > X_VMEM_BUDGET:
        return core_ops._spmv_csr(A, x)
    tm, tk = _csr_tiles(A.shape[0], A.nnz, resolve_config(A, cfg), tm=tm, tk=tk)
    rows = core_ops.csr_row_ids(A.indptr, A.capacity, A.shape[0])
    return _csr.csr_spmv(A.indptr, rows, A.indices, A.data, x, tm=tm, tk=tk,
                         interpret=interpret_mode())


def hyb_spmv(A: HYB, x: jax.Array, cfg: Optional[dict] = None) -> jax.Array:
    """HYB SpMV: ELL kernel for the regular planes + the CSR kernel for the
    COO overflow tail. The tail's row ids are already in hand, so the CSR
    layout is assembled directly (stable sort + bincount row pointers);
    everything fuses with the caller under jit, and plan-built tails are
    already row-sorted so the sort is cheap. ``cfg`` nests per-part
    configs: ``{"ell": {...}, "csr": {...}}``."""
    from repro.core import ops as core_ops
    cfg = resolve_config(A, cfg)
    y = ell_spmv(A.ell, x, cfg=cfg.get("ell"))
    c = A.coo
    if (3 * c.capacity + x.size) * 4 > X_VMEM_BUDGET:
        return y + core_ops._spmv_coo(c, x)
    order = jnp.argsort(c.row, stable=True)
    rows = c.row[order]
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(rows, length=A.shape[0])).astype(jnp.int32)])
    tm, tk = _csr_tiles(A.shape[0], c.nnz, cfg.get("csr"))
    tail = _csr.csr_spmv(indptr, rows, c.col[order], c.data[order], x,
                         tm=tm, tk=tk, interpret=interpret_mode())
    return y + tail


def _bsr_brow(A: BSR):
    """Precompute (host) the non-decreasing block-row id of every block."""
    indptr = np.asarray(A.indptr)
    nblk = A.nblocks
    brow = np.searchsorted(indptr, np.arange(nblk), side="right").astype(np.int32) - 1
    return jnp.asarray(np.clip(brow, 0, max(0, len(indptr) - 2)))


def _bsr_rows_nonempty(A: BSR) -> bool:
    indptr = np.asarray(A.indptr)
    return bool(np.all(np.diff(indptr) >= 1)) and int(indptr[-1]) == A.nblocks


def bsr_spmm(A: BSR, B: jax.Array, tn: Optional[int] = None,
             cfg: Optional[dict] = None, _op: str = "spmm") -> jax.Array:
    cfg = resolve_config(A, cfg, op=_op)
    tn = int(_pick(tn, cfg, "tn", A))
    if not _bsr_rows_nonempty(A):
        from repro.core import ops as core_ops
        return core_ops._spmm_bsr(A, B)
    brow = _bsr_brow(A)
    return _bsr.bsr_spmm(A.indptr, brow, A.indices, A.data, B, A.shape[0],
                         tn=tn, interpret=interpret_mode())


def bsr_spmv(A: BSR, x: jax.Array, tn: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    # tuned as op="spmv": a BSR spmv record must not be read as spmm's
    return bsr_spmm(A, x[:, None], tn=tn, cfg=cfg, _op="spmv")[:, 0]


# Registries consumed by repro.core.ops.spmv/spmm(backend="pallas").
SPMV_PALLAS = {DIA: dia_spmv, ELL: ell_spmv, BSR: bsr_spmv, CSR: csr_spmv,
               HYB: hyb_spmv}
SPMM_PALLAS = {BSR: bsr_spmm}
