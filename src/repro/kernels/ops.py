"""jit'd wrappers binding the Pallas kernels to the core containers.

``INTERPRET`` is True off-TPU: the kernel bodies execute in Python on CPU
(correctness validation); on TPU the same code lowers through Mosaic. The
``REPRO_FORCE_INTERPRET=0|1`` environment variable overrides the TPU
detection in either direction — re-read on every call, so tests/CI can
exercise the compiled-path plumbing (or pin interpret mode on a TPU host)
without monkeypatching module state.

Wrappers enforce each kernel's structural preconditions and fall back to the
pure-jnp reference path when they do not hold (e.g. x too large for VMEM
residency, empty BSR block rows) — the dynamic-format machinery guarantees a
correct answer either way.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR, CSR, DIA, ELL, HYB
from repro.kernels import bsr_spmm as _bsr
from repro.kernels import csr_spmv as _csr
from repro.kernels import dia_spmv as _dia
from repro.kernels import ell_spmv as _ell


def _env_interpret():
    v = os.environ.get("REPRO_FORCE_INTERPRET", "").strip()
    if v in ("0", "1"):
        return v == "1"
    return None


_DETECTED = jax.default_backend() != "tpu"
INTERPRET = _env_interpret() if _env_interpret() is not None else _DETECTED


def interpret_mode() -> bool:
    """Effective interpret flag: ``REPRO_FORCE_INTERPRET`` (if set) wins
    over the import-time TPU detection baked into ``INTERPRET``."""
    env = _env_interpret()
    return INTERPRET if env is None else env


def auto_backend() -> str:
    """Backend the ``"auto"`` spmv/spmm routing resolves to right now:
    ``"pallas"`` when the kernels compile natively (TPU, or the interpret
    override is forced off), ``"ref"`` when they would run interpreted."""
    return "ref" if interpret_mode() else "pallas"


# VMEM residency budget for the x vector (bytes); beyond this the wrappers
# fall back to the reference path (v5e has ~16 MiB VMEM per core).
X_VMEM_BUDGET = 6 * 1024 * 1024


def dia_spmv(A: DIA, x: jax.Array, tm: int = 512) -> jax.Array:
    n = A.shape[1]
    if (n + 2 * (A.data.shape[1] + tm)) * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_dia(A, x)
    return _dia.dia_spmv(A.offsets, A.data, x, n, tm=tm, interpret=interpret_mode())


def ell_spmv(A: ELL, x: jax.Array, tm: int = 256) -> jax.Array:
    if x.size * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_ell(A, x)
    return _ell.ell_spmv(A.cols, A.data, x, tm=tm, interpret=interpret_mode())


def csr_spmv(A: CSR, x: jax.Array, tm: int = 256, tk: int = 512) -> jax.Array:
    """CSR SpMV via the row-tiled Pallas kernel; the (rows, indices, data)
    arrays plus x must fit the VMEM residency budget, else ref fallback."""
    from repro.core import ops as core_ops
    resident = (3 * A.capacity + x.size) * 4
    if resident > X_VMEM_BUDGET:
        return core_ops._spmv_csr(A, x)
    rows = core_ops.csr_row_ids(A.indptr, A.capacity, A.shape[0])
    return _csr.csr_spmv(A.indptr, rows, A.indices, A.data, x, tm=tm, tk=tk,
                         interpret=interpret_mode())


def hyb_spmv(A: HYB, x: jax.Array) -> jax.Array:
    """HYB SpMV: ELL kernel for the regular planes + the CSR kernel for the
    COO overflow tail. The tail's row ids are already in hand, so the CSR
    layout is assembled directly (stable sort + bincount row pointers, no
    searchsorted row recovery); everything fuses with the caller under jit,
    and plan-built tails are already row-sorted so the sort is cheap."""
    from repro.core import ops as core_ops
    y = ell_spmv(A.ell, x)
    c = A.coo
    if (3 * c.capacity + x.size) * 4 > X_VMEM_BUDGET:
        return y + core_ops._spmv_coo(c, x)
    order = jnp.argsort(c.row, stable=True)
    rows = c.row[order]
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(rows, length=A.shape[0])).astype(jnp.int32)])
    tail = _csr.csr_spmv(indptr, rows, c.col[order], c.data[order], x,
                         interpret=interpret_mode())
    return y + tail


def _bsr_brow(A: BSR):
    """Precompute (host) the non-decreasing block-row id of every block."""
    indptr = np.asarray(A.indptr)
    nblk = A.nblocks
    brow = np.searchsorted(indptr, np.arange(nblk), side="right").astype(np.int32) - 1
    return jnp.asarray(np.clip(brow, 0, max(0, len(indptr) - 2)))


def _bsr_rows_nonempty(A: BSR) -> bool:
    indptr = np.asarray(A.indptr)
    return bool(np.all(np.diff(indptr) >= 1)) and int(indptr[-1]) == A.nblocks


def bsr_spmm(A: BSR, B: jax.Array, tn: int = 128) -> jax.Array:
    if not _bsr_rows_nonempty(A):
        from repro.core import ops as core_ops
        return core_ops._spmm_bsr(A, B)
    brow = _bsr_brow(A)
    return _bsr.bsr_spmm(A.indptr, brow, A.indices, A.data, B, A.shape[0],
                         tn=tn, interpret=interpret_mode())


def bsr_spmv(A: BSR, x: jax.Array, tn: int = 128) -> jax.Array:
    return bsr_spmm(A, x[:, None], tn=tn)[:, 0]


# Registries consumed by repro.core.ops.spmv/spmm(backend="pallas").
SPMV_PALLAS = {DIA: dia_spmv, ELL: ell_spmv, BSR: bsr_spmv, CSR: csr_spmv,
               HYB: hyb_spmv}
SPMM_PALLAS = {BSR: bsr_spmm}
