"""jit'd wrappers binding the Pallas kernels to the core containers.

``INTERPRET`` is True off-TPU: the kernel bodies execute in Python on CPU
(correctness validation); on TPU the same code lowers through Mosaic. The
``REPRO_FORCE_INTERPRET=0|1`` environment variable overrides the TPU
detection in either direction — re-read on every call, so tests/CI can
exercise the compiled-path plumbing (or pin interpret mode on a TPU host)
without monkeypatching module state.

Every SpMV/SpMM entry point takes ``cfg=`` — a kernel tile-config dict
(e.g. ``{"tm": 256, "tk": 2048}`` for CSR, ``{"tm": 1024, "layout":
"col"}`` for ELL). Explicit keyword arguments win over ``cfg`` entries,
which win over :func:`default_config`'s density heuristic (tile sizes
derived from the matrix's shape and average row nnz). Measured winning
configs come from ``repro.tuning.kernel_tune`` and are threaded here by
``repro.core.ops.spmv(backend="auto")``.

Wrappers enforce each kernel's structural preconditions and fall back to the
pure-jnp reference path when they do not hold (e.g. x too large for VMEM
residency, empty BSR block rows) — the dynamic-format machinery guarantees a
correct answer either way.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR, CSR, DIA, ELL, HYB, SELL
from repro.kernels import bsr_spmm as _bsr
from repro.kernels import csr_spmm as _csr_mm
from repro.kernels import csr_spmv as _csr
from repro.kernels import dia_spmv as _dia
from repro.kernels import ell_spmv as _ell
from repro.kernels import sell_spmv as _sell


def _env_interpret():
    v = os.environ.get("REPRO_FORCE_INTERPRET", "").strip()
    if v in ("0", "1"):
        return v == "1"
    return None


_DETECTED = jax.default_backend() != "tpu"
INTERPRET = _env_interpret() if _env_interpret() is not None else _DETECTED


def interpret_mode() -> bool:
    """Effective interpret flag: ``REPRO_FORCE_INTERPRET`` (if set) wins
    over the import-time TPU detection baked into ``INTERPRET``."""
    env = _env_interpret()
    return INTERPRET if env is None else env


def auto_backend() -> str:
    """Backend the kernels would *compile* to right now: ``"pallas"`` when
    they lower natively (TPU, or the interpret override is forced off),
    ``"ref"`` when they would run interpreted. NOTE: ``"auto"`` SpMV
    routing no longer uses this compile test alone — it requires a
    measured kernel config that beats the reference path (see
    ``repro.core.ops.resolve_backend``); this predicate remains for
    callers that only care whether native lowering is available."""
    return "ref" if interpret_mode() else "pallas"


# VMEM residency budget for the x vector (bytes); beyond this the wrappers
# fall back to the reference path (v5e has ~16 MiB VMEM per core).
X_VMEM_BUDGET = 6 * 1024 * 1024


# ---------------------------------------------------------------------------
# Default tile configs: the per-matrix density heuristic
# ---------------------------------------------------------------------------


def _pow2_clamp(v: float, lo: int, hi: int) -> int:
    """Smallest power of two >= v, clamped into [lo, hi]."""
    p = 1 << max(0, int(np.ceil(np.log2(max(1.0, float(v))))))
    return int(min(max(p, lo), hi))


def _csr_tiles(m: int, nnz: int, cfg: Optional[dict],
               tm: Optional[int] = None, tk: Optional[int] = None):
    """(tm, tk) for the CSR kernel: explicit args > cfg > density heuristic.

    Heuristic: tm rides the VPU sweet spot (256 rows, or the whole matrix
    when smaller); tk sizes each nnz chunk to roughly a quarter of the
    average tile's window (avg row nnz x tm / 4) so sparse tiles take one
    cheap chunk while dense tiles stream several full ones.
    """
    cfg = cfg or {}
    tm = int(tm if tm is not None else cfg.get("tm") or _pow2_clamp(min(m, 256), 8, 8192))
    avg = max(1.0, nnz / max(1, m))
    tk = int(tk if tk is not None else cfg.get("tk") or _pow2_clamp(avg * tm / 4, 256, 4096))
    return tm, tk


def resolve_config(A, cfg: Optional[dict], op: str = "spmv",
                   ncols: Optional[int] = None) -> dict:
    """The tile config a wrapper should run with: an explicit ``cfg``
    wins; otherwise the *tuned* winner cached for ``A``'s shape bucket
    (host dict lookup, trace-time only); otherwise the density heuristic.

    Consulting the tuned cache here — not just on the ``"auto"`` route —
    means resolve-then-dispatch callers (``resolve_backend("auto", A)``
    followed by ``spmv(backend="pallas")``) also run the measured winner
    rather than silently falling back to an untuned default. ``ncols``
    is the rhs width for the spmm ops — part of the tuned-record key (a
    winner measured at one batch width is never replayed at another).
    """
    if cfg is not None:
        return cfg
    try:
        from repro.tuning import kernel_tune  # lazy: tuning imports kernels
        rec = kernel_tune.best_config(A, op=op, ncols=ncols)
        if rec is not None:
            return dict(rec.cfg)
    except ImportError:  # pragma: no cover - partial installs
        pass
    return default_config(A, op=op, ncols=ncols)


def _pick(explicit, cfg: dict, key: str, A, op: str = "spmv",
          ncols: Optional[int] = None):
    """The one precedence rule for kernel params: explicit kwarg > ``cfg``
    entry > density-heuristic default (guards tuned records that predate a
    newly added key)."""
    if explicit is not None:
        return explicit
    v = cfg.get(key)
    return v if v is not None else default_config(A, op=op, ncols=ncols)[key]


def _rhs_tile(ncols: Optional[int]) -> int:
    """Default rhs tile: the whole (pow2-rounded) batch width up to 256 —
    b=1 decode runs a 1-lane tile instead of padding to a full slab."""
    return _pow2_clamp(ncols or 128, 1, 256)


def default_config(A, op: str = "spmv", ncols: Optional[int] = None) -> dict:
    """Density-heuristic tile config for ``A`` (the no-tuning default).

    ``repro.tuning.kernel_tune.best_config`` supersedes this with a
    measured winner when one is cached for the matrix's (shape bucket,
    rhs-width bucket) (see :func:`resolve_config`). ``op`` selects the
    kernel family: the spmm ops add the ``tn`` rhs tile, and ELL's layout
    default flips to the plane-streaming ``"col"`` once rows are long
    enough that a (tm, K, tn) row-layout gather would blow the transient
    footprint.
    """
    m = A.shape[0]
    nnz = max(1, int(getattr(A, "nnz", 1)))
    spmm = op in ("spmm", "spmm_t")
    if isinstance(A, CSR):
        tm, tk = _csr_tiles(m, nnz, None)
        if spmm:
            # wide rhs: each nnz chunk costs tk*tn work — shrink the chunk
            tk = _pow2_clamp(tk / max(1, _rhs_tile(ncols) // 8), 256, 4096)
            return {"tm": tm, "tk": tk, "tn": _rhs_tile(ncols)}
        return {"tm": tm, "tk": tk}
    if isinstance(A, ELL):
        k = A.data.shape[1]
        if spmm:
            layout = "row" if k <= 32 else "col"
            return {"tm": _pow2_clamp(min(m, 1024), 8, 8192),
                    "layout": layout, "tn": _rhs_tile(ncols)}
        # interpret mode pays per grid step: prefer one big tile; native
        # Mosaic wants lane-aligned (K, tm) tiles in VMEM.
        if interpret_mode():
            return {"tm": _pow2_clamp(m, 8, 8192), "layout": "row"}
        return {"tm": 256, "layout": "col"}
    if isinstance(A, SELL):
        # ts slices per program; aim for ~512 sorted rows per grid step
        # (interpret mode pays per step; each unrolled slice adds trace
        # size, so ts stays bounded). c/sigma are *container* parameters —
        # kernel_tune rebuilds the matrix to explore them; the wrapper
        # only picks the launch geometry.
        ts = _pow2_clamp(512 // max(1, A.c), 1, 64)
        if spmm:
            return {"ts": ts, "tn": _rhs_tile(ncols)}
        return {"ts": ts}
    if isinstance(A, DIA):
        return {"tm": _pow2_clamp(min(m, 512), 8, 2048)}
    if isinstance(A, BSR):
        return {"tn": 128}
    if isinstance(A, HYB):
        sub = {"ell": default_config(A.ell, op=op, ncols=ncols)}
        if spmm:
            tm, tk = _csr_tiles(m, max(1, int(A.coo.nnz)), None)
            sub["csr"] = {"tm": tm, "tk": tk, "tn": _rhs_tile(ncols)}
        return sub
    return {}


# ---------------------------------------------------------------------------
# SpMV / SpMM entry points (all take cfg=)
# ---------------------------------------------------------------------------


def dia_spmv(A: DIA, x: jax.Array, tm: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    cfg = resolve_config(A, cfg)
    tm = int(_pick(tm, cfg, "tm", A))
    n = A.shape[1]
    if (n + 2 * (A.data.shape[1] + tm)) * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_dia(A, x)
    return _dia.dia_spmv(A.offsets, A.data, x, n, tm=tm,
                         interpret=interpret_mode())


def ell_spmv(A: ELL, x: jax.Array, tm: Optional[int] = None,
             layout: Optional[str] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    cfg = resolve_config(A, cfg)
    tm = int(_pick(tm, cfg, "tm", A))
    layout = _pick(layout, cfg, "layout", A)
    if x.size * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_ell(A, x)
    return _ell.ell_spmv(A.cols, A.data, x, tm=tm, layout=layout,
                         interpret=interpret_mode())


def sell_spmv(A: SELL, x: jax.Array, ts: Optional[int] = None,
              cfg: Optional[dict] = None) -> jax.Array:
    """SELL-C-sigma SpMV via the slice-tiled Pallas kernel. ``cfg`` may
    carry ``c``/``sigma`` from a tuned record — those describe the
    container the tuner rebuilt, not a launch knob, and are ignored
    here; only ``ts`` (slices per program) shapes the launch."""
    cfg = resolve_config(A, cfg)
    ts = int(_pick(ts, cfg, "ts", A))
    if (2 * A.capacity + x.size) * 4 > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_sell(A, x)
    return _sell.sell_spmv(A.slice_ptrs, A.cols, A.data, A.perm, x,
                           m=A.shape[0], c=A.c, ts=ts,
                           interpret=interpret_mode())


def csr_spmv(A: CSR, x: jax.Array, tm: Optional[int] = None,
             tk: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    """CSR SpMV via the 2-D row x nnz tiled Pallas kernel; the
    (rows, indices, data) arrays plus x must fit the VMEM residency
    budget, else ref fallback."""
    from repro.core import ops as core_ops
    resident = (3 * A.capacity + x.size) * 4
    if resident > X_VMEM_BUDGET:
        return core_ops._spmv_csr(A, x)
    tm, tk = _csr_tiles(A.shape[0], A.nnz, resolve_config(A, cfg), tm=tm, tk=tk)
    rows = core_ops.csr_row_ids(A.indptr, A.capacity, A.shape[0])
    return _csr.csr_spmv(A.indptr, rows, A.indices, A.data, x, tm=tm, tk=tk,
                         interpret=interpret_mode())


def hyb_spmv(A: HYB, x: jax.Array, cfg: Optional[dict] = None) -> jax.Array:
    """HYB SpMV: ELL kernel for the regular planes + the CSR kernel for the
    COO overflow tail. The tail's row ids are already in hand, so the CSR
    layout is assembled directly (stable sort + bincount row pointers);
    everything fuses with the caller under jit, and plan-built tails are
    already row-sorted so the sort is cheap. ``cfg`` nests per-part
    configs: ``{"ell": {...}, "csr": {...}}``."""
    from repro.core import ops as core_ops
    cfg = resolve_config(A, cfg)
    y = ell_spmv(A.ell, x, cfg=cfg.get("ell"))
    c = A.coo
    if (3 * c.capacity + x.size) * 4 > X_VMEM_BUDGET:
        return y + core_ops._spmv_coo(c, x)
    order = jnp.argsort(c.row, stable=True)
    rows = c.row[order]
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(rows, length=A.shape[0])).astype(jnp.int32)])
    tm, tk = _csr_tiles(A.shape[0], c.nnz, cfg.get("csr"))
    tail = _csr.csr_spmv(indptr, rows, c.col[order], c.data[order], x,
                         tm=tm, tk=tk, interpret=interpret_mode())
    return y + tail


def _bsr_brow(A: BSR):
    """Precompute (host) the non-decreasing block-row id of every block."""
    indptr = np.asarray(A.indptr)
    nblk = A.nblocks
    brow = np.searchsorted(indptr, np.arange(nblk), side="right").astype(np.int32) - 1
    return jnp.asarray(np.clip(brow, 0, max(0, len(indptr) - 2)))


def _bsr_rows_nonempty(A: BSR) -> bool:
    indptr = np.asarray(A.indptr)
    return bool(np.all(np.diff(indptr) >= 1)) and int(indptr[-1]) == A.nblocks


def bsr_spmm(A: BSR, B: jax.Array, tn: Optional[int] = None,
             cfg: Optional[dict] = None, _op: str = "spmm") -> jax.Array:
    ncols = B.shape[1] if _op in ("spmm", "spmm_t") else None
    cfg = resolve_config(A, cfg, op=_op, ncols=ncols)
    tn = int(_pick(tn, cfg, "tn", A))
    if not _bsr_rows_nonempty(A):
        from repro.core import ops as core_ops
        return core_ops._spmm_bsr(A, B)
    brow = _bsr_brow(A)
    return _bsr.bsr_spmm(A.indptr, brow, A.indices, A.data, B, A.shape[0],
                         tn=tn, interpret=interpret_mode())


def bsr_spmv(A: BSR, x: jax.Array, tn: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    # tuned as op="spmv": a BSR spmv record must not be read as spmm's
    return bsr_spmm(A, x[:, None], tn=tn, cfg=cfg, _op="spmv")[:, 0]


# ---------------------------------------------------------------------------
# SpMM wrappers: Y = A @ B (B (N, K)) and the transposed-rhs serving
# orientation Y = X @ A^T (X (T, N)). ``tn`` tiles the rhs/batch axis;
# defaults and tuned records are keyed by the rhs-width bucket.
# ---------------------------------------------------------------------------


def _spmm_cfg(A, cfg, op, ncols, tm=None, tk=None, tn=None):
    cfg = resolve_config(A, cfg, op=op, ncols=ncols)
    tm = int(_pick(tm, cfg, "tm", A, op=op, ncols=ncols))
    tk = int(_pick(tk, cfg, "tk", A, op=op, ncols=ncols))
    tn = int(_pick(tn, cfg, "tn", A, op=op, ncols=ncols))
    return tm, tk, tn


def csr_spmm(A: CSR, B: jax.Array, tm: Optional[int] = None,
             tk: Optional[int] = None, tn: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    """Y = A @ B via the row x rhs tiled Pallas kernel. The VMEM check
    counts the per-tile B slab (N x tn), not all of B."""
    from repro.core import ops as core_ops
    tm, tk, tn = _spmm_cfg(A, cfg, "spmm", B.shape[1], tm=tm, tk=tk, tn=tn)
    resident = (3 * A.capacity + (A.shape[1] + tm) * tn) * 4
    if resident > X_VMEM_BUDGET:
        return core_ops._spmm_csr(A, B)
    rows = core_ops.csr_row_ids(A.indptr, A.capacity, A.shape[0])
    return _csr_mm.csr_spmm(A.indptr, rows, A.indices, A.data, B,
                            tm=tm, tk=tk, tn=tn, interpret=interpret_mode())


def csr_spmm_t(A: CSR, X: jax.Array, tm: Optional[int] = None,
               tk: Optional[int] = None, tn: Optional[int] = None,
               cfg: Optional[dict] = None) -> jax.Array:
    """Y = X @ A^T for activations X (T, N) — no activation transposes."""
    from repro.core import ops as core_ops
    tm, tk, tn = _spmm_cfg(A, cfg, "spmm_t", X.shape[0], tm=tm, tk=tk, tn=tn)
    resident = (3 * A.capacity + (A.shape[1] + tm) * tn) * 4
    if resident > X_VMEM_BUDGET:
        return core_ops._spmm_csr(A, X.T).T
    rows = core_ops.csr_row_ids(A.indptr, A.capacity, A.shape[0])
    return _csr_mm.csr_spmm_t(A.indptr, rows, A.indices, A.data, X,
                              tm=tm, tk=tk, tn=tn, interpret=interpret_mode())


def _ell_spmm_cfg(A, cfg, op, ncols, tm=None, layout=None, tn=None):
    cfg = resolve_config(A, cfg, op=op, ncols=ncols)
    tm = int(_pick(tm, cfg, "tm", A, op=op, ncols=ncols))
    layout = _pick(layout, cfg, "layout", A, op=op, ncols=ncols)
    tn = int(_pick(tn, cfg, "tn", A, op=op, ncols=ncols))
    return tm, layout, tn


def _ell_spmm_fits(A: ELL, tm: int, layout: str, tn: int, n: int) -> bool:
    k = A.data.shape[1]
    transient = tm * k * tn if layout == "row" else tm * tn
    resident = 2 * tm * k + n * tn + tm * tn + transient
    return resident * 4 <= X_VMEM_BUDGET


def ell_spmm(A: ELL, B: jax.Array, tm: Optional[int] = None,
             layout: Optional[str] = None, tn: Optional[int] = None,
             cfg: Optional[dict] = None) -> jax.Array:
    from repro.core import ops as core_ops
    tm, layout, tn = _ell_spmm_cfg(A, cfg, "spmm", B.shape[1],
                                   tm=tm, layout=layout, tn=tn)
    if not _ell_spmm_fits(A, tm, layout, tn, A.shape[1]):
        return core_ops._spmm_ell(A, B)
    return _ell.ell_spmm(A.cols, A.data, B, tm=tm, tn=tn, layout=layout,
                         interpret=interpret_mode())


def ell_spmm_t(A: ELL, X: jax.Array, tm: Optional[int] = None,
               layout: Optional[str] = None, tn: Optional[int] = None,
               cfg: Optional[dict] = None) -> jax.Array:
    from repro.core import ops as core_ops
    tm, layout, tn = _ell_spmm_cfg(A, cfg, "spmm_t", X.shape[0],
                                   tm=tm, layout=layout, tn=tn)
    if not _ell_spmm_fits(A, tm, layout, tn, A.shape[1]):
        return core_ops._spmm_ell(A, X.T).T
    return _ell.ell_spmm_t(A.cols, A.data, X, tm=tm, tn=tn, layout=layout,
                           interpret=interpret_mode())


def _hyb_tail_csr(A: HYB):
    """The COO overflow tail in CSR layout (stable sort + bincount row
    pointers), same assembly as :func:`hyb_spmv` — plan-built tails are
    already row-sorted so the sort is cheap under jit."""
    c = A.coo
    order = jnp.argsort(c.row, stable=True)
    rows = c.row[order]
    indptr = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.bincount(rows, length=A.shape[0])).astype(jnp.int32)])
    return indptr, rows, c.col[order], c.data[order]


def hyb_spmm(A: HYB, B: jax.Array, cfg: Optional[dict] = None) -> jax.Array:
    from repro.core import ops as core_ops
    cfg = resolve_config(A, cfg, op="spmm", ncols=B.shape[1])
    y = ell_spmm(A.ell, B, cfg=cfg.get("ell"))
    sub = cfg.get("csr") or {}
    tm, tk = _csr_tiles(A.shape[0], max(1, int(A.coo.nnz)), sub)
    tn = int(sub.get("tn") or _rhs_tile(B.shape[1]))
    if (3 * A.coo.capacity + (A.shape[1] + tm) * tn) * 4 > X_VMEM_BUDGET:
        return y + core_ops._spmm_coo(A.coo, B)
    indptr, rows, col, data = _hyb_tail_csr(A)
    tail = _csr_mm.csr_spmm(indptr, rows, col, data, B, tm=tm, tk=tk, tn=tn,
                            interpret=interpret_mode())
    return y + tail


def hyb_spmm_t(A: HYB, X: jax.Array, cfg: Optional[dict] = None) -> jax.Array:
    from repro.core import ops as core_ops
    cfg = resolve_config(A, cfg, op="spmm_t", ncols=X.shape[0])
    y = ell_spmm_t(A.ell, X, cfg=cfg.get("ell"))
    sub = cfg.get("csr") or {}
    tm, tk = _csr_tiles(A.shape[0], max(1, int(A.coo.nnz)), sub)
    tn = int(sub.get("tn") or _rhs_tile(X.shape[0]))
    if (3 * A.coo.capacity + (A.shape[1] + tm) * tn) * 4 > X_VMEM_BUDGET:
        return y + core_ops._spmm_coo(A.coo, X.T).T
    indptr, rows, col, data = _hyb_tail_csr(A)
    tail = _csr_mm.csr_spmm_t(indptr, rows, col, data, X, tm=tm, tk=tk,
                              tn=tn, interpret=interpret_mode())
    return y + tail


def _sell_spmm_cfg(A, cfg, op, ncols, ts=None, tn=None):
    cfg = resolve_config(A, cfg, op=op, ncols=ncols)
    ts = int(_pick(ts, cfg, "ts", A, op=op, ncols=ncols))
    tn = int(_pick(tn, cfg, "tn", A, op=op, ncols=ncols))
    return ts, tn


def sell_spmm(A: SELL, B: jax.Array, ts: Optional[int] = None,
              tn: Optional[int] = None,
              cfg: Optional[dict] = None) -> jax.Array:
    from repro.core import ops as core_ops
    ts, tn = _sell_spmm_cfg(A, cfg, "spmm", B.shape[1], ts=ts, tn=tn)
    if (2 * A.capacity + (A.shape[1] + ts * A.c) * tn) * 4 > X_VMEM_BUDGET:
        return core_ops._spmm_sell(A, B)
    return _sell.sell_spmm(A.slice_ptrs, A.cols, A.data, A.perm, B,
                           m=A.shape[0], c=A.c, ts=ts, tn=tn,
                           interpret=interpret_mode())


def sell_spmm_t(A: SELL, X: jax.Array, ts: Optional[int] = None,
                tn: Optional[int] = None,
                cfg: Optional[dict] = None) -> jax.Array:
    from repro.core import ops as core_ops
    ts, tn = _sell_spmm_cfg(A, cfg, "spmm_t", X.shape[0], ts=ts, tn=tn)
    if (2 * A.capacity + (A.shape[1] + ts * A.c) * tn) * 4 > X_VMEM_BUDGET:
        return core_ops._spmm_sell(A, X.T).T
    return _sell.sell_spmm_t(A.slice_ptrs, A.cols, A.data, A.perm, X,
                             m=A.shape[0], c=A.c, ts=ts, tn=tn,
                             interpret=interpret_mode())


def bsr_spmm_t(A: BSR, X: jax.Array, tn: Optional[int] = None,
               cfg: Optional[dict] = None) -> jax.Array:
    """BSR has no native transposed-rhs kernel yet: run the (N, K) kernel
    on X^T. Still one fused jit region, but pays the two transposes —
    tuned separately (op="spmm_t") so the veto is honest about that cost."""
    return bsr_spmm(A, X.T, tn=tn, cfg=cfg, _op="spmm_t").T


# Registries consumed by repro.core.ops.spmv/spmm(backend="pallas").
SPMV_PALLAS = {DIA: dia_spmv, ELL: ell_spmv, BSR: bsr_spmv, CSR: csr_spmv,
               HYB: hyb_spmv, SELL: sell_spmv}
SPMM_PALLAS = {BSR: bsr_spmm, CSR: csr_spmm, ELL: ell_spmm, HYB: hyb_spmm,
               SELL: sell_spmm}
SPMM_T_PALLAS = {CSR: csr_spmm_t, ELL: ell_spmm_t, HYB: hyb_spmm_t,
                 BSR: bsr_spmm_t, SELL: sell_spmm_t}
