"""jit'd wrappers binding the Pallas kernels to the core containers.

``INTERPRET`` is True off-TPU: the kernel bodies execute in Python on CPU
(correctness validation); on TPU the same code lowers through Mosaic.

Wrappers enforce each kernel's structural preconditions and fall back to the
pure-jnp reference path when they do not hold (e.g. x too large for VMEM
residency, empty BSR block rows) — the dynamic-format machinery guarantees a
correct answer either way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR, DIA, ELL
from repro.kernels import bsr_spmm as _bsr
from repro.kernels import dia_spmv as _dia
from repro.kernels import ell_spmv as _ell

INTERPRET = jax.default_backend() != "tpu"

# VMEM residency budget for the x vector (bytes); beyond this the wrappers
# fall back to the reference path (v5e has ~16 MiB VMEM per core).
X_VMEM_BUDGET = 6 * 1024 * 1024


def dia_spmv(A: DIA, x: jax.Array, tm: int = 512) -> jax.Array:
    n = A.shape[1]
    if (n + 2 * (A.data.shape[1] + tm)) * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_dia(A, x)
    return _dia.dia_spmv(A.offsets, A.data, x, n, tm=tm, interpret=INTERPRET)


def ell_spmv(A: ELL, x: jax.Array, tm: int = 256) -> jax.Array:
    if x.size * x.dtype.itemsize > X_VMEM_BUDGET:
        from repro.core import ops as core_ops
        return core_ops._spmv_ell(A, x)
    return _ell.ell_spmv(A.cols, A.data, x, tm=tm, interpret=INTERPRET)


def _bsr_brow(A: BSR):
    """Precompute (host) the non-decreasing block-row id of every block."""
    indptr = np.asarray(A.indptr)
    nblk = A.nblocks
    brow = np.searchsorted(indptr, np.arange(nblk), side="right").astype(np.int32) - 1
    return jnp.asarray(np.clip(brow, 0, max(0, len(indptr) - 2)))


def _bsr_rows_nonempty(A: BSR) -> bool:
    indptr = np.asarray(A.indptr)
    return bool(np.all(np.diff(indptr) >= 1)) and int(indptr[-1]) == A.nblocks


def bsr_spmm(A: BSR, B: jax.Array, tn: int = 128) -> jax.Array:
    if not _bsr_rows_nonempty(A):
        from repro.core import ops as core_ops
        return core_ops._spmm_bsr(A, B)
    brow = _bsr_brow(A)
    return _bsr.bsr_spmm(A.indptr, brow, A.indices, A.data, B, A.shape[0],
                         tn=tn, interpret=INTERPRET)


def bsr_spmv(A: BSR, x: jax.Array, tn: int = 128) -> jax.Array:
    return bsr_spmm(A, x[:, None], tn=tn)[:, 0]


# Registries consumed by repro.core.ops.spmv/spmm(backend="pallas").
SPMV_PALLAS = {DIA: dia_spmv, ELL: ell_spmv, BSR: bsr_spmv}
SPMM_PALLAS = {BSR: bsr_spmm}
