"""Pallas TPU kernel: CSR-format SpMV — the paper's reference format.

CSR's row walk is serial on paper but the layout is still the densest
general-purpose encoding, so the reference format deserves a real kernel
rather than the pure-jnp segment-sum fallback. The TPU derivation
(DESIGN.md §2, §8) replaces the GPU's warp-per-row trick with a 2-D
row x nnz tiling:

  * grid over row tiles of ``tm`` rows; the row-pointer array rides in
    SMEM via scalar prefetch and bounds each tile's nnz window
    ``[indptr[row0], indptr[row0 + tm])``;
  * the window streams through in fixed ``tk``-entry chunks via ``pl.ds``
    dynamic-start loads from the VMEM-resident value/index arrays — the
    trip count is the tile's *own* nnz (the per-tile density heuristic:
    a sparse tile costs its actual entries, a dense tile streams more
    chunks; load imbalance never pads), which makes this an
    nnz-partitioned schedule rather than a padded one;
  * per chunk: VPU gather of x at the stored columns, f32 multiply, then
    a segment reduction onto the tile's rows via a **segmented prefix
    sum** (Hillis-Steele, log2(tk) statically-unrolled shift/add steps)
    whose running sum *resets at every row boundary*: row r's chunk
    partial reads out directly at its last position, so it only ever
    accumulates r's own entries. This keeps the O(tk log tk + tm) cost
    that replaced the one-hot ``(tk, tm)`` matmul (O(tk*tm) MACs per
    chunk, the term that dominated the kernel's cost) *without* the
    catastrophic cancellation of a plain prefix-sum difference, whose
    per-row error scales with the chunk's running total rather than the
    row's own magnitude;
  * f32 accumulation throughout, cast to the output dtype once.

Chunk tails need no masking: the scan is a prefix — positions past the
tile's window belong to later rows, sit after a row-boundary reset, and
are never read out; capacity padding past ``indptr[-1]`` is zero.

Tile sizes ``(tm, tk)`` are the kernel's tuning space — searched by
``repro.tuning.kernel_tune`` per (shape bucket, backend, device) and
threaded through ``repro.kernels.ops`` as ``cfg=``. Preconditions handled
by the ops wrapper: per-entry row ids are precomputed on device (one
searchsorted over indptr — jit-able, fused with the caller), and the
(rows, indices, data) arrays plus x must fit the VMEM residency budget,
else it falls back to the reference path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segmented_cumsum(v: jax.Array, flags: jax.Array) -> jax.Array:
    """Inclusive prefix sum of ``v`` that restarts wherever ``flags`` is
    True (Hillis-Steele, statically unrolled — vector shifts and adds
    only, no scatter)."""
    n = v.shape[0]
    f = flags
    d = 1
    while d < n:
        vs = jnp.concatenate([jnp.zeros((d,), v.dtype), v[:-d]])
        fs = jnp.concatenate([jnp.zeros((d,), jnp.bool_), f[:-d]])
        v = v + jnp.where(f, jnp.zeros((), v.dtype), vs)
        f = f | fs
        d *= 2
    return v


def _csr_kernel(indptr_ref, starts_ref, ends_ref, rows_ref, indices_ref,
                data_ref, x_ref, y_ref, *, tm: int, tk: int):
    i = pl.program_id(0)
    row0 = i * tm
    w0 = indptr_ref[row0]          # this tile's nnz window [w0, wend)
    wend = indptr_ref[row0 + tm]
    starts = starts_ref[...]       # (tm,) per-row entry ranges
    ends = ends_ref[...]
    x = x_ref[...]

    def window(w, acc):
        base = w0 + w * tk
        cols = pl.load(indices_ref, (pl.ds(base, tk),))
        vals = pl.load(data_ref, (pl.ds(base, tk),))
        rws = pl.load(rows_ref, (pl.ds(base, tk),))
        contrib = (vals.astype(jnp.float32)
                   * jnp.take(x, cols, mode="clip").astype(jnp.float32))
        # segment boundaries = row changes; the scan implicitly restarts at
        # the chunk start, which is exactly a row's continuation point.
        flags = jnp.concatenate(
            [jnp.zeros((1,), jnp.bool_), rws[1:] != rws[:-1]])
        seg = _segmented_cumsum(contrib, flags)
        lo = jnp.clip(starts - base, 0, tk)
        hi = jnp.clip(ends - base, 0, tk)
        # row r's partial over this chunk reads out at its last position
        part = jnp.take(seg, jnp.maximum(hi - 1, 0))
        return acc + jnp.where(hi > lo, part, 0.0)

    nwin = (wend - w0 + tk - 1) // tk  # this tile's own nnz, in chunks
    acc = jax.lax.fori_loop(0, nwin, window, jnp.zeros((tm,), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tk", "interpret"))
def csr_spmv(indptr: jax.Array, rows: jax.Array, indices: jax.Array,
             data: jax.Array, x: jax.Array, tm: int = 256, tk: int = 512,
             interpret: bool = True) -> jax.Array:
    """y = A @ x for CSR A given as (indptr[M+1], indices[cap], data[cap]).

    ``rows`` is the precomputed per-entry row id array (see
    ``repro.core.ops.csr_row_ids``); capacity padding past ``indptr[-1]``
    is never read because every tile stops at its own window end.
    """
    m = indptr.shape[0] - 1
    cap = data.shape[0]
    mp = ((m + tm - 1) // tm) * tm
    indptr = indptr.astype(jnp.int32)
    if mp != m:
        # padded rows are empty: their window [indptr[-1], indptr[-1]) is nil
        indptr = jnp.concatenate(
            [indptr, jnp.broadcast_to(indptr[-1], (mp - m,))])
    starts = indptr[:-1]
    ends = indptr[1:]
    # window loads start anywhere in [0, end); pad so the last chunk of the
    # last window stays in bounds for any start alignment.
    capp = ((cap + tk - 1) // tk) * tk + tk
    rows = jnp.pad(rows, (0, capp - cap))
    indices = jnp.pad(indices, (0, capp - cap))
    data = jnp.pad(data, (0, capp - cap))

    grid = (mp // tm,)
    kernel = functools.partial(_csr_kernel, tm=tm, tk=tk)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm,), lambda i, *_: (i,)),
                pl.BlockSpec((tm,), lambda i, *_: (i,)),
                pl.BlockSpec(rows.shape, lambda i, *_: (0,)),
                pl.BlockSpec(indices.shape, lambda i, *_: (0,)),
                pl.BlockSpec(data.shape, lambda i, *_: (0,)),
                pl.BlockSpec(x.shape, lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((tm,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((mp,), x.dtype),
        interpret=interpret,
    )(indptr, starts, ends, rows, indices, data, x)
    return y[:m]
