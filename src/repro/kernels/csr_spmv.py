"""Pallas TPU kernel: CSR-format SpMV — the paper's reference format.

CSR's row walk is serial on paper but the layout is still the densest
general-purpose encoding, so the reference format deserves a real kernel
rather than the pure-jnp segment-sum fallback. The TPU derivation
(DESIGN.md §2, §8) replaces the GPU's warp-per-row trick with:

  * grid over row tiles of ``tm`` rows; the row-pointer array rides in
    SMEM via scalar prefetch and bounds each tile's nnz window
    ``[indptr[row0], indptr[row0 + tm])``;
  * the window streams through in fixed ``tk``-entry chunks via ``pl.ds``
    dynamic-start loads from the VMEM-resident value/index arrays (the
    trip count is the tile's own nnz — load imbalance costs a tile only
    its actual entries, which is what makes this an *nnz-partitioned*
    schedule rather than a padded one);
  * per chunk: VPU gather of x at the stored columns, f32 multiply, then
    a segment reduction onto the tile's rows expressed as a one-hot
    (tk, tm) matmul — the MXU replacement for scatter-add, which Mosaic
    does not vectorise;
  * f32 accumulation throughout, cast to the output dtype once.

Preconditions handled by the ``repro.kernels.ops`` wrapper: per-entry row
ids are precomputed on device (one searchsorted over indptr — jit-able,
fused with the caller), and the wrapper falls back to the reference path
when the nnz arrays + x exceed the VMEM residency budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _csr_kernel(indptr_ref, rows_ref, indices_ref, data_ref, x_ref, y_ref,
                *, tm: int, tk: int):
    i = pl.program_id(0)
    row0 = i * tm
    start = indptr_ref[row0]
    end = indptr_ref[row0 + tm]
    x = x_ref[...]
    lane = jax.lax.broadcasted_iota(jnp.int32, (tk, 1), 0)[:, 0]
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (tk, tm), 1)

    def window(w, acc):
        base = start + w * tk
        live = (base + lane) < end
        cols = pl.load(indices_ref, (pl.ds(base, tk),))
        vals = pl.load(data_ref, (pl.ds(base, tk),))
        rws = pl.load(rows_ref, (pl.ds(base, tk),))
        gathered = jnp.take(x, cols, mode="clip").astype(jnp.float32)
        contrib = jnp.where(live, vals.astype(jnp.float32) * gathered, 0.0)
        # segment-sum onto the tile's rows as a one-hot MXU matmul
        onehot = ((rws - row0)[:, None] == row_iota).astype(jnp.float32)
        return acc + jnp.dot(contrib[None, :], onehot,
                             preferred_element_type=jnp.float32)[0]

    nwin = (end - start + tk - 1) // tk  # this tile's own nnz, in chunks
    acc = jax.lax.fori_loop(0, nwin, window, jnp.zeros((tm,), jnp.float32))
    y_ref[...] = acc.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tm", "tk", "interpret"))
def csr_spmv(indptr: jax.Array, rows: jax.Array, indices: jax.Array,
             data: jax.Array, x: jax.Array, tm: int = 256, tk: int = 512,
             interpret: bool = True) -> jax.Array:
    """y = A @ x for CSR A given as (indptr[M+1], indices[cap], data[cap]).

    ``rows`` is the precomputed per-entry row id array (see
    ``repro.core.ops.csr_row_ids``); capacity padding past ``indptr[-1]``
    is never read because every tile stops at its own window end.
    """
    m = indptr.shape[0] - 1
    cap = data.shape[0]
    mp = ((m + tm - 1) // tm) * tm
    indptr = indptr.astype(jnp.int32)
    if mp != m:
        # padded rows are empty: their window [indptr[-1], indptr[-1]) is nil
        indptr = jnp.concatenate(
            [indptr, jnp.broadcast_to(indptr[-1], (mp - m,))])
    # window loads start anywhere in [0, end); pad so the last chunk of the
    # last window stays in bounds for any start alignment.
    capp = ((cap + tk - 1) // tk) * tk + tk
    rows = jnp.pad(rows, (0, capp - cap))
    indices = jnp.pad(indices, (0, capp - cap))
    data = jnp.pad(data, (0, capp - cap))

    grid = (mp // tm,)
    kernel = functools.partial(_csr_kernel, tm=tm, tk=tk)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(rows.shape, lambda i, *_: (0,)),
                pl.BlockSpec(indices.shape, lambda i, *_: (0,)),
                pl.BlockSpec(data.shape, lambda i, *_: (0,)),
                pl.BlockSpec(x.shape, lambda i, *_: (0,)),
            ],
            out_specs=pl.BlockSpec((tm,), lambda i, *_: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((mp,), x.dtype),
        interpret=interpret,
    )(indptr, rows, indices, data, x)
    return y[:m]
