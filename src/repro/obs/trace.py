"""Zero-dep structured tracing: spans, events, Chrome/Perfetto export.

Every runtime decision the system makes — format selection, switch
planning, kernel routing, distributed builds — was previously invisible
outside ad-hoc prints. This module makes them observable at near-zero
cost:

* ``span("plan.switch", fmt="ELL")`` is a context manager that times a
  region and records it (name, wall time, thread, parent span, attrs)
  into a bounded thread-safe ring buffer.
* The ``REPRO_TRACE`` environment variable gates everything:

    - ``off``      (default) ``span()`` returns a shared no-op object —
                   the hot path costs one global-load + one branch.
    - ``summary``  spans are timed and folded into per-name aggregates
                   (count/total/min/max); no per-event storage.
    - ``full``     aggregates *plus* the event ring buffer, exportable
                   to ``trace.json`` (Chrome ``chrome://tracing`` /
                   Perfetto ``ui.perfetto.dev``) via :func:`export_chrome`.

* Timing is **device-sync aware**: JAX dispatch is asynchronous, so a
  span wrapping ``y = f(x)`` would otherwise measure only the dispatch.
  Register the result with ``sp.sync(y)`` and the span calls
  ``jax.block_until_ready`` *once, at span close* — never on the
  untraced path, and never anywhere else in the span body.

The tracer is importable with zero heavy dependencies: ``jax`` is only
imported lazily inside the sync handling of an *active* span.

Span-name taxonomy (the first dotted component is the phase the report
attributes time to — see ``repro.obs.report``):

    select.*    FormatPolicy decisions (``select.policy``, ``select.batch``)
    plan.*      symbolic phases (``plan.switch``, ``plan.partition``, ...)
    convert.*   numeric conversion phases
    kernel.*    kernel routing / tile-config decisions
    exchange.*  halo-exchange issue points (trace-time markers)
    solver.*    solve wall time (``solver.solve``, ``solver.cg`` traces)
    build.*     composite build phases (``build.dist``, ``build.mg_level``)
    mg.*        V-cycle structure (``mg.vcycle`` per level)
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
import warnings
from typing import Dict, List, Optional

from repro.obs import metrics as _metrics

ENV_VAR = "REPRO_TRACE"
MODES = ("off", "summary", "full")

# Ring-buffer capacity (events). Old events are overwritten, newest win.
RING_CAPACITY = 65536

_LOCK = threading.Lock()
_MODE: Optional[str] = None      # lazily resolved from $REPRO_TRACE
_IDS = itertools.count(1)
_T0 = time.perf_counter_ns()     # trace epoch: ts fields are relative us

# ring buffer of finished events (dicts); _RING_POS wraps at capacity
_RING: List[dict] = []
_RING_POS = 0
_DROPPED = 0

# per-name aggregates: name -> [count, total_us, min_us, max_us]
_AGG: Dict[str, list] = {}

_TLS = threading.local()         # .stack: list of open span ids


def _resolve_mode() -> str:
    v = os.environ.get(ENV_VAR, "off").strip().lower() or "off"
    return v if v in MODES else "off"


def mode() -> str:
    """Effective trace mode (cached; first call reads ``$REPRO_TRACE``)."""
    global _MODE
    m = _MODE
    if m is None:
        m = _MODE = _resolve_mode()
    return m


def enabled() -> bool:
    return mode() != "off"


def set_mode(m: str) -> None:
    """Override the env-derived mode (tests / embedding callers)."""
    global _MODE
    if m not in MODES:
        raise ValueError(f"trace mode {m!r} not in {MODES}")
    _MODE = m


class tracing:
    """``with tracing("full"): ...`` — scoped mode override (restores the
    previous mode on exit; does not clear collected data)."""

    def __init__(self, m: str):
        if m not in MODES:
            raise ValueError(f"trace mode {m!r} not in {MODES}")
        self._m = m
        self._prev: Optional[str] = None

    def __enter__(self):
        self._prev = mode()
        set_mode(self._m)
        return self

    def __exit__(self, *exc):
        set_mode(self._prev)
        return False


def _stack() -> list:
    s = getattr(_TLS, "stack", None)
    if s is None:
        s = _TLS.stack = []
    return s


def _record(ev: dict) -> None:
    global _RING_POS, _DROPPED
    with _LOCK:
        a = _AGG.setdefault(ev["name"], [0, 0.0, float("inf"), 0.0])
        dur = ev["dur"]
        a[0] += 1
        a[1] += dur
        a[2] = min(a[2], dur)
        a[3] = max(a[3], dur)
        if mode() == "full":
            if len(_RING) < RING_CAPACITY:
                _RING.append(ev)
            else:
                _RING[_RING_POS % RING_CAPACITY] = ev
                _DROPPED += 1
                _RING_POS += 1
                # surfaced outside the trace itself: a full-mode run that
                # silently wrapped used to look complete in every export.
                _metrics.inc("trace.dropped_events")


class _Span:
    """An active span. Use via :func:`span`; not constructed directly."""

    __slots__ = ("name", "attrs", "id", "parent", "tid", "_t0", "_sync")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.id = next(_IDS)
        self.tid = threading.get_ident()
        self._sync: list = []
        self.parent = None
        self._t0 = 0

    def sync(self, *values) -> "_Span":
        """Register values to ``jax.block_until_ready`` at span close, so
        the span measures execution, not async dispatch. Chainable."""
        self._sync.extend(values)
        return self

    def set(self, **attrs) -> "_Span":
        """Attach/overwrite span attributes (e.g. the decision made)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        st = _stack()
        self.parent = st[-1].id if st else None
        st.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        if self._sync:
            import jax  # lazy: the tracer itself is zero-dep

            jax.block_until_ready(self._sync)
        t1 = time.perf_counter_ns()
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        else:  # tolerate mispaired exits rather than corrupting the stack
            try:
                st.remove(self)
            except ValueError:
                pass
        _record({"name": self.name, "ts": (self._t0 - _T0) / 1e3,
                 "dur": (t1 - self._t0) / 1e3, "tid": self.tid,
                 "id": self.id, "parent": self.parent,
                 "args": self.attrs})
        return False


class _NullSpan:
    """The off-mode span: every operation is a no-op."""

    __slots__ = ()

    def sync(self, *values):
        return self

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


def span(name: str, **attrs):
    """Open a traced span. Off mode returns a shared no-op object."""
    m = _MODE
    if m is None:
        m = mode()
    if m == "off":
        return _NULL
    return _Span(name, attrs)


def event(name: str, **attrs) -> None:
    """Record an instantaneous event (zero duration, current parent)."""
    m = _MODE
    if m is None:
        m = mode()
    if m == "off":
        return
    st = _stack()
    _record({"name": name, "ts": (time.perf_counter_ns() - _T0) / 1e3,
             "dur": 0.0, "tid": threading.get_ident(), "id": next(_IDS),
             "parent": st[-1].id if st else None, "args": attrs})


# ---------------------------------------------------------------------------
# Introspection / export
# ---------------------------------------------------------------------------


def events() -> List[dict]:
    """Snapshot of the ring buffer, oldest first (full mode only)."""
    with _LOCK:
        if len(_RING) < RING_CAPACITY:
            return list(_RING)
        p = _RING_POS % RING_CAPACITY
        return _RING[p:] + _RING[:p]


def aggregate() -> Dict[str, dict]:
    """Per-span-name stats: {name: {count, total_us, min_us, max_us, mean_us}}."""
    with _LOCK:
        return {name: {"count": a[0], "total_us": a[1], "min_us": a[2],
                       "max_us": a[3], "mean_us": a[1] / max(1, a[0])}
                for name, a in _AGG.items()}


def dropped() -> int:
    """Events overwritten because the ring buffer wrapped."""
    return _DROPPED


def clear() -> None:
    """Drop all collected events and aggregates (mode is unchanged)."""
    global _RING_POS, _DROPPED, _TRUNCATION_WARNED
    with _LOCK:
        _RING.clear()
        _RING_POS = 0
        _DROPPED = 0
        _TRUNCATION_WARNED = False
        _AGG.clear()


def summary(sort_by: str = "total_us") -> str:
    """Human-readable per-span-name table of the collected aggregates."""
    agg = aggregate()
    if not agg:
        return "(trace empty)"
    rows = sorted(agg.items(), key=lambda kv: -kv[1].get(sort_by, 0.0))
    w = max(len("span"), max(len(n) for n, _ in rows))
    out = [f"{'span':<{w}}  {'count':>6}  {'total_ms':>9}  {'mean_us':>9}  "
           f"{'max_us':>9}",
           "-" * (w + 40)]
    for name, s in rows:
        out.append(f"{name:<{w}}  {s['count']:>6}  "
                   f"{s['total_us'] / 1e3:>9.2f}  {s['mean_us']:>9.1f}  "
                   f"{s['max_us']:>9.1f}")
    return "\n".join(out)


_TRUNCATION_WARNED = False


def export_chrome(path: str) -> str:
    """Write the ring buffer as a Chrome/Perfetto ``trace.json``.

    Open with ``chrome://tracing`` or https://ui.perfetto.dev. Span attrs
    land in ``args``; the span/parent ids ride along for programmatic
    consumers (``repro.obs.report`` reads them back). When the ring
    wrapped the export only holds the newest ``RING_CAPACITY`` events —
    warned once per process (and recorded in the doc's
    ``otherData.dropped_events`` and the ``trace.dropped_events``
    counter) so a truncated trace is never mistaken for a complete one.
    """
    global _TRUNCATION_WARNED
    evs = events()
    if _DROPPED and not _TRUNCATION_WARNED:
        _TRUNCATION_WARNED = True
        warnings.warn(
            f"trace ring wrapped: export is truncated to the newest "
            f"{RING_CAPACITY} events ({_DROPPED} older events dropped — "
            f"see the trace.dropped_events counter)", RuntimeWarning,
            stacklevel=2)
    out = []
    for e in evs:
        out.append({"name": e["name"], "ph": "X", "cat": e["name"].split(".")[0],
                    "ts": e["ts"], "dur": max(e["dur"], 0.001),
                    "pid": 0, "tid": e["tid"],
                    "args": {**e["args"], "span_id": e["id"],
                             "parent_id": e["parent"]}})
    doc = {"traceEvents": out, "displayTimeUnit": "ms",
           "otherData": {"dropped_events": _DROPPED}}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
