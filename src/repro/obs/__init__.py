"""repro.obs — structured tracing, counters, and per-phase profiling.

Three small, zero-heavy-dep pieces:

* :mod:`repro.obs.trace`   — ``span()``/``event()`` tracer gated by
  ``REPRO_TRACE=off|summary|full``, Chrome/Perfetto export, ``summary()``.
* :mod:`repro.obs.metrics` — named monotonic counters + histograms with
  ``snapshot()``/``reset()`` and order-independent ``scope()`` deltas.
* :mod:`repro.obs.report`  — per-phase attribution tables
  (select/plan/convert/kernel/exchange/solver) from a live or exported
  trace, plus the distributed exchange-overlap table from
  ``BENCH_obs.json``. CLI: ``python -m repro.obs.report``.

:func:`repro.obs.provenance.env_info` records run provenance (jax
version, backend, devices, git rev) in every ``BENCH_*.json``.
"""
from repro.obs import metrics
from repro.obs import trace
from repro.obs.provenance import env_info
from repro.obs.trace import event, span, tracing

__all__ = ["metrics", "trace", "span", "event", "tracing", "env_info"]
