"""repro.obs — the perf flight recorder: tracing, metrics, decisions.

Small, zero-heavy-dep pieces:

* :mod:`repro.obs.trace`   — ``span()``/``event()`` tracer gated by
  ``REPRO_TRACE=off|summary|full``, Chrome/Perfetto export, ``summary()``.
* :mod:`repro.obs.metrics` — named monotonic counters, gauges, and
  fixed-bucket histograms (p50/p95/p99 via :func:`metrics.quantile`) with
  ``snapshot()``/``reset()`` and order-independent ``scope()`` deltas.
* :mod:`repro.obs.ledger`  — bounded ring of structured decision records
  (format selections with CART paths, kernel-route vetoes, switch plans,
  serving requests), gated by ``REPRO_LEDGER`` (on by default).
* :mod:`repro.obs.explain` — replays the ledger into a human-readable
  decision trail. CLI: ``python -m repro.obs.explain``.
* :mod:`repro.obs.regress` — bench-trajectory store + noise-aware
  baseline regression gate. CLI: ``python -m repro.obs.regress``.
* :mod:`repro.obs.report`  — per-phase attribution tables
  (select/plan/convert/kernel/exchange/solver) from a live or exported
  trace, plus the distributed exchange-overlap table from
  ``BENCH_obs.json``. CLI: ``python -m repro.obs.report``.

:func:`repro.obs.provenance.env_info` records run provenance (jax
version, backend, devices, git rev) in every ``BENCH_*.json``.
"""
from repro.obs import ledger
from repro.obs import metrics
from repro.obs import trace
from repro.obs.provenance import env_info
from repro.obs.trace import event, span, tracing

__all__ = ["ledger", "metrics", "trace", "span", "event", "tracing",
           "env_info"]
