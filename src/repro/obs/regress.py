"""Bench-trajectory regression harness: the perf flight recorder's gate.

The six ``BENCH_*.json`` artifacts are point-in-time snapshots; this
module gives them a *trajectory* and a *gate*:

* **History store** — every ``benchmarks/run.py`` invocation appends the
  rows it just measured (with their ``env_info()`` provenance) as one
  JSONL line per artifact to ``results/history/trajectory.jsonl``, so
  perf over PRs is a first-class record, not an archaeology project
  (``make_experiments_md`` renders it as the trajectory table).
* **Baseline compare** — ``python -m repro.obs.regress`` compares the
  current ``BENCH_*.json`` files against a blessed baseline
  (``results/baseline.json``) with *noise-aware per-row tolerance
  classes*: best-of-iters wall times are jittery on shared CI hosts, so
  raw ``us_per_call`` rows get a wide band, while ``speedup_vs_ref``
  rows — ratios of two timings from the *same* run, where host noise
  largely cancels — get a tighter band plus a win-flip rule. Decision
  rows (us == 0) and rows missing from the baseline are informational,
  never failures. Exit status is the gate: nonzero iff any row regressed.
* **Environment guard** — timings from a different device/backend/
  interpret-mode are not comparable; when the baseline's environment
  fingerprint differs from the current one, timing comparisons are
  downgraded to informational with a loud note (CI blesses its own
  same-machine baseline before gating; the committed baseline serves
  same-machine development runs).

Tolerance classes (``classify``):

    speedup     derived carries ``speedup_vs_ref`` (or ``*_vs_csr``):
                fail if current < baseline * (1 - 0.45), or a clear win
                (>= 1.3x) flipped to a clear loss (< 0.95x).
    throughput  derived carries ``tok_per_s``: fail below
                baseline * (1 - 0.45). Higher is better.
    time        raw ``us_per_call`` > 0: fail above
                baseline * (1 + 0.75).
    info        decision rows (us == 0): derived changes are notes only.

Baseline workflow: ``--bless`` rewrites the baseline from the current
artifacts — run it after a *legitimate* perf change lands, commit the
new ``results/baseline.json`` with the PR that caused it, and the report
becomes the PR's perf changelog.

CLI::

    python -m repro.obs.regress                       # gate cwd vs baseline
    python -m repro.obs.regress --report regress.md   # + markdown report
    python -m repro.obs.regress --bless               # re-bless baseline
    python -m repro.obs.regress --inject-slowdown format_CSR_n512:2.0
                                                      # gate self-test
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

ARTIFACTS = ("BENCH_spmv", "BENCH_convert", "BENCH_dist", "BENCH_hpcg",
             "BENCH_obs", "BENCH_serve")

DEFAULT_BASELINE = os.path.join("results", "baseline.json")
DEFAULT_HISTORY = os.path.join("results", "history")
HISTORY_FILE = "trajectory.jsonl"

# Noise-aware tolerance bands per row class (see module docstring).
# Calibrated against measured back-to-back --quick runs on a loaded CPU
# container: interpret-mode speedup rows wobble up to ~40% run-to-run,
# so the band sits at 45% — wide enough for that noise, tight enough
# that a genuine 2x slowdown (ratio 0.50 < 0.55) still fails the gate.
TOL = {"speedup": 0.45, "throughput": 0.45, "time": 0.75}
# A clear win (>= FLIP_WIN x) that becomes a clear loss (< FLIP_LOSS x)
# is a regression even inside the relative band — the paper's headline
# numbers are exactly these flips. FLIP_WIN sits above the ~1.1-1.2x
# zone where marginal kernels land on either side of 1.0 by luck.
FLIP_WIN, FLIP_LOSS = 1.30, 0.95

# env_info() fields that decide whether two timings are comparable.
ENV_COMPARE_KEYS = ("backend", "device_kind", "interpret_mode")


def parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived fields -> dict (floats where possible)."""
    out = {}
    for part in str(derived or "").split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def classify(row: dict) -> Tuple[str, float]:
    """Tolerance class and the comparable value for one bench row."""
    d = parse_derived(row.get("derived", ""))
    for key in ("speedup_vs_ref", "speedup_vs_csr", "speedup_vs_csr_ref"):
        if isinstance(d.get(key), float):
            return "speedup", d[key]
    if isinstance(d.get("tok_per_s"), float):
        return "throughput", d["tok_per_s"]
    us = float(row.get("us_per_call", 0) or 0)
    if us > 0:
        return "time", us
    return "info", 0.0


# ---------------------------------------------------------------------------
# Row comparison
# ---------------------------------------------------------------------------


def compare_row(name: str, base: Optional[dict], cur: Optional[dict],
                enforce: bool = True) -> dict:
    """Compare one row; returns a finding dict with ``status`` in
    ``ok | regression | improved | new | missing | info``."""
    if cur is None:
        return {"name": name, "cls": "info", "status": "missing",
                "note": "row present in baseline but absent from this run"}
    cls, cur_v = classify(cur)
    if base is None:
        return {"name": name, "cls": cls, "status": "new", "current": cur_v,
                "note": "no baseline row — informational"}
    bcls, base_v = classify(base)
    if cls != bcls:
        return {"name": name, "cls": cls, "status": "info",
                "baseline": base_v, "current": cur_v,
                "note": f"metric class changed ({bcls} -> {cls})"}
    if cls == "info":
        note = None
        if str(base.get("derived", "")) != str(cur.get("derived", "")):
            note = (f"decision changed: {base.get('derived', '')!r} -> "
                    f"{cur.get('derived', '')!r}")
        return {"name": name, "cls": cls, "status": "info", "note": note}

    tol = TOL[cls]
    ratio = cur_v / base_v if base_v else float("inf")
    finding = {"name": name, "cls": cls, "baseline": base_v,
               "current": cur_v, "ratio": ratio}
    if cls == "time":
        bad = cur_v > base_v * (1 + tol)
        better = cur_v < base_v * (1 - tol)
        why = f"{cur_v:.0f}us vs baseline {base_v:.0f}us (x{ratio:.2f})"
    else:  # speedup / throughput: higher is better
        bad = cur_v < base_v * (1 - tol)
        if cls == "speedup" and base_v >= FLIP_WIN and cur_v < FLIP_LOSS:
            bad = True
            finding["note"] = (f"win flipped to loss: {base_v:.2f}x -> "
                               f"{cur_v:.2f}x vs ref")
        better = cur_v > base_v * (1 + tol)
        why = f"{cur_v:.2f} vs baseline {base_v:.2f} (x{ratio:.2f})"
    finding.setdefault("note", why)
    if bad:
        finding["status"] = "regression" if enforce else "info"
        if not enforce:
            finding["note"] = f"[env mismatch, not enforced] {finding['note']}"
    elif better:
        finding["status"] = "improved"
    else:
        finding["status"] = "ok"
    return finding


def env_matches(base_env: Optional[dict], cur_env: Optional[dict]) -> bool:
    """Are two env_info() fingerprints timing-comparable?"""
    if not base_env or not cur_env:
        return False
    return all(base_env.get(k) == cur_env.get(k) for k in ENV_COMPARE_KEYS)


def load_artifact(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def compare(baseline: dict, json_dir: str = ".",
            current_env: Optional[dict] = None,
            inject: Optional[Dict[str, float]] = None) -> List[dict]:
    """Compare every current ``BENCH_*.json`` under ``json_dir`` against
    the blessed ``baseline`` doc; returns findings, regressions first.

    ``inject`` maps row name -> slowdown factor applied to the current
    value before comparing (the gate's self-test: an injected 2x slowdown
    MUST come back as a regression)."""
    findings: List[dict] = []
    arts = baseline.get("artifacts", {})
    for art in ARTIFACTS:
        cur_doc = load_artifact(os.path.join(json_dir, f"{art}.json"))
        base_art = arts.get(art)
        if cur_doc is None and base_art is None:
            continue
        base_rows = dict(base_art.get("rows", {})) if base_art else {}
        cur_rows = {r["name"]: dict(r)
                    for r in (cur_doc or {}).get("rows", [])}
        if inject:
            for name, factor in inject.items():
                if name in cur_rows:
                    cur_rows[name] = _inject_slowdown(cur_rows[name], factor)
        env = (cur_doc or {}).get("meta", {}).get("env") or current_env
        enforce = env_matches(base_art.get("env") if base_art else None, env)
        for name in sorted(set(base_rows) | set(cur_rows)):
            f = compare_row(name, base_rows.get(name), cur_rows.get(name),
                            enforce=enforce or base_art is None)
            f["artifact"] = art
            if not enforce and base_art is not None and f["status"] == "ok":
                f["note"] = "[env mismatch, not enforced] " + str(
                    f.get("note") or "")
            findings.append(f)
    order = {"regression": 0, "improved": 1, "new": 2, "missing": 3,
             "info": 4, "ok": 5}
    findings.sort(key=lambda f: (order.get(f["status"], 9), f["name"]))
    return findings


def _inject_slowdown(row: dict, factor: float) -> dict:
    """Apply a synthetic slowdown to a row (gate self-test only): times
    get slower by ``factor``, ratios/throughput worse by ``factor``."""
    row = dict(row)
    d = parse_derived(row.get("derived", ""))
    parts = []
    for k, v in d.items():
        if k.startswith("speedup_vs") or k == "tok_per_s":
            v = float(v) / factor
        parts.append(f"{k}={v}")
    if parts:
        row["derived"] = ";".join(parts)
    row["us_per_call"] = float(row.get("us_per_call", 0) or 0) * factor
    return row


# ---------------------------------------------------------------------------
# Baseline bless / load
# ---------------------------------------------------------------------------


def bless(json_dir: str = ".", baseline_path: str = DEFAULT_BASELINE) -> dict:
    """Write the current artifacts as the new blessed baseline."""
    doc = {"blessed_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
           "artifacts": {}}
    for art in ARTIFACTS:
        cur = load_artifact(os.path.join(json_dir, f"{art}.json"))
        if cur is None:
            continue
        doc["artifacts"][art] = {
            "env": cur.get("meta", {}).get("env"),
            "rows": {r["name"]: r for r in cur.get("rows", [])},
        }
    if not doc["artifacts"]:
        raise SystemExit(f"nothing to bless: no BENCH_*.json under "
                         f"{os.path.abspath(json_dir)}")
    d = os.path.dirname(baseline_path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{baseline_path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, baseline_path)
    return doc


def load_baseline(path: str = DEFAULT_BASELINE) -> Optional[dict]:
    return load_artifact(path)


# ---------------------------------------------------------------------------
# History store (results/history/trajectory.jsonl)
# ---------------------------------------------------------------------------


def append_history(artifact: str, rows, meta: dict,
                   history_dir: str = DEFAULT_HISTORY) -> str:
    """Append one run's rows for ``artifact`` as a JSONL trajectory entry.

    ``rows`` are the bench harness's (name, us, derived) triples — only
    the rows *this* run measured, not the merged artifact, so the
    trajectory records what actually ran."""
    os.makedirs(history_dir, exist_ok=True)
    path = os.path.join(history_dir, HISTORY_FILE)
    entry = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
             "artifact": artifact,
             "git_rev": (meta.get("env") or {}).get("git_rev"),
             "env": {k: (meta.get("env") or {}).get(k)
                     for k in ENV_COMPARE_KEYS},
             "rows": [{"name": str(n), "us_per_call": float(us),
                       "derived": str(der)} for n, us, der in rows]}
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return path


def load_history(history_dir: str = DEFAULT_HISTORY) -> List[dict]:
    """All trajectory entries, oldest first (empty when no history)."""
    path = os.path.join(history_dir, HISTORY_FILE)
    out = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except ValueError:
                    continue
    except OSError:
        pass
    return out


# ---------------------------------------------------------------------------
# Report rendering
# ---------------------------------------------------------------------------


def render_markdown(findings: List[dict], baseline_path: str) -> str:
    """The regression report: regressions first, then the rest."""
    n_reg = sum(1 for f in findings if f["status"] == "regression")
    n_imp = sum(1 for f in findings if f["status"] == "improved")
    n_new = sum(1 for f in findings if f["status"] == "new")
    n_ok = sum(1 for f in findings if f["status"] == "ok")
    out = ["# Perf regression report",
           "",
           f"Baseline: `{baseline_path}` — "
           f"**{n_reg} regression(s)**, {n_imp} improved, {n_new} new, "
           f"{n_ok} within tolerance.",
           ""]
    if n_reg:
        out += ["## Regressions", "",
                "| row | artifact | class | baseline | current | note |",
                "|---|---|---|---|---|---|"]
        for f in findings:
            if f["status"] != "regression":
                continue
            out.append(f"| `{f['name']}` | {f['artifact']} | {f['cls']} "
                       f"| {f.get('baseline', '-'):.4g} "
                       f"| {f.get('current', '-'):.4g} "
                       f"| {f.get('note') or ''} |")
        out.append("")
    notable = [f for f in findings
               if f["status"] in ("improved", "new", "missing")
               or (f["status"] == "info" and f.get("note"))]
    if notable:
        out += ["## Notable (non-gating)", "",
                "| row | artifact | status | note |",
                "|---|---|---|---|"]
        for f in notable:
            out.append(f"| `{f['name']}` | {f['artifact']} | {f['status']} "
                       f"| {f.get('note') or ''} |")
        out.append("")
    out.append(f"Tolerances: speedup ±{TOL['speedup']:.0%} (+ win-flip "
               f"rule {FLIP_WIN}x -> <{FLIP_LOSS}x), throughput "
               f"-{TOL['throughput']:.0%}, raw time +{TOL['time']:.0%}; "
               "decision/new/missing rows are informational. Timing rows "
               "are only enforced when the baseline's environment "
               f"fingerprint ({', '.join(ENV_COMPARE_KEYS)}) matches.")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="Compare current BENCH_*.json against the blessed "
                    "baseline; exit nonzero on regression")
    p.add_argument("--json-dir", default=".",
                   help="where the current BENCH_*.json files live")
    p.add_argument("--baseline", default=DEFAULT_BASELINE)
    p.add_argument("--report", default=None,
                   help="write the markdown report here")
    p.add_argument("--bless", action="store_true",
                   help="rewrite the baseline from the current artifacts "
                        "(the legitimate-perf-change workflow) and exit")
    p.add_argument("--inject-slowdown", default=None, metavar="NAME:FACTOR",
                   help="gate self-test: pretend row NAME measured "
                        "FACTOR x slower and verify the gate catches it")
    p.add_argument("--json", action="store_true",
                   help="emit findings as JSON instead of the table")
    args = p.parse_args(argv)

    if args.bless:
        doc = bless(args.json_dir, args.baseline)
        rows = sum(len(a["rows"]) for a in doc["artifacts"].values())
        print(f"blessed {len(doc['artifacts'])} artifact(s), {rows} rows "
              f"-> {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    if baseline is None:
        print(f"no baseline at {args.baseline} — run with --bless first "
              "(nothing to gate against; exiting 0)", file=sys.stderr)
        return 0
    inject = None
    if args.inject_slowdown:
        name, _, factor = args.inject_slowdown.rpartition(":")
        inject = {name: float(factor)}
    findings = compare(baseline, json_dir=args.json_dir, inject=inject)
    report = render_markdown(findings, args.baseline)
    if args.report:
        d = os.path.dirname(args.report)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.report, "w") as f:
            f.write(report + "\n")
    try:
        if args.json:
            print(json.dumps(findings, indent=1, default=str))
        else:
            print(report)
    except BrokenPipeError:
        # downstream `head`/`grep -q` closed the pipe — the exit code
        # (the gate verdict) is the contract, not the stdout rendering
        sys.stderr.close()
        return 1 if any(f["status"] == "regression" for f in findings) else 0
    regressions = [f for f in findings if f["status"] == "regression"]
    if regressions:
        print(f"\nREGRESSION: {len(regressions)} row(s) failed the gate: "
              + ", ".join(f["name"] for f in regressions), file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
