"""Replay the decision ledger: "why did the policy pick that?".

Renders :mod:`repro.obs.ledger` records — live ring or a ``dump_json``
file — into a human-readable account of every format selection (feature
vector, the CART tree path actually taken, candidate scores, cache
hit/miss, pinned kernel decision), kernel route (cfg incl. SELL (c, σ)
geometry, measured speedup, veto reason), switch plan, and serving
request.

CLI::

    python -m repro.obs.explain                 # demo: select + tune +
                                                # route a power-law matrix,
                                                # then replay the ledger
    python -m repro.obs.explain --family stencil27 --seed 3
    python -m repro.obs.explain ledger.json     # replay a dump_json file
    python -m repro.obs.explain --kind kernel.route --last 5
    python -m repro.obs.explain --dump ledger.json   # also write the dump

The demo answers the ROADMAP question in one command: build a matrix,
let ``FormatPolicy`` (cached mode) pick its format, tune its kernel,
route through ``kernel_route``, and print the full decision trail.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Optional

from repro.obs import ledger


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------


def _ts(rec: dict) -> str:
    try:
        return time.strftime("%H:%M:%S", time.localtime(float(rec["ts"])))
    except (KeyError, ValueError, OSError):
        return "--:--:--"


def _fmt_us(v) -> str:
    try:
        return f"{float(v):.1f}us"
    except (TypeError, ValueError):
        return "?"


def _cfg_str(cfg) -> str:
    if not cfg:
        return "-"
    return "/".join(f"{k}={v}" for k, v in sorted(cfg.items()))


def _render_tree_path(path: List[dict], indent: str = "    ") -> List[str]:
    out = [f"{indent}CART path:"]
    for step in path:
        if step.get("leaf"):
            out.append(f"{indent}  leaf[{step['node']}] -> "
                       f"{step.get('predict_name', step.get('predict'))}")
        else:
            op = "<=" if step["went"] == "left" else ">"
            out.append(f"{indent}  node[{step['node']}] "
                       f"{step['feature']} = {step['value']:.4g} {op} "
                       f"{step['thresh']:.4g} -> {step['went']}")
    return out


def _render_kernel(k: dict, indent: str = "    ") -> str:
    return (f"{indent}kernel record: {k.get('fmt')}/{k.get('op')} "
            f"cfg[{_cfg_str(k.get('cfg'))}] "
            f"{_fmt_us(k.get('kernel_us'))} vs ref "
            f"{_fmt_us(k.get('ref_us'))} "
            f"({float(k.get('speedup', 0)):.2f}x)")


def render_record(rec: dict, verbose: bool = True) -> str:
    """One ledger record -> a multi-line human-readable block."""
    kind = rec.get("kind", "?")
    head = f"[#{rec.get('seq', '?')} {_ts(rec)}] {kind}"
    lines = []
    if kind == "format.select":
        ncols = rec.get("ncols")
        width = f" b={ncols}" if ncols else ""
        lines.append(f"{head} mode={rec.get('mode')} op={rec.get('op')}"
                     f"{width} -> {rec.get('chosen')} "
                     f"(tier={rec.get('tier')}, "
                     f"backend={rec.get('backend') or 'auto'})")
        if rec.get("cache"):
            lines.append(f"    cache: {rec['cache']}")
        if verbose and rec.get("features"):
            feats = rec["features"]
            pairs = [f"{k}={v:.4g}" for k, v in feats.items()]
            for i in range(0, len(pairs), 5):
                prefix = "    features: " if i == 0 else "              "
                lines.append(prefix + " ".join(pairs[i:i + 5]))
        if rec.get("tree_path"):
            lines += _render_tree_path(rec["tree_path"])
        if rec.get("tree_rejected"):
            lines.append(f"    tree pick rejected: {rec['tree_rejected']}")
        if rec.get("scores"):
            pairs = " ".join(f"{k}={v:.3e}" for k, v in rec["scores"].items())
            lines.append(f"    candidate scores (s): {pairs}")
        if rec.get("cfg"):
            lines.append(f"    pinned cfg: {_cfg_str(rec['cfg'])}")
        if rec.get("kernel"):
            lines.append(_render_kernel(rec["kernel"]))
        if rec.get("kernel_veto"):
            lines.append(f"    veto: {rec['kernel_veto']}")
    elif kind == "format.select_batch":
        lines.append(f"{head} mode={rec.get('mode')} parts={rec.get('parts')}"
                     f" -> {rec.get('chosen_counts')}")
    elif kind == "kernel.route":
        lines.append(f"{head} op={rec.get('op')} fmt={rec.get('fmt')} -> "
                     f"{rec.get('route')}")
        if rec.get("kernel"):
            lines.append(_render_kernel(rec["kernel"]))
        if rec.get("reason"):
            lines.append(f"    reason: {rec['reason']}")
        if rec.get("bucket"):
            lines.append(f"    bucket: {rec['bucket']}")
    elif kind == "plan.switch":
        lines.append(f"{head} -> {rec.get('fmt')} "
                     f"hints[{_cfg_str(rec.get('hints'))}]"
                     + (f" geometry from {rec['geometry_source']}"
                        if rec.get("geometry_source") else ""))
    elif kind == "serve.request":
        lines.append(f"{head} rid={rec.get('rid')} "
                     f"queue={_fmt_us(rec.get('queue_us'))} "
                     f"prefill={_fmt_us(rec.get('prefill_us'))} "
                     f"decode={_fmt_us(rec.get('decode_us'))} "
                     f"total={_fmt_us(rec.get('total_us'))} "
                     f"tokens={rec.get('tokens')}")
    else:
        extra = {k: v for k, v in rec.items()
                 if k not in ("seq", "ts", "kind")}
        lines.append(f"{head} {json.dumps(extra, default=str)}")
    return "\n".join(lines)


def render(records: List[dict], verbose: bool = True) -> str:
    if not records:
        return ("(ledger empty — run a selection with REPRO_LEDGER=on, or "
                "use the --family demo)")
    return "\n".join(render_record(r, verbose=verbose) for r in records)


# ---------------------------------------------------------------------------
# Demo: one matrix through the whole decision stack
# ---------------------------------------------------------------------------


def run_demo(family: str = "powerlaw", seed: int = 7,
             tune_iters: int = 2) -> None:
    """Build a matrix, select, plan, tune, and route — filling the ledger
    so the replay shows the complete decision trail for one operand."""
    import os
    import tempfile

    import jax.numpy as jnp
    import numpy as np

    from repro.core import convert_execute, ops as core_ops
    from repro.tuning import SelectionCache, kernel_tune
    from repro.tuning.corpus import make_matrix
    from repro.tuning.policy import FormatPolicy

    coo = make_matrix(family, np.random.default_rng(seed))
    with tempfile.TemporaryDirectory() as td:
        kcache = SelectionCache(os.path.join(td, "kernels.json"))
        policy = FormatPolicy("cached", cache=kcache)
        rep = policy.select(coo)                     # format.select record
        plan = policy.plan_for(coo, fmt=rep.best)    # plan.switch record
        A = convert_execute(coo, plan)
        kernel_tune.tune_kernel(
            A, cache=kcache, grid=kernel_tune.default_grid(A, smoke=True),
            iters=tune_iters, inner=1)
        # the measured auto route (+ kernel.route record, veto or pallas)
        backend, _ = core_ops.kernel_route(A, cache=kcache)
        x = jnp.ones((A.shape[1],), A.dtype)
        core_ops.spmv(A, x, backend=backend)
        # a second select now hits the cache — the hit is its own record
        policy.select(coo)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        description="Replay the repro.obs decision ledger")
    p.add_argument("ledger_file", nargs="?", default=None,
                   help="a ledger.dump_json file to replay (default: run "
                        "the --family demo and replay the live ring)")
    p.add_argument("--family", default="powerlaw",
                   help="demo matrix family (corpus.FAMILIES; default "
                        "powerlaw — the SELL-C-sigma regime)")
    p.add_argument("--seed", type=int, default=7)
    p.add_argument("--kind", default=None,
                   help="only records of this kind (e.g. kernel.route)")
    p.add_argument("--last", type=int, default=None,
                   help="only the newest N matching records")
    p.add_argument("--dump", default=None,
                   help="also write the ledger as JSON to this path")
    p.add_argument("--json", action="store_true",
                   help="emit raw records as JSON instead of the account")
    p.add_argument("--quiet", action="store_true",
                   help="skip the per-record feature vectors")
    args = p.parse_args(argv)

    if args.ledger_file:
        doc = ledger.load_json(args.ledger_file)
        recs = doc["records"]
        if doc.get("dropped"):
            print(f"(ledger wrapped: {doc['dropped']} older records lost)",
                  file=sys.stderr)
    else:
        ledger.set_enabled(True)
        run_demo(family=args.family, seed=args.seed)
        recs = ledger.records()
    if args.kind:
        recs = [r for r in recs if r.get("kind") == args.kind]
    if args.last:
        recs = recs[-args.last:]
    if args.dump:
        if args.ledger_file:
            with open(args.dump, "w") as f:
                json.dump({"records": recs, "dropped": 0,
                           "capacity": ledger.CAPACITY}, f, indent=1)
        else:
            ledger.dump_json(args.dump)
        print(f"ledger dump written to {args.dump}", file=sys.stderr)
    try:
        if args.json:
            print(json.dumps(recs, indent=1, default=str))
        else:
            print(render(recs, verbose=not args.quiet))
    except BrokenPipeError:
        # downstream `head`/`grep -q` closed the pipe — not an error
        sys.stderr.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
