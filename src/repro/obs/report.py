"""Render traces into per-phase attribution tables.

The span taxonomy (``repro.obs.trace``) prefixes every span with its
phase: ``select.*``, ``plan.*``, ``convert.*``, ``kernel.*``,
``exchange.*``, ``solver.*``, ``build.*``, ``mg.*``. This module folds a
trace (live buffers or an exported ``trace.json``) into the question the
ROADMAP actually asks: *where does the wall time go* — selection,
planning, conversion, kernel routing, exchange, or the solve itself?

Attribution uses **self time**: a span's duration minus its children's,
so ``build.dist`` does not double-count the ``plan.*``/``convert.*``
spans it contains.

The overlap table reads ``BENCH_obs.json`` (``benchmarks/bench_obs.py``,
run via ``python -m benchmarks.run --only obs``): per shard count, the
ghost-mode distributed SpMV decomposed into local-compute wall time,
exchange+remote wall time, and the combined call — the difference is the
overlap XLA's scheduler actually achieved, which is how the p8
regression (``scaling_spmv_ghost_p8`` at 0.78x) is localized.

CLI::

    python -m repro.obs.report trace.json          # phase attribution
    python -m repro.obs.report --bench BENCH_obs.json   # overlap table
    python -m repro.obs.report                     # both, from cwd
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
from typing import Dict, List, Optional

PHASES = ("select", "plan", "convert", "kernel", "exchange", "solver",
          "build", "mg")


def phase_of(name: str) -> str:
    head = name.split(".", 1)[0]
    return head if head in PHASES else "other"


# ---------------------------------------------------------------------------
# Trace loading
# ---------------------------------------------------------------------------


def load_trace(path: str) -> List[dict]:
    """Read an exported Chrome ``trace.json`` back into event dicts."""
    with open(path) as f:
        doc = json.load(f)
    evs = []
    for e in doc.get("traceEvents", []):
        if e.get("ph") != "X":
            continue
        args = dict(e.get("args", {}))
        evs.append({"name": e["name"], "ts": float(e.get("ts", 0.0)),
                    "dur": float(e.get("dur", 0.0)),
                    "tid": e.get("tid", 0),
                    "id": args.pop("span_id", None),
                    "parent": args.pop("parent_id", None),
                    "args": args})
    return evs


def live_events() -> List[dict]:
    from repro.obs import trace
    return trace.events()


# ---------------------------------------------------------------------------
# Phase attribution
# ---------------------------------------------------------------------------


def attribution(events: List[dict]) -> List[dict]:
    """Fold events into per-phase rows sorted by self time, largest first.

    Returns ``[{"phase", "calls", "total_ms", "self_ms", "share"}]``.
    ``share`` is self time over the summed self time of all phases (the
    trace's attributed wall clock).
    """
    self_us: Dict[Optional[int], float] = {}
    for e in events:
        self_us[e["id"]] = e["dur"]
    for e in events:
        p = e.get("parent")
        if p in self_us:
            self_us[p] -= e["dur"]

    rows: Dict[str, dict] = {}
    for e in events:
        ph = phase_of(e["name"])
        r = rows.setdefault(ph, {"phase": ph, "calls": 0, "total_ms": 0.0,
                                 "self_ms": 0.0})
        r["calls"] += 1
        r["total_ms"] += e["dur"] / 1e3
        r["self_ms"] += max(0.0, self_us.get(e["id"], 0.0)) / 1e3
    wall = sum(r["self_ms"] for r in rows.values()) or 1.0
    out = sorted(rows.values(), key=lambda r: -r["self_ms"])
    for r in out:
        r["share"] = r["self_ms"] / wall
    return out


def render_attribution(rows: List[dict]) -> str:
    if not rows:
        return "(no spans recorded — is REPRO_TRACE set?)"
    out = [f"{'phase':<10} {'calls':>7} {'total_ms':>10} {'self_ms':>10} "
           f"{'share':>7}",
           "-" * 48]
    for r in rows:
        out.append(f"{r['phase']:<10} {r['calls']:>7} {r['total_ms']:>10.2f} "
                   f"{r['self_ms']:>10.2f} {r['share']:>6.1%}")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# The p8 overlap table (from BENCH_obs.json)
# ---------------------------------------------------------------------------


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def overlap_rows(doc: dict) -> List[dict]:
    """Extract per-shard-count overlap rows from a BENCH_obs.json doc."""
    rows = []
    for r in doc.get("rows", []):
        m = re.fullmatch(r"obs_overlap_(\w+)_p(\d+)", r["name"])
        if not m:
            continue
        d = _parse_derived(r.get("derived", ""))
        rows.append({"version": m.group(1), "p": int(m.group(2)),
                     "full_us": r["us_per_call"], **d})
    return sorted(rows, key=lambda r: (r["version"], r["p"]))


def render_overlap(rows: List[dict]) -> str:
    if not rows:
        return ("(no obs_overlap rows — run "
                "`python -m benchmarks.run --only obs`)")
    out = [f"{'version':<10} {'P':>3} {'local_us':>9} {'exch_us':>9} "
           f"{'sum_us':>9} {'full_us':>9} {'hidden_us':>10} {'hidden':>7} "
           f"{'overhead':>8}",
           "-" * 81]
    for r in rows:
        loc = r.get("local_us", 0.0)
        exc = r.get("exch_us", 0.0)
        full = r["full_us"]
        if "hidden_frac" in r:  # absent at P=1 (remote part statically empty)
            hidden = loc + exc - full
            denom = min(loc, exc) if min(loc, exc) > 0 else 1.0
            hid = f"{hidden:>10.0f}"
            frac = f"{max(0.0, hidden) / denom:>6.1%}"
            over = f"{max(0.0, -hidden) / denom:>7.1%}"
        else:
            hid, frac, over = f"{'-':>10}", f"{'-':>6}", f"{'-':>7}"
        out.append(f"{r['version']:<10} {r['p']:>3} {loc:>9.0f} {exc:>9.0f} "
                   f"{loc + exc:>9.0f} {full:>9.0f} {hid} {frac} {over}")
    out.append("")
    out.append("hidden_us = local_us + exch_us - full_us (signed): the wall "
               "time the scheduler overlapped.")
    out.append("hidden ~ 100% => exchange fully hidden behind local compute; "
               "0% => nothing hidden; overhead > 0% => composing the phases "
               "costs *more* than running them apart (serialization penalty).")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="Render repro.obs traces into per-phase attribution")
    p.add_argument("trace", nargs="?", default=None,
                   help="exported trace.json (default: ./trace.json if present)")
    p.add_argument("--bench", default=None,
                   help="BENCH_obs.json for the overlap table "
                        "(default: ./BENCH_obs.json if present)")
    p.add_argument("--json", action="store_true",
                   help="emit the attribution rows as JSON instead of a table")
    args = p.parse_args(argv)

    trace_path = args.trace or ("trace.json" if os.path.exists("trace.json")
                                else None)
    bench_path = args.bench or ("BENCH_obs.json"
                                if os.path.exists("BENCH_obs.json") else None)
    printed = False
    if trace_path:
        evs = load_trace(trace_path)
        rows = attribution(evs)
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            print(f"# phase attribution ({trace_path}, {len(evs)} spans)")
            print(render_attribution(rows))
        printed = True
    if bench_path and not args.json:
        try:
            with open(bench_path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
        print(f"\n# exchange/local overlap per shard count ({bench_path})")
        print(render_overlap(overlap_rows(doc)))
        printed = True
    if not printed:
        p.error("nothing to report: no trace.json or BENCH_obs.json found "
                "(pass paths explicitly)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
