"""Named monotonic counters, gauges, and bucketed histograms.

Counters are always on (one dict update under a lock — nanoseconds, and
only ever on host-side decision paths, never inside jitted device code).
They answer the questions the tracer's spans cannot: *how many* times did
each decision go each way over a whole run?

Standard counter names (incremented by the instrumented layers):

    planned_pulls            sanctioned symbolic-phase d2h transfers
                             (``repro.core.convert._planned_pull``)
    selection.cache_hit/.cache_miss
                             SelectionCache decision lookups
    kernel.route.pallas/.ref/.veto
                             ``kernel_route`` outcomes (veto = a record
                             exists but measured slower than ref)
    replan.pattern_sig       memoised DistPlan format plans dropped
                             because the live pattern changed
    halo.bytes               bytes a traced ``dist_spmv`` exchanges per
                             call (recorded at trace time)
    trace.dropped_events     full-mode trace ring overwrites (the export
                             is truncated when this is nonzero)
    serve.requests/.tokens/.format_switch/.retune
                             DecodeEngine / LinearSparse serving events

Standard histogram names (``observe``):

    ell.padding_waste        1 - nnz/(m*k) of each planned ELL layout
    hyb.padding_waste        same for the ELL part of each HYB plan
    sell.padding_waste       1 - nnz/capacity of each planned SELL-C-σ
                             slicing (per-slice widths, post σ-sort)
    serve.latency_us         per-request submit→finish wall time
    serve.queue_us/.prefill_us/.decode_us
                             per-request phase latencies (DecodeEngine)
    serve.queue_depth        pending-queue depth sampled at each refill

Histograms carry **fixed bucket boundaries** (a 1-2-5 geometric series
spanning 1e-3 .. 1e9 by default, ~±25% resolution anywhere in range) so
p50/p95/p99 are reportable via :func:`quantile` without storing raw
samples; :func:`define_histogram` overrides the boundaries per name.
Gauges (:func:`set_gauge`) record last-written values (queue depth).

``snapshot()`` returns a plain dict (JSON-ready); ``scope()`` gives tests
an order-independent view: deltas against the values at scope entry, so
assertions stop depending on what ran earlier in the process.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

_LOCK = threading.Lock()
_COUNTERS: Dict[str, float] = {}
# name -> [count, sum, min, max, bucket_counts]; bucket_counts has
# len(boundaries) + 1 slots (the last one is the overflow bucket).
_HISTS: Dict[str, list] = {}
# name -> boundaries tuple (sorted, ascending); set lazily at first observe
# from DEFAULT_BUCKETS unless define_histogram() registered custom ones.
_BOUNDS: Dict[str, Tuple[float, ...]] = {}
_GAUGES: Dict[str, float] = {}


def _geometric_125(lo_exp: int, hi_exp: int) -> Tuple[float, ...]:
    """1-2-5 series boundaries covering 10**lo_exp .. 10**hi_exp."""
    out = []
    for e in range(lo_exp, hi_exp + 1):
        for m in (1.0, 2.0, 5.0):
            out.append(m * 10.0 ** e)
    return tuple(out)


# ~±25% quantile resolution from sub-millisecond fractions to 1e9 (covers
# 0..1 waste ratios, microsecond latencies, and multi-second builds alike).
DEFAULT_BUCKETS = _geometric_125(-3, 8)


def define_histogram(name: str, buckets: Sequence[float]) -> None:
    """Register fixed bucket boundaries for histogram ``name``.

    Must be called before the first ``observe`` for the name (an existing
    histogram keeps the boundaries it was created with — re-binning counts
    is impossible without the raw samples)."""
    b = tuple(sorted(float(v) for v in buckets))
    if not b:
        raise ValueError("buckets must be non-empty")
    with _LOCK:
        if name in _HISTS:
            raise ValueError(f"histogram {name!r} already has observations; "
                             "define buckets before the first observe()")
        _BOUNDS[name] = b


def inc(name: str, n: float = 1) -> None:
    """Increment counter ``name`` by ``n`` (created at 0 on first use)."""
    with _LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0) + n


def set_gauge(name: str, value: float) -> None:
    """Set gauge ``name`` to ``value`` (last write wins)."""
    with _LOCK:
        _GAUGES[name] = float(value)


def gauge(name: str, default: float = 0) -> float:
    """Current value of gauge ``name``."""
    with _LOCK:
        return _GAUGES.get(name, default)


def observe(name: str, value: float) -> None:
    """Record ``value`` into histogram ``name`` (count/sum/min/max plus
    its fixed-boundary bucket — quantiles come from the buckets)."""
    v = float(value)
    with _LOCK:
        h = _HISTS.get(name)
        if h is None:
            bounds = _BOUNDS.setdefault(name, DEFAULT_BUCKETS)
            h = _HISTS[name] = [0, 0.0, float("inf"), float("-inf"),
                                [0] * (len(bounds) + 1)]
        h[0] += 1
        h[1] += v
        h[2] = min(h[2], v)
        h[3] = max(h[3], v)
        h[4][bisect.bisect_left(_BOUNDS[name], v)] += 1


def value(name: str, default: float = 0) -> float:
    """Current value of counter ``name``."""
    with _LOCK:
        return _COUNTERS.get(name, default)


def quantile(name: str, q: float) -> Optional[float]:
    """Estimate the ``q``-quantile (0..1) of histogram ``name`` from its
    bucket counts: linear interpolation of rank within the target bucket,
    clamped to the observed [min, max]. None when the histogram is empty.

    Resolution is the bucket width (~±25% with the default 1-2-5 series)
    — the price of never storing raw samples."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile {q} outside [0, 1]")
    with _LOCK:
        h = _HISTS.get(name)
        if h is None or h[0] == 0:
            return None
        count, lo, hi = h[0], h[2], h[3]
        counts = list(h[4])
        bounds = _BOUNDS[name]
    rank = q * (count - 1) + 0.5  # mid-rank convention
    seen = 0.0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if seen + c >= rank:
            # bucket i spans (bounds[i-1], bounds[i]]; the edge buckets
            # are clamped by the observed min/max.
            b_lo = bounds[i - 1] if i > 0 else lo
            b_hi = bounds[i] if i < len(bounds) else hi
            b_lo = max(b_lo, lo)
            b_hi = min(b_hi, hi)
            if b_hi <= b_lo:
                return float(b_lo)
            frac = (rank - seen) / c
            return float(b_lo + frac * (b_hi - b_lo))
        seen += c
    return float(hi)


def quantiles(name: str, qs: Sequence[float] = (0.5, 0.95, 0.99)
              ) -> Dict[str, Optional[float]]:
    """``{"p50": ..., "p95": ..., "p99": ...}`` for the standard cuts."""
    return {f"p{round(q * 100)}": quantile(name, q) for q in qs}


def snapshot() -> dict:
    """JSON-ready snapshot: counters, gauges, and histograms with their
    p50/p95/p99 bucket-estimated quantiles."""
    with _LOCK:
        hist_names = list(_HISTS)
        base = {
            name: {"count": h[0], "sum": h[1], "min": h[2], "max": h[3],
                   "mean": h[1] / max(1, h[0])}
            for name, h in _HISTS.items()}
        counters = dict(_COUNTERS)
        gauges = dict(_GAUGES)
    for name in hist_names:
        base[name].update(quantiles(name))
    return {"counters": counters, "gauges": gauges, "histograms": base}


def reset(names: Optional[Iterable[str]] = None) -> None:
    """Zero counters, gauges, and histograms (all, or just ``names``).
    Custom bucket definitions survive a reset."""
    with _LOCK:
        if names is None:
            _COUNTERS.clear()
            _HISTS.clear()
            _GAUGES.clear()
        else:
            for n in names:
                _COUNTERS.pop(n, None)
                _HISTS.pop(n, None)
                _GAUGES.pop(n, None)


class Scope:
    """Delta view of the counters since scope entry (see :func:`scope`)."""

    def __init__(self):
        with _LOCK:
            self._base = dict(_COUNTERS)

    def delta(self, name: str) -> float:
        """Counter growth since the scope opened."""
        return value(name) - self._base.get(name, 0)

    def deltas(self) -> Dict[str, float]:
        """All counters that moved since the scope opened."""
        with _LOCK:
            cur = dict(_COUNTERS)
        out = {}
        for name, v in cur.items():
            d = v - self._base.get(name, 0)
            if d:
                out[name] = d
        return out

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def scope() -> Scope:
    """``with metrics.scope() as s: ...; s.delta("planned_pulls")``.

    The scope never mutates the global counters, so nested/concurrent
    scopes and unrelated earlier activity cannot perturb each other —
    the fix for order-dependent transfer-count assertions.
    """
    return Scope()
