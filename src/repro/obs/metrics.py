"""Named monotonic counters and histograms for runtime decisions.

Counters are always on (one dict update under a lock — nanoseconds, and
only ever on host-side decision paths, never inside jitted device code).
They answer the questions the tracer's spans cannot: *how many* times did
each decision go each way over a whole run?

Standard counter names (incremented by the instrumented layers):

    planned_pulls            sanctioned symbolic-phase d2h transfers
                             (``repro.core.convert._planned_pull``)
    selection.cache_hit/.cache_miss
                             SelectionCache decision lookups
    kernel.route.pallas/.ref/.veto
                             ``kernel_route`` outcomes (veto = a record
                             exists but measured slower than ref)
    replan.pattern_sig       memoised DistPlan format plans dropped
                             because the live pattern changed
    halo.bytes               bytes a traced ``dist_spmv`` exchanges per
                             call (recorded at trace time)

Standard histogram names (``observe``):

    ell.padding_waste        1 - nnz/(m*k) of each planned ELL layout
    hyb.padding_waste        same for the ELL part of each HYB plan
    sell.padding_waste       1 - nnz/capacity of each planned SELL-C-σ
                             slicing (per-slice widths, post σ-sort)

``snapshot()`` returns a plain dict (JSON-ready); ``scope()`` gives tests
an order-independent view: deltas against the values at scope entry, so
assertions stop depending on what ran earlier in the process.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Optional

_LOCK = threading.Lock()
_COUNTERS: Dict[str, float] = {}
# name -> [count, sum, min, max]
_HISTS: Dict[str, list] = {}


def inc(name: str, n: float = 1) -> None:
    """Increment counter ``name`` by ``n`` (created at 0 on first use)."""
    with _LOCK:
        _COUNTERS[name] = _COUNTERS.get(name, 0) + n


def observe(name: str, value: float) -> None:
    """Record ``value`` into histogram ``name`` (count/sum/min/max)."""
    v = float(value)
    with _LOCK:
        h = _HISTS.setdefault(name, [0, 0.0, float("inf"), float("-inf")])
        h[0] += 1
        h[1] += v
        h[2] = min(h[2], v)
        h[3] = max(h[3], v)


def value(name: str, default: float = 0) -> float:
    """Current value of counter ``name``."""
    with _LOCK:
        return _COUNTERS.get(name, default)


def snapshot() -> dict:
    """JSON-ready snapshot: ``{"counters": {...}, "histograms": {...}}``."""
    with _LOCK:
        return {
            "counters": dict(_COUNTERS),
            "histograms": {
                name: {"count": h[0], "sum": h[1], "min": h[2], "max": h[3],
                       "mean": h[1] / max(1, h[0])}
                for name, h in _HISTS.items()},
        }


def reset(names: Optional[Iterable[str]] = None) -> None:
    """Zero counters and histograms (all, or just ``names``)."""
    with _LOCK:
        if names is None:
            _COUNTERS.clear()
            _HISTS.clear()
        else:
            for n in names:
                _COUNTERS.pop(n, None)
                _HISTS.pop(n, None)


class Scope:
    """Delta view of the counters since scope entry (see :func:`scope`)."""

    def __init__(self):
        with _LOCK:
            self._base = dict(_COUNTERS)

    def delta(self, name: str) -> float:
        """Counter growth since the scope opened."""
        return value(name) - self._base.get(name, 0)

    def deltas(self) -> Dict[str, float]:
        """All counters that moved since the scope opened."""
        with _LOCK:
            cur = dict(_COUNTERS)
        out = {}
        for name, v in cur.items():
            d = v - self._base.get(name, 0)
            if d:
                out[name] = d
        return out

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def scope() -> Scope:
    """``with metrics.scope() as s: ...; s.delta("planned_pulls")``.

    The scope never mutates the global counters, so nested/concurrent
    scopes and unrelated earlier activity cannot perturb each other —
    the fix for order-dependent transfer-count assertions.
    """
    return Scope()
