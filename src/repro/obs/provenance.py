"""Run provenance: the environment fingerprint every artifact should carry.

``BENCH_*.json`` files used to hold numbers with no record of what
produced them — useless for cross-machine comparison and for the
selection-corpus training data the ML follow-up (arXiv:2303.05098) needs.
``env_info()`` collects the facts that determine whether two measurements
are comparable: jax version, backend, device kind/count, the
interpret-mode override, and the git revision of the code that ran.
"""
from __future__ import annotations

import os
import platform
import subprocess
import sys
from typing import Optional


def git_rev(cwd: Optional[str] = None) -> Optional[str]:
    """Short git revision of the running checkout (None outside a repo)."""
    if cwd is None:
        cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=cwd, capture_output=True, text=True,
                             timeout=5)
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else None
    except (OSError, subprocess.SubprocessError):
        return None


def env_info() -> dict:
    """Environment/provenance dict embedded in every benchmark artifact.

    Cheap (one cached git subprocess, no device work beyond what import
    already did) and always JSON-serializable; failures degrade to None
    fields, never to an exception.
    """
    import jax

    try:
        devs = jax.devices()
        device_kind = devs[0].device_kind if devs else None
        device_count = len(devs)
    except RuntimeError:
        device_kind, device_count = None, 0
    try:
        from repro.kernels.ops import interpret_mode
        interp = bool(interpret_mode())
    except Exception:  # pragma: no cover - partial installs
        interp = None
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": device_kind,
        "device_count": device_count,
        "interpret_mode": interp,
        "force_interpret": os.environ.get("REPRO_FORCE_INTERPRET") or None,
        "trace_mode": os.environ.get("REPRO_TRACE") or "off",
        "xla_flags": os.environ.get("XLA_FLAGS") or None,
        "git_rev": git_rev(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
    }
