"""Decision-explainability ledger: a bounded ring of structured records.

The selection machinery makes hundreds of runtime decisions per run —
CART format picks, kernel-route vetoes, SELL (c, σ) geometry choices —
and until now the only artifacts were their *outcomes* (a counter bumped,
a format chosen). This module records the decisions themselves, with
enough structure to answer "why?" after the fact:

* ``FormatPolicy.select``/``select_batch`` append ``format.select`` /
  ``format.select_batch`` records: the feature vector, the CART tree
  path actually taken (node-by-node, with the feature value and
  threshold at each split), per-candidate scores when an engine produced
  them, the cache hit/miss, and the pinned kernel decision including any
  veto reason.
* ``kernel_route`` appends ``kernel.route`` records: route taken, the
  cached :class:`~repro.tuning.kernel_tune.KernelRecord` (cfg incl. SELL
  (c, σ) geometry, kernel_us/ref_us/speedup) and the reason when the
  Pallas path was refused.
* ``FormatPolicy.plan_for`` appends ``plan.switch`` records: the format
  planned for and where its geometry hints came from (caller vs tuned
  record).
* ``DecodeEngine`` appends ``serve.request`` records with per-phase
  latencies.

Records are plain JSON-ready dicts in a thread-safe bounded ring
(newest win; ``dropped()`` counts overwrites). The ledger is **on by
default** — each record is a small host-side dict built on paths that
already do host dict lookups — and ``REPRO_LEDGER=off`` disables it
entirely. ``python -m repro.obs.explain`` replays the ring (or a
``dump_json`` file) into a human-readable account.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Dict, List, Optional

ENV_VAR = "REPRO_LEDGER"
CAPACITY = 4096

_LOCK = threading.Lock()
_RING: collections.deque = collections.deque(maxlen=CAPACITY)
_SEQ = 0
_DROPPED = 0
_ENABLED: Optional[bool] = None  # lazily resolved from $REPRO_LEDGER


def enabled() -> bool:
    """Ledger gate (cached; first call reads ``$REPRO_LEDGER``)."""
    global _ENABLED
    e = _ENABLED
    if e is None:
        e = _ENABLED = os.environ.get(ENV_VAR, "on").strip().lower() not in (
            "off", "0", "false")
    return e


def set_enabled(flag: bool) -> None:
    """Override the env-derived gate (tests / embedding callers)."""
    global _ENABLED
    _ENABLED = bool(flag)


def record(kind: str, **fields) -> None:
    """Append a decision record (no-op when the ledger is off).

    ``fields`` must be JSON-serializable (the instrumented layers pass
    strings, numbers, and small dicts only).
    """
    global _SEQ, _DROPPED
    if not enabled():
        return
    with _LOCK:
        _SEQ += 1
        if len(_RING) == CAPACITY:
            _DROPPED += 1
        _RING.append({"seq": _SEQ, "ts": time.time(), "kind": kind, **fields})


def records(kind: Optional[str] = None, last: Optional[int] = None
            ) -> List[dict]:
    """Snapshot of the ring, oldest first; filter by ``kind`` and/or keep
    only the ``last`` N matches."""
    with _LOCK:
        out = list(_RING)
    if kind is not None:
        out = [r for r in out if r["kind"] == kind]
    if last is not None:
        out = out[-last:]
    return out


def dropped() -> int:
    """Records overwritten because the ring wrapped."""
    return _DROPPED


def clear() -> None:
    """Drop all records (the gate and the sequence counter are kept — seq
    stays monotonic across clears so dumps from one process never alias)."""
    global _DROPPED
    with _LOCK:
        _RING.clear()
        _DROPPED = 0


def dump_json(path: str) -> str:
    """Write the ring as a JSON document ``{"records": [...], "dropped",
    "capacity"}`` — the CI artifact ``repro.obs.explain`` replays."""
    doc = {"records": records(), "dropped": dropped(), "capacity": CAPACITY}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def load_json(path: str) -> Dict:
    """Read a :func:`dump_json` document back (records under "records")."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "records" not in doc:
        raise ValueError(f"{path} is not a ledger dump (no 'records' key)")
    return doc
