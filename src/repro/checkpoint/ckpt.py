"""Sharded checkpointing with elastic resharding (DESIGN.md §5).

Layout:
  <dir>/step_<k>/manifest.json      tree structure, shapes, dtypes, checksums
  <dir>/step_<k>/arr_<i>.npy        one file per leaf (gathered)

Fault-tolerance properties:
  * atomic publish: shard files are written first, the manifest last and
    fsync'd — a crash mid-write leaves a detectably-partial step that
    ``latest_step`` skips;
  * per-file CRC32 checksums catch torn writes on restore;
  * elastic restore: arrays are loaded host-side and re-placed under ANY
    mesh/sharding (re-slicing happens in device_put) — a checkpoint written
    on 256 chips restores onto 8 or 512 (node failure => re-mesh => resume).

This file intentionally uses gathered (replicated-host) arrays: per-host
shard files are a straightforward extension (write leaf[addressable_shards])
but the single-process container used here cannot exercise them honestly.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Write checkpoint for ``step``; returns the step directory."""
    stepdir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmpdir = stepdir + ".tmp"
    os.makedirs(tmpdir, exist_ok=True)
    flat, treedef = _leaf_paths(tree)
    # structure is re-supplied via `like` at restore; record a stable string
    # fingerprint so cross-structure restores fail loudly
    manifest = {"step": step, "n_leaves": len(flat),
                "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        path = os.path.join(tmpdir, f"arr_{i:05d}.npy")
        # numpy can't round-trip ml_dtypes (bf16 etc.): store a byte view,
        # the true dtype travels in the manifest
        np.save(path, arr.view(np.uint8) if arr.dtype.kind == "V" or
                arr.dtype.name == "bfloat16" else arr)
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"].append({"file": os.path.basename(path),
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype), "crc32": crc})
    mpath = os.path.join(tmpdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmpdir, stepdir)  # atomic publish
    return stepdir


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; place with ``shardings``
    (a matching pytree of NamedSharding / None) — the elastic-resharding
    path: the target mesh can differ arbitrarily from the writer's."""
    stepdir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(stepdir, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _leaf_paths(like)
    if manifest["n_leaves"] != len(flat_like):
        raise ValueError(f"checkpoint has {manifest['n_leaves']} leaves, "
                         f"target structure has {len(flat_like)}")
    shard_flat = (jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
                  if shardings is not None else [None] * len(flat_like))
    out = []
    for i, (meta, ref, shd) in enumerate(zip(manifest["leaves"], flat_like, shard_flat)):
        path = os.path.join(stepdir, meta["file"])
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        if crc != meta["crc32"]:
            raise IOError(f"checksum mismatch in {path} (torn write?)")
        arr = np.load(path)
        want = np.dtype(jnp.bfloat16 if meta["dtype"] == "bfloat16" else meta["dtype"])
        if arr.dtype != want:
            arr = arr.view(want)
        if list(arr.shape) != list(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {np.shape(ref)}")
        out.append(jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def cleanup(ckpt_dir: str, keep: int = 3):
    """Retain the newest ``keep`` steps (bounded disk for long runs)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (latest_step(ckpt_dir),) if s is not None)
    all_steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            all_steps.append(int(m.group(1)))
    for s in sorted(all_steps)[:-keep]:
        import shutil
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
