"""Backend-aware XLA environment setup (applied *before* jax initializes).

XLA reads ``XLA_FLAGS`` once, at backend initialization — flags appended
after the first ``import jax`` touch are silently ignored, and *unknown*
flags can abort process startup. This module therefore

  * never imports jax at module level (``repro`` is a namespace package,
    so ``from repro import env`` stays jax-free);
  * gates every flag on the resolved backend: GPU gets the
    async-collective / latency-hiding scheduler flags that let the
    interior/boundary-split ``dist_spmv`` actually run its interior SpMV
    while the halo ``ppermute`` is in flight, CPU gets only the
    forced-host-device-count flag (the SPMD test/bench harness);
  * merges with any caller-set ``XLA_FLAGS``, replacing only the flags it
    manages — a user's unrelated flags pass through untouched.

Entry points (``benchmarks/run.py``, the bench subprocess scripts,
``examples/hpcg_solve.py``, CI) call :func:`apply` first thing::

    from repro import env
    env.apply(host_devices=8)      # CPU SPMD: 8 forced host devices
    import jax                     # now initializes with the flags set

:func:`describe` reports what was applied for the BENCH_*.json meta.
"""
from __future__ import annotations

import os
import sys
import warnings
from typing import Dict, List, Optional

# Flags this module owns; merge replaces exactly these, nothing else.
_MANAGED_PREFIXES = (
    "--xla_force_host_platform_device_count",
    "--xla_gpu_enable_async_collectives",
    "--xla_gpu_enable_latency_hiding_scheduler",
    "--xla_gpu_enable_highest_priority_async_stream",
)

# The async-collective set: the GPU scheduler only overlaps a collective
# with independent compute when these are on (bayespec's env pattern).
_GPU_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

_applied: Optional[Dict[str, object]] = None


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve the target backend without importing jax.

    Priority: explicit argument > ``JAX_PLATFORMS``/``JAX_PLATFORM_NAME``
    env > ``REPRO_BACKEND`` env > ``"cpu"``.
    """
    if backend:
        return backend.lower()
    for var in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME", "REPRO_BACKEND"):
        v = os.environ.get(var)
        if v:
            return v.split(",")[0].strip().lower()
    return "cpu"


def _merge_flags(existing: str, managed: List[str]) -> str:
    """Union of the caller's XLA_FLAGS and ours; ours win on overlap."""
    kept = [f for f in existing.split()
            if not any(f.startswith(p) for p in _MANAGED_PREFIXES)]
    return " ".join(kept + managed).strip()


def apply(backend: Optional[str] = None,
          host_devices: Optional[int] = None) -> Dict[str, object]:
    """Set ``XLA_FLAGS`` for ``backend`` (resolved per :func:`resolve_backend`).

    ``host_devices`` forces N host (CPU) devices — the SPMD harness for
    distributed tests/benches on machines without N accelerators. On GPU
    backends the async-collective/latency-hiding flags are added; on CPU
    they are *not* (unknown or inapplicable flags can abort XLA startup,
    so every flag is backend-gated).

    Idempotent and safe to call multiple times; warns (but still sets the
    environment for child processes) when jax already initialized in this
    process, since the running backend will not see the change.
    """
    global _applied
    bk = resolve_backend(backend)
    managed: List[str] = []
    if host_devices is not None and int(host_devices) > 0:
        managed.append(
            f"--xla_force_host_platform_device_count={int(host_devices)}")
    if bk in ("gpu", "cuda", "rocm"):
        managed.extend(_GPU_FLAGS)

    if "jax" in sys.modules and managed:
        warnings.warn(
            "repro.env.apply() called after jax was imported: the current "
            "process's XLA backend is already initialized and will not see "
            "these flags (child processes will).", RuntimeWarning,
            stacklevel=2)

    flags = _merge_flags(os.environ.get("XLA_FLAGS", ""), managed)
    if flags:
        os.environ["XLA_FLAGS"] = flags
    _applied = {"backend": bk, "host_devices": host_devices,
                "managed_flags": list(managed), "xla_flags": flags}
    return dict(_applied)


def describe() -> Dict[str, object]:
    """What :func:`apply` last did (for BENCH meta provenance); reads the
    live environment when apply was never called in this process."""
    if _applied is not None:
        return dict(_applied)
    return {"backend": resolve_backend(), "host_devices": None,
            "managed_flags": [], "xla_flags": os.environ.get("XLA_FLAGS", "")}
