import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# 512 placeholder host devices back both production meshes (256-chip pod and
# 2x256 multi-pod). Never set this globally — tests/benches must see 1 device.

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

For every (architecture x input-shape x mesh) cell:
  1. build the step fn (train_step / prefill / serve_step per shape kind),
  2. ``jax.jit(...).lower(**input_specs).compile()`` on the production mesh,
  3. print ``compiled.memory_analysis()``  (proves the cell fits HBM),
     print ``compiled.cost_analysis()``    (FLOPs / bytes for §Roofline),
  4. parse the compiled HLO for collective operand bytes,
  5. [--cost] compile depth-0 and depth-1(unrolled) variants: XLA counts a
     lax.scan body ONCE regardless of trip count (verified empirically), so
     the corrected cost is  c0 + L*(c1 - c0)  with no scans left inside c1.

Results land in results/dryrun/<arch>__<shape>__<mesh>.json, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm_1_6b \
      --shape train_4k --mesh single --cost
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, skip_reason, token_input_specs
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models import sharding_ctx
from repro.optim.adamw import AdamW, AdamWState

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

COLLECTIVE_RE = re.compile(
    r"(\w+\[[^\]]*\](?:, \w+\[[^\]]*\])*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([\d,]*)\]")
DTYPE_BYTES = {"f64": 8, "s64": 8, "c64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s8": 1, "u8": 1, "pred": 1}


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op (per-device shapes)."""
    out = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?\S+ = (.*)", line)
        if not m:
            continue
        rhs = m.group(1)
        cm = re.match(
            r"(.*?)\s(all-gather|all-reduce|reduce-scatter|"
            r"all-to-all|collective-permute)(-start|-done)?\(", rhs)
        if not cm or cm.group(3) == "-done":
            continue
        op = cm.group(2)
        shapes = SHAPE_RE.findall(cm.group(1))
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES.get(dt, 4)
        out[op] = out.get(op, 0) + nbytes
        out["total"] = out.get("total", 0) + nbytes
    return out



def accum_steps(cfg, cell) -> int:
    """Gradient-accumulation factor (hillclimbed, EXPERIMENTS.md §Perf).

    More microbatches shrink per-microbatch activations (incl. flash
    custom_vjp residuals) enough that sequence parallelism — and its
    per-layer all-gathers, the dominant collective term — can be dropped
    for every arch < 60B. Capped so each microbatch still fills the
    data-parallel axis (batch/dp >= 1: no redundant compute)."""
    n = cfg.n_params()
    want = 16 if n >= 2.5e10 else (8 if n >= 1.2e10 else 4)
    cap = max(1, cell.global_batch // 16)  # dp axis = 16
    a = min(want, cap)
    while a > 1 and cell.global_batch % a:
        a //= 2
    return max(1, a)


def sp_axis(cfg) -> str:
    """Sequence-parallel axis for train cells: only the >=60B models still
    need SP for memory after microbatching; everywhere else SP's per-layer
    gathers dominated the collective roofline term (qwen: 7.7 TB/dev -> 
    ~30 GB/dev corrected when dropped; §Perf)."""
    return "model" if cfg.n_params() >= 6e10 else None


def grad_accum(model, params, batch, accum: int, unroll: bool,
               grad_pspecs=None):
    """Mean loss + grads over ``accum`` microbatches (lax.scan or unrolled).

    The scan keeps all per-microbatch activations (incl. custom_vjp flash
    residuals, which remat cannot discard) scoped to one microbatch.
    ``grad_pspecs`` pins per-microbatch grads to the param layout before
    accumulation (stops GSPMD materialising full unsharded dW tiles).
    """
    loss_fn = lambda p, b: model.loss(p, b, unroll=unroll)

    def constrain_g(g):
        if grad_pspecs is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_pspecs)

    if accum <= 1:
        l, g = jax.value_and_grad(loss_fn)(params, batch)
        return l, constrain_g(g)
    micro = jax.tree.map(
        lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]), batch)

    def body(acc, mb):
        l, g = jax.value_and_grad(loss_fn)(params, mb)
        g = constrain_g(g)
        return (acc[0] + l / accum,
                jax.tree.map(lambda x, y: x + y / accum, acc[1], g)), None

    zeros = (jnp.zeros((), jnp.float32),
             jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
    if unroll:
        acc = zeros
        for i in range(accum):
            acc, _ = body(acc, jax.tree.map(lambda a: a[i], micro))
    else:
        acc, _ = jax.lax.scan(body, zeros, micro)
    return acc


def build_step(model, cfg, shape_name, mesh):
    """Returns (jitted_fn, kwargs_of_abstract_args)."""
    cell = SHAPES[shape_name]
    if cell.kind == "train":
        opt = AdamW()
        # mixed precision: bf16 params + f32 Adam moments for the largest
        # models (halves param args + weight-sized backward transients)
        pdtype = jnp.bfloat16 if cfg.n_params() >= 6e10 else jnp.float32
        specs = model.specs(pdtype)
        p_sh = shd.param_shardings(specs, mesh, shd.TRAIN_RULES)
        opt_sh = AdamWState(NamedSharding(mesh, P()), p_sh, p_sh)
        batch_specs = token_input_specs(cfg, cell, with_labels=True)
        b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.batch_pspec(mesh, batch_specs),
                            is_leaf=lambda x: isinstance(x, P))

        accum = accum_steps(cfg, cell)
        g_ps = shd.param_pspecs(specs, mesh, shd.TRAIN_RULES)

        def train_step(params, opt_state, batch):
            loss, grads = grad_accum(model, params, batch, accum, unroll=False,
                                     grad_pspecs=g_ps)
            new_p, new_o = opt.update(grads, opt_state, params)
            return new_p, new_o, loss

        fn = jax.jit(train_step,
                     in_shardings=(p_sh, opt_sh, b_sh),
                     out_shardings=(p_sh, opt_sh, NamedSharding(mesh, P())),
                     donate_argnums=(0, 1))
        args = (model.abstract_params(pdtype),
                AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                           model.abstract_params(), model.abstract_params()),
                batch_specs)
        return fn, args

    if cell.kind == "prefill":
        specs = model.specs(jnp.bfloat16)
        rules = dict(shd.DECODE_RULES,
                     embed="data" if cfg.n_params() > 1e10 else None)
        p_sh = shd.param_shardings(specs, mesh, rules)
        batch_specs = token_input_specs(cfg, cell, with_labels=False)
        b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.batch_pspec(mesh, batch_specs),
                            is_leaf=lambda x: isinstance(x, P))
        fn = jax.jit(lambda params, batch: model.prefill(params, batch),
                     in_shardings=(p_sh, b_sh))
        return fn, (model.abstract_params(jnp.bfloat16), batch_specs)

    # decode. Models >10B cannot replicate bf16 params over 'data'
    # (command-r: 13 GiB/dev) -> weight-gathered decode (2-D sharded params,
    # per-layer all-gather amortised over the 128-sequence batch).
    specs = model.specs(jnp.bfloat16)
    rules = dict(shd.DECODE_RULES,
                 embed="data" if cfg.n_params() > 1e10 else None)
    p_sh = shd.param_shardings(specs, mesh, rules)
    chips = int(np.prod(list(mesh.shape.values())))
    bf16_cache = (2 * 2 * cfg.n_layers * cell.global_batch * cell.seq_len
                  * cfg.n_kv * cfg.hd) if cfg.n_kv else 0
    kv_quant = cfg.family in ("dense", "moe", "vlm") and \
        bf16_cache / chips > 8 * 2 ** 30  # int8 cache when bf16 won't fit
    cache_specs = model.cache_specs(cell.global_batch, cell.seq_len,
                                    kv_quant=kv_quant)
    c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        shd.cache_pspec(mesh, cache_specs, cfg),
                        is_leaf=lambda x: isinstance(x, P))
    tok = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
    pos = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
    rep = NamedSharding(mesh, P())
    fn = jax.jit(lambda params, cache, tokens, pos:
                 model.decode_step(params, cache, tokens, pos),
                 in_shardings=(p_sh, c_sh, rep, rep),
                 out_shardings=(rep, c_sh),
                 donate_argnums=(1,))
    return fn, (model.abstract_params(jnp.bfloat16), cache_specs, tok, pos)


def _reduced_cfg(cfg, n_layers):
    """Depth-reduced config for the c0/c1 cost compiles."""
    return dataclasses.replace(cfg, n_layers=n_layers)


def compile_cell(arch, shape_name, multi_pod, *, with_cost=False,
                 unroll_for_cost=True, save=True, verbose=True,
                 cfg_override=None, tag=""):
    cfg = cfg_override or get_config(arch)
    reason = skip_reason(cfg, shape_name)
    mesh_name = "multipod" if multi_pod else "pod"
    cellname = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "n_params": None, "skip": reason}
    if reason:
        if verbose:
            print(f"[{cellname}] SKIP: {reason}")
        if save:
            _save(cellname, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    rec["n_params"] = cfg.n_params()
    rec["n_active_params"] = cfg.n_active_params()
    cell = SHAPES[shape_name]
    rec["tokens"] = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    rec["chips"] = int(np.prod(list(mesh.shape.values())))

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    cellk = SHAPES[shape_name].kind
    sharding_ctx.set_policy(dp=dp if len(dp) > 1 else dp[0], tp="model",
                            sp=sp_axis(cfg) if cellk == "train" else None)
    t0 = time.perf_counter()
    with mesh:
        fn, args = build_step(model, cfg, shape_name, mesh)
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec["memory"] = {
        k: int(getattr(mem, k))
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes")
        if hasattr(mem, k)}
    per_dev = (rec["memory"].get("temp_size_in_bytes", 0)
               + rec["memory"].get("argument_size_in_bytes", 0))
    rec["bytes_per_device"] = per_dev
    rec["cost_reported"] = {k: float(cost.get(k, 0.0))
                            for k in ("flops", "bytes accessed")}
    hlo = compiled.as_text()
    rec["collectives_reported"] = parse_collective_bytes(hlo)

    if verbose:
        print(f"[{cellname}] compiled in {t_compile:.0f}s | "
              f"per-device {per_dev / 2**30:.2f} GiB | "
              f"reported GFLOPs {rec['cost_reported']['flops'] / 1e9:.1f} | "
              f"collective MB {rec['collectives_reported'].get('total', 0) / 2**20:.1f}")
        print(f"  memory_analysis: {rec['memory']}")

    if with_cost:
        rec["cost_corrected"] = corrected_costs(
            arch, shape_name, cfg, mesh, unroll=unroll_for_cost,
            verbose=verbose)

    if save:
        _save(cellname, rec)
    return rec


def corrected_costs(arch, shape_name, cfg, mesh, *, unroll=True, verbose=True):
    """c0 + L*(c1 - c0): exact scan-trip-count-corrected cost terms."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    cellk = SHAPES[shape_name].kind
    sharding_ctx.set_policy(dp=dp if len(dp) > 1 else dp[0], tp="model",
                            sp=sp_axis(cfg) if cellk == "train" else None)
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    n_units = cfg.n_layers // unit
    out = {}
    costs = {}
    for depth_units, key in ((0, "c0"), (1, "c1")):
        dcfg = _reduced_cfg(cfg, depth_units * unit)
        dmodel = build_model(dcfg)
        cell = SHAPES[shape_name]
        with mesh:
            if cell.kind == "train":
                fn, args = _train_step_unrolled(dmodel, dcfg, cell, mesh, unroll)
            else:
                fn, args = build_step(dmodel, dcfg, shape_name, mesh)
            comp = fn.lower(*args).compile()
        cost = comp.cost_analysis()
        coll = parse_collective_bytes(comp.as_text())
        costs[key] = {"flops": float(cost.get("flops", 0.0)),
                      "bytes": float(cost.get("bytes accessed", 0.0)),
                      "coll": float(coll.get("total", 0.0))}
    for term, key in (("flops", "flops"), ("bytes", "bytes"), ("coll", "coll")):
        c0, c1 = costs["c0"][key], costs["c1"][key]
        out[term] = c0 + n_units * max(0.0, c1 - c0)
    out["c0"] = costs["c0"]
    out["c1"] = costs["c1"]
    out["n_units"] = n_units
    if verbose:
        print(f"  corrected: GFLOPs {out['flops'] / 1e9:.1f} | "
              f"GiB accessed {out['bytes'] / 2**30:.1f} | "
              f"collective GiB {out['coll'] / 2**30:.2f} (x{n_units} units)")
    return out


def _train_step_unrolled(model, cfg, cell, mesh, unroll):
    """Train step with python-loop layers + unrolled attention (cost-exact)."""
    opt = AdamW()
    pdtype0 = jnp.bfloat16 if cfg.n_params() >= 6e10 else jnp.float32
    specs = model.specs(pdtype0)
    p_sh = shd.param_shardings(specs, mesh, shd.TRAIN_RULES)
    opt_sh = AdamWState(NamedSharding(mesh, P()), p_sh, p_sh)
    batch_specs = token_input_specs(cfg, cell, with_labels=True)
    b_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        shd.batch_pspec(mesh, batch_specs),
                        is_leaf=lambda x: isinstance(x, P))

    accum = accum_steps(cfg, cell)
    g_ps = shd.param_pspecs(specs, mesh, shd.TRAIN_RULES)
    pdtype = jnp.bfloat16 if cfg.n_params() >= 6e10 else jnp.float32

    def train_step(params, opt_state, batch):
        loss, grads = grad_accum(model, params, batch, accum, unroll=unroll,
                                 grad_pspecs=g_ps)
        new_p, new_o = opt.update(grads, opt_state, params)
        return new_p, new_o, loss

    fn = jax.jit(train_step, in_shardings=(p_sh, opt_sh, b_sh),
                 out_shardings=(p_sh, opt_sh, NamedSharding(mesh, P())))
    args = (model.abstract_params(pdtype0),
            AdamWState(jax.ShapeDtypeStruct((), jnp.int32),
                       model.abstract_params(), model.abstract_params()),
            batch_specs)
    return fn, args


def _save(cellname, rec):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, cellname + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="")
    p.add_argument("--shape", default="", choices=[""] + list(SHAPES))
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--cost", action="store_true",
                   help="also run the c0/c1 corrected-cost compiles")
    p.add_argument("--continue-on-error", action="store_true")
    args = p.parse_args(argv)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    compile_cell(arch, shape, mp, with_cost=args.cost and not mp)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[{arch}__{shape}__{'multipod' if mp else 'pod'}] "
                          f"FAILED: {e}")
                    traceback.print_exc()
                    if not args.continue_on_error:
                        raise
    print(f"\ndone. {len(failures)} failures.")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
