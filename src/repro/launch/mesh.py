"""Production mesh definitions (DESIGN.md §5).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """General mesh builder for tests/benchmarks (e.g. (8,), ('data',))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh ('pod' included)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def tp_axis(mesh):
    return "model" if "model" in mesh.axis_names else None


def flat_axes(mesh) -> tuple:
    """All axes flattened — used by the HPCG row partition (512-way)."""
    return tuple(mesh.axis_names)
