"""Serving driver: batched greedy decoding with a static-slot batch engine.

A deliberately simple continuous-batching-lite design: a fixed pool of
decode slots; finished sequences (EOS or max length) are retired and their
slots refilled from the request queue between jit'd decode steps (the step
itself is slot-count static, so one compiled program serves the whole run).

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm_1_6b --smoke \
      --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


class DecodeEngine:
    """Static-slot batched greedy decoder."""

    def __init__(self, model, params, slots: int, max_len: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.tokens = np.zeros((slots,), np.int32)
        self.pos = np.zeros((slots,), np.int32)
        self.active = np.zeros((slots,), bool)
        self.outputs: List[Optional[list]] = [None] * slots
        self.request_ids = [-1] * slots
        self._step = jax.jit(model.decode_step)

    def add_request(self, rid: int, prompt: np.ndarray) -> bool:
        """Prefill-by-decode: feed prompt tokens through the decode path
        (single compiled program; fine at smoke scale — a production server
        would run model.prefill for long prompts)."""
        free = np.where(~self.active)[0]
        if len(free) == 0:
            return False
        s = int(free[0])
        self.active[s] = True
        self.request_ids[s] = rid
        self.outputs[s] = []
        # feed prompt
        for i, t in enumerate(prompt):
            self.tokens[s] = t
            self.pos[s] = i
            logits, self.cache = self._step(
                self.params, self.cache,
                jnp.asarray(self.tokens), jnp.asarray(self.pos))
        self.tokens[s] = int(np.asarray(jnp.argmax(logits[s])))
        self.pos[s] = len(prompt)
        self.outputs[s].append(int(self.tokens[s]))
        return True

    def step(self, max_new: int, eos: int = -1):
        """One decode step for every active slot; retire finished ones."""
        if not self.active.any():
            return []
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(self.tokens),
                                        jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        finished = []
        for s in range(self.slots):
            if not self.active[s]:
                continue
            self.outputs[s].append(int(nxt[s]))
            self.tokens[s] = nxt[s]
            self.pos[s] += 1
            done = (len(self.outputs[s]) >= max_new or int(nxt[s]) == eos
                    or int(self.pos[s]) >= self.max_len - 1)
            if done:
                finished.append((self.request_ids[s], self.outputs[s]))
                self.active[s] = False
        return finished


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = DecodeEngine(model, params, args.slots, args.max_len)

    rng = np.random.default_rng(args.seed)
    queue = [(i, rng.integers(0, cfg.vocab, (args.prompt_len,)).astype(np.int32))
             for i in range(args.requests)]
    done, t0, steps = [], time.perf_counter(), 0
    while queue or engine.active.any():
        while queue and engine.add_request(*queue[0]):
            queue.pop(0)
        done += engine.step(args.max_new)
        steps += 1
    dt = time.perf_counter() - t0
    ntok = sum(len(o) for _, o in done)
    print(f"served {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s, {steps} engine steps)")
    for rid, out in sorted(done)[:4]:
        print(f"  req {rid}: {out[:10]}{'...' if len(out) > 10 else ''}")
    return 0


if __name__ == "__main__":
    main()
