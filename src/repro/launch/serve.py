"""Serving driver: batched greedy decoding with a static-slot batch engine.

A deliberately simple continuous-batching-lite design: a fixed pool of
decode slots; finished sequences (EOS or max length) are retired and their
slots refilled from the request queue between jit'd decode steps (the step
itself is slot-count static, so one compiled program serves the whole run).

Prefill is ONE jit'd forward per admission batch (``model.prefill_cache``):
pending requests accumulate in a queue and are admitted together whenever
slots free up, padded to pow2 (rows, prompt-len) buckets so the jit cache
stays small.  Families without an addressable kv cache (ssm/hybrid) fall
back to per-token prefill through the decode path.

``repro.env.apply()`` runs at entry-point import time — *before* jax
initializes — so backend-gated XLA flags actually reach the runtime.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm_1_6b --smoke \
      --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    from repro import env as _env
    _env.apply()

import argparse
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.obs import ledger as _ledger
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def _pow2_at_least(n: int, cap: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return min(p, cap)


class DecodeEngine:
    """Static-slot batched greedy decoder with batched jit'd prefill.

    Requests enter via :meth:`submit` (a pending queue); :meth:`refill`
    admits as many as there are free slots in ONE ``prefill_cache`` call,
    padded to pow2 (rows, prompt-len) buckets — pad rows replicate the
    last real request so duplicate cache scatters write identical values.

    Every request is measured through its lifecycle (submit -> admit ->
    finish): per-phase latencies land in the ``serve.queue_us`` /
    ``serve.prefill_us`` / ``serve.decode_us`` / ``serve.latency_us``
    histograms (p50/p95/p99 via ``metrics.quantiles``), the pending-queue
    depth in the ``serve.queue_depth`` gauge+histogram (sampled at each
    refill), finished requests in :attr:`request_log` (JSON-ready dicts —
    what ``bench_serve`` turns into latency rows) and as ``serve.request``
    ledger records. A batched prefill is one device call for n requests,
    so its wall time is attributed to each admitted request as the
    per-request share (total / n).
    """

    def __init__(self, model, params, slots: int, max_len: int):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.tokens = np.zeros((slots,), np.int32)
        self.pos = np.zeros((slots,), np.int32)
        self.active = np.zeros((slots,), bool)
        self.outputs: List[Optional[list]] = [None] * slots
        self.request_ids = [-1] * slots
        self.pending: List[Tuple[int, np.ndarray]] = []
        self.prefill_calls = 0
        self.request_log: List[dict] = []   # finished-request telemetry
        self._req_meta: dict = {}           # rid -> in-flight timestamps
        self._step = jax.jit(model.decode_step)
        self._prefill = {}  # (R, P) bucket -> jit'd prefill_cache

    # -- admission ---------------------------------------------------------

    def submit(self, rid: int, prompt: np.ndarray) -> None:
        """Queue a request; admitted at the next :meth:`refill`."""
        self.pending.append((rid, np.asarray(prompt, np.int32)))
        self._req_meta[rid] = {"t_submit": time.perf_counter_ns()}
        _metrics.inc("serve.requests")

    def refill(self) -> int:
        """Admit pending requests into free slots (one batched prefill).

        Returns the number of requests admitted."""
        depth = len(self.pending)
        _metrics.set_gauge("serve.queue_depth", depth)
        _metrics.observe("serve.queue_depth", depth)
        free = np.where(~self.active)[0]
        n = min(len(free), depth)
        if n == 0:
            return 0
        batch, self.pending = self.pending[:n], self.pending[n:]
        slots = free[:n]
        t0 = time.perf_counter_ns()
        with _trace.span("serve.refill", admitted=n):
            if self.model.supports_prefill_cache():
                first = self._prefill_batched(batch, slots)
            else:
                first = [self._prefill_by_decode(prompt, int(s))
                         for (_, prompt), s in zip(batch, slots)]
        t_admit = time.perf_counter_ns()
        prefill_share_us = (t_admit - t0) / 1e3 / n
        for (rid, prompt), s, tok in zip(batch, slots, first):
            s = int(s)
            self.active[s] = True
            self.request_ids[s] = rid
            self.tokens[s] = tok
            self.pos[s] = len(prompt)
            self.outputs[s] = [tok]
            meta = self._req_meta.get(rid)
            if meta is not None:
                meta["t_admit"] = t_admit
                meta["queue_us"] = (t0 - meta["t_submit"]) / 1e3
                meta["prefill_us"] = prefill_share_us
                _metrics.observe("serve.queue_us", meta["queue_us"])
                _metrics.observe("serve.prefill_us", prefill_share_us)
        return n

    def _prefill_batched(self, batch, slots) -> List[int]:
        """ONE jit'd forward primes the cache for every admitted request.

        Rows and prompt length are padded to pow2 buckets so a stream of
        ragged admissions compiles a handful of programs, not one per
        shape; pad rows duplicate the last real request (identical scatter
        values make the duplicate slot indices well-defined)."""
        lens = [len(p) for _, p in batch]
        R = _pow2_at_least(len(batch), self.slots)
        P = _pow2_at_least(max(lens), self.max_len)
        tokens = np.zeros((R, P), np.int32)
        for i, (_, prompt) in enumerate(batch):
            tokens[i, :len(prompt)] = prompt
        lengths = np.asarray(lens + [lens[-1]] * (R - len(batch)), np.int32)
        srows = np.asarray(list(slots) + [slots[-1]] * (R - len(batch)),
                           np.int32)
        tokens[len(batch):] = tokens[len(batch) - 1]
        key = (R, P)
        if key not in self._prefill:
            self._prefill[key] = jax.jit(self.model.prefill_cache)
        logits, self.cache = self._prefill[key](
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(srows), jnp.asarray(lengths))
        self.prefill_calls += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        return [int(t) for t in nxt[:len(batch)]]

    def _prefill_by_decode(self, prompt: np.ndarray, s: int) -> int:
        """Fallback for recurrent-state families (ssm/hybrid): the cache
        is positional, so the prompt must be stepped token by token."""
        logits = None
        self.prefill_calls += 1
        for i, t in enumerate(prompt):
            self.tokens[s] = t
            self.pos[s] = i
            logits, self.cache = self._step(
                self.params, self.cache,
                jnp.asarray(self.tokens), jnp.asarray(self.pos))
        return int(np.asarray(jnp.argmax(logits[s])))

    # -- decode ------------------------------------------------------------

    def step(self, max_new: int, eos: int = -1):
        """One decode step for every active slot; retire finished ones."""
        if not self.active.any():
            return []
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(self.tokens),
                                        jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        finished = []
        for s in range(self.slots):
            if not self.active[s]:
                continue
            self.outputs[s].append(int(nxt[s]))
            self.tokens[s] = nxt[s]
            self.pos[s] += 1
            done = (len(self.outputs[s]) >= max_new or int(nxt[s]) == eos
                    or int(self.pos[s]) >= self.max_len - 1)
            if done:
                finished.append((self.request_ids[s], self.outputs[s]))
                self.active[s] = False
                self._finish(self.request_ids[s], len(self.outputs[s]))
        return finished

    def _finish(self, rid: int, ntokens: int) -> None:
        """Close a request's telemetry span: per-phase latencies into the
        serve histograms, the request_log, and the decision ledger."""
        _metrics.inc("serve.tokens", ntokens)
        meta = self._req_meta.pop(rid, None)
        if meta is None or "t_admit" not in meta:
            return
        now = time.perf_counter_ns()
        decode_us = (now - meta["t_admit"]) / 1e3
        total_us = (now - meta["t_submit"]) / 1e3
        _metrics.observe("serve.decode_us", decode_us)
        _metrics.observe("serve.latency_us", total_us)
        entry = {"rid": rid, "queue_us": meta["queue_us"],
                 "prefill_us": meta["prefill_us"], "decode_us": decode_us,
                 "total_us": total_us, "tokens": ntokens}
        self.request_log.append(entry)
        if _ledger.enabled():
            _ledger.record("serve.request", **entry)


def serve(engine: DecodeEngine, requests, max_new: int, eos: int = -1):
    """Run the engine to completion over ``requests`` [(rid, prompt), ...].

    Returns (done, steps): done is [(rid, output_tokens), ...]."""
    for rid, prompt in requests:
        engine.submit(rid, prompt)
    done, steps = [], 0
    while engine.pending or engine.active.any():
        engine.refill()
        done += engine.step(max_new, eos=eos)
        steps += 1
    return done, steps


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--max-len", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = DecodeEngine(model, params, args.slots, args.max_len)

    rng = np.random.default_rng(args.seed)
    requests = [
        (i, rng.integers(0, cfg.vocab, (args.prompt_len,)).astype(np.int32))
        for i in range(args.requests)]
    t0 = time.perf_counter()
    done, steps = serve(engine, requests, args.max_new)
    dt = time.perf_counter() - t0
    ntok = sum(len(o) for _, o in done)
    mode = "batched" if model.supports_prefill_cache() else "by-decode"
    print(f"served {len(done)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s, {steps} engine steps, "
          f"{engine.prefill_calls} {mode} prefills)")
    show = len(done) if args.smoke else 4
    for rid, out in sorted(done)[:show]:
        print(f"  req {rid}: {out[:10]}{'...' if len(out) > 10 else ''}")
    return 0


if __name__ == "__main__":
    main()
