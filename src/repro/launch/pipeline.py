"""Pipeline parallelism: GPipe-style microbatch schedule over a 'stage' axis.

The layer-stacked transformer maps naturally onto stages: each stage owns
L/S contiguous layers; activations hand off between neighbouring stages via
``ppermute`` inside ``shard_map``. The schedule runs M + S - 1 ticks; tick t
has stage s working on microbatch t - s (bubble fraction (S-1)/(M+S-1)).
Autodiff through the schedule gives the backward pipeline for free
(transpose of ppermute is the reverse permute); the stage body is remat'd
so saved activations stay O(ticks x microbatch), not O(ticks x layers).

This is the optional trainer flag promised in DESIGN.md §5; the assigned
256/512-chip dry-run meshes use DP x TP, which dominates PP at these model
sizes, so PP is exercised at test scale (tests/test_pipeline.py) and
available for deeper-than-HBM models.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat


def pipeline_apply(stage_fn: Callable, params_staged, x_micro, mesh: Mesh,
                   axis: str = "stage"):
    """Run microbatches through the stage pipeline.

    stage_fn(params_slab, x) -> x        one stage's compute (L/S layers)
    params_staged: pytree, leaves (S, ...) — dim 0 sharded over ``axis``
    x_micro: (M, mb, ...) microbatched activations (replicated)
    Returns (M, mb, ...) outputs of the LAST stage (zeros elsewhere).
    """
    s = mesh.shape[axis]
    m = x_micro.shape[0]
    ticks = m + s - 1

    def body(params_slab, xm):
        # params_slab: (1, ...) local stage slab; xm: (M, mb, ...)
        slab = jax.tree.map(lambda a: a[0], params_slab)
        stage = jax.lax.axis_index(axis)
        fwd = [(i, i + 1) for i in range(s - 1)]

        def tick(carry, t):
            buf, out = carry  # buf: (mb, ...) current stage input
            # stage 0 injects microbatch t; others use what arrived
            inject = jnp.where(t < m, t, 0)
            x0 = xm[inject]
            x_in = jnp.where(stage == 0, x0, buf)
            active = (t - stage >= 0) & (t - stage < m)

            y = jax.checkpoint(stage_fn)(slab, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # hand off to the next stage
            nxt = jax.lax.ppermute(y, axis, fwd)
            # last stage records its finished microbatch
            done_idx = jnp.clip(t - (s - 1), 0, m - 1)
            is_last = stage == s - 1
            rec = (active & is_last)
            out = out.at[done_idx].set(jnp.where(rec, y, out[done_idx]))
            return (nxt, out), None

        # carries become device-varying after the ppermute: mark them so
        buf0 = compat.pcast(jnp.zeros_like(xm[0]), (axis,), to="varying")
        out0 = compat.pcast(jnp.zeros_like(xm), (axis,), to="varying")
        (buf, out), _ = jax.lax.scan(tick, (buf0, out0),
                                     jnp.arange(ticks, dtype=jnp.int32))
        # every stage holds `out`; only the last stage's is real
        return jax.lax.psum(jnp.where(stage == s - 1, out, jnp.zeros_like(out)),
                            axis)

    spec_p = jax.tree.map(lambda a: P(axis, *(None,) * (a.ndim - 1)), params_staged)
    fn = compat.shard_map(body, mesh=mesh, in_specs=(spec_p, P()), out_specs=P())
    return fn(params_staged, x_micro)


def stage_params(params_stacked, n_stages: int):
    """Reshape (L, ...) layer-stacked params to (S, L/S, ...) stage slabs."""
    def one(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape((n_stages, l // n_stages) + a.shape[1:])
    return jax.tree.map(one, params_stacked)
