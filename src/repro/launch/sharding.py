"""Sharding rules: logical param axes -> mesh axes (MaxText-style).

Rules are plain data so §Perf hillclimbing edits tables, not model code.
Every rule is divisibility-checked against the actual dim size; a dim that
does not divide falls back to replication (compile-success guarantee — the
dry-run must never fail on an awkward head count).

Train layout (DP/FSDP x TP, 2-D sharded params — required to fit 104B +
Adam in 16 GB/chip):   embed-ish dims -> 'data' (FSDP), wide dims -> 'model'.
Decode layout: params TP over 'model', replicated over 'data' (batch over
'data'); FSDP would force per-step all-gathers on the latency path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import leading_axis_spec
from repro.models.spec import P as SpecP, is_spec

# logical axis -> mesh axis (axis tuples allowed), per step kind
TRAIN_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "embed": "data",      # FSDP shard over data
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "expert": "model",    # EP
    "layers": None,
}

DECODE_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "expert": "model",
    "layers": None,
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def spec_to_pspec(spec: SpecP, mesh: Mesh, rules: Dict[str, Optional[str]]) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback."""
    used = set()
    out = []
    for dim, ax in zip(spec.shape, spec.axes):
        mesh_axis = rules.get(ax) if ax is not None else None
        if mesh_axis is None or mesh_axis in used:
            out.append(None)
            continue
        if dim % _axis_size(mesh, mesh_axis) != 0:
            out.append(None)  # replicate: non-divisible (e.g. 40 heads / 16)
            continue
        used.add(mesh_axis)
        out.append(mesh_axis)
    return P(*out)


def param_shardings(specs, mesh: Mesh, rules=None):
    """NamedSharding tree for a spec tree."""
    rules = rules or TRAIN_RULES
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules)),
        specs, is_leaf=is_spec)


def param_pspecs(specs, mesh: Mesh, rules=None):
    rules = rules or TRAIN_RULES
    return jax.tree.map(lambda s: spec_to_pspec(s, mesh, rules), specs,
                        is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------


def _dp(mesh: Mesh):
    names = mesh.axis_names
    axes = tuple(a for a in ("pod", "data") if a in names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_pspec(mesh: Mesh, batch_specs: dict) -> dict:
    """Token batches: leading (batch) dim over DP axes when divisible."""
    dp = _dp(mesh)
    dp_size = _axis_size(mesh, dp)

    def one(s):
        b = s.shape[0]
        lead = dp if (dp is not None and b % dp_size == 0) else None
        return leading_axis_spec(lead, len(s.shape))

    return jax.tree.map(one, batch_specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_pspec(mesh: Mesh, cache_specs: dict, cfg) -> dict:
    """Decode caches.

    KV caches (L, B, S, KVH, HD): batch over DP; head_dim over 'model'
    (always 128-divisible) — scores contract HD with a small psum, keeping
    the big cache tensors fully sharded even when KVH < mesh model size.
    SSM states (L, B, H, N, P): batch over DP, heads over 'model'.
    """
    dp = _dp(mesh)
    dp_size = _axis_size(mesh, dp)
    tp = "model" if "model" in mesh.axis_names else None
    tp_size = _axis_size(mesh, tp)

    def one(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        b = s.shape[1]
        bax = dp if (dp is not None and b % dp_size == 0) else None
        if name in ("k_scale", "v_scale"):
            kv_ok = tp and s.shape[-1] % tp_size == 0
            seq_ok = tp and s.shape[2] % tp_size == 0
            if kv_ok:
                return P(None, bax, None, tp)
            if seq_ok:
                return P(None, bax, tp, None)
            return P(None, bax, None, None)
        if name in ("k", "v"):
            kv_ok = tp and s.shape[-2] % tp_size == 0
            seq_ok = tp and s.shape[2] % tp_size == 0
            if kv_ok:
                return P(None, bax, None, tp, None)  # head-sharded: no comms
            if seq_ok:
                # kv heads don't divide TP: shard the SEQUENCE dim. The
                # attention contraction over S turns into a small psum of
                # (B,H)-sized partials; head-dim sharding instead forces
                # involuntary replicate-repartition of the whole cache per
                # layer (measured 59 GiB temp on qwen decode_32k, §Perf).
                return P(None, bax, tp, None, None)
            return P(None, bax, None, None, None)
        if name == "ssm":
            h_ok = tp and s.shape[2] % tp_size == 0
            return P(None, bax, tp if h_ok else None, None, None)
        if name == "conv":
            c_ok = tp and s.shape[-1] % tp_size == 0
            return P(None, bax, None, tp if c_ok else None)
        return P(*(None,) * len(s.shape))

    return jax.tree_util.tree_map_with_path(one, cache_specs)


def with_dp_constraint(x, mesh: Mesh):
    """Activation constraint: batch dim over DP axes."""
    dp = _dp(mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, leading_axis_spec(dp, x.ndim)))
