"""Training driver: sharded train step, fault-tolerant loop, auto-resume.

Usage (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_1_6b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance (DESIGN.md §5): checkpoint every --ckpt-every steps with
atomic publish; --resume restores the latest valid step onto the *current*
mesh (elastic resharding — the mesh may differ from the writer's); the data
pipeline is stateless-seekable so step k always sees batch k.
"""
from __future__ import annotations

import argparse
import functools
import os
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import get_config
from repro.configs.shapes import token_input_specs, ShapeCell
from repro.data.pipeline import make_source
from repro.launch import sharding as shd
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import build_model
from repro.models import sharding_ctx
from repro.optim.adamw import AdamW, AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def make_train_step(model, optimizer, mesh, *, q_chunk=512, kv_chunk=1024,
                    donate=True):
    """jit'd SPMD train step with explicit in/out shardings."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if dp:
        sharding_ctx.set_policy(dp=dp if len(dp) > 1 else dp[0],
                                tp="model" if "model" in mesh.axis_names else None)
    specs = model.specs()
    p_sh = shd.param_shardings(specs, mesh, shd.TRAIN_RULES)
    opt_sh = AdamWState(NamedSharding(mesh, P()), p_sh, p_sh)

    def step_fn(state: TrainState, batch):
        def loss_fn(params):
            return model.loss(params, batch, q_chunk=q_chunk, kv_chunk=kv_chunk)

        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        return TrainState(new_params, new_opt), loss

    jit_kw = dict(
        in_shardings=(TrainState(p_sh, opt_sh), None),
        out_shardings=(TrainState(p_sh, opt_sh), NamedSharding(mesh, P())),
    )
    if donate:
        jit_kw["donate_argnums"] = (0,)
    return jax.jit(step_fn, **jit_kw), p_sh, opt_sh



def train_loop(args) -> dict:
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        ndev = len(jax.devices())
        mesh = make_mesh((ndev, 1), ("data", "model"))

    optimizer = AdamW(lr=args.lr, total_steps=args.steps,
                      warmup_steps=min(100, max(1, args.steps // 10)))
    step_fn, p_sh, opt_sh = make_train_step(
        model, optimizer, mesh, q_chunk=min(args.seq, 512),
        kv_chunk=min(args.seq, 1024))

    # --- init or elastic resume -------------------------------------------
    start_step = 0
    state = None
    if args.resume and args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            print(f"[resume] restoring step {latest} (elastic re-shard onto "
                  f"{len(jax.devices())} devices)")
            params0 = jax.eval_shape(lambda: model.abstract_params())
            opt0 = jax.eval_shape(lambda p: optimizer.init(p), params0)
            state = ckpt_lib.restore(args.ckpt_dir, latest,
                                     TrainState(params0, opt0),
                                     TrainState(p_sh, opt_sh))
            start_step = latest
    if state is None:
        with mesh:
            params = jax.jit(model.init, static_argnums=(),
                             out_shardings=p_sh)(jax.random.PRNGKey(args.seed))
            opt = jax.jit(optimizer.init, out_shardings=opt_sh)(params)
        state = TrainState(params, opt)

    source = make_source(cfg, args.seq, args.batch, seed=args.seed,
                         path=args.data or None)

    def put_batch(b):
        return {k: jax.device_put(v, NamedSharding(
            mesh, P("data" if v.shape[0] % mesh.shape["data"] == 0 else None,
                    *(None,) * (v.ndim - 1)))) for k, v in b.items()}

    # --- loop ----------------------------------------------------------------
    losses = []
    t_start = time.perf_counter()
    for step in range(start_step, args.steps):
        batch = put_batch(source.batch_at(step))
        state, loss = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            lv = float(loss)
            losses.append((step, lv))
            tok_s = args.batch * args.seq * (step - start_step + 1) / (
                time.perf_counter() - t_start)
            print(f"step {step:5d} loss {lv:.4f} ({tok_s:,.0f} tok/s)")
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            path = ckpt_lib.save(args.ckpt_dir, step + 1, state)
            ckpt_lib.cleanup(args.ckpt_dir, keep=3)
            print(f"[ckpt] step {step + 1} -> {path}")
    return {"losses": losses, "final_loss": losses[-1][1] if losses else None,
            "state": state}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true", help="reduced config")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--data", default="", help="token file (memmap); synthetic if empty")
    p.add_argument("--mesh", default="local", choices=["local", "production"])
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=0)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)
    out = train_loop(args)
    print("final loss:", out["final_loss"])


if __name__ == "__main__":
    main()
