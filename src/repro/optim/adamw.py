"""AdamW optimizer (built in-tree: no external deps), pytree-generic.

State is a pytree mirroring params (m, v) + step counter; everything is
shard-friendly (states inherit param shardings — the ZeRO/FSDP layout falls
out of the sharding rules in launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    lr_min_ratio: float = 0.1
    total_steps: int = 10000

    def init(self, params) -> AdamWState:
        z = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), t)
        return AdamWState(jnp.zeros((), jnp.int32), z(params), z(params))

    def schedule(self, step):
        """Linear warmup + cosine decay to lr_min_ratio."""
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        prog = jnp.clip((step - self.warmup_steps)
                        / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (self.lr_min_ratio + (1 - self.lr_min_ratio) * cos)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state.v, grads)
        mh = jax.tree.map(lambda mm: mm / (1 - b1 ** step.astype(jnp.float32)), m)
        vh = jax.tree.map(lambda vv: vv / (1 - b2 ** step.astype(jnp.float32)), v)
        lr = self.schedule(step)

        def upd(p, mm, vv):
            u = mm / (jnp.sqrt(vv) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mh, vh)
        return new_params, AdamWState(step, m, v)
