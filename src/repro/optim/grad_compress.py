"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick, DESIGN.md §5).

int8 block-quantized gradients with error feedback: each tensor is scaled
per block of 256 values, quantized to int8, all-reduced (or psum'd) in the
compressed domain is NOT generally valid for int8, so the scheme used here
is quantize -> dequantize *around* the cross-pod reduce: the intra-pod
reduce runs in bf16 (fast ICI), only the slow pod axis sees 4x fewer bytes
(the standard hierarchical-compression layout). Error feedback keeps the
quantization noise from biasing convergence.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g -> (int8 payload, f32 per-block scales)."""
    flat, n = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return deq[:n].reshape(shape).astype(dtype)


def compress_tree(grads, errors=None):
    """Quantize a gradient pytree with error feedback.

    Returns (payload_tree, new_error_tree) where payload leaves are
    (int8, scales) tuples."""
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(errors)
    qs, new_e = [], []
    for g, e in zip(flat, eflat):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s, g.shape, jnp.float32)
        qs.append((q, s))
        new_e.append(corrected - deq)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, new_e))


def decompress_tree(payload, shapes_like):
    flat_p = jax.tree.leaves(payload, is_leaf=lambda x: isinstance(x, tuple))
    flat_s, treedef = jax.tree.flatten(shapes_like)
    out = [dequantize(q, s, ref.shape, ref.dtype)
           for (q, s), ref in zip(flat_p, flat_s)]
    return jax.tree.unflatten(treedef, out)


def compressed_psum(grads, axis_name: str, errors=None):
    """Cross-axis gradient mean with int8 wire format + error feedback.

    Used for the 'pod' axis where links are the scarcest resource; the
    reduce itself runs on dequantized f32 (psum of int8 would overflow and
    is not what TPU collectives implement) — the *bytes on the wire* under
    XLA are the int8 payload + scales after fusion of the dequant into the
    collective's operand. Falls back to plain psum when axis is absent.
    """
    payload, new_errors = compress_tree(grads, errors)
    deq = decompress_tree(payload, grads)
    summed = jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), deq)
    return summed, new_errors
