"""FormatPolicy — the unified format-selection front-end.

One object answers "which format should this matrix be stored in?" four
ways, with an explicit fallback chain so every mode always returns a pick:

    mode="profile"   run every candidate, pick the fastest (ground truth;
                     needs real profiling runs — setup-phase only).
    mode="ml"        pre-trained decision tree over pattern features
                     (arXiv:2303.05098); falls back to analytic when no
                     tree is available or it predicts outside the
                     candidate set.
    mode="analytic"  bytes-touched / bandwidth model; zero measurements.
    mode="cached"    persistent per-(pattern, backend, device) cache; on a
                     miss, selects via the ml chain and stores the result —
                     a warm cache answers from a dict lookup, with no
                     profiling or prediction work at all.

The chain is therefore:  cached -> ml -> analytic  (profile never runs
unless explicitly requested, it is the only mode that must execute device
code).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convert import (SwitchPlan, plan_switch as _plan_switch,
                                to_coo as _to_coo_fn)
from repro.obs import ledger as _ledger
from repro.obs import trace as _trace
from repro.core.dynamic import DEFAULT_CANDIDATES, DynamicMatrix
from repro.core.formats import Format
from repro.tuning.cache import SelectionCache
from repro.tuning.engines import TuneReport, analytic_select, profile_select
from repro.tuning.features import FEATURE_NAMES, PatternFeatures, batch_features
from repro.tuning.tree import DecisionTree, load_default_tree

MODES = ("ml", "profile", "analytic", "cached")


class FormatPolicy:
    """Format selector with mode ``"ml" | "profile" | "analytic" | "cached"``.

    Parameters
    ----------
    mode: selection strategy (see module docstring for the fallback chain).
    candidates: formats considered; the pick is always one of these.
    tree: a ``DecisionTree``, a path to a serialized one, or None for the
        packaged default tree.
    cache: a ``SelectionCache``, a path, or None for the default location
        (``$REPRO_TUNING_CACHE`` or ``~/.cache/repro-tuning``).
    profile_iters: timing repetitions in profile mode.
    """

    def __init__(self, mode: str = "ml",
                 candidates: Sequence[Format] = DEFAULT_CANDIDATES,
                 tree: Union[DecisionTree, str, None] = None,
                 cache: Union[SelectionCache, str, None] = None,
                 profile_iters: int = 6):
        if mode not in MODES:
            raise ValueError(f"mode {mode!r} not in {MODES}")
        self.mode = mode
        self.candidates = tuple(Format(c) for c in candidates)
        self._tree = DecisionTree.load(tree) if isinstance(tree, str) else tree
        self._tree_resolved = tree is not None and not isinstance(tree, str)
        self.cache = (cache if isinstance(cache, SelectionCache)
                      else SelectionCache(cache))
        self.profile_iters = profile_iters

    # -- the tree (lazy: loading JSON per policy instance is wasteful) -------

    @property
    def tree(self) -> Optional[DecisionTree]:
        if self._tree is None and not self._tree_resolved:
            self._tree = load_default_tree()
            self._tree_resolved = True
        return self._tree

    # -- selection ----------------------------------------------------------

    def select(self, A, x=None, op: str = "spmv",
               ncols: Optional[int] = None) -> TuneReport:
        """Pick a format for ``A`` (a concrete container or DynamicMatrix).

        ``x`` is only used by profile mode (synthesized as ones when
        absent). ``op``/``ncols`` state the *computation* the pick is for
        — ``op="spmm"``/``"spmm_t"`` with the rhs batch width makes the
        decision batch-width-aware: profile mode measures the actual SpMM
        at that width, cached mode keys the stored decision by
        (op, width bucket), and the pinned kernel config comes from the
        matching width bucket. The default (``"spmv"``) preserves the
        historical pattern-only behaviour and cache keys.
        """
        # detail is the decision ledger's workspace: the inner tiers fill
        # in what they actually did (cache hit/miss, tree path, scores,
        # kernel pin/veto) only when the ledger wants a record.
        detail: Optional[dict] = {} if _ledger.enabled() else None
        if _trace.mode() == "off":
            rep = self._select(A, x, op, ncols, detail)
        else:
            with _trace.span("select.policy", mode=self.mode, op=op) as sp:
                rep = self._select(A, x, op, ncols, detail)
                sp.set(chosen=Format(rep.best).name, tier=rep.mode,
                       backend=rep.backend or "auto")
        if detail is not None:
            _ledger.record("format.select", mode=self.mode, op=op,
                           ncols=ncols, chosen=Format(rep.best).name,
                           tier=rep.mode, backend=rep.backend,
                           cfg=dict(rep.cfg) if rep.cfg else None, **detail)
        return rep

    def _select(self, A, x=None, op: str = "spmv",
                ncols: Optional[int] = None,
                detail: Optional[dict] = None) -> TuneReport:
        A = A.concrete if isinstance(A, DynamicMatrix) else A
        if self.mode == "profile":
            if x is None:
                if op == "spmm":
                    x = jnp.ones((A.shape[1], ncols or 1), A.dtype)
                elif op == "spmm_t":
                    x = jnp.ones((ncols or 1, A.shape[1]), A.dtype)
                else:
                    x = jnp.ones((A.shape[1],), A.dtype)
            rep = profile_select(A, x, candidates=self.candidates,
                                 iters=self.profile_iters, op=op)
            _fill_scores(detail, rep)
            return rep

        feats = PatternFeatures.from_coo(_to_coo_fn(A))
        _fill_features(detail, feats)
        if self.mode == "analytic":
            rep = analytic_select(feats.to_stats(), candidates=self.candidates)
            _fill_scores(detail, rep)
            return rep
        if self.mode == "ml":
            return self._select_ml(feats, detail)

        # mode == "cached"
        from repro.tuning import kernel_tune

        key = SelectionCache.key(feats, self.candidates, jax.default_backend(),
                                 _device_kind(), op_ctx=_op_ctx(op, ncols))
        hit = self.cache.get_decision(key)
        if hit is not None and hit[0] in self.candidates:
            fmt, kb, cfg, tag = hit
            if kb is not None and tag != kernel_tune.backend_tag():
                # the pinned (backend, cfg) was measured under a different
                # kernel-execution mode (interp vs native): never replay it —
                # re-derive the pin from this mode's kernel records instead.
                if detail is not None:
                    detail["cache"] = ("hit (stale backend tag — kernel pin "
                                       "re-derived for this mode)")
                kb, cfg = self._kernel_decision(fmt, feats, op=op, ncols=ncols,
                                                detail=detail)
            elif detail is not None:
                detail["cache"] = "hit"
            return TuneReport(fmt, {}, "cached", backend=kb, cfg=cfg)
        if detail is not None:
            detail["cache"] = ("miss" if hit is None
                               else "stale (cached pick left the candidate "
                                    "set) — reselected")
        rep = self._select_ml(feats, detail)
        kb, cfg = self._kernel_decision(rep.best, feats, op=op, ncols=ncols,
                                        detail=detail)
        self.cache.put_decision(key, rep.best, kb, cfg,
                                tag=kernel_tune.backend_tag() if kb else None)
        return TuneReport(rep.best, rep.times, f"cached-miss:{rep.mode}",
                          backend=kb, cfg=cfg)

    __call__ = select

    def select_batch(self, A, x=None) -> np.ndarray:
        """Per-shard selection over a *stacked* COO batch (leading axis P).

        Returns an int32 ``(P,)`` vector of indices into ``self.candidates``
        — the per-shard format-id vector a stacked ``SwitchDynamicMatrix``
        dispatches on. For the ``cached``/``ml``/``analytic`` modes the
        whole batch is featurised in one vmapped device pass
        (:func:`repro.tuning.features.batch_features`, a single planned
        host pull independent of P); the per-shard work that remains is
        host-side dict/tree lookups only — no profiling runs, no per-shard
        conversions, no index arrays through host. ``profile`` mode has no
        batched analogue (it must execute each shard's candidates) and
        falls back to per-shard :meth:`select` — setup-phase only.
        """
        A = A.concrete if isinstance(A, DynamicMatrix) else A
        nparts = int(jax.tree_util.tree_leaves(A)[0].shape[0])
        if _trace.mode() != "off":
            with _trace.span("select.batch", mode=self.mode, parts=nparts):
                ids = self._select_batch(A, x, nparts)
        else:
            ids = self._select_batch(A, x, nparts)
        if _ledger.enabled():
            counts: dict = {}
            for i in ids:
                name = self.candidates[int(i)].name
                counts[name] = counts.get(name, 0) + 1
            _ledger.record("format.select_batch", mode=self.mode,
                           parts=nparts, chosen_counts=counts)
        return ids

    def _select_batch(self, A, x, nparts: int) -> np.ndarray:
        if self.mode == "profile":
            ids = [self.candidates.index(
                self.select(jax.tree.map(lambda a, i=i: a[i], A), x=x).best)
                for i in range(nparts)]
            return np.asarray(ids, np.int32)

        feats = batch_features(A)
        ids = np.empty(nparts, np.int32)
        if self.mode == "cached":
            backend = jax.default_backend()
            kind = _device_kind()
            autoflush, self.cache.autoflush = self.cache.autoflush, False
            wrote = False
            try:
                from repro.tuning import kernel_tune
                ktag = kernel_tune.backend_tag()
                for i, f in enumerate(feats):
                    key = SelectionCache.key(f, self.candidates, backend, kind)
                    best = self.cache.get(key)
                    if best is None or best not in self.candidates:
                        best = self._select_ml(f).best
                        kb, cfg = self._kernel_decision(best, f)
                        self.cache.put_decision(key, best, kb, cfg,
                                                tag=ktag if kb else None)
                        wrote = True
                    ids[i] = self.candidates.index(best)
            finally:
                self.cache.autoflush = autoflush
            if wrote and autoflush:
                self.cache.flush()  # one write for the whole batch
            return ids

        for i, f in enumerate(feats):
            if self.mode == "analytic":
                best = analytic_select(f.to_stats(),
                                       candidates=self.candidates).best
            else:  # "ml"
                best = self._select_ml(f).best
            ids[i] = self.candidates.index(best)
        return ids

    def plan_for(self, A, fmt=None, x=None, **hints) -> SwitchPlan:
        """Select a format for ``A`` (unless ``fmt`` is given) and return
        the :class:`SwitchPlan` the jit-able numeric phase needs — the
        policy-supplied half of the plan/execute switch pipeline.

        ``hints`` (``k=``, ``offsets=``, ``block_size=``, ...) forward to
        ``plan_switch`` and short-circuit the device analysis. For SELL
        the tuned kernel record's ``(c, sigma)`` — container geometry, not
        kernel kwargs — seeds the plan when the caller gave no explicit
        hint, so a measured slicing choice survives the format switch.
        """
        A = A.concrete if isinstance(A, DynamicMatrix) else A
        if fmt is None:
            fmt = self.select(A, x=x).best
        fmt = Format(fmt)
        geometry_source = "caller hints" if hints else None
        if fmt == Format.SELL and "c" not in hints and "sigma" not in hints:
            from repro.tuning import kernel_tune
            rec = kernel_tune.best_config_for(
                fmt, A.shape[0], A.shape[1], max(1, int(getattr(A, "nnz", 1))),
                cache=self.cache)
            if rec is not None and "c" in rec.cfg:
                hints = dict(hints, c=int(rec.cfg["c"]),
                             sigma=int(rec.cfg.get("sigma", 8 * rec.cfg["c"])))
                geometry_source = "tuned kernel record"
        if _ledger.enabled():
            _ledger.record("plan.switch", fmt=fmt.name,
                           hints={k: v for k, v in hints.items()
                                  if isinstance(v, (int, float, str, bool))},
                           geometry_source=geometry_source)
        return _plan_switch(A, fmt, **hints)

    def _kernel_decision(self, fmt: Format, feats: PatternFeatures,
                         op: str = "spmv", ncols: Optional[int] = None,
                         detail: Optional[dict] = None):
        """(backend, cfg) to pin alongside a format pick: the tuned Pallas
        tile config for the pattern's (shape bucket[, rhs-width bucket])
        when one is cached AND measured faster than ref; (None, None)
        otherwise — the decision stays format-only and
        ``spmv(backend="auto")`` routes per call.

        The lookup goes through *this policy's* cache: format selections
        and kernel records share one JSON store, so a policy configured
        with its own cache file must consult that file, not the process
        default."""
        from repro.tuning import kernel_tune

        rec = kernel_tune.best_config_for(Format(fmt), feats.m, feats.n,
                                          max(1, feats.nnz), op=op,
                                          ncols=ncols, cache=self.cache)
        if detail is not None and rec is not None:
            detail["kernel"] = _kernel_dict(rec)
        if rec is not None and rec.speedup >= 1.0:
            return "pallas", dict(rec.cfg)
        if detail is not None:
            detail["kernel_veto"] = (
                f"cached kernel measured {rec.speedup:.2f}x vs ref (< 1.0): "
                "Pallas pin refused" if rec is not None
                else "no tuned kernel record for this "
                     "(format, shape bucket, op) — route stays auto/ref")
        return None, None

    def _select_ml(self, feats: PatternFeatures,
                   detail: Optional[dict] = None) -> TuneReport:
        tree = self.tree
        if tree is not None:
            vec = feats.vector()
            fmt = Format(tree.predict_one(vec))
            if detail is not None:
                path = tree.decision_path(vec)
                for step in path:
                    if step.get("leaf"):
                        step["predict_name"] = Format(step["predict"]).name
                detail["tree_path"] = path
            if fmt in self.candidates:
                return TuneReport(fmt, {}, "ml")
            if detail is not None:
                detail["tree_rejected"] = (f"{fmt.name} outside the candidate "
                                           "set — analytic fallback")
        # no tree shipped, or it predicts a format outside the candidate set
        rep = analytic_select(feats.to_stats(), candidates=self.candidates)
        _fill_scores(detail, rep)
        return rep


def _fill_features(detail: Optional[dict], feats: PatternFeatures) -> None:
    if detail is not None:
        detail["features"] = {n: float(v) for n, v in
                              zip(FEATURE_NAMES, feats.vector())}


def _fill_scores(detail: Optional[dict], rep: TuneReport) -> None:
    if detail is not None and rep.times:
        detail["scores"] = {Format(f).name: float(t)
                            for f, t in rep.times.items()}


def _kernel_dict(rec) -> dict:
    return {"fmt": rec.fmt, "op": rec.op, "cfg": dict(rec.cfg),
            "kernel_us": float(rec.kernel_us), "ref_us": float(rec.ref_us),
            "speedup": float(rec.speedup)}


def _op_ctx(op: str, ncols: Optional[int]) -> str:
    """Cache-key op context: empty for spmv (historical keys unchanged),
    ``"spmm-b<lg width>"`` for the batched ops."""
    if op == "spmv":
        return ""
    from repro.tuning import kernel_tune
    return f"{op}-{kernel_tune.rhs_bucket(ncols)}"


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind
    except (IndexError, RuntimeError):
        return "unknown"
