"""Synthetic training corpus + trainer for the format-selection classifier.

Families span the pattern regimes the paper's evaluation covers (stencil /
banded regular matrices, uniform random, power-law row lengths, block
structure), sized so that labeling on a CPU host finishes in minutes.
Labels come from ``profile_select`` on the *current* backend — the winning
format varies per device (Morpheus-unleashed observation), so a shipped
tree is a per-backend-family artifact and ``python -m repro.tuning.corpus``
retrains it in place.

    python -m repro.tuning.corpus --samples 240 --holdout 0.25

writes ``default_tree.json`` next to this file and prints train/holdout
agreement with the profiling oracle.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hpcg
from repro.core.formats import COO, Format, banded_coo, coo_from_arrays, random_coo
from repro.tuning.engines import predicted_bytes, profile_select
from repro.tuning.features import PatternFeatures
from repro.tuning.tree import (DEFAULT_TREE_PATH, DecisionTree,
                               load_default_tree)

FAMILIES = ("stencil27", "stencil7", "banded", "random", "powerlaw", "block")

DEFAULT_CANDIDATES = (Format.COO, Format.CSR, Format.DIA, Format.ELL, Format.SELL)


def make_matrix(family: str, rng: np.random.Generator) -> COO:
    """One random matrix from ``family`` (host-side, CPU-tractable size)."""
    if family == "stencil27":
        dims = rng.integers(6, 13, size=3)
        prob = hpcg.generate_problem(*map(int, dims))
        return hpcg.to_coo(prob)
    if family == "stencil7":
        nx, ny, nz = map(int, rng.integers(6, 14, size=3))
        n = nx * ny * nz
        offs = sorted({-nx * ny, -nx, -1, 0, 1, nx, nx * ny})
        return banded_coo((n, n), offs)
    if family == "banded":
        n = int(rng.integers(256, 4097))
        ndiag = int(rng.integers(3, 28))
        band = max(1, int(rng.integers(1, max(2, n // 8))))
        offs = rng.choice(np.arange(-band, band + 1), size=min(ndiag, 2 * band + 1),
                          replace=False)
        offs = np.unique(np.append(offs, 0))
        return banded_coo((n, n), [int(o) for o in offs])
    if family == "random":
        n = int(rng.integers(128, 1025))
        density = float(10 ** rng.uniform(-3, -0.9))
        return random_coo(int(rng.integers(0, 2 ** 31 - 1)), (n, n),
                          density=density)
    if family == "powerlaw":
        n = int(rng.integers(256, 2049))
        shape = float(rng.uniform(1.05, 2.0))
        scale = float(rng.uniform(1.0, 6.0))
        rows, cols = [], []
        for i in range(n):
            k = int(min(n, 1 + rng.pareto(shape) * scale))
            c = rng.choice(n, size=k, replace=False)
            rows.append(np.full(k, i, np.int64))
            cols.append(np.sort(c).astype(np.int64))
        r = np.concatenate(rows)
        c = np.concatenate(cols)
        v = rng.standard_normal(len(r)).astype(np.float32)
        v = np.where(np.abs(v) < 1e-3, 1e-3, v)
        return coo_from_arrays(r, c, v, (n, n))
    if family == "block":
        bs = int(rng.choice([8, 16, 32]))
        nb = int(rng.integers(8, 33))
        n = bs * nb
        occ = max(nb, int(rng.uniform(0.02, 0.15) * nb * nb))
        blk = rng.choice(nb * nb, size=min(occ, nb * nb), replace=False)
        br, bc = blk // nb, blk % nb
        ii, jj = np.meshgrid(np.arange(bs), np.arange(bs), indexing="ij")
        r = (br[:, None, None] * bs + ii[None]).ravel()
        c = (bc[:, None, None] * bs + jj[None]).ravel()
        v = rng.standard_normal(len(r)).astype(np.float32)
        v = np.where(np.abs(v) < 1e-3, 1e-3, v)
        order = np.lexsort((c, r))
        return coo_from_arrays(r[order], c[order], v[order], (n, n))
    raise ValueError(f"unknown corpus family {family!r}")


def generate_corpus(n_samples: int, seed: int = 0,
                    families: Sequence[str] = FAMILIES
                    ) -> Tuple[List[COO], List[str]]:
    """``n_samples`` matrices cycling through ``families``."""
    rng = np.random.default_rng(seed)
    mats, fams = [], []
    for i in range(n_samples):
        fam = families[i % len(families)]
        mats.append(make_matrix(fam, rng))
        fams.append(fam)
    return mats, fams


def label_matrix(A: COO,
                 candidates: Sequence[Format] = DEFAULT_CANDIDATES,
                 iters: int = 6, inner: int = 8,
                 tie_tol: float = 1.5) -> Format:
    """Profiling-oracle label for one matrix, with deterministic ties.

    Label reproducibility bounds the trained tree's achievable agreement
    with the oracle, so two measures are taken against timing noise:
    ``inner``-amortized timing (see ``engines.time_fn``), and a tie rule —
    when several candidates measure within ``tie_tol`` (relative) of the
    winner, the label falls back to the analytic byte model's cheapest
    format among them.

    ``tie_tol=1.5`` is deliberately wider than pure timing noise: it is a
    footprint-for-speed trade (a format up to 2.5x slower but smaller may
    be preferred — the SwitchDynamicMatrix union pays for every resident
    candidate, and shared-host measurements here swing by ~3x run to run).
    The end-to-end cost is measured, not assumed: bench_select reports the
    shipped tree's picks within ~1.1x (geomean) of the profiling oracle's
    SpMV time. Shrink ``tie_tol`` toward ~0.3 on a quiet, dedicated host.
    """
    x = jnp.ones((A.shape[1],), A.dtype)
    rep = profile_select(A, x, candidates=candidates, iters=iters, inner=inner)
    best_t = rep.times[rep.best]
    near = [f for f, t in rep.times.items() if t <= best_t * (1 + tie_tol)]
    if len(near) <= 1:
        return rep.best
    stats = PatternFeatures.from_coo(A).to_stats()
    return min(near, key=lambda f: predicted_bytes(stats, f))


def label_corpus(mats: Sequence[COO],
                 candidates: Sequence[Format] = DEFAULT_CANDIDATES,
                 iters: int = 6, inner: int = 8,
                 tie_tol: float = 1.5) -> np.ndarray:
    """``label_matrix`` over a corpus -> ``Format`` int values."""
    return np.asarray([int(label_matrix(A, candidates, iters, inner, tie_tol))
                       for A in mats], np.int64)


def build_dataset(mats: Sequence[COO]) -> np.ndarray:
    """Feature matrix (n_samples, len(FEATURE_NAMES))."""
    return np.stack([PatternFeatures.from_coo(A).vector() for A in mats])


def train_tree(X: np.ndarray, y: np.ndarray, max_depth: int = 10,
               min_samples_leaf: int = 2) -> DecisionTree:
    return DecisionTree().fit(X, y, max_depth=max_depth,
                              min_samples_leaf=min_samples_leaf)


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--samples", type=int, default=240)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--holdout", type=float, default=0.25)
    p.add_argument("--iters", type=int, default=8,
                   help="profiling repetitions per candidate (label quality)")
    p.add_argument("--max-depth", type=int, default=10)
    p.add_argument("--out", default=DEFAULT_TREE_PATH)
    args = p.parse_args(argv)

    print(f"generating {args.samples} matrices over {FAMILIES} ...")
    mats, fams = generate_corpus(args.samples, seed=args.seed)
    print("labeling with profile_select (this profiles every candidate) ...")
    y = label_corpus(mats, iters=args.iters)
    X = build_dataset(mats)
    dist = {Format(k).name: int(v) for k, v in
            zip(*map(list, np.unique(y, return_counts=True)))}
    print(f"label distribution: {dist}")

    rng = np.random.default_rng(args.seed + 1)
    perm = rng.permutation(len(y))
    n_hold = int(len(y) * args.holdout)
    hold, train = perm[:n_hold], perm[n_hold:]
    tree = train_tree(X[train], y[train], max_depth=args.max_depth)
    acc_train = tree.score(X[train], y[train])
    acc_hold = tree.score(X[hold], y[hold]) if n_hold else float("nan")
    print(f"tree: {tree.n_nodes} nodes; train acc {acc_train:.3f}, "
          f"holdout acc {acc_hold:.3f}")
    for fam in FAMILIES:
        idx = np.asarray([i for i in hold if fams[i] == fam])
        if idx.size:
            print(f"  holdout[{fam:9s}]: {tree.score(X[idx], y[idx]):.3f} "
                  f"(n={idx.size})")
    tree.save(args.out)
    if args.out == DEFAULT_TREE_PATH:
        load_default_tree.cache_clear()  # retrained in place: drop the memo
    print(f"saved -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
