"""Persistent on-disk format-selection cache.

Selections are keyed by *pattern signature x backend x device kind x
candidate set*: the winning format is a property of (sparsity pattern,
hardware), so a selection learned on one device class must never be replayed
on another (Morpheus-unleashed: the winner varies per device), while a
restarted process on the same device should pay zero re-selection cost —
the production answer to "profiling 512 shards x 6 formats each restart is
not viable".

The store is a flat JSON dict written atomically (tmp + rename); corrupt or
missing files degrade to an empty cache, never to an error.

Two value schemas share the store:

* v1 (legacy) — a bare format name (``"DIA"``). Still written by
  :meth:`SelectionCache.put` and always readable.
* v2 — a full *(format, backend, kernel config, mode tag)* decision
  encoded as ``"v2|DIA|pallas|cpu-interp|{\"tm\": 512}"`` via
  :func:`encode_decision`. The read path (:meth:`get` /
  :meth:`get_decision`) accepts both, so caches written by older
  versions keep working unchanged.

The ``kernel:`` key namespace (raw JSON values, see
``repro.tuning.kernel_tune``) rides the same store and flush path through
:meth:`get_raw`/:meth:`put_raw`.
"""
from __future__ import annotations

import hashlib
import json
import os
import warnings
from typing import Dict, Optional, Sequence, Tuple

from repro.core.formats import Format
from repro.obs import metrics as _metrics
from repro.tuning.features import PatternFeatures

CACHE_PATH_ENV = "REPRO_TUNING_CACHE"

# Versioned decision-value schema ("v2|FMT|backend|mode-tag|cfg-json").
# ``mode-tag`` records the kernel-execution mode the pinned (backend, cfg)
# was measured under (``kernel_tune.backend_tag()``, e.g. "cpu-interp"):
# readers must not replay a pin tuned in one mode against another.
DECISION_PREFIX = "v2|"


def encode_decision(fmt: Format, backend: Optional[str] = None,
                    cfg: Optional[dict] = None,
                    tag: Optional[str] = None) -> str:
    """Serialize a (format, backend, kernel cfg, mode tag) decision
    (schema v2)."""
    return (f"{DECISION_PREFIX}{Format(fmt).name}|{backend or ''}|{tag or ''}|"
            f"{json.dumps(cfg, sort_keys=True) if cfg else ''}")


def decode_decision(value: str) -> Tuple[Optional[Format], Optional[str],
                                         Optional[dict], Optional[str]]:
    """Parse a stored decision value, either schema.

    Returns ``(format, backend, cfg, tag)``; backend/cfg/tag are None for
    v1 values (or when the v2 fields are empty). Unknown formats decode
    to all-None — stale entries from an older format zoo.
    """
    backend: Optional[str] = None
    cfg: Optional[dict] = None
    tag: Optional[str] = None
    name = value
    if value.startswith(DECISION_PREFIX):
        try:
            name, backend_s, tag_s, cfg_s = \
                value[len(DECISION_PREFIX):].split("|", 3)
        except ValueError:
            return None, None, None, None
        backend = backend_s or None
        tag = tag_s or None
        if cfg_s:
            try:
                cfg = json.loads(cfg_s)
            except ValueError:
                cfg = None
    try:
        return Format[name], backend, cfg, tag
    except KeyError:
        return None, None, None, None


def default_cache_path() -> str:
    env = os.environ.get(CACHE_PATH_ENV)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro-tuning", "selections.json")


def pattern_signature(feats: PatternFeatures, digits: int = 4) -> str:
    """Stable short hash of the quantized feature vector + exact dims.

    Quantizing the float features makes the signature robust to numeric
    noise while still separating genuinely different patterns; the exact
    (m, n, nnz) triple is appended so distinct problems with coincidentally
    similar features stay distinct.
    """
    vec = feats.vector()
    payload = ",".join(f"{v:.{digits}e}" for v in vec)
    payload += f"|{feats.m}x{feats.n}|{feats.nnz}"
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


class SelectionCache:
    """Dict-on-disk of selection keys -> format names."""

    def __init__(self, path: Optional[str] = None, autoflush: bool = True):
        self.path = path or default_cache_path()
        self.autoflush = autoflush
        self._data: Optional[Dict[str, str]] = None
        self._write_failed = False

    # -- storage ------------------------------------------------------------

    def _load(self) -> Dict[str, str]:
        if self._data is None:
            self._data = self._read_disk()
        return self._data

    def _read_disk(self) -> Dict[str, str]:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return {}
        if not isinstance(raw, dict):
            return {}  # valid JSON but not a cache — degrade, don't crash
        return {str(k): str(v) for k, v in raw.items()}

    def flush(self) -> None:
        if self._data is None:
            return
        try:
            # Merge-on-flush: concurrent processes (one per host in a
            # multi-host launch) each rewrite the whole file; unioning with
            # what is on disk first means last-writer-wins only applies to
            # true per-key races, not to whole snapshots.
            self._data = {**self._read_disk(), **self._data}
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            tmp = f"{self.path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(self._data, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError as e:
            # An unwritable cache degrades to in-memory: selection must
            # never fail because persistence is unavailable.
            if not self._write_failed:
                self._write_failed = True
                warnings.warn(f"selection cache not persistable at "
                              f"{self.path!r}: {e}")

    def clear(self) -> None:
        self._data = {}
        if self.autoflush:
            self.flush()

    def __len__(self) -> int:
        return len(self._load())

    # -- keys & lookups ------------------------------------------------------

    @staticmethod
    def key(feats: PatternFeatures, candidates: Sequence[Format],
            backend: str, device_kind: str, op_ctx: str = "") -> str:
        """``op_ctx`` carries the operation context (e.g. ``"spmm-b8"``:
        op + rhs-width bucket) for selections that depend on the
        *computation*, not just the pattern — per Stylianou et al.
        (arXiv:2303.05098). Empty for SpMV, so historical keys are
        untouched and old caches keep answering."""
        cand = "-".join(Format(c).name for c in candidates)
        base = f"{pattern_signature(feats)}|{backend}|{device_kind}|{cand}"
        return f"{base}|{op_ctx}" if op_ctx else base

    def get(self, key: str) -> Optional[Format]:
        value = self._load().get(key)
        if value is None:
            _metrics.inc("selection.cache_miss")
            return None
        fmt = decode_decision(value)[0]
        _metrics.inc("selection.cache_hit" if fmt is not None
                     else "selection.cache_miss")
        return fmt

    def put(self, key: str, fmt: Format) -> None:
        self._load()[key] = Format(fmt).name
        if self.autoflush:
            self.flush()

    # -- v2 decision tuples (format, backend, kernel cfg, mode tag) ----------

    def get_decision(self, key: str) -> Optional[Tuple[Format, Optional[str],
                                                       Optional[dict],
                                                       Optional[str]]]:
        value = self._load().get(key)
        if value is None:
            _metrics.inc("selection.cache_miss")
            return None
        fmt, backend, cfg, tag = decode_decision(value)
        if fmt is None:
            _metrics.inc("selection.cache_miss")
            return None  # stale/corrupt entry — treat as a miss
        _metrics.inc("selection.cache_hit")
        return fmt, backend, cfg, tag

    def put_decision(self, key: str, fmt: Format,
                     backend: Optional[str] = None,
                     cfg: Optional[dict] = None,
                     tag: Optional[str] = None) -> None:
        self._load()[key] = encode_decision(fmt, backend, cfg, tag)
        if self.autoflush:
            self.flush()

    # -- raw string values (the kernel: namespace) ---------------------------

    def get_raw(self, key: str) -> Optional[str]:
        return self._load().get(key)

    def put_raw(self, key: str, value: str) -> None:
        self._load()[key] = str(value)
        if self.autoflush:
            self.flush()
