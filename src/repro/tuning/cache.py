"""Persistent on-disk format-selection cache.

Selections are keyed by *pattern signature x backend x device kind x
candidate set*: the winning format is a property of (sparsity pattern,
hardware), so a selection learned on one device class must never be replayed
on another (Morpheus-unleashed: the winner varies per device), while a
restarted process on the same device should pay zero re-selection cost —
the production answer to "profiling 512 shards x 6 formats each restart is
not viable".

The store is a flat JSON dict written atomically (tmp + rename); corrupt or
missing files degrade to an empty cache, never to an error.
"""
from __future__ import annotations

import hashlib
import json
import os
import warnings
from typing import Dict, Optional, Sequence

from repro.core.formats import Format
from repro.tuning.features import PatternFeatures

CACHE_PATH_ENV = "REPRO_TUNING_CACHE"


def default_cache_path() -> str:
    env = os.environ.get(CACHE_PATH_ENV)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro-tuning", "selections.json")


def pattern_signature(feats: PatternFeatures, digits: int = 4) -> str:
    """Stable short hash of the quantized feature vector + exact dims.

    Quantizing the float features makes the signature robust to numeric
    noise while still separating genuinely different patterns; the exact
    (m, n, nnz) triple is appended so distinct problems with coincidentally
    similar features stay distinct.
    """
    vec = feats.vector()
    payload = ",".join(f"{v:.{digits}e}" for v in vec)
    payload += f"|{feats.m}x{feats.n}|{feats.nnz}"
    return hashlib.sha1(payload.encode()).hexdigest()[:16]


class SelectionCache:
    """Dict-on-disk of selection keys -> format names."""

    def __init__(self, path: Optional[str] = None, autoflush: bool = True):
        self.path = path or default_cache_path()
        self.autoflush = autoflush
        self._data: Optional[Dict[str, str]] = None
        self._write_failed = False

    # -- storage ------------------------------------------------------------

    def _load(self) -> Dict[str, str]:
        if self._data is None:
            self._data = self._read_disk()
        return self._data

    def _read_disk(self) -> Dict[str, str]:
        try:
            with open(self.path) as f:
                raw = json.load(f)
        except (OSError, ValueError):
            return {}
        if not isinstance(raw, dict):
            return {}  # valid JSON but not a cache — degrade, don't crash
        return {str(k): str(v) for k, v in raw.items()}

    def flush(self) -> None:
        if self._data is None:
            return
        try:
            # Merge-on-flush: concurrent processes (one per host in a
            # multi-host launch) each rewrite the whole file; unioning with
            # what is on disk first means last-writer-wins only applies to
            # true per-key races, not to whole snapshots.
            self._data = {**self._read_disk(), **self._data}
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            tmp = f"{self.path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(self._data, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError as e:
            # An unwritable cache degrades to in-memory: selection must
            # never fail because persistence is unavailable.
            if not self._write_failed:
                self._write_failed = True
                warnings.warn(f"selection cache not persistable at "
                              f"{self.path!r}: {e}")

    def clear(self) -> None:
        self._data = {}
        if self.autoflush:
            self.flush()

    def __len__(self) -> int:
        return len(self._load())

    # -- keys & lookups ------------------------------------------------------

    @staticmethod
    def key(feats: PatternFeatures, candidates: Sequence[Format],
            backend: str, device_kind: str) -> str:
        cand = "-".join(Format(c).name for c in candidates)
        return f"{pattern_signature(feats)}|{backend}|{device_kind}|{cand}"

    def get(self, key: str) -> Optional[Format]:
        name = self._load().get(key)
        if name is None:
            return None
        try:
            return Format[name]
        except KeyError:
            return None  # stale entry from an older format zoo

    def put(self, key: str, fmt: Format) -> None:
        self._load()[key] = Format(fmt).name
        if self.autoflush:
            self.flush()
