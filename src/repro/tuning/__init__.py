"""repro.tuning — ML-driven, cache-backed format selection.

The production answer to the paper's "naive auto-tuner": a trained
classifier over sparsity-pattern features (arXiv:2303.05098) with
per-backend persistent caching (the winning format varies per device —
arXiv:2304.09511), fronted by ``FormatPolicy`` with a
cached -> ml -> analytic fallback chain.

    from repro.tuning import FormatPolicy
    policy = FormatPolicy("ml")
    fmt = policy.select(A).best

Retrain the packaged model on the current backend with
``python -m repro.tuning.corpus``.
"""
from repro.tuning.cache import (CACHE_PATH_ENV, SelectionCache,
                                decode_decision, default_cache_path,
                                encode_decision, pattern_signature)
from repro.tuning.engines import (GATHER_PENALTY, HBM_BW, TuneReport,
                                  analytic_select, calibrate_gather_penalty,
                                  predicted_bytes, profile_select, time_fn)
from repro.tuning.features import FEATURE_NAMES, PatternFeatures, PatternStats
from repro.tuning.kernel_tune import (KernelRecord, best_config,
                                      best_config_for, default_grid,
                                      kernel_key, shape_bucket, tune_kernel)
from repro.tuning.policy import MODES, FormatPolicy
from repro.tuning.tree import (DEFAULT_TREE_PATH, DecisionTree,
                               load_default_tree)

__all__ = [
    "FormatPolicy", "MODES",
    "PatternFeatures", "PatternStats", "FEATURE_NAMES",
    "DecisionTree", "load_default_tree", "DEFAULT_TREE_PATH",
    "SelectionCache", "pattern_signature", "default_cache_path",
    "CACHE_PATH_ENV", "encode_decision", "decode_decision",
    "TuneReport", "analytic_select", "profile_select", "predicted_bytes",
    "calibrate_gather_penalty", "time_fn", "HBM_BW", "GATHER_PENALTY",
    "KernelRecord", "tune_kernel", "best_config", "best_config_for",
    "default_grid", "kernel_key", "shape_bucket",
]

# The corpus generator/trainer is import-on-demand (repro.tuning.corpus):
# importing it here would re-trigger package init under `python -m`.
