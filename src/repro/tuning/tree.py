"""Pure-numpy CART decision-tree classifier for format selection.

A deliberately small, dependency-free implementation (the container ships no
sklearn): axis-aligned splits, Gini impurity, greedy growth with depth /
leaf-size / gain stopping rules. Trees serialize to plain JSON so a
pre-trained model can be checked into the package (``default_tree.json``)
and loaded on any backend.

Labels are ``Format`` integer values; ``predict`` returns them as stored, so
``Format(tree.predict_one(v))`` recovers the enum.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.tuning.features import FEATURE_NAMES


def _gini(counts: np.ndarray) -> np.ndarray:
    """Gini impurity of class-count rows; counts (..., n_classes)."""
    tot = counts.sum(axis=-1, keepdims=True)
    with np.errstate(invalid="ignore", divide="ignore"):
        p = counts / tot
    g = 1.0 - np.nansum(p * p, axis=-1)
    return np.where(tot[..., 0] > 0, g, 0.0)


class DecisionTree:
    """CART classifier stored as parallel node arrays.

    ``feature[i] < 0`` marks node i as a leaf predicting ``value[i]`` (an
    index into ``classes_``); internal nodes route ``x[feature] <= thresh``
    to ``left`` else ``right``.
    """

    def __init__(self, feature_names: Sequence[str] = FEATURE_NAMES):
        self.feature_names = tuple(feature_names)
        self.classes_: np.ndarray = np.zeros((0,), np.int64)
        self.feature = np.zeros((0,), np.int32)
        self.thresh = np.zeros((0,), np.float64)
        self.left = np.zeros((0,), np.int32)
        self.right = np.zeros((0,), np.int32)
        self.value = np.zeros((0,), np.int32)

    # -- training -----------------------------------------------------------

    def fit(self, X, y, max_depth: int = 10, min_samples_leaf: int = 2,
            min_gain: float = 1e-7) -> "DecisionTree":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.int64)
        if X.ndim != 2 or len(X) != len(y) or not len(y):
            raise ValueError(f"bad training set: X{X.shape} y{y.shape}")
        self.classes_, yi = np.unique(y, return_inverse=True)
        nodes = []  # list of [feature, thresh, left, right, value]

        def grow(idx: np.ndarray, depth: int) -> int:
            node_id = len(nodes)
            counts = np.bincount(yi[idx], minlength=len(self.classes_))
            majority = int(counts.argmax())
            nodes.append([-1, 0.0, -1, -1, majority])
            if (depth >= max_depth or len(idx) < 2 * min_samples_leaf
                    or counts.max() == len(idx)):
                return node_id
            split = self._best_split(X[idx], yi[idx], len(self.classes_),
                                     min_samples_leaf)
            if split is None or split[2] < min_gain:
                return node_id
            f, thr, _gain = split
            go_left = X[idx, f] <= thr
            nodes[node_id][0] = f
            nodes[node_id][1] = thr
            nodes[node_id][2] = grow(idx[go_left], depth + 1)
            nodes[node_id][3] = grow(idx[~go_left], depth + 1)
            return node_id

        grow(np.arange(len(yi)), 0)
        arr = np.asarray(nodes, np.float64)
        self.feature = arr[:, 0].astype(np.int32)
        self.thresh = arr[:, 1]
        self.left = arr[:, 2].astype(np.int32)
        self.right = arr[:, 3].astype(np.int32)
        self.value = arr[:, 4].astype(np.int32)
        return self

    @staticmethod
    def _best_split(X: np.ndarray, yi: np.ndarray, n_classes: int,
                    min_samples_leaf: int) -> Optional[Tuple[int, float, float]]:
        """Best (feature, threshold, gini gain) over all features, or None."""
        n = len(yi)
        onehot = np.eye(n_classes)[yi]
        base = float(_gini(onehot.sum(axis=0)))
        best = None
        for f in range(X.shape[1]):
            order = np.argsort(X[:, f], kind="stable")
            xs = X[order, f]
            # cumulative class counts left of each candidate split point
            left_counts = np.cumsum(onehot[order], axis=0)[:-1]
            right_counts = left_counts[-1] + onehot[order][-1] - left_counts
            nl = np.arange(1, n)
            valid = (xs[1:] != xs[:-1]) & (nl >= min_samples_leaf) \
                    & (n - nl >= min_samples_leaf)
            if not valid.any():
                continue
            g = (nl * _gini(left_counts) + (n - nl) * _gini(right_counts)) / n
            g = np.where(valid, g, np.inf)
            k = int(np.argmin(g))
            gain = base - float(g[k])
            if best is None or gain > best[2]:
                best = (f, float((xs[k] + xs[k + 1]) / 2), gain)
        return best

    # -- inference ----------------------------------------------------------

    def predict_one(self, x) -> int:
        x = np.asarray(x, np.float64)
        i = 0
        while self.feature[i] >= 0:
            i = self.left[i] if x[self.feature[i]] <= self.thresh[i] else self.right[i]
        return int(self.classes_[self.value[i]])

    def predict(self, X) -> np.ndarray:
        X = np.asarray(X, np.float64)
        return np.array([self.predict_one(row) for row in X], np.int64)

    def decision_path(self, x) -> list:
        """The node-by-node route ``predict_one(x)`` takes, as JSON-ready
        step dicts — the decision ledger's explanation of a tree pick.

        Internal-node steps carry the feature name, the sample's value,
        the threshold, and which side it went; the final step is the leaf
        with its predicted class (``predict``, an int ``Format`` value).
        """
        x = np.asarray(x, np.float64)
        path = []
        i = 0
        while self.feature[i] >= 0:
            f = int(self.feature[i])
            name = (self.feature_names[f]
                    if f < len(self.feature_names) else f"f{f}")
            v, thr = float(x[f]), float(self.thresh[i])
            went = "left" if v <= thr else "right"
            path.append({"node": i, "feature": name, "value": v,
                         "thresh": thr, "went": went})
            i = int(self.left[i] if v <= thr else self.right[i])
        path.append({"node": i, "leaf": True,
                     "predict": int(self.classes_[self.value[i]])})
        return path

    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y, np.int64)))

    @property
    def n_nodes(self) -> int:
        return len(self.feature)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "feature_names": list(self.feature_names),
            "classes": self.classes_.tolist(),
            "feature": self.feature.tolist(),
            "thresh": self.thresh.tolist(),
            "left": self.left.tolist(),
            "right": self.right.tolist(),
            "value": self.value.tolist(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "DecisionTree":
        t = cls(tuple(d["feature_names"]))
        t.classes_ = np.asarray(d["classes"], np.int64)
        t.feature = np.asarray(d["feature"], np.int32)
        t.thresh = np.asarray(d["thresh"], np.float64)
        t.left = np.asarray(d["left"], np.int32)
        t.right = np.asarray(d["right"], np.int32)
        t.value = np.asarray(d["value"], np.int32)
        return t

    def save(self, path: str) -> None:
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "DecisionTree":
        with open(path) as f:
            return cls.from_dict(json.load(f))


DEFAULT_TREE_PATH = os.path.join(os.path.dirname(__file__), "default_tree.json")


@functools.lru_cache(maxsize=1)
def load_default_tree() -> Optional[DecisionTree]:
    """The packaged pre-trained tree (``python -m repro.tuning.corpus``
    regenerates it and clears this memo); None when the package ships
    without one. Memoized: per-selection callers (one FormatPolicy per
    shard) must not re-read the JSON from disk every time."""
    if not os.path.exists(DEFAULT_TREE_PATH):
        return None
    return DecisionTree.load(DEFAULT_TREE_PATH)
