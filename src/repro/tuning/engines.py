"""Selection engines: the paper's profiling tuner + the analytic byte model.

Moved out of ``core.autotune`` (which keeps its public API as shims); the
``FormatPolicy`` front-end in ``repro.tuning.policy`` composes these with
the ML classifier and the persistent cache.

* ``profile_select`` — the paper's §V-E approach: run each candidate
  format's compiled SpMV a few times and pick the fastest.
* ``analytic_select`` — SpMV is memory-bandwidth bound, so predicted time =
  bytes_touched / HBM_bw with an irregularity penalty on gathered x
  accesses. Works at trace time, no profiling runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convert import convert_execute, plan_switch
from repro.core import ops as _ops
from repro.core.dynamic import DynamicMatrix
from repro.core.formats import Format
from repro.tuning.features import PatternStats

# v5e-class constants; overridable for other targets.
HBM_BW = 819e9  # bytes/s
GATHER_PENALTY = 4.0  # effective-bandwidth derate for data-dependent gathers

# Measured gather penalty, keyed by jax.default_backend(): a process that
# mixes backends (cpu tests + tpu jobs) must not reuse the wrong number.
_CALIBRATED_PENALTY: Dict[str, float] = {}


def calibrate_gather_penalty(n: int = 1 << 18, iters: int = 5) -> float:
    """Measure the *actual* gather-vs-stream bandwidth ratio of the running
    backend and use it as the analytic model's penalty (makes the
    no-profiling tuner performance-portable — the v5e default of 4.0 is
    wrong on e.g. CPU). Cached per backend per process."""
    backend = jax.default_backend()
    if backend in _CALIBRATED_PENALTY:
        return _CALIBRATED_PENALTY[backend]
    key = np.random.default_rng(0)
    x = jnp.asarray(key.standard_normal(n).astype(np.float32))
    idx = jnp.asarray(key.integers(0, n, n).astype(np.int32))
    stream = jax.jit(lambda v: v * 2.0 + 1.0)
    gather = jax.jit(lambda v, i: jnp.take(v, i, mode="clip"))
    t_s = time_fn(stream, x, iters=iters)
    t_g = time_fn(gather, x, idx, iters=iters)
    penalty = float(max(1.0, t_g / max(t_s, 1e-9)))
    _CALIBRATED_PENALTY[backend] = penalty
    return penalty


@dataclasses.dataclass
class TuneReport:
    """A selection decision: the winning format plus — when the engine
    resolved them — the kernel backend and tile config to run it with.

    ``backend``/``cfg`` are None when the decision is format-only (the
    historical schema); ``repro.core.ops.spmv(backend="auto")`` then
    routes per call from the kernel-config cache instead.
    """

    best: Format
    times: Dict[Format, float]  # seconds (measured or predicted)
    mode: str
    backend: Optional[str] = None   # "ref" | "pallas" | None (unresolved)
    cfg: Optional[dict] = None      # kernel tile config for `backend`

    def __repr__(self):
        rows = ", ".join(f"{f.name}={t:.3e}s" for f, t in self.times.items())
        extra = f", backend={self.backend}, cfg={self.cfg}" if self.backend else ""
        return f"TuneReport(best={self.best.name}, mode={self.mode}{extra}, {rows})"


def predicted_bytes(stats: PatternStats, fmt: Format,
                    gather_penalty: Optional[float] = None) -> float:
    """Bytes touched by one SpMV in ``fmt`` (matrix + x-access cost model)."""
    GATHER = gather_penalty if gather_penalty is not None else GATHER_PENALTY
    w, m, n = stats.itemsize, stats.m, stats.n
    ii = 4  # index itemsize
    if fmt == Format.COO:
        mat = stats.nnz * (2 * ii + w)
        x = stats.nnz * w * GATHER
    elif fmt == Format.CSR:
        mat = stats.nnz * (ii + w) + (m + 1) * ii
        x = stats.nnz * w * GATHER
    elif fmt == Format.DIA:
        mat = stats.ndiag * m * w + stats.ndiag * ii
        x = stats.ndiag * m * w  # contiguous shifted reads: NO penalty
    elif fmt == Format.ELL:
        mat = stats.max_row_nnz * m * (ii + w)
        x = stats.max_row_nnz * m * w * GATHER
    elif fmt == Format.SELL:
        # sigma-window sorting pads each C-row slice only to its own width:
        # slack grows with row-length dispersion but is bounded well below
        # ELL's global-kmax blowup. Model slots as nnz inflated by a cv-
        # scaled factor, clamped to the ELL ceiling; the permutation adds
        # one index read per row (scatter back to matrix order).
        cv = float(getattr(stats, "row_cv", 0.0))
        slots = min(float(stats.max_row_nnz * m),
                    stats.nnz * (1.0 + 0.35 * min(cv, 4.0)) + m)
        mat = slots * (ii + w) + m * ii
        x = slots * w * GATHER
    elif fmt == Format.BSR:
        bs = 128
        blocks = max(1, int(np.ceil(stats.nnz / (bs * bs))))  # lower bound
        mat = blocks * bs * bs * w + blocks * ii
        x = blocks * bs * w
    elif fmt == Format.HYB:
        k = min(stats.max_row_nnz, max(1, stats.nnz // max(1, stats.m)))
        ell_n = min(stats.nnz, k * stats.m)
        coo_n = stats.nnz - ell_n
        mat = ell_n * (ii + w) + coo_n * (2 * ii + w)
        x = (ell_n + coo_n) * w * GATHER
    elif fmt == Format.DENSE:
        mat = m * n * w
        x = n * w * max(1, m // 1024)
    else:
        raise ValueError(fmt)
    y = m * w
    return float(mat + x + y)


def analytic_select(stats: PatternStats,
                    candidates: Sequence[Format] = (Format.COO, Format.CSR, Format.DIA, Format.ELL, Format.SELL),
                    hbm_bw: float = HBM_BW,
                    calibrate: bool = False) -> TuneReport:
    pen = calibrate_gather_penalty() if calibrate else None
    times = {Format(f): predicted_bytes(stats, Format(f), pen) / hbm_bw
             for f in candidates}
    best = min(times, key=times.get)
    return TuneReport(best, times, "analytic-calibrated" if calibrate else "analytic")


def time_fn(fn, *args, iters: int = 10, warmup: int = 2,
            inner: int = 1) -> float:
    """Best-of-``iters`` wall time of a call (compile excluded).

    ``inner`` > 1 times a block of back-to-back dispatches and divides: for
    microsecond-scale ops the per-call dispatch jitter rivals the op itself,
    and amortizing it is what makes profiling labels reproducible on a
    shared/loaded host.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = None
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best


def profile_select(A, x,
                   candidates: Sequence[Format] = (Format.COO, Format.CSR, Format.DIA, Format.ELL, Format.SELL),
                   iters: int = 10, backend: str = "ref",
                   conv_kwargs: Optional[dict] = None,
                   inner: int = 4,
                   backends: Optional[Sequence[str]] = None,
                   op: str = "spmv") -> TuneReport:
    """The paper's profiling auto-tuner: convert, compile, time, pick best.

    ``backends`` extends the search from formats to (format, backend)
    pairs: with ``("ref", "pallas")`` each candidate format is also timed
    through its Pallas kernel (using the tuned tile config for its shape
    bucket when one is cached, else the density-heuristic default), and
    the report's ``backend``/``cfg`` record the winning pair. Default
    (None) keeps the historical ref-only behaviour — ``times`` stays
    keyed by Format either way, holding each format's best time.

    ``op`` selects the computation profiled: ``"spmv"`` with vector ``x``,
    ``"spmm"`` with rhs ``x`` of shape (N, K), or ``"spmm_t"`` with
    activations ``x`` of shape (T, N) — the measurement (and hence the
    winning format) genuinely depends on the batch width, which is the
    mechanism behind width-keyed format selection.
    """
    A = A.concrete if isinstance(A, DynamicMatrix) else A
    conv_kwargs = conv_kwargs or {}
    backends = tuple(backends) if backends is not None else (backend,)
    op_fn = {"spmv": _ops.spmv, "spmm": _ops.spmm, "spmm_t": _ops.spmm_t}[op]
    ncols = None if op == "spmv" else (x.shape[1] if op == "spmm" else x.shape[0])
    times: Dict[Format, float] = {}
    winner: Dict[Format, tuple] = {}
    skipped: Dict[str, str] = {}
    for fmt in candidates:
        fmt = Format(fmt)
        try:
            # plan once (symbolic, one small sync), then build the candidate
            # with the device-resident numeric phase — profiling never ships
            # index arrays through host.
            plan = plan_switch(A, fmt, **conv_kwargs.get(fmt, {}))
            Af = convert_execute(A, plan)
        except (ValueError, MemoryError) as e:
            # e.g. BSR on a non-block-aligned shape
            skipped[fmt.name] = f"{type(e).__name__}: {e}"
            continue
        for b in backends:
            cfg = None
            if b == "pallas":
                from repro.kernels import ops as kops
                registry = {"spmv": kops.SPMV_PALLAS,
                            "spmm": kops.SPMM_PALLAS,
                            "spmm_t": kops.SPMM_T_PALLAS}[op]
                if type(Af) not in registry:
                    # no kernel for this format: timing "pallas" would just
                    # re-run the ref fallback and could record a phantom win
                    continue
                from repro.tuning import kernel_tune
                rec = kernel_tune.best_config(Af, op=op, ncols=ncols)
                cfg = dict(rec.cfg) if rec is not None else None
            fn = jax.jit(lambda a, v, b=b, cfg=cfg: op_fn(
                a, v, backend=b, cfg=cfg))
            t = time_fn(fn, Af, x, iters=iters, inner=inner)
            if fmt not in times or t < times[fmt]:
                times[fmt] = t
                winner[fmt] = (b, cfg)
    if not times:
        raise ValueError(
            f"profile_select: every candidate format failed conversion for "
            f"matrix of shape {tuple(A.shape)}; skipped candidates: {skipped}")
    best = min(times, key=times.get)
    b, cfg = winner[best]
    resolved = len(backends) > 1 or backends != ("ref",)
    return TuneReport(best, times, "profile",
                      backend=b if resolved else None,
                      cfg=cfg if resolved else None)
