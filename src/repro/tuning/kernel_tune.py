"""Kernel-config autotuning: pick (tile config) per (format, shape, device).

The format-selection subsystem answers "which *format*?"; this module
answers the next question down the stack: "with which *kernel
configuration*?" — tile sizes, layouts, and ultimately whether the Pallas
kernel beats the pure-jnp reference path at all. AlphaSparse
(arXiv:2212.10432) shows the winning kernel is a property of the matrix,
and Morpheus-unleashed (arXiv:2304.09511) that it is a property of the
device; both are runtime facts, so we measure them once and cache them.

Design:

* Winners are keyed by ``kernel:`` namespace entries in the *same*
  :class:`~repro.tuning.cache.SelectionCache` JSON store (same flush
  path, same merge-on-flush concurrency story) — one cache file holds
  both format selections and kernel configs.
* The key is (op, format, **shape bucket**, jax backend + interpret
  mode, device kind). The bucket quantizes (m, n, avg row nnz) to
  powers of two: matrices in the same bucket share a winner, so tuning
  one HPCG slab covers every same-sized shard.
* :func:`tune_kernel` times a small tile grid (``default_grid``) with
  the existing ``repro.tuning.engines.time_fn`` harness against the
  reference SpMV, and persists the winner *with both timings* — the
  record keeps ``ref_us`` so routing can refuse a kernel that lost.
* :func:`best_config` is the pure lookup used on the hot path
  (``repro.core.ops.resolve_backend("auto")``): no measuring, host
  dict access only. ``"auto"`` routes to Pallas **iff** a cached record
  exists for the bucket and its measured time beats the reference —
  never merely because the kernel compiles.

``REPRO_FORCE_INTERPRET`` interacts through the backend tag: configs
tuned under interpret mode are keyed ``cpu-interp`` (or ``tpu-interp``)
and never replayed against natively-compiled kernels, and vice versa.

CLI::

    python -m repro.tuning.kernel_tune           # warm the default cache
    python -m repro.tuning.kernel_tune --smoke   # tiny-grid CI self-check
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BSR, CSR, DIA, ELL, HYB, SELL, Format
from repro.tuning.cache import SelectionCache, default_cache_path
from repro.tuning.engines import time_fn

KERNEL_NS = "kernel"
KERNEL_SCHEMA = 1


# ---------------------------------------------------------------------------
# Records & keys
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelRecord:
    """A measured kernel-config winner for one (op, format, bucket, device).

    ``speedup`` is kernel-vs-reference: routing treats ``>= 1.0`` as "the
    Pallas path earned the hot path" and anything less as a measured veto.
    """

    fmt: str            # Format name
    op: str             # "spmv" | "spmm" | "spmm_t"
    cfg: dict           # kernel kwargs (tm/tk/layout/tn/...)
    kernel_us: float    # best measured time of cfg, microseconds
    ref_us: float       # reference-path time on the same matrix

    @property
    def speedup(self) -> float:
        return self.ref_us / max(self.kernel_us, 1e-9)

    def to_json(self) -> str:
        return json.dumps({"v": KERNEL_SCHEMA, "fmt": self.fmt, "op": self.op,
                           "cfg": self.cfg, "kernel_us": self.kernel_us,
                           "ref_us": self.ref_us}, sort_keys=True)

    @classmethod
    def from_json(cls, raw: str) -> Optional["KernelRecord"]:
        try:
            d = json.loads(raw)
            if d.get("v") != KERNEL_SCHEMA:
                return None
            return cls(str(d["fmt"]), str(d["op"]), dict(d["cfg"]),
                       float(d["kernel_us"]), float(d["ref_us"]))
        except (ValueError, KeyError, TypeError):
            return None


def _lg(v: float) -> int:
    return int(round(np.log2(max(1.0, float(v)))))


def shape_bucket(m: int, n: int, nnz: int) -> str:
    """Power-of-two bucket of (rows, cols, avg row nnz): the granularity at
    which a tuned config is reused."""
    return f"m{_lg(m)}n{_lg(n)}r{_lg(max(1, nnz) / max(1, m))}"


def backend_tag() -> str:
    """``"<jax backend>-interp"`` or ``"-native"``: a config measured
    against interpreted kernel bodies must never route compiled ones."""
    from repro.kernels import ops as kops
    mode = "interp" if kops.interpret_mode() else "native"
    return f"{jax.default_backend()}-{mode}"


def _device_kind() -> str:
    try:
        return jax.devices()[0].device_kind.replace("|", "/")
    except (IndexError, RuntimeError):
        return "unknown"


def rhs_bucket(ncols: Optional[int]) -> str:
    """Pow2 bucket of the rhs batch width — part of the spmm/spmm_t key.
    ``None`` means "width not stated" and lands in the b=1 bucket, so a
    forgetful caller reads and writes the narrow-decode record
    consistently rather than aliasing every width onto one entry."""
    return f"b{_lg(ncols or 1)}"


def kernel_key(fmt: Format, m: int, n: int, nnz: int, op: str = "spmv",
               backend: Optional[str] = None,
               ncols: Optional[int] = None) -> str:
    """The spmm ops carry the rhs-width bucket in the key (a winner tuned
    at b=1 is never replayed at b=256); spmv keys are unchanged, so
    records tuned before the width axis existed stay valid."""
    width = f"|{rhs_bucket(ncols)}" if op in ("spmm", "spmm_t") else ""
    return (f"{KERNEL_NS}:v{KERNEL_SCHEMA}|{op}|{Format(fmt).name}|"
            f"{shape_bucket(m, n, nnz)}{width}|{backend or backend_tag()}|"
            f"{_device_kind()}")


# Process-wide default cache handle. Re-created when $REPRO_TUNING_CACHE
# repoints the default path (tests / multi-config jobs stay isolated) OR
# when the file changed on disk since it was loaded — SelectionCache reads
# the file once, so without the stamp a tune flushed through a different
# handle (or another process) would be invisible to auto routing for the
# rest of this process's life.
_DEFAULT_CACHE: Optional[SelectionCache] = None
_DEFAULT_STAMP = None


def _cache_stamp(path: str):
    import os
    try:
        st = os.stat(path)
        return (st.st_mtime_ns, st.st_size)
    except OSError:
        return None


def default_kernel_cache() -> SelectionCache:
    global _DEFAULT_CACHE, _DEFAULT_STAMP
    path = default_cache_path()
    stamp = _cache_stamp(path)
    if (_DEFAULT_CACHE is None or _DEFAULT_CACHE.path != path
            or stamp != _DEFAULT_STAMP):
        _DEFAULT_CACHE = SelectionCache(path)
        _DEFAULT_STAMP = stamp
    return _DEFAULT_CACHE


def best_config(A, backend: Optional[str] = None, *, op: str = "spmv",
                ncols: Optional[int] = None,
                cache: Optional[SelectionCache] = None) -> Optional[KernelRecord]:
    """Cached winner for ``A``'s (format, shape bucket[, rhs-width bucket])
    on ``backend`` (default: the running process's tag). Pure lookup —
    never measures."""
    fmt = getattr(A, "format", None)
    if fmt is None:
        return None
    nnz = max(1, int(getattr(A, "nnz", 1)))
    return best_config_for(Format(fmt), A.shape[0], A.shape[1], nnz,
                           backend=backend, op=op, ncols=ncols, cache=cache)


def best_config_for(fmt: Format, m: int, n: int, nnz: int,
                    backend: Optional[str] = None, *, op: str = "spmv",
                    ncols: Optional[int] = None,
                    cache: Optional[SelectionCache] = None
                    ) -> Optional[KernelRecord]:
    # NB: "cache or ..." would misfire — an *empty* SelectionCache is falsy
    cache = cache if cache is not None else default_kernel_cache()
    raw = cache.get_raw(kernel_key(fmt, m, n, nnz, op=op, backend=backend,
                                   ncols=ncols))
    if raw is None:
        return None
    rec = KernelRecord.from_json(raw)
    if rec is None or rec.fmt != Format(fmt).name:
        return None
    return rec


# ---------------------------------------------------------------------------
# Tile grids
# ---------------------------------------------------------------------------


def default_grid(A, smoke: bool = False, op: str = "spmv",
                 ncols: Optional[int] = None) -> List[dict]:
    """The small per-format tile grid :func:`tune_kernel` searches.

    ``smoke=True`` shrinks it to 2-3 configs for CI self-checks. Grids
    always include the density-heuristic default so the tuner can only
    improve on the untuned path. The spmm ops add the ``tn`` rhs-tile
    axis: candidates bracket the (pow2) batch width, so a b=256 sweep
    tries both one wide slab and split rhs tiles.
    """
    from repro.kernels import ops as kops

    # one quantizer for grid generation and the defaults it must include
    _pow2ceil = kops._pow2_clamp
    m = A.shape[0]
    spmm = op in ("spmm", "spmm_t")
    base = kops.default_config(A, op=op, ncols=ncols)
    if isinstance(A, CSR):
        if spmm:
            tn0 = kops._rhs_tile(ncols)
            tns = sorted({tn0, max(1, tn0 // 8)})
            if smoke:
                grid = [base] + [{"tm": 128, "tk": 256, "tn": tn}
                                 for tn in tns]
            else:
                tms = sorted({128, 256, _pow2ceil(min(m, 1024), 128, 1024)})
                grid = [base] + [{"tm": tm, "tk": tk, "tn": tn}
                                 for tm in tms for tk in (512, 2048)
                                 for tn in tns]
        elif smoke:
            grid = [base, {"tm": 128, "tk": 256}]
        else:
            tms = sorted({128, 256, _pow2ceil(min(m, 1024), 128, 1024)})
            tks = (512, 2048, 4096)
            grid = [base] + [{"tm": tm, "tk": tk} for tm in tms for tk in tks]
    elif isinstance(A, ELL):
        if spmm:
            tn0 = kops._rhs_tile(ncols)
            lays = ("row", "col")
            if smoke:
                grid = [base] + [{"tm": 128, "layout": lay, "tn": tn0}
                                 for lay in lays]
            else:
                tms = sorted({256, _pow2ceil(min(m, 1024), 128, 8192)})
                grid = [base] + [{"tm": tm, "layout": lay, "tn": tn}
                                 for tm in tms for lay in lays
                                 for tn in sorted({tn0, max(1, tn0 // 8)})]
        elif smoke:
            grid = [base, {"tm": 128, "layout": "row"},
                    {"tm": 128, "layout": "col"}]
        else:
            tms = sorted({256, 1024, _pow2ceil(m, 128, 8192)})
            grid = [base] + [{"tm": tm, "layout": lay}
                             for tm in tms for lay in ("row", "col")]
    elif isinstance(A, SELL):
        # (c, sigma) reshape the *container* (slice height / sort window) —
        # tune_kernel rebuilds the matrix per cfg; ts is launch geometry.
        # Every cfg carries explicit (c, sigma) so the persisted record
        # names the container geometry its timing was measured on.
        own = {"c": A.c, "sigma": A.sigma}
        base = dict(own, **base)
        if smoke:
            alt_c = 64 if A.c != 64 else 32
            grid = [base, {"c": alt_c, "sigma": 8 * alt_c, "ts": 2}]
        else:
            grid = [base] + [{"c": c, "sigma": 8 * c, "ts": ts}
                             for c in (32, 64, 256) for ts in (1, 2, 8)]
        if spmm:
            tn0 = kops._rhs_tile(ncols)
            grid = [dict(g, tn=g.get("tn", tn0)) for g in grid]
    elif isinstance(A, DIA):
        grid = [base] + ([{"tm": 128}] if smoke else
                         [{"tm": tm} for tm in (256, 512, 1024)])
    elif isinstance(A, BSR):
        grid = [base] + ([] if smoke else [{"tn": 256}])
    elif isinstance(A, HYB):
        sub = default_grid(A.ell, smoke=smoke, op=op, ncols=ncols)
        if spmm:
            csr_sub = base.get("csr", {})
            grid = [{"ell": g, "csr": csr_sub} for g in sub]
        else:
            grid = [{"ell": g} for g in sub]
    else:
        grid = [base]
    # dedup while keeping order (the heuristic default may recur in the grid)
    seen, out = set(), []
    for g in grid:
        key = json.dumps(g, sort_keys=True)
        if key not in seen:
            seen.add(key)
            out.append(g)
    return out


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def _cfg_operand(A, cfg: dict):
    """The container a cfg must be timed on. For SELL, ``c``/``sigma`` are
    container-geometry knobs, not kernel kwargs: a cfg that changes them is
    timed on a rebuilt matrix (same pattern, different slicing)."""
    if isinstance(A, SELL) and cfg:
        c = int(cfg.get("c", A.c))
        sigma = int(cfg.get("sigma", A.sigma))
        if (c, sigma) != (A.c, A.sigma):
            from repro.core.convert import coo_to_sell, sell_to_coo
            return coo_to_sell(sell_to_coo(A), c=c, sigma=sigma)
    return A


def tune_kernel(A, x=None, *, op: str = "spmv",
                cache: Optional[SelectionCache] = None,
                grid: Optional[Sequence[dict]] = None,
                iters: int = 5, inner: int = 4,
                B_cols: int = 8) -> KernelRecord:
    """Search the tile grid for ``A``, persist and return the winner.

    Times every config of ``grid`` (default: :func:`default_grid`) plus
    the reference path with the shared :func:`~repro.tuning.engines.time_fn`
    harness; the stored :class:`KernelRecord` carries both timings so the
    ``"auto"`` route can *refuse* a kernel that measured slower than ref.
    Setup-phase work — never call this inside a jitted step.
    """
    from repro.core import ops as _ops

    cache = cache if cache is not None else default_kernel_cache()
    # A is closed over (not a jit argument): wrappers with host-side
    # preconditions (BSR's indptr scan) need the concrete arrays, and the
    # operand-only signature matches how a solver-jitted SpMV sees them.
    ncols = None
    if op == "spmv":
        if x is None:
            x = jnp.ones((A.shape[1],), A.dtype)
        ref_fn = jax.jit(lambda v: _ops.spmv(A, v, backend="ref"))
        run = lambda cfg: jax.jit(
            lambda v, a=_cfg_operand(A, cfg): _ops.spmv(
                a, v, backend="pallas", cfg=cfg))
    elif op == "spmm":
        if x is None:
            x = jnp.ones((A.shape[1], B_cols), A.dtype)
        ncols = x.shape[1]
        ref_fn = jax.jit(lambda b: _ops.spmm(A, b, backend="ref"))
        run = lambda cfg: jax.jit(
            lambda b, a=_cfg_operand(A, cfg): _ops.spmm(
                a, b, backend="pallas", cfg=cfg))
    elif op == "spmm_t":
        if x is None:
            x = jnp.ones((B_cols, A.shape[1]), A.dtype)
        ncols = x.shape[0]
        ref_fn = jax.jit(lambda b: _ops.spmm_t(A, b, backend="ref"))
        run = lambda cfg: jax.jit(
            lambda b, a=_cfg_operand(A, cfg): _ops.spmm_t(
                a, b, backend="pallas", cfg=cfg))
    else:
        raise ValueError(f"op {op!r} not in ('spmv', 'spmm', 'spmm_t')")

    ref_t = time_fn(ref_fn, x, iters=iters, inner=inner)
    times: Dict[str, float] = {}
    cfgs: Dict[str, dict] = {}
    search = grid if grid is not None else default_grid(A, op=op, ncols=ncols)
    for cfg in search:
        key = json.dumps(cfg, sort_keys=True)
        times[key] = time_fn(run(cfg), x, iters=iters, inner=inner)
        cfgs[key] = cfg
    best_key = min(times, key=times.get)
    rec = KernelRecord(fmt=Format(A.format).name, op=op, cfg=cfgs[best_key],
                       kernel_us=times[best_key] * 1e6, ref_us=ref_t * 1e6)
    nnz = max(1, int(getattr(A, "nnz", 1)))
    cache.put_raw(kernel_key(Format(A.format), A.shape[0], A.shape[1], nnz,
                             op=op, ncols=ncols), rec.to_json())
    return rec


# ---------------------------------------------------------------------------
# CLI: cache warm-up + CI smoke self-check
# ---------------------------------------------------------------------------


def _suite(smoke: bool):
    """Representative matrices to warm the cache with (HPCG stencil +
    irregular random, CSR/ELL/DIA, plus a power-law-rows SELL target)."""
    from repro.core import convert, hpcg, random_coo
    from repro.tuning.corpus import make_matrix

    sizes = ((8, 8, 8),) if smoke else ((8, 8, 8), (16, 16, 16))
    mats = []
    for s in sizes:
        prob = hpcg.generate_problem(*s)
        coo = hpcg.to_coo(prob)
        for fmt in (Format.CSR, Format.ELL, Format.DIA):
            mats.append(convert(coo, fmt))
    n = 512 if smoke else 2048
    rnd = random_coo(0, (n, n), density=0.02)
    for fmt in (Format.CSR, Format.ELL):
        mats.append(convert(rnd, fmt))
    # irregular power-law rows — the workload SELL-C-sigma exists for
    pow_coo = make_matrix("powerlaw", np.random.default_rng(7))
    mats.append(convert(pow_coo, Format.SELL))
    if not smoke:
        mats.append(convert(pow_coo, Format.CSR))
        mats.append(convert(pow_coo, Format.ELL))
    return mats


def run_smoke(cache_path: str, iters: int = 3, inner: int = 2) -> List[KernelRecord]:
    """Tiny-grid tune + the three CI invariants:

    1. the ``kernel:`` records round-trip through a *fresh* cache handle;
    2. ``resolve_backend("auto")`` never routes a config measured slower
       than the reference path;
    3. the auto route agrees numerically with the reference SpMV.
    """
    import os

    from repro.core import ops as _ops
    from repro.tuning.cache import CACHE_PATH_ENV

    # Point the process-default cache at the smoke path so the real
    # spmv("auto") route (not a test-only seam) is what gets exercised.
    prev = os.environ.get(CACHE_PATH_ENV)
    os.environ[CACHE_PATH_ENV] = cache_path
    try:
        cache = SelectionCache(cache_path)
        recs = []
        for A in _suite(smoke=True):
            rec = tune_kernel(A, cache=cache, grid=default_grid(A, smoke=True),
                              iters=iters, inner=inner)
            recs.append(rec)
            fresh = best_config(A, cache=SelectionCache(cache_path))
            assert fresh is not None and fresh.cfg == rec.cfg, \
                f"kernel cache round-trip failed for {rec}"
            backend, cfg = _ops.kernel_route(A, cache=SelectionCache(cache_path))
            if rec.speedup < 1.0:
                assert backend == "ref", \
                    f"auto routed a losing config: {rec} -> {backend}"
            else:
                assert backend == "pallas" and cfg == rec.cfg, (rec, backend, cfg)
            x = jnp.ones((A.shape[1],), A.dtype)
            y_auto = _ops.spmv(A, x, backend="auto")
            y_ref = _ops.spmv(A, x, backend="ref")
            np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_ref),
                                       rtol=1e-4, atol=1e-4)
        # SELL: the persisted record must name the container geometry its
        # timing was measured on — CI asserts a tuned (C, sigma) pair
        # landed in the cache artifact.
        sell_recs = [r for r in recs if r.fmt == "SELL"]
        assert sell_recs, "smoke suite lost its SELL matrix"
        assert all({"c", "sigma", "ts"} <= set(r.cfg) for r in sell_recs), \
            f"SELL record missing container geometry: {sell_recs}"
        # rhs-width isolation: an spmm record tuned at b=1 must be found
        # in the b=1 bucket and invisible to a b=256 lookup.
        A = _suite(smoke=True)[0]
        b1 = jnp.ones((A.shape[1], 1), A.dtype)
        rec = tune_kernel(A, b1, op="spmm", cache=cache,
                          grid=default_grid(A, smoke=True, op="spmm", ncols=1),
                          iters=iters, inner=inner)
        recs.append(rec)
        fresh = SelectionCache(cache_path)
        assert best_config(A, op="spmm", ncols=1, cache=fresh) is not None
        assert best_config(A, op="spmm", ncols=256, cache=fresh) is None, \
            "a b=1 spmm record leaked into the b=256 bucket"
        B = jnp.arange(A.shape[1] * 8, dtype=A.dtype).reshape(A.shape[1], 8)
        np.testing.assert_allclose(
            np.asarray(_ops.spmm(A, B, backend="auto")),
            np.asarray(_ops.spmm(A, B, backend="ref")), rtol=1e-4, atol=1e-4)
        return recs
    finally:
        if prev is None:
            os.environ.pop(CACHE_PATH_ENV, None)
        else:
            os.environ[CACHE_PATH_ENV] = prev


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="tiny grid + cache/routing self-checks (CI)")
    p.add_argument("--cache", default=None,
                   help="cache path (default: the process default)")
    args = p.parse_args(argv)

    if args.smoke:
        import tempfile
        with tempfile.TemporaryDirectory() as td:
            path = args.cache or f"{td}/kernels.json"
            recs = run_smoke(path)
        for r in recs:
            print(f"smoke {r.fmt:4s} cfg={r.cfg} "
                  f"{r.kernel_us:9.1f}us vs ref {r.ref_us:9.1f}us "
                  f"(x{r.speedup:.2f})")
        print(f"kernel_tune smoke OK: {len(recs)} records, "
              f"cache round-trip + auto-routing verified")
        return

    cache = SelectionCache(args.cache) if args.cache else default_kernel_cache()
    for A in _suite(smoke=False):
        rec = tune_kernel(A, cache=cache)
        print(f"tuned {rec.fmt:4s} {A.shape}: cfg={rec.cfg} "
              f"{rec.kernel_us:9.1f}us vs ref {rec.ref_us:9.1f}us "
              f"(x{rec.speedup:.2f})")
    print(f"kernel cache: {cache.path}")


if __name__ == "__main__":
    main()
