"""Host-side sparsity-pattern features for format selection.

Two granularities:

* ``PatternStats`` — the minimal statistics driving the analytic byte model
  (moved here from ``core.autotune``; that module re-exports it).
* ``PatternFeatures`` — the rich feature vector consumed by the ML
  classifier (arXiv:2303.05098 trains exactly this kind of model): row-nnz
  distribution moments, diagonal fill, bandwidth, block density, ELLPACK
  efficiency. ``from_coo`` computes them on host from one matrix's COO
  pattern; ``batch_features`` computes them for a whole *stacked* batch of
  shard parts in a single vmapped device pass with one small (P, stats)
  host pull — the distributed builder's per-shard selection never loops
  index arrays through host.

Feature extraction is setup-phase work (like conversion's symbolic phase):
it costs O(nnz), transfers only compacted statistics, and never runs inside
a jitted solver step.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.convert import _planned_pull
from repro.core.formats import COO

# Order matters: this is the layout of ``PatternFeatures.vector()`` and the
# feature ids stored inside serialized decision trees.
FEATURE_NAMES = (
    "log_m",           # log10 rows
    "log_n",           # log10 cols
    "log_nnz",         # log10 stored non-zeros
    "density",         # nnz / (m*n)
    "row_nnz_mean",    # mean row length
    "row_nnz_std",     # row length standard deviation
    "row_nnz_max",     # longest row
    "row_cv",          # std / mean row length (irregularity)
    "row_max_frac",    # max row length / n  (ELL padding risk)
    "ndiag",           # occupied diagonals
    "ndiag_frac",      # ndiag / (m + n - 1)
    "diag_fill",       # nnz / (ndiag * min(m, n))  (DIA efficiency)
    "bandwidth_frac",  # max |col - row| / n
    "block_density",   # nnz / touched 8x8 blocks' capacity (BSR efficiency)
    "ell_efficiency",  # nnz / (m * row_nnz_max)  (ELL payload utilisation)
)


@dataclasses.dataclass
class PatternStats:
    """Host-side sparsity-pattern statistics driving the analytic model."""

    m: int
    n: int
    nnz: int
    max_row_nnz: int
    ndiag: int
    itemsize: int = 4
    row_cv: float = 0.0  # std / mean row length (drives the SELL byte model)

    @classmethod
    def from_coo(cls, A: COO) -> "PatternStats":
        r = np.asarray(A.row)
        c = np.asarray(A.col)
        d = np.asarray(A.data)
        live = d != 0
        r, c = r[live], c[live]
        nnz = int(live.sum())
        counts = np.bincount(r, minlength=A.shape[0]) if nnz else np.zeros(1)
        max_row = int(counts.max()) if nnz else 1
        cv = float(counts.std() / max(counts.mean(), 1e-12)) if nnz else 0.0
        ndiag = int(np.unique(c.astype(np.int64) - r.astype(np.int64)).size) if nnz else 1
        return cls(A.shape[0], A.shape[1], nnz, max(1, max_row), max(1, ndiag),
                   np.dtype(A.dtype).itemsize, cv)


@dataclasses.dataclass
class PatternFeatures:
    """Rich pattern features (superset of ``PatternStats``)."""

    m: int
    n: int
    nnz: int
    itemsize: int
    row_nnz_mean: float
    row_nnz_std: float
    row_nnz_max: int
    ndiag: int
    bandwidth: int
    diag_fill: float
    block_density: float
    ell_efficiency: float

    BLOCK_PROBE = 8  # block grid used for the block_density feature

    @classmethod
    def from_coo(cls, A: COO) -> "PatternFeatures":
        m, n = A.shape
        r = np.asarray(A.row).astype(np.int64)
        c = np.asarray(A.col).astype(np.int64)
        d = np.asarray(A.data)
        live = d != 0
        r, c = r[live], c[live]
        nnz = int(live.sum())
        if nnz == 0:
            return cls(m, n, 0, np.dtype(A.dtype).itemsize,
                       0.0, 0.0, 1, 1, 0, 0.0, 0.0, 0.0)
        counts = np.bincount(r, minlength=m)
        row_max = int(counts.max())
        diffs = c - r
        ndiag = int(np.unique(diffs).size)
        bandwidth = int(np.abs(diffs).max())
        bs = cls.BLOCK_PROBE
        nbc = (n + bs - 1) // bs
        nblocks = int(np.unique((r // bs) * nbc + (c // bs)).size)
        return cls(
            m=m, n=n, nnz=nnz, itemsize=np.dtype(A.dtype).itemsize,
            row_nnz_mean=float(counts.mean()),
            row_nnz_std=float(counts.std()),
            row_nnz_max=row_max,
            ndiag=ndiag,
            bandwidth=bandwidth,
            diag_fill=nnz / (ndiag * min(m, n)),
            block_density=nnz / (nblocks * bs * bs),
            ell_efficiency=nnz / (m * row_max),
        )

    def vector(self) -> np.ndarray:
        """Feature vector in ``FEATURE_NAMES`` order (float64)."""
        m, n, nnz = self.m, self.n, max(self.nnz, 1)
        mean = max(self.row_nnz_mean, 1e-12)
        return np.array([
            np.log10(max(m, 1)),
            np.log10(max(n, 1)),
            np.log10(nnz),
            self.nnz / (m * n),
            self.row_nnz_mean,
            self.row_nnz_std,
            float(self.row_nnz_max),
            self.row_nnz_std / mean,
            self.row_nnz_max / max(n, 1),
            float(self.ndiag),
            self.ndiag / (m + n - 1),
            self.diag_fill,
            self.bandwidth / max(n, 1),
            self.block_density,
            self.ell_efficiency,
        ], dtype=np.float64)

    def to_stats(self) -> PatternStats:
        """Project down to the analytic model's statistics."""
        return PatternStats(self.m, self.n, max(self.nnz, 1),
                            max(1, self.row_nnz_max), max(1, self.ndiag),
                            self.itemsize,
                            self.row_nnz_std / max(self.row_nnz_mean, 1e-12))


# ---------------------------------------------------------------------------
# Batched (device-pass) featurisation for stacked shard containers
# ---------------------------------------------------------------------------

# Raw per-part statistics emitted by the device kernel, in order.
_RAW_STATS = ("nnz", "row_mean", "row_std", "row_max", "ndiag", "bandwidth",
              "nblocks")

_SENTINEL = np.iinfo(np.int32).max


def _distinct_live(vals: jax.Array) -> jax.Array:
    """Count distinct values in ``vals`` ignoring ``_SENTINEL`` entries.

    The vmap-safe replacement for ``np.unique(...).size``: sort pushes the
    sentinels (dead entries) to the tail, transitions count the distinct
    live values.
    """
    s = jnp.sort(vals)
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    return jnp.sum((first & (s != _SENTINEL)).astype(jnp.int32))


def _stats_kernel(row, col, data, *, m: int, n: int, block: int) -> jax.Array:
    """Per-part pattern statistics (``_RAW_STATS`` order), jit/vmap-able."""
    live = data != 0
    nnz = jnp.sum(live.astype(jnp.int32))
    counts = jax.ops.segment_sum(live.astype(jnp.int32), row, num_segments=m)
    row_max = jnp.max(counts)
    mean = nnz.astype(jnp.float32) / m
    std = jnp.sqrt(jnp.maximum(
        jnp.mean((counts.astype(jnp.float32) - mean) ** 2), 0.0))
    diffs = col.astype(jnp.int32) - row.astype(jnp.int32)
    ndiag = _distinct_live(jnp.where(live, diffs, _SENTINEL))
    bandwidth = jnp.max(jnp.where(live, jnp.abs(diffs), 0))
    nbc = (n + block - 1) // block
    gid = jnp.where(live, (row // block) * nbc + (col // block), _SENTINEL)
    nblocks = _distinct_live(gid)
    return jnp.stack([nnz.astype(jnp.float32), mean, std,
                      row_max.astype(jnp.float32), ndiag.astype(jnp.float32),
                      bandwidth.astype(jnp.float32),
                      nblocks.astype(jnp.float32)])


@functools.partial(jax.jit, static_argnames=("m", "n", "block"))
def _stats_batch(row, col, data, *, m: int, n: int, block: int) -> jax.Array:
    kern = functools.partial(_stats_kernel, m=m, n=n, block=block)
    return jax.vmap(kern)(row, col, data)


def batch_features(C: COO) -> List[PatternFeatures]:
    """Featurise a stacked batch of same-shape COO parts in ONE device pass.

    ``C`` carries ``(P, capacity)`` arrays (the distributed partitioner's
    stacked output). The vmapped statistics kernel runs once; a single
    (P, len(_RAW_STATS)) planned pull crosses to host, from which exact
    ``PatternFeatures`` are assembled — no per-part index-array transfers,
    no Python loop over device work.
    """
    if not isinstance(C, COO) or getattr(C.data, "ndim", 1) != 2:
        raise TypeError("batch_features expects a stacked COO container "
                        "with (P, capacity) arrays")
    m, n = C.shape
    bs = PatternFeatures.BLOCK_PROBE
    raw = _planned_pull(_stats_batch(C.row, C.col, C.data, m=m, n=n, block=bs))
    itemsize = np.dtype(C.dtype).itemsize
    out = []
    for nnz_f, mean, std, row_max_f, ndiag_f, bw_f, nblocks_f in raw:
        nnz, row_max = int(nnz_f), int(row_max_f)
        ndiag, nblocks = int(ndiag_f), int(nblocks_f)
        if nnz == 0:
            out.append(PatternFeatures(m, n, 0, itemsize,
                                       0.0, 0.0, 1, 1, 0, 0.0, 0.0, 0.0))
            continue
        out.append(PatternFeatures(
            m=m, n=n, nnz=nnz, itemsize=itemsize,
            row_nnz_mean=float(mean), row_nnz_std=float(std),
            row_nnz_max=row_max, ndiag=ndiag, bandwidth=int(bw_f),
            diag_fill=nnz / (ndiag * min(m, n)),
            block_density=nnz / (nblocks * bs * bs),
            ell_efficiency=nnz / (m * row_max),
        ))
    return out
