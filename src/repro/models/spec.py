"""Parameter-spec system: single source of truth for shapes, dtypes,
logical sharding axes, and initializers.

Every model family defines a nested dict of ``P`` leaves; ``init_params``
materializes arrays from RNG, ``abstract_params`` produces
ShapeDtypeStructs (for the dry-run), and ``logical_axes`` the parallel tree
of logical-axis tuples consumed by launch/sharding.py.

Logical axes vocabulary (mapped to mesh axes by sharding rules):
  "vocab"   embedding/unembedding vocabulary dim
  "embed"   d_model dim
  "mlp"     ffn hidden dim
  "heads"   attention heads * head_dim fused dim
  "kv"      kv heads * head_dim fused dim
  "expert"  MoE expert dim
  "layers"  stacked-scan layer dim (never sharded)
  None      replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter spec leaf."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal
    dtype: jnp.dtype = jnp.float32
    fan_in_dims: Tuple[int, ...] = ()  # dims to scale 1/sqrt(fan_in) over

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(spec: P, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = 1
    for d in (spec.fan_in_dims or range(len(spec.shape) - 1)):
        fan_in *= spec.shape[d]
    scale = 1.0 / math.sqrt(max(1, fan_in))
    if spec.init == "small_normal":
        scale *= 0.1
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, P)


def init_params(specs, key) -> dict:
    """Materialize a params pytree from a spec tree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_leaf(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs) -> dict:
    """ShapeDtypeStruct tree — used by .lower() in the dry-run (no alloc)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
                        is_leaf=is_spec)


def logical_axes(specs) -> dict:
    """Parallel tree of logical-axis tuples."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def cast_dtype(specs, dtype) -> dict:
    """Spec tree with every floating leaf recast (e.g. bf16 inference)."""
    return jax.tree.map(
        lambda s: dataclasses.replace(s, dtype=dtype) if jnp.issubdtype(s.dtype, jnp.floating) else s,
        specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=is_spec))
