"""GQA attention: flash-style chunked softmax (train/prefill) + cached decode.

The chunked path never materialises the full (S x S) score matrix: query
chunks are a static reshape, key/value chunks a ``lax.scan`` with an online
(max, sum, acc) softmax carry — the standard memory-linear attention
formulation, which is what makes the 32k-prefill cells compile within HBM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.flash import flash_attention
from repro.models.layers import P, apply_rope

NEG_INF = -1e30


def attn_specs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    s = {
        "wq": P((d, h * hd), ("embed", "heads")),
        "wk": P((d, kv * hd), ("embed", "kv")),
        "wv": P((d, kv * hd), ("embed", "kv")),
        "wo": P((h * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = P((h * hd,), ("heads",), init="zeros")
        s["bk"] = P((kv * hd,), ("kv",), init="zeros")
        s["bv"] = P((kv * hd,), ("kv",), init="zeros")
    return s


def _project_qkv(p, x, cfg, positions):
    dt = x.dtype
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    if cfg.n_heads > 0 and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(p, x, cfg, *, causal: bool, positions=None,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    unroll: bool = False, return_kv: bool = False):
    """Full attention over x. Returns (out, (k, v) | None)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = flash_attention(q, k, v, causal, q_chunk, kv_chunk, unroll)
    out = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    return (out, (k, v)) if return_kv else (out, None)


def _quantize_kv(vec):
    """Per-(token, head) int8 quantization: vec (..., D) -> (int8, scale)."""
    scale = jnp.max(jnp.abs(vec.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(vec.astype(jnp.float32)
                  / jnp.maximum(scale, 1e-12)[..., None]).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def decode_attention(p, x, k_cache, v_cache, pos, cfg,
                     k_scale=None, v_scale=None):
    """One-token cached decode. x: (B,1,d); caches: (B,S_max,KV,D); pos: (B,)
    index of the slot the new token writes.

    int8 cache mode (the dynamic-format idea applied to the KV container —
    the only way MHA-40 x 32k x 128 fits HBM, see §Perf): caches are int8
    with bf16 per-(token, head) ``k_scale``/``v_scale``; dequantisation is a
    per-layer transient. Returns (out, k_cache, v_cache[, scales...]).
    """
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    g = h // kvh
    quant = k_scale is not None
    positions = pos[:, None].astype(jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)

    # write the new kv at pos (per batch row)
    bidx = jnp.arange(b)
    if quant:
        kq, ks = _quantize_kv(k_new[:, 0])
        vq, vs = _quantize_kv(v_new[:, 0])
        k_cache = k_cache.at[bidx, pos].set(kq)
        v_cache = v_cache.at[bidx, pos].set(vq)
        k_scale = k_scale.at[bidx, pos].set(ks)
        v_scale = v_scale.at[bidx, pos].set(vs)
        k_eff = k_cache.astype(q.dtype) * k_scale.astype(q.dtype)[..., None]
        v_eff = v_cache.astype(q.dtype) * v_scale.astype(q.dtype)[..., None]
    else:
        k_cache = k_cache.at[bidx, pos].set(k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, pos].set(v_new[:, 0].astype(v_cache.dtype))
        k_eff = k_cache.astype(q.dtype)
        v_eff = v_cache.astype(q.dtype)

    qh = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qh, k_eff,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    valid = jnp.arange(k_cache.shape[1], dtype=jnp.int32)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(q.dtype), v_eff,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * hd).astype(x.dtype) @ p["wo"].astype(x.dtype)
    if quant:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache
