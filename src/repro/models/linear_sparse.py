"""LinearSparse: a linear layer whose weight is a dynamic sparse matrix.

The paper's technique applied to *weights* (DESIGN.md §4, minitron-8b):
a pruned model's linears are served from a runtime-selectable sparse
container — decode is memory-bandwidth-bound, so storing only the surviving
weights converts sparsity directly into read-bandwidth savings, and the
best container (ELL for balanced rows, BSR for block-pruned, CSR/COO for
ragged) is a per-matrix runtime decision made by the same auto-tuner that
drives SpMV format selection.

    w_sparse = prune_magnitude(w, density=0.25)          # host, once
    layer    = LinearSparse.from_dense(w_sparse, fmt=None)  # autotuned
    y        = layer(x)                                  # spmm path
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.core import (DynamicMatrix, Format, coo_from_dense_np, convert,
                        spmm_t)
from repro.obs import metrics as _metrics
from repro.tuning.policy import FormatPolicy

# Weight matrices are ragged post-pruning; DIA is never competitive there,
# while HYB handles the long-tail rows a magnitude prune leaves behind.
WEIGHT_CANDIDATES = (Format.CSR, Format.ELL, Format.HYB, Format.SELL, Format.COO)


def prune_magnitude(w: np.ndarray, density: float) -> np.ndarray:
    """Global magnitude pruning: keep the largest |w| entries."""
    w = np.asarray(w)
    k = max(1, int(density * w.size))
    thresh = np.partition(np.abs(w).ravel(), -k)[-k]
    return np.where(np.abs(w) >= thresh, w, 0.0).astype(w.dtype)


@jax.tree_util.register_pytree_node_class
class LinearSparse:
    """y = x @ W with W stored as a DynamicMatrix (any supported format).

    ``backend`` is the SpMM backend the layer calls through (pytree aux
    data, so it survives jit/vmap): ``"auto"`` (default) routes to a
    tuned Pallas kernel exactly when one measured faster than ref *for
    this batch-width bucket* — an untuned layer runs the reference path,
    identical numerics either way.
    """

    def __init__(self, weight: DynamicMatrix, bias=None,
                 backend: str = "auto"):
        self.weight = weight  # DynamicMatrix, stored (d_out, d_in)
        self.bias = bias
        self.backend = backend

    @classmethod
    def from_dense(cls, w, fmt: Optional[Format] = None, bias=None,
                   tune="analytic", backend: str = "auto",
                   ncols: Optional[int] = None,
                   **conv_kwargs) -> "LinearSparse":
        """Build from a (pruned) dense weight (d_in, d_out); fmt=None
        auto-tunes via a FormatPolicy — ``tune`` is a policy mode string
        ("ml" | "profile" | "analytic" | "cached") or a FormatPolicy.
        Stored TRANSPOSED (d_out, d_in): y = x@W computes as
        ``spmm_t(W^T, x)`` = x @ W with no activation transposes.
        ``ncols`` (the expected batch width) makes the selection
        batch-width-aware: profile mode measures the actual transposed-rhs
        SpMM at that width, so decode (b=1) and prefill (b=256) builds can
        legitimately pick different formats."""
        coo = coo_from_dense_np(np.asarray(w).T)
        if fmt is None:
            policy = (tune if isinstance(tune, FormatPolicy)
                      else FormatPolicy(tune, candidates=WEIGHT_CANDIDATES,
                                        profile_iters=3))
            fmt = policy.select(coo, op="spmm_t", ncols=ncols).best
        return cls(DynamicMatrix(convert(coo, fmt, **conv_kwargs)), bias,
                   backend=backend)

    @property
    def format(self) -> Format:
        return self.weight.active

    def activate(self, fmt: Format, **kw) -> "LinearSparse":
        """Runtime format switch (paper activate())."""
        return LinearSparse(self.weight.activate(fmt, **kw), self.bias,
                            backend=self.backend)

    def retune(self, ncols: int, tune="profile", **conv_kwargs) -> "LinearSparse":
        """Re-select the weight's format for a new batch width and switch
        to it — the serving-loop hook for decode->prefill transitions
        (``activate()`` between steps; the switch is the device-resident
        numeric phase)."""
        policy = (tune if isinstance(tune, FormatPolicy)
                  else FormatPolicy(tune, candidates=WEIGHT_CANDIDATES,
                                    profile_iters=3))
        fmt = policy.select(self.weight, op="spmm_t", ncols=ncols).best
        _metrics.inc("serve.retune")
        if fmt == self.format:
            return self
        _metrics.inc("serve.format_switch")
        return self.activate(fmt, **conv_kwargs)

    def __call__(self, x):
        """x: (..., d_in) -> (..., d_out) via the transposed-rhs SpMM —
        activations stay row-major on both sides (the old
        ``spmm(W, x.T).T`` round-trip copied them twice per layer)."""
        shape = x.shape
        xf = x.reshape(-1, shape[-1])  # (T, d_in)
        y = spmm_t(self.weight, xf, backend=self.backend)
        if self.bias is not None:
            y = y + self.bias
        return y.reshape(shape[:-1] + (y.shape[-1],))

    def tree_flatten(self):
        return (self.weight, self.bias), self.backend

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], backend=aux or "auto")

    def __repr__(self):
        return f"LinearSparse<{self.format.name}>{self.weight.shape}"
