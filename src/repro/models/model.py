"""Public model API: init / forward / loss / cache / decode per architecture.

``build_model(cfg)`` returns a ``Model`` with:
    specs()                      parameter P-spec tree
    init(key)                    materialized params
    forward(params, batch)       logits (train/prefill)
    loss(params, batch)          scalar LM loss (+ MoE aux)
    init_cache(b, s)             decode cache pytree (abstract via specs)
    prefill(params, batch)       last-token logits + primed cache
    decode_step(params, cache, tokens, pos)   one-token serve step
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import spec as spec_mod
from repro.models import transformer as tfm
from repro.models.layers import embed_apply, mlp_apply, rms_norm, unembed_apply


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(logits, labels, vocab: int):
    """Token cross-entropy, f32, ignoring label == -1."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = labels >= 0
    safe = jnp.clip(labels, 0, vocab - 1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def lm_loss_chunked(unembed_params, x, labels, vocab: int, chunk: int,
                    unroll: bool = False):
    """CE without materialising the full (B, S, V) logits: a remat'd scan
    over sequence chunks bounds peak memory at (B, chunk, V/shards) — the
    big-vocab archs (256k) cannot afford the full tensor in HBM."""
    from repro.models.layers import unembed_apply
    from repro.models.sharding_ctx import constrain

    b, s, d = x.shape
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)  # (nc, B, c, d)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, inp):
        xi, li = inp
        logits = constrain(unembed_apply(unembed_params, xi), "logits_chunk")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = li >= 0
        safe = jnp.clip(li, 0, vocab - 1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (acc[0] + jnp.sum(nll * mask), acc[1] + jnp.sum(mask)), None

    acc0 = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if unroll:  # cost-compile path: every chunk visible to cost analysis
        acc = acc0
        for i in range(nc):
            acc, _ = body(acc, (xc[i], lc[i]))
        tot, cnt = acc
    else:
        (tot, cnt), _ = jax.lax.scan(body, acc0, (xc, lc))
    return tot / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------------------
# Decode bodies
# ---------------------------------------------------------------------------


def _decode_attn_family(params, cache, x, pos, cfg):
    # Index-based scan: layer params and cache slices are dynamically
    # indexed inside the body. Feeding the stacked cache through scan-xs
    # lets XLA hoist the (CPU-lowering) bf16->f32 dot-operand convert of
    # the WHOLE cache out of the loop — a 20 GiB/device f32 ghost copy on
    # the qwen decode cell (§Perf). Dynamic indexing pins the convert to
    # one layer's slice.
    blocks = params["blocks"]
    if cfg.n_layers == 0:  # depth-0 cost-compile variant (dryrun c0)
        return x, cache
    quant = "k_scale" in cache
    idx = lambda t, i: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False)
    upd = jax.lax.dynamic_update_index_in_dim

    def body(carry, i):
        x, c = carry
        pl = jax.tree.map(lambda a: idx(a, i), blocks)
        kc, vc = idx(c["k"], i), idx(c["v"], i)
        scales = (idx(c["k_scale"], i), idx(c["v_scale"], i)) if quant else (None, None)
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        res = attn.decode_attention(pl["attn"], h, kc, vc, pos, cfg, *scales)
        out, kc, vc = res[:3]
        x = x + out
        h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            h2, _ = moe_mod.moe_apply(pl["moe"], h2, cfg)
        else:
            h2 = mlp_apply(pl["mlp"], h2, cfg.mlp_act)
        c = dict(c, k=upd(c["k"], kc, i, 0), v=upd(c["v"], vc, i, 0))
        if quant:
            c["k_scale"] = upd(c["k_scale"], res[3], i, 0)
            c["v_scale"] = upd(c["v_scale"], res[4], i, 0)
        return (x + h2, c), None

    (x, cache), _ = jax.lax.scan(
        body, (x, cache), jnp.arange(cfg.n_layers, dtype=jnp.int32))
    return x, cache


def _decode_ssm_family(params, cache, x, pos, cfg):
    def body(x, layer):
        pl, conv, ssm = layer
        h = rms_norm(x, pl["ln"], cfg.norm_eps)
        out, conv, ssm = m2.mamba_decode(pl["mixer"], h, conv, ssm, cfg)
        return x + out, (conv, ssm)

    x, (conv, ssm) = jax.lax.scan(body, x, (params["blocks"], cache["conv"], cache["ssm"]))
    return x, {"conv": conv, "ssm": ssm}


def _decode_hybrid(params, cache, x, pos, cfg):
    g = cfg.attn_every
    ng = cfg.n_layers // g
    grouped = jax.tree.map(lambda a: a.reshape((ng, g) + a.shape[1:]),
                           params["blocks"])
    conv_g = cache["conv"].reshape((ng, g) + cache["conv"].shape[1:])
    ssm_g = cache["ssm"].reshape((ng, g) + cache["ssm"].shape[1:])
    shared = params["shared_attn"]
    dcfg = tfm._as_dense(cfg)

    def group(x, layer):
        pg, conv, ssm, kc, vc = layer

        def inner(x, l):
            pl, cv, sm = l
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            out, cv, sm = m2.mamba_decode(pl["mixer"], h, cv, sm, cfg)
            return x + out, (cv, sm)

        x, (conv, ssm) = jax.lax.scan(inner, x, (pg, conv, ssm))
        h = rms_norm(x, shared["ln1"], cfg.norm_eps)
        out, kc, vc = attn.decode_attention(shared["attn"], h, kc, vc, pos, dcfg)
        x = x + out
        h2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
        x = x + mlp_apply(shared["mlp"], h2, cfg.mlp_act)
        return x, (conv, ssm, kc, vc)

    x, (conv, ssm, k, v) = jax.lax.scan(group, x, (grouped, conv_g, ssm_g,
                                                   cache["k"], cache["v"]))
    return x, {"conv": conv.reshape(cache["conv"].shape),
               "ssm": ssm.reshape(cache["ssm"].shape), "k": k, "v": v}


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params -----------------------------------------------------------
    def specs(self, param_dtype=jnp.float32) -> dict:
        s = tfm.model_specs(self.cfg)
        if param_dtype != jnp.float32:
            s = spec_mod.cast_dtype(s, param_dtype)
        return s

    def init(self, key, param_dtype=jnp.float32) -> dict:
        return spec_mod.init_params(self.specs(param_dtype), key)

    def abstract_params(self, param_dtype=jnp.float32) -> dict:
        return spec_mod.abstract_params(self.specs(param_dtype))

    def logical_axes(self) -> dict:
        return spec_mod.logical_axes(self.specs())

    def n_params(self) -> int:
        return spec_mod.param_count(self.specs())

    # -- training ----------------------------------------------------------
    def forward(self, params, batch, **kw):
        return tfm.forward(params, batch, self.cfg, **kw)

    def loss(self, params, batch, ce_chunk: int = 1024, **kw):
        cfg = self.cfg
        labels = batch["labels"]
        s = labels.shape[1]
        # chunk the CE when the full (B,S,V) logits tensor is HBM-hostile
        if s % max(1, ce_chunk) == 0 and s // ce_chunk > 1 \
                and s * cfg.padded_vocab > 2 ** 27:
            x, aux = tfm.forward(params, batch, cfg, logits_mode="none", **kw)
            ce = lm_loss_chunked(params["unembed"], x, labels,
                                 cfg.padded_vocab, ce_chunk,
                                 unroll=kw.get("unroll", False))
            return ce + 0.01 * aux
        logits, aux = tfm.forward(params, batch, cfg, **kw)
        return lm_loss(logits, labels, cfg.padded_vocab) + 0.01 * aux

    # -- serving -----------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                    kv_quant: bool = False) -> dict:
        """``kv_quant=True``: int8 k/v + bf16 per-(token, head) scales —
        4x smaller cache (how MHA-40 x 32k fits HBM; §Perf)."""
        cfg = self.cfg
        out: Dict[str, Any] = {}
        if cfg.family in ("dense", "moe", "vlm"):
            kv = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.hd)
            if kv_quant:
                out = {"k": jax.ShapeDtypeStruct(kv, jnp.int8),
                       "v": jax.ShapeDtypeStruct(kv, jnp.int8),
                       "k_scale": jax.ShapeDtypeStruct(kv[:-1], jnp.bfloat16),
                       "v_scale": jax.ShapeDtypeStruct(kv[:-1], jnp.bfloat16)}
            else:
                out = {"k": jax.ShapeDtypeStruct(kv, dtype),
                       "v": jax.ShapeDtypeStruct(kv, dtype)}
        elif cfg.family == "ssm":
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            out = {"conv": jax.ShapeDtypeStruct(
                       (cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
                   "ssm": jax.ShapeDtypeStruct(
                       (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
                        cfg.ssm_head_dim), jnp.float32)}
        elif cfg.family == "hybrid":
            ng = cfg.n_layers // cfg.attn_every
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            kv = (ng, batch, max_len, cfg.n_kv, cfg.hd)
            out = {"conv": jax.ShapeDtypeStruct(
                       (cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
                   "ssm": jax.ShapeDtypeStruct(
                       (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_state,
                        cfg.ssm_head_dim), jnp.float32),
                   "k": jax.ShapeDtypeStruct(kv, dtype),
                   "v": jax.ShapeDtypeStruct(kv, dtype)}
        else:
            raise ValueError(f"{cfg.family} has no decode cache")
        return out

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_quant: bool = False) -> dict:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_specs(batch, max_len, dtype, kv_quant))

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B,) int32; pos: (B,) write positions. -> (logits, cache)."""
        cfg = self.cfg
        dtype = _dtype(cfg)
        x = embed_apply(params["embed"], tokens, dtype)[:, None, :]
        if cfg.family in ("dense", "moe", "vlm"):
            x, cache = _decode_attn_family(params, cache, x, pos, cfg)
        elif cfg.family == "ssm":
            x, cache = _decode_ssm_family(params, cache, x, pos, cfg)
        elif cfg.family == "hybrid":
            x, cache = _decode_hybrid(params, cache, x, pos, cfg)
        else:
            raise ValueError(cfg.family)
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = unembed_apply(params["unembed"], x)[:, 0]
        return logits, cache

    def prefill(self, params, batch, **kw):
        """Prefill forward: last-token logits (cache priming is decode-side
        via repeated decode_step in serve.py; the dry-run lowers this)."""
        return tfm.forward(params, batch, self.cfg, logits_mode="last",
                           remat=False, **kw)

    def supports_prefill_cache(self) -> bool:
        """Whether :meth:`prefill_cache` is available: attention families
        with a token frontend (the kv cache is addressable by position;
        ssm/hybrid recurrent state must be built by stepping)."""
        return self.cfg.family in ("dense", "moe")

    def prefill_cache(self, params, cache, tokens, slots, lengths):
        """ONE jit'd forward that primes the decode cache for R prompts.

        tokens: (R, P) right-padded prompt rows; slots: (R,) batch rows of
        ``cache`` to fill; lengths: (R,) true prompt lengths (<= P).
        Returns (last_logits (R, V), cache) — the logits at each prompt's
        final real token, i.e. what the first ``decode_step`` needs.

        The causal forward collects every layer's projected (k, v) via the
        scan's ys (``collect_kv``) and scatters them into cache rows —
        replacing the per-token prefill-by-decode loop (P sequential
        decode_steps, each touching the whole cache) with a single
        chunked-flash pass. Positions >= length hold kv computed from pad
        tokens; that is safe because ``decode_attention`` masks to
        ``arange <= pos`` and overwrites each slot before first attending
        it — a pad entry is never read.
        """
        cfg = self.cfg
        if not self.supports_prefill_cache():
            raise ValueError(f"{cfg.family} has no batched cache prefill")
        p_len = tokens.shape[1]
        x, _, (k, v) = tfm.forward(params, {"tokens": tokens}, cfg,
                                   logits_mode="none", remat=False,
                                   collect_kv=True)
        # k/v: (L, R, P, KV, hd); cache["k"]: (L, B, S_max, KV, hd)
        if "k_scale" in cache:
            kq, ks = attn._quantize_kv(k)
            vq, vs = attn._quantize_kv(v)
            cache = dict(cache,
                         k=cache["k"].at[:, slots, :p_len].set(kq),
                         v=cache["v"].at[:, slots, :p_len].set(vq),
                         k_scale=cache["k_scale"].at[:, slots, :p_len].set(ks),
                         v_scale=cache["v_scale"].at[:, slots, :p_len].set(vs))
        else:
            kv_dt = cache["k"].dtype
            cache = dict(cache,
                         k=cache["k"].at[:, slots, :p_len].set(k.astype(kv_dt)),
                         v=cache["v"].at[:, slots, :p_len].set(v.astype(kv_dt)))
        last = jnp.take_along_axis(
            x, (lengths - 1)[:, None, None].astype(jnp.int32), axis=1)  # (R,1,d)
        logits = unembed_apply(params["unembed"], last)[:, 0]
        return logits, cache


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
