"""Activation-sharding context: lets the launcher inject mesh-axis names
into model code without coupling model definitions to a mesh.

The launcher calls ``set_policy(dp=..., tp=...)`` (or uses ``policy()`` as a
context manager); model code calls ``constrain(x, kind)`` at the few places
where GSPMD propagation needs an anchor (post-embed activations, scan
carries, logits). With no policy set, constrain() is a no-op — single-device
tests and examples are unaffected.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass
class Policy:
    dp: Axes = None   # data-parallel axes (batch dim)
    tp: Axes = None   # tensor-parallel axis (vocab/mlp dims)
    sp: Axes = None   # sequence-parallel axis (S dim of activations)


_POLICY = Policy()


def set_policy(dp: Axes = None, tp: Axes = None, sp: Axes = None):
    global _POLICY
    _POLICY = Policy(dp, tp, sp)


def get_policy() -> Policy:
    return _POLICY


@contextlib.contextmanager
def policy(dp: Axes = None, tp: Axes = None, sp: Axes = None):
    global _POLICY
    old = _POLICY
    _POLICY = Policy(dp, tp, sp)
    try:
        yield
    finally:
        _POLICY = old


def _safe_constraint(x, spec):
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):  # no mesh context / axis mismatch
        return x


def constrain(x, kind: str):
    """kind: 'act' (B,S,D) | 'logits' (B,S,V) | 'batch' (B,...)."""
    pol = _POLICY
    if pol.dp is None and pol.tp is None:
        return x
    if kind == "act":
        # sequence parallelism: (B, S, D) -> (dp, sp, None)
        return _safe_constraint(x, P(pol.dp, pol.sp, *(None,) * (x.ndim - 2)))
    if kind == "logits_chunk":
        # chunked CE: the chunk's S dim is small — shard vocab over tp
        return _safe_constraint(x, P(pol.dp, *(None,) * (x.ndim - 2), pol.tp))
    if kind == "logits":
        # a mesh axis may appear once: sequence-parallel CE shards S and
        # leaves vocab unsharded; otherwise shard vocab over tp
        vax = pol.tp if pol.sp != pol.tp else None
        return _safe_constraint(x, P(pol.dp, pol.sp, *(None,) * (x.ndim - 3), vax))
    if kind == "expert_rows":
        # (E*C[+1], d) inside a vmap: rows over tp (expert-parallel); the
        # vmapped batch dim stays unconstrained (propagates dp). Needed only
        # on multi-axis-dp meshes, where GSPMD otherwise replicates the full
        # dispatched buffer (measured: deepseek multipod prefill 51 GiB);
        # on the 2-axis pod mesh the anchor slightly hurts (+1.2 GiB).
        if not isinstance(pol.dp, (tuple, list)) or len(pol.dp) < 2:
            return x
        return _safe_constraint(x, P(pol.tp, *(None,) * (x.ndim - 1)))
    if kind == "batch":
        return _safe_constraint(x, P(pol.dp, *(None,) * (x.ndim - 1)))
    return x
