"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD training path (quadratic-within-chunk, linear-across-chunks,
``lax.scan`` state recurrence) + O(1)-state cached decode step, which is
what makes the ``long_500k`` decode cell trivial for SSM archs.

Single B/C group (ngroups=1, the released-model configuration).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import P, rms_norm


def mamba_specs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads
    w = cfg.ssm_conv
    conv_dim = di + 2 * ds
    return {
        # fused in_proj -> [z (di), xBC (di+2ds), dt (nh)]
        "in_proj": P((d, 2 * di + 2 * ds + nh), ("embed", "mlp")),
        "conv_w": P((w, conv_dim), (None, "mlp")),
        "conv_b": P((conv_dim,), ("mlp",), init="zeros"),
        "A_log": P((nh,), (None,), init="zeros"),
        "D": P((nh,), (None,), init="ones"),
        "dt_bias": P((nh,), (None,), init="zeros"),
        "norm_w": P((di,), ("mlp",), init="ones"),
        "out_proj": P((di, d), ("mlp", "embed")),
    }


def _segsum_decay(dA):
    """dA: (..., Q) per-step log-decay -> (..., Q, Q) lower-tri decay matrix
    L[q, s] = exp(sum_{s < i <= q} dA_i), 0 for s > q."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., q, s)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, chunk: int, unroll: bool = False):
    """SSD scan. x: (b,T,H,P); dt: (b,T,H); A: (H,); B,C: (b,T,N).
    Returns (y (b,T,H,P), final_state (b,H,N,P)). f32 internal.

    One ``lax.scan`` over chunks: each step does the quadratic intra-chunk
    work for its own chunk and carries the inter-chunk state. Materialising
    all chunks' (Q x Q) decay matrices at once — the textbook batched form —
    costs b*nc*h*Q^2 f32 (~78 TiB for the mamba2 train cell); the scan form
    is O(b*h*Q^2) per step. Steps are remat'd for the backward.
    """
    b, t, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q
    xc = x.reshape(b, nc, q, h, p).swapaxes(0, 1)
    dtc = dt.reshape(b, nc, q, h).swapaxes(0, 1)
    Bc = B.reshape(b, nc, q, n).swapaxes(0, 1)
    Cc = C.reshape(b, nc, q, n).swapaxes(0, 1)

    @jax.checkpoint
    def step(hprev, inp):
        x_i, dt_i, B_i, C_i = jax.tree.map(lambda a: a.astype(jnp.float32), inp)
        dA = dt_i * A  # (b,q,h) log decay (A negative)
        dA_cum = jnp.cumsum(dA, axis=1)  # inclusive over q
        xdt = x_i * dt_i[..., None]
        # intra-chunk (quadratic within the chunk only)
        L = _segsum_decay(dA.transpose(0, 2, 1))  # (b,h,q,q)
        scores = jnp.einsum("bqn,bsn->bqs", C_i, B_i)
        y_diag = jnp.einsum("bqs,bhqs,bshp->bqhp", scores, L, xdt)
        # contribution of the carried state
        in_decay = jnp.exp(dA_cum)  # (b,q,h)
        y_off = jnp.einsum("bqn,bhnp,bqh->bqhp", C_i, hprev, in_decay)
        # state update
        decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)  # (b,q,h)
        S = jnp.einsum("bsn,bsh,bshp->bhnp", B_i, decay_to_end, xdt)
        chunk_decay = jnp.exp(dA_cum[:, -1, :])  # (b,h)
        hnew = hprev * chunk_decay[..., None, None] + S
        return hnew, (y_diag + y_off).astype(x.dtype)

    h0 = jnp.zeros((b, h, n, p), jnp.float32)
    if unroll:
        ys = []
        hs = h0
        for i in range(nc):
            hs, y_i = step(hs, (xc[i], dtc[i], Bc[i], Cc[i]))
            ys.append(y_i)
        y = jnp.stack(ys, 0)
        hfinal = hs
    else:
        hfinal, y = jax.lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = y.swapaxes(0, 1).reshape(b, t, h, p)
    return y.astype(x.dtype), hfinal


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv. xbc: (b,T,C); w: (W,C)."""
    wlen = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (wlen - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(wlen))
    return out + bias[None, None, :]


def mamba_apply(p, x, cfg, *, return_state: bool = False,
                unroll: bool = False):
    """Full-sequence Mamba2 mixer. x: (b,T,d)."""
    dt_ = x.dtype
    b, t, d = x.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xBC_raw, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC_raw, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_)))
    xin, B, C = jnp.split(xBC, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    y, state = ssd_chunked(xin.reshape(b, t, nh, hd), dt, A, B, C,
                           cfg.ssm_chunk, unroll=unroll)
    y = y + xin.reshape(b, t, nh, hd) * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(b, t, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(dt_)
    if return_state:
        conv_tail = jnp.pad(
            xBC_raw, ((0, 0), (max(0, cfg.ssm_conv - 1 - t), 0), (0, 0))
        )[:, -(cfg.ssm_conv - 1):, :]
        return out, (conv_tail, state)
    return out, None


def mamba_decode(p, x, conv_state, ssm_state, cfg):
    """One-token decode. x: (b,1,d); conv_state: (b,W-1,conv_dim);
    ssm_state: (b,H,N,P). Returns (out, conv_state, ssm_state)."""
    dt_ = x.dtype
    b = x.shape[0]
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = (x[:, 0] @ p["in_proj"].astype(dt_))  # (b, ...)
    z, xBC_new, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)

    window = jnp.concatenate([conv_state, xBC_new[:, None, :]], axis=1)  # (b,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(dt_)
    xin, B, C = jnp.split(xBC, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (b,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)  # (b,nh)

    xh = xin.reshape(b, nh, hd).astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    # state <- state * dA + dt * (B outer x)
    ssm_state = (ssm_state * dA[..., None, None]
                 + jnp.einsum("bn,bh,bhp->bhnp", Bf, dt, xh))
    y = jnp.einsum("bn,bhnp->bhp", Cf, ssm_state)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, di).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    return out, window[:, 1:], ssm_state
