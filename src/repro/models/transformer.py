"""Model assembly: scan-over-layers stacks for every assigned family.

One generic decoder/encoder runtime covers dense / moe / audio / vlm; the
ssm family stacks Mamba2 blocks; hybrid (zamba2) interleaves Mamba2 groups
with one *shared* attention block (parameters reused across applications).

Layers are stacked (leading ``layers`` dim) and driven by ``lax.scan`` so
HLO size and compile time are independent of depth — essential for the
512-device dry-run. Remat (activation checkpointing) wraps the scan body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.layers import (P, embed_apply, embed_specs, mlp_apply,
                                 mlp_specs, rms_norm, unembed_apply,
                                 unembed_specs)
from repro.models.sharding_ctx import constrain
from repro.models.spec import P as PS


def _stack_specs(specs: dict, n: int) -> dict:
    """Prefix every spec in the tree with a ``layers`` dim of size n."""
    import dataclasses as dc
    return jax.tree.map(
        lambda s: dc.replace(s, shape=(n,) + s.shape, axes=("layers",) + s.axes),
        specs, is_leaf=lambda x: isinstance(x, PS))


# ---------------------------------------------------------------------------
# Block bodies (one layer each)
# ---------------------------------------------------------------------------


def _attn_block_specs(cfg) -> dict:
    s = {
        "ln1": P((cfg.d_model,), ("embed",), init="ones"),
        "attn": attn.attn_specs(cfg),
        "ln2": P((cfg.d_model,), ("embed",), init="ones"),
    }
    if cfg.family == "moe":
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return s


def _attn_block(p, x, cfg, *, causal, positions=None, q_chunk, kv_chunk,
                unroll=False, return_kv=False):
    h, kv = attn.attention_block(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                 cfg, causal=causal, positions=positions,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk,
                                 unroll=unroll, return_kv=return_kv)
    x = x + h
    hin = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        h, aux = moe_mod.moe_apply(p["moe"], hin, cfg)
    else:
        h, aux = mlp_apply(p["mlp"], hin, cfg.mlp_act), jnp.zeros((), jnp.float32)
    if return_kv:
        return x + h, aux, kv
    return x + h, aux


def _mamba_block_specs(cfg) -> dict:
    return {"ln": P((cfg.d_model,), ("embed",), init="ones"),
            "mixer": m2.mamba_specs(cfg)}


def _mamba_block(p, x, cfg, *, return_state=False, unroll=False):
    h, state = m2.mamba_apply(p["mixer"], rms_norm(x, p["ln"], cfg.norm_eps),
                              cfg, return_state=return_state, unroll=unroll)
    return x + h, state


# ---------------------------------------------------------------------------
# Specs per family
# ---------------------------------------------------------------------------


def model_specs(cfg) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    s: Dict[str, Any] = {"final_ln": P((d,), ("embed",), init="ones")}

    if cfg.frontend == "audio":
        s["frontend"] = {"proj": P((cfg.frontend_dim, d), (None, "embed"))}
    elif cfg.frontend == "vision":
        s["frontend"] = {"proj": P((cfg.frontend_dim, d), (None, "embed"))}
        s["embed"] = embed_specs(v, d)
    else:
        s["embed"] = embed_specs(v, d)

    if not cfg.encoder_only or cfg.family == "audio":
        s["unembed"] = unembed_specs(d, v)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        s["blocks"] = _stack_specs(_attn_block_specs(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        s["blocks"] = _stack_specs(_mamba_block_specs(cfg), cfg.n_layers)
    elif cfg.family == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        s["blocks"] = _stack_specs(_mamba_block_specs(cfg), cfg.n_layers)
        s["shared_attn"] = _attn_block_specs(
            _as_dense(cfg))  # one block, reused every attn_every layers
    else:
        raise ValueError(cfg.family)
    return s


def _as_dense(cfg):
    import dataclasses as dc
    return dc.replace(cfg, family="dense")


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _python_scan(body, carry, stacked, n):
    for i in range(n):
        carry, _ = body(carry, jax.tree.map(lambda a: a[i], stacked))
    return carry


def _embed_inputs(params, batch, cfg, dtype):
    """Token/frontend embedding. Returns (x, positions)."""
    if cfg.frontend == "audio":
        x = batch["frames"].astype(dtype) @ params["frontend"]["proj"].astype(dtype)
    elif cfg.frontend == "vision":
        pe = batch["patches"].astype(dtype) @ params["frontend"]["proj"].astype(dtype)
        te = embed_apply(params["embed"], batch["tokens"], dtype)
        x = jnp.concatenate([pe, te], axis=1)
    else:
        x = embed_apply(params["embed"], batch["tokens"], dtype)
    b, s = x.shape[:2]
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    return x, positions


def forward(params, batch, cfg, *, remat: bool = True,
            q_chunk: int = 512, kv_chunk: int = 1024,
            logits_mode: str = "all", unroll: bool = False,
            collect_kv: bool = False):
    """Full-sequence forward. Returns (logits, aux_loss).

    logits_mode: 'all' (training CE) | 'last' (prefill serving) | 'none'.
    unroll: Python-loop layers + attention kv chunks instead of lax.scan —
    used by the roofline cost-compiles so XLA cost analysis sees every
    FLOP (scan bodies are otherwise counted once; see dryrun.py).
    collect_kv: additionally return every layer's projected (k, v) as the
    scan's stacked ys — (L, B, S, KV, hd) each — so a serving prefill can
    prime the decode cache from ONE forward instead of S decode steps.
    Attention families only (ssm/hybrid state is positional, not a kv
    cache); the return becomes (out, aux, (k, v)).
    """
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x, positions = _embed_inputs(params, batch, cfg, dtype)
    x = constrain(x, "act")
    causal = not cfg.encoder_only
    if collect_kv and cfg.family not in ("dense", "moe", "vlm", "audio"):
        raise ValueError(f"collect_kv: {cfg.family} has no kv cache — "
                         "prefill ssm/hybrid families by decode steps")

    kvs = None
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(carry, pl):
            x, aux = carry
            out = _attn_block(pl, x, cfg, causal=causal, positions=positions,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              unroll=unroll, return_kv=collect_kv)
            if collect_kv:
                x, a, kv = out
            else:
                (x, a), kv = out, None
            return (constrain(x, "act"), aux + a), kv
        body = jax.checkpoint(body) if remat else body
        carry0 = (x, jnp.zeros((), jnp.float32))
        if unroll:
            (x, aux) = _python_scan(body, carry0, params["blocks"], cfg.n_layers)
        else:
            (x, aux), kvs = jax.lax.scan(body, carry0, params["blocks"])
    elif cfg.family == "ssm":
        def body(carry, pl):
            x, aux = carry
            x, _ = _mamba_block(pl, x, cfg, unroll=unroll)
            return (constrain(x, "act"), aux), None
        body = jax.checkpoint(body) if remat else body
        carry0 = (x, jnp.zeros((), jnp.float32))
        if unroll:
            (x, aux) = _python_scan(body, carry0, params["blocks"], cfg.n_layers)
        else:
            (x, aux), _ = jax.lax.scan(body, carry0, params["blocks"])
    elif cfg.family == "hybrid":
        g = cfg.attn_every
        ng = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, g) + a.shape[1:]), params["blocks"])
        shared = params["shared_attn"]

        def group_body(carry, pg):
            x, aux = carry
            if unroll:
                # Python loop: every mamba block visible to cost analysis
                for i in range(g):
                    x, _ = _mamba_block(jax.tree.map(lambda a: a[i], pg), x,
                                        cfg, unroll=True)
            else:
                def inner(xc, pl):
                    xc, _ = _mamba_block(pl, xc, cfg)
                    return xc, None
                x, _ = jax.lax.scan(inner, x, pg)
            x, a = _attn_block(shared, x, _as_dense(cfg), causal=causal,
                               positions=positions, q_chunk=q_chunk,
                               kv_chunk=kv_chunk, unroll=unroll)
            return (constrain(x, "act"), aux + a), None
        group_body = jax.checkpoint(group_body) if remat else group_body
        carry0 = (x, jnp.zeros((), jnp.float32))
        if unroll:
            (x, aux) = _python_scan(group_body, carry0, grouped, ng)
        else:
            (x, aux), _ = jax.lax.scan(group_body, carry0, grouped)
    else:
        raise ValueError(cfg.family)

    if collect_kv and kvs is None:  # unroll path has no scan ys
        raise ValueError("collect_kv requires the lax.scan layer loop "
                         "(unroll=False)")
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if logits_mode == "none":
        return (x, aux, kvs) if collect_kv else (x, aux)
    if logits_mode == "last":
        x = x[:, -1:, :]
    logits = constrain(unembed_apply(params["unembed"], x), "logits")
    return (logits, aux, kvs) if collect_kv else (logits, aux)
