"""Model stack: layers, attention, MoE, Mamba2/SSD, assembly."""
from repro.models.model import Model, build_model, lm_loss
