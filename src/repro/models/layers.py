"""Common model layers: norms, RoPE, MLPs, embeddings.

Functional style: ``*_specs(cfg)`` returns the P-spec tree, ``*_apply``
consumes the matching params subtree. Compute dtype follows the input
activations; params are cast at the call site (mixed precision).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.spec import P


def rms_norm(x, w, eps: float):
    # f32 only for the per-token statistics: the (B,S,D)-sized products
    # stay in the activation dtype (a full f32 copy per call costs ~3 GiB
    # per 104B-train layer in the backward; see EXPERIMENTS.md §Perf).
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return (x * inv) * w.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_specs(d: int, ff: int, act: str) -> dict:
    if act == "swiglu":
        return {
            "gate": P((d, ff), ("embed", "mlp")),
            "up": P((d, ff), ("embed", "mlp")),
            "down": P((ff, d), ("mlp", "embed")),
        }
    return {
        "up": P((d, ff), ("embed", "mlp")),
        "up_b": P((ff,), ("mlp",), init="zeros"),
        "down": P((ff, d), ("mlp", "embed")),
        "down_b": P((d,), ("embed",), init="zeros"),
    }


def mlp_apply(p, x, act: str):
    dt = x.dtype
    if act == "swiglu":
        h = jax.nn.silu(x @ p["gate"].astype(dt)) * (x @ p["up"].astype(dt))
        return h @ p["down"].astype(dt)
    h = jax.nn.gelu(x @ p["up"].astype(dt) + p["up_b"].astype(dt))
    return h @ p["down"].astype(dt) + p["down_b"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_specs(vocab: int, d: int) -> dict:
    return {"tokens": P((vocab, d), ("vocab", "embed"), init="small_normal")}


def embed_apply(p, tokens, dtype):
    return jnp.take(p["tokens"], tokens, axis=0).astype(dtype)


def unembed_specs(d: int, vocab: int) -> dict:
    return {"out": P((d, vocab), ("embed", "vocab"))}


def unembed_apply(p, x):
    # logits in f32 for numerically-stable CE
    return (x @ p["out"].astype(x.dtype)).astype(jnp.float32)
