"""Flash attention with a hand-derived chunked backward (custom_vjp).

Why: differentiating through the online-softmax scan makes JAX save the
per-chunk score tiles (or per-step accumulators) — O(S^2) or O(nk * S * D)
f32 residuals per layer, ~13 GiB/device for the 104B train cell. The
flash-attention backward recomputes score tiles from (q, k, v, out, lse)
instead, so residuals are O(S * D): this file is the memory-critical path
that makes every train_4k cell fit HBM.

Math (per q-chunk i, kv-chunk j, per head; scale s = d^-1/2):
    S_ij = s * Q_i K_j^T          P_ij = exp(S_ij - lse_i)
    dV_j += P_ij^T dO_i
    dP_ij = dO_i V_j^T            D_i = rowsum(dO_i * O_i)
    dS_ij = P_ij * (dP_ij - D_i)
    dQ_i += s * dS_ij K_j         dK_j += s * dS_ij^T Q_i

Shapes: q (B,Sq,H,D); k,v (B,Skv,KV,D); GQA via H = KV * G.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, unroll):
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = d ** -0.5
    qc, kc = min(q_chunk, sq), min(kv_chunk, skv)
    nq, nk = sq // qc, skv // kc

    qr = q.reshape(b, nq, qc, kvh, g, d)
    kr = k.reshape(b, nk, kc, kvh, d)
    vr = v.reshape(b, nk, kc, kvh, d)
    q_pos = jnp.arange(sq, dtype=jnp.int32).reshape(nq, qc)
    k_pos = jnp.arange(skv, dtype=jnp.int32).reshape(nk, kc)

    def per_qchunk(q_i, qpos_i):
        def step(carry, inp):
            m, l, acc = carry
            k_j, v_j, kpos_j = inp
            s_ij = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                              preferred_element_type=jnp.float32) * scale
            if causal:
                mask = qpos_i[:, None] >= kpos_j[None, :]
                s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            m_new = jnp.maximum(m, s_ij.max(axis=-1))
            p_ij = jnp.exp(s_ij - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p_ij.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p_ij.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, d), jnp.float32)
        if unroll:
            carry = (m0, l0, a0)
            for j in range(nk):
                carry, _ = step(carry, (kr[:, j], vr[:, j], k_pos[j]))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(
                step, (m0, l0, a0),
                (kr.swapaxes(0, 1), vr.swapaxes(0, 1), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse  # (b,kvh,g,qc,d), (b,kvh,g,qc)

    out, lse = jax.vmap(per_qchunk, in_axes=(1, 0), out_axes=(1, 1))(qr, q_pos)
    # out: (b,nq,kvh,g,qc,d) -> (b,sq,h,d);  lse: (b,nq,kvh,g,qc)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, sq, h, d).astype(q.dtype)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 1024, unroll: bool = False):
    out, _ = _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, unroll)
    return out


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk, unroll):
    out, lse = _fwd_impl(q, k, v, causal, q_chunk, kv_chunk, unroll)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_chunk, kv_chunk, unroll, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = d ** -0.5
    qc, kc = min(q_chunk, sq), min(kv_chunk, skv)
    nq, nk = sq // qc, skv // kc

    qr = q.reshape(b, nq, qc, kvh, g, d)
    dor = dout.reshape(b, nq, qc, kvh, g, d)
    our = out.reshape(b, nq, qc, kvh, g, d)
    kr = k.reshape(b, nk, kc, kvh, d)
    vr = v.reshape(b, nk, kc, kvh, d)
    q_pos = jnp.arange(sq, dtype=jnp.int32).reshape(nq, qc)
    k_pos = jnp.arange(skv, dtype=jnp.int32).reshape(nk, kc)
    # D_i = rowsum(dO * O): (b, nq, kvh, g, qc)
    delta = jnp.einsum("bnqhgd,bnqhgd->bnhgq", dor.astype(jnp.float32),
                       our.astype(jnp.float32))
    lse_r = lse  # (b, nq, kvh, g, qc)

    def qstep(carry, inp):
        dk_acc, dv_acc = carry
        q_i, do_i, lse_i, delta_i, qpos_i = inp

        def kstep(c2, inp2):
            dq_i, dk_acc, dv_acc = c2
            j, kpos_j = inp2
            k_j = jax.lax.dynamic_index_in_dim(kr, j, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vr, j, 1, keepdims=False)
            s_ij = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                              preferred_element_type=jnp.float32) * scale
            if causal:
                mask = qpos_i[:, None] >= kpos_j[None, :]
                s_ij = jnp.where(mask[None, None, None], s_ij, NEG_INF)
            p_ij = jnp.exp(s_ij - lse_i[..., None])
            dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p_ij,
                              do_i.astype(jnp.float32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_i.astype(jnp.float32),
                            v_j.astype(jnp.float32))
            ds = p_ij * (dp - delta_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                     k_j.astype(jnp.float32))
            dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q_i.astype(jnp.float32))
            dk_acc = dk_acc.at[:, j].add(dk_j)
            dv_acc = dv_acc.at[:, j].add(dv_j)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, qc, kvh, g, d), jnp.float32)
        if unroll:
            c2 = (dq0, dk_acc, dv_acc)
            for j in range(nk):
                c2, _ = kstep(c2, (jnp.asarray(j), k_pos[j]))
            dq_i, dk_acc, dv_acc = c2
        else:
            (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
                kstep, (dq0, dk_acc, dv_acc),
                (jnp.arange(nk), k_pos))
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((b, nk, kc, kvh, d), jnp.float32)
    dv0 = jnp.zeros((b, nk, kc, kvh, d), jnp.float32)
    xs = (qr.swapaxes(0, 1), dor.swapaxes(0, 1), lse_r.swapaxes(0, 1),
          delta.swapaxes(0, 1), q_pos)
    if unroll:
        carry = (dk0, dv0)
        dqs = []
        for i in range(nq):
            carry, dq_i = qstep(carry, jax.tree.map(lambda a: a[i], xs))
            dqs.append(dq_i)
        dk_acc, dv_acc = carry
        dq = jnp.stack(dqs, axis=1)
    else:
        (dk_acc, dv_acc), dq = jax.lax.scan(qstep, (dk0, dv0), xs)
        dq = dq.swapaxes(0, 1)  # (b, nq, qc, kvh, g, d)

    dq = dq.reshape(b, sq, h, d).astype(q.dtype)
    dk = dk_acc.reshape(b, skv, kvh, d).astype(k.dtype)
    dv = dv_acc.reshape(b, skv, kvh, d).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)
