"""Mixture-of-Experts layer with *dynamic-format* dispatch (DESIGN.md §4).

The token->expert dispatch/combine operator IS a dynamic sparse matrix
(one nonzero per (token, routed expert) pair). Three interchangeable
implementations — selectable at runtime, auto-tunable, same results:

  dense  one-hot einsum dispatch (reference; O(T*E*C) memory — smoke only)
  sort   sort/scatter dispatch (production path: static shapes, EP-friendly)
  coo    the dispatch matrix built literally as a repro.core COO container
         and applied with the library's spmm — the paper's technique
         integrated into the model stack.

All paths are capacity-based (static shapes): per-expert capacity
C = ceil(T * top_k / E * capacity_factor); overflow tokens are dropped
(standard practice) and the drop fraction is an auxiliary metric.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import P, mlp_apply, mlp_specs


def moe_specs(cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = {
        "router": P((d, e), ("embed", None), init="small_normal"),
        "experts": {
            "gate": P((e, d, ff), ("expert", "embed", "mlp"), fan_in_dims=(1,)),
            "up": P((e, d, ff), ("expert", "embed", "mlp"), fan_in_dims=(1,)),
            "down": P((e, ff, d), ("expert", "mlp", "embed"), fan_in_dims=(1,)),
        },
    }
    for i in range(cfg.n_shared_experts):
        s[f"shared_{i}"] = mlp_specs(d, ff, "swiglu")
    return s


def _route(p, x, cfg):
    """Router: top-k gates (renormalised) + flat assignment table."""
    t = x.shape[0]
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # aux load-balance loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.zeros((cfg.n_experts,)).at[idx.reshape(-1)].add(1.0) / (t * cfg.top_k)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return gates, idx, aux


def _capacity(cfg, t: int) -> int:
    c = int(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)


def _expert_ffn(pe, xe, dtype):
    """Batched expert SwiGLU: xe (E, C, d) -> (E, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xe, pe["gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, pe["up"].astype(dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, pe["down"].astype(dtype))


# ---------------------------------------------------------------------------
# dispatch impls
# ---------------------------------------------------------------------------


def _dispatch_dense(p, x, gates, idx, cfg):
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(cfg, t)
    # position of each assignment within its expert
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (T, k, E)
    pos = jnp.cumsum(onehot.reshape(t * k, e), axis=0).reshape(t, k, e) - 1
    pos = jnp.sum(pos * onehot, axis=-1)  # (T, k)
    keep = pos < c
    disp = jnp.einsum("tke,tkc->tec", jax.nn.one_hot(idx, e, dtype=x.dtype) * keep[..., None],
                      jax.nn.one_hot(pos, c, dtype=x.dtype))
    xe = jnp.einsum("tec,td->ecd", disp, x)
    ye = _expert_ffn(p["experts"], xe, x.dtype)
    comb = jnp.einsum("tke,tkc,tk->tec", jax.nn.one_hot(idx, e, dtype=x.dtype),
                      jax.nn.one_hot(pos, c, dtype=x.dtype) * keep[..., None],
                      gates.astype(x.dtype))
    return jnp.einsum("tec,ecd->td", comb, ye)


def _assignments(x, gates, idx, cfg):
    """Shared sort-based symbolic step: slot/token/gate per kept assignment."""
    t = x.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(cfg, t)
    eid = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(eid, stable=True)
    sorted_eid = eid[order]
    counts = jnp.bincount(sorted_eid, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_eid].astype(jnp.int32)
    keep = rank < c
    slot = jnp.where(keep, sorted_eid * c + rank, e * c)  # overflow -> drop row
    token = order // k
    gate = gates.reshape(-1)[order]
    return slot, token, gate, keep, c


def _dispatch_sort(p, x, gates, idx, cfg):
    from repro.models.sharding_ctx import constrain
    t, d = x.shape
    e = cfg.n_experts
    slot, token, gate, keep, c = _assignments(x, gates, idx, cfg)
    xe = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(x[token])
    # EP anchor: keep the dispatched buffer expert-sharded (and the batch
    # dim, added by vmap, data-sharded) — without it GSPMD replicates the
    # full (B, E*C, d) buffer on the 3-axis multipod mesh (§Perf).
    xe = constrain(xe, "expert_rows")
    ye = _expert_ffn(p["experts"], xe[:-1].reshape(e, c, d), x.dtype).reshape(e * c, d)
    ye = constrain(ye, "expert_rows")
    contrib = ye[jnp.clip(slot, 0, e * c - 1)] * (gate * keep)[:, None].astype(x.dtype)
    return jnp.zeros((t, d), x.dtype).at[token].add(contrib)


def _dispatch_coo(p, x, gates, idx, cfg):
    """Dispatch through the paper's library: a COO DynamicMatrix of shape
    (E*C, T) applied with repro.core.spmm (and its transpose to combine)."""
    from repro.core.formats import COO
    from repro.core.ops import spmm

    t, d = x.shape
    e = cfg.n_experts
    slot, token, gate, keep, c = _assignments(x, gates, idx, cfg)
    live = keep.astype(x.dtype)
    disp = COO(row=jnp.clip(slot, 0, e * c - 1).astype(jnp.int32),
               col=token.astype(jnp.int32),
               data=live, shape=(e * c, t), nnz=int(slot.shape[0]))
    xe = spmm(disp, x)  # (E*C, d)
    ye = _expert_ffn(p["experts"], xe.reshape(e, c, d), x.dtype).reshape(e * c, d)
    comb = COO(row=token.astype(jnp.int32),
               col=jnp.clip(slot, 0, e * c - 1).astype(jnp.int32),
               data=(gate * keep).astype(x.dtype), shape=(t, e * c),
               nnz=int(slot.shape[0]))
    return spmm(comb, ye)


DISPATCH = {"dense": _dispatch_dense, "sort": _dispatch_sort, "coo": _dispatch_coo}


def moe_apply(p, x, cfg, dispatch: str = None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Routing/dispatch is **per sequence** (vmapped over the batch dim): the
    sort and capacity bookkeeping stay local to each batch row, so under
    data-parallel sharding every shard routes only its own tokens (GShard/
    Switch-style local capacity). A single global argsort over all B*S
    tokens would force GSPMD to all-gather the whole batch (measured:
    ~108 GiB/device on the deepseek prefill cell; see EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    impl = DISPATCH[dispatch or cfg.moe_dispatch]

    def per_row(xr):
        gates, idx, aux = _route(p, xr, cfg)
        return impl(p, xr, gates, idx, cfg), aux

    y, aux = jax.vmap(per_row)(x)
    xf = x.reshape(b * s, d)
    yf = y.reshape(b * s, d)
    for i in range(cfg.n_shared_experts):
        yf = yf + mlp_apply(p[f"shared_{i}"], xf, "swiglu")
    return yf.reshape(b, s, d), jnp.mean(aux)
