"""Quickstart: dynamic sparse matrices in 60 seconds.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (DynamicMatrix, Format, SwitchDynamicMatrix, autotune,
                        banded_coo, convert, convert_execute, random_coo,
                        spmv, to_dense_np)


def main():
    # 1. Build a stencil-like banded matrix (the paper's HPCG pattern).
    A = banded_coo((4096, 4096), [-64, -1, 0, 1, 64])
    x = jnp.ones((4096,), jnp.float32)

    # 2. Wrap it in a DynamicMatrix — the paper's core abstraction.
    dyn = DynamicMatrix(A)
    print("active format:", dyn.active.name)

    # 3. Same algorithm interface, any active state (State pattern).
    y_coo = dyn.spmv(x)
    for fmt in [Format.CSR, Format.DIA, Format.ELL]:
        switched = dyn.activate(fmt)  # runtime format switch (convert)
        y = switched.spmv(x)
        print(f"  spmv in {fmt.name:5s}: max|y - y_coo| = "
              f"{float(jnp.abs(y - y_coo).max()):.2e}")

    # 4. Plan/execute switching: the symbolic phase runs once, the numeric
    #    phase is jit-able and never leaves the device — the cheap-switch
    #    pipeline solvers use to re-format mid-run.
    plan = dyn.plan(Format.DIA)
    execute = jax.jit(convert_execute, static_argnums=1)
    A_dia = execute(A, plan)  # compiled; re-runs at memory-bandwidth cost
    print("planned switch ->", A_dia.format.name,
          f"(ndiag={A_dia.ndiag}, zero host syncs)")

    # 5. Let the auto-tuner pick the best format.
    report = autotune(A, x, mode="profile", iters=5)
    print("profile auto-tune:", report)
    report = autotune(A, mode="analytic")
    print("analytic auto-tune:", report)

    # 6. SwitchDynamicMatrix: all formats resident, O(1) runtime dispatch
    #    (this is what per-shard Multi-Format selection uses under SPMD).
    sw = SwitchDynamicMatrix.from_matrix(A, active=report.best)
    y = sw.spmv(x)
    print("switch-dispatch spmv matches:",
          bool(jnp.allclose(y, y_coo, rtol=1e-4, atol=1e-4)))

    # 7. Pallas TPU kernels (interpret mode on CPU): backend="pallas".
    for fmt in (Format.DIA, Format.CSR):
        Af = convert(A, fmt)
        y_pallas = spmv(Af, x, backend="pallas")
        print(f"pallas {fmt.name} kernel matches:",
              bool(jnp.allclose(y_pallas, y_coo, rtol=1e-4, atol=1e-4)))


if __name__ == "__main__":
    main()
