"""Serving example (deliverable b): batched greedy decoding with KV cache.

Loads a smoke-scale model (optionally from a training checkpoint), runs the
static-slot batch engine from repro.launch.serve over a stream of prompts,
and reports tokens/s. Works for every decoder arch, including the SSM
family (constant-state cache) and hybrid zamba2.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch mamba2_2_7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import DecodeEngine, serve
from repro.models import build_model


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="stablelm_1_6b")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--ckpt", default="", help="optional checkpoint dir")
    args = p.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only — no decode path")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        from repro.checkpoint import ckpt as ckpt_lib
        step = ckpt_lib.latest_step(args.ckpt)
        if step is not None:
            print(f"restoring params from step {step}")
            state = ckpt_lib.restore(args.ckpt, step, params)
            params = state

    print(f"serving {cfg.name} (smoke config, family={cfg.family}) "
          f"with {args.slots} slots")
    engine = DecodeEngine(model, params, args.slots, args.max_len)

    rng = np.random.default_rng(0)
    requests = [
        (i, rng.integers(0, cfg.vocab, (args.prompt_len,)).astype(np.int32))
        for i in range(args.requests)]
    t0 = time.perf_counter()
    done, _ = serve(engine, requests, args.max_new)
    dt = time.perf_counter() - t0
    ntok = sum(len(o) for _, o in done)
    mode = "batched" if model.supports_prefill_cache() else "by-decode"
    print(f"served {len(done)} requests / {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s, {engine.prefill_calls} {mode} prefills)")
    for rid, out in sorted(done)[:3]:
        print(f"  req {rid:2d}: {out[:12]}{'...' if len(out) > 12 else ''}")


if __name__ == "__main__":
    main()
