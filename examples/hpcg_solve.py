"""Morpheus-enabled HPCG (paper §IV-B): distributed CG with dynamic formats.

Reproduces the paper's workflow end-to-end:
  1. Problem setup          — 27-point-stencil Poisson system on a 3D grid
  2. Problem optimization   — partition into local/remote parts per shard,
                              select formats (fixed or auto-tuned per shard)
  3. Optimized timing       — CG solve, SpMV-dominated
  4. Validation             — solution must be the all-ones vector

Run (8 simulated devices):
  HPCG_DEVICES=8 PYTHONPATH=src python examples/hpcg_solve.py --mode multiformat
  HPCG_DEVICES=8 PYTHONPATH=src python examples/hpcg_solve.py \
      --mode multiformat --tune cached   # warm cache: zero profiling runs
  HPCG_DEVICES=8 PYTHONPATH=src python examples/hpcg_solve.py \
      --precond mg --mode multiformat    # full MG-PCG, per-level DistPlans
  PYTHONPATH=src python examples/hpcg_solve.py --local DIA --remote COO
"""
import argparse
import os
import sys
import time

if __name__ == "__main__":
    # repro.env is jax-free: backend-gated XLA flags land before jax
    # initializes (async collectives on GPU, forced host devices for SPMD)
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from repro import env as _env

    _env.apply(host_devices=int(os.environ.get("HPCG_DEVICES", 0)) or None)

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import Format, hpcg  # noqa: E402
from repro.core.distributed import (build_dist_matrix,  # noqa: E402
                                    distribute_vector)
from repro.core.solvers import cg, operator, pcg  # noqa: E402
from repro.obs import trace  # noqa: E402


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--grid", type=int, nargs=3, default=[16, 16, 32])
    p.add_argument("--mode", choices=["uniform", "multiformat"], default="uniform")
    p.add_argument("--tune", default="ml",
                   choices=["ml", "cached", "analytic", "profile"],
                   help="per-shard selection policy in multiformat mode "
                        "(repro.tuning.FormatPolicy)")
    p.add_argument("--local", default="DIA", choices=[f.name for f in Format])
    p.add_argument("--remote", default="COO", choices=[f.name for f in Format])
    p.add_argument("--backend", default="auto",
                   choices=["auto", "ref", "pallas"],
                   help="SpMV kernel routing: auto = Pallas where it "
                        "compiles natively, jnp reference otherwise")
    p.add_argument("--tol", type=float, default=1e-7)
    p.add_argument("--maxiter", type=int, default=500)
    p.add_argument("--precond", nargs="?", const="jacobi", default="none",
                   choices=["none", "jacobi", "mg"],
                   help="preconditioner: 'mg' = geometric multigrid V-cycle "
                        "with the multicolored SymGS smoother (repro.mg — "
                        "HPCG's real preconditioner, made vector-parallel "
                        "by the 8-coloring; per-level slab DistPlans), "
                        "'jacobi' = diag(A) fallback. Bare --precond keeps "
                        "the historical Jacobi behaviour.")
    p.add_argument("--mg-levels", type=int, default=None,
                   help="cap the MG hierarchy depth (default: coarsen while "
                        "dims stay even and slabs divide the mesh)")
    p.add_argument("--verbose", action="store_true",
                   help="print the per-iteration convergence curve "
                        "(||r_k|| from the solver's residual history)")
    args = p.parse_args(argv)

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("rows",))
    print(f"devices: {ndev}, grid: {args.grid}")

    # --- 1. problem setup ---------------------------------------------------
    t0 = time.perf_counter()
    with trace.span("build.problem", grid="x".join(map(str, args.grid))):
        prob = hpcg.generate_problem(*args.grid)
    print(f"setup: n={prob.shape[0]} nnz={len(prob.val)} "
          f"({time.perf_counter() - t0:.2f}s)")

    # --- 2. problem optimization (Morpheus: partition + format selection) ---
    # The z-slab structure of the stencil is known analytically: slab_plan
    # replaces the partition scan, and being correct by construction it can
    # also skip the builder's stale-plan validation (check_plan=False) — the
    # triplets are then touched exactly once, by the device scatter. In mg
    # mode the whole hierarchy is the optimization product: its level 0 IS
    # the distributed operator (building it separately would run the
    # partition + per-shard selection twice).
    t0 = time.perf_counter()
    hier = None
    opt_span = trace.span("build.optimize", mode=args.mode,
                          precond=args.precond)
    opt_span.__enter__()
    if args.precond == "mg":
        from repro.mg import build_dist_hierarchy

        hier = build_dist_hierarchy(
            prob, mesh, "rows", nlevels=args.mg_levels, mode=args.mode,
            tune=args.tune, local_format=Format[args.local],
            remote_format=Format[args.remote], backend=args.backend)
        A = hier.levels[0].A
        print(f"optimization: {hier} ({time.perf_counter() - t0:.2f}s)")
        if args.mode == "multiformat":
            for rec in hier.formats():
                bnd = (f" boundary={rec['boundary']}"
                       if "boundary" in rec else "")
                print(f"  level {rec['level']} {rec['dims']}: "
                      f"local={rec['local']}{bnd} remote={rec['remote']}")
    else:
        plan = hpcg.slab_plan(prob, ndev) if prob.nz % ndev == 0 else None
        A = build_dist_matrix(prob.row, prob.col, prob.val, prob.shape, mesh,
                              "rows", local_format=Format[args.local],
                              remote_format=Format[args.remote], mode=args.mode,
                              tune=args.tune, plan=plan, check_plan=plan is None)
        print(f"optimization: {A} ({time.perf_counter() - t0:.2f}s)")
        if args.mode == "multiformat":
            from repro.core import DEFAULT_CANDIDATES
            names = [f.name for f in DEFAULT_CANDIDATES]
            label = "interior" if A.split else "local"
            print(f"  per-shard {label} formats: ",
                  [names[i] for i in np.asarray(A.local.active_id)])
            if A.split:
                print("  per-shard boundary formats:",
                      [names[i] for i in np.asarray(A.boundary.active_id)])
            print("  per-shard remote formats:",
                  [names[i] for i in np.asarray(A.remote.active_id)])

    opt_span.__exit__(None, None, None)
    b = distribute_vector(hpcg.rhs_for_ones(prob), mesh, "rows")

    # --- 3. optimized timing -------------------------------------------------
    if args.precond == "mg":
        apply_M = hier.apply_M()
        solve = jax.jit(lambda a, bb: pcg(
            operator(a, mesh, backend=args.backend), bb, tol=args.tol,
            maxiter=args.maxiter, apply_M=apply_M))
    elif args.precond == "jacobi":
        diag = jnp.asarray(
            np.full(prob.shape[0], 26.0, np.float32))  # HPCG diagonal
        solve = jax.jit(lambda a, bb: pcg(
            operator(a, mesh, backend=args.backend), bb, diag, tol=args.tol,
            maxiter=args.maxiter))
    else:
        solve = jax.jit(lambda a, bb: cg(
            operator(a, mesh, backend=args.backend), bb, tol=args.tol,
            maxiter=args.maxiter))
    with trace.span("solver.compile", precond=args.precond) as sp:
        sp.sync(solve(A, b))  # compile + warm
    t0 = time.perf_counter()
    with trace.span("solver.solve", precond=args.precond) as sp:
        res = solve(A, b)
        sp.sync(res)
    res = jax.block_until_ready(res)
    dt = time.perf_counter() - t0
    iters = int(res.iters)
    # HPCG's figure of merit: ~ (2 * nnz) flops per SpMV, 1 SpMV per iter
    gflops = 2 * len(prob.val) * iters / dt / 1e9

    # --- 4. validation --------------------------------------------------------
    err = float(np.abs(np.asarray(res.x) - 1.0).max())
    print(f"solve: {iters} iters, {dt * 1e3:.1f} ms, ||r||={float(res.resnorm):.2e}, "
          f"SpMV-rate ~{gflops:.2f} GFLOP/s")
    if args.verbose and res.history is not None:
        hist = np.asarray(res.history)
        hist = hist[~np.isnan(hist)]
        print("convergence (||r_k||, relative to ||r_0||):")
        r0 = hist[0] if hist.size and hist[0] > 0 else 1.0
        for k, rn in enumerate(hist):
            print(f"  iter {k:4d}: {rn:.3e}  rel={rn / r0:.3e}")
    print(f"validation: max|x - 1| = {err:.2e} -> {'PASS' if err < 1e-3 else 'FAIL'}")

    if trace.enabled():
        print("\n# trace summary (REPRO_TRACE=" + trace.mode() + ")")
        print(trace.summary())
        if trace.mode() == "full":
            out = os.environ.get("REPRO_TRACE_EXPORT", "trace.json")
            print(f"trace exported: {trace.export_chrome(out)} "
                  f"(render: python -m repro.obs.report {out})")
    return 0 if err < 1e-3 else 1


if __name__ == "__main__":
    sys.exit(main())
