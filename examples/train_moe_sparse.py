"""End-to-end training driver (deliverable b): train a ~100M-param MoE for a
few hundred steps with the *dynamic sparse dispatch* — the paper's
format-switching idea applied to the token->expert dispatch operator.

The run auto-tunes the dispatch implementation ('dense' one-hot einsum vs
'sort' scatter vs 'coo' through repro.core spmm) on the first batch — a
live demonstration of runtime data-structure selection — then trains with
the winner, checkpointing and (optionally) resuming.

Run:  PYTHONPATH=src python examples/train_moe_sparse.py --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.models import build_model
from repro.models.moe import DISPATCH, moe_apply
from repro.optim.adamw import AdamW


def tune_dispatch(model, params, batch) -> str:
    """Profile the three dispatch 'formats' on one step (paper's §V-E
    profiling auto-tuner, applied to MoE dispatch)."""
    times = {}
    for name in DISPATCH:
        cfg = dataclasses.replace(model.cfg, moe_dispatch=name)
        m = dataclasses.replace(model, cfg=cfg)
        f = jax.jit(lambda p, b: m.loss(p, b, q_chunk=64, kv_chunk=64))
        try:
            jax.block_until_ready(f(params, batch))  # compile
            t0 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(f(params, batch))
            times[name] = (time.perf_counter() - t0) / 3
        except Exception as e:  # noqa: BLE001
            print(f"  dispatch {name}: failed ({e!r})")
    for k, v in sorted(times.items(), key=lambda kv: kv[1]):
        print(f"  dispatch {k:6s}: {v * 1e3:8.2f} ms/step")
    return min(times, key=times.get)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--log-every", type=int, default=20)
    args = p.parse_args(argv)

    # ~100M-param fine-grained MoE (deepseek-moe family, scaled down)
    cfg = dataclasses.replace(
        get_config("deepseek_moe_16b"),
        n_layers=4, d_model=512, n_heads=8, n_kv=8, d_ff=352, vocab=8192,
        n_experts=16, top_k=4, n_shared_experts=1, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = model.n_params()
    print(f"model: {cfg.name}-mini, {n / 1e6:.1f}M params, "
          f"{cfg.n_experts} experts top-{cfg.top_k}")

    src = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    batch0 = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}

    print("auto-tuning dispatch format (paper technique on MoE dispatch):")
    best = tune_dispatch(model, params, batch0)
    print(f"  -> selected '{best}'")
    cfg = dataclasses.replace(cfg, moe_dispatch=best)
    model = build_model(cfg)

    opt = AdamW(lr=args.lr, total_steps=args.steps,
                warmup_steps=max(1, args.steps // 20))
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, q_chunk=64, kv_chunk=64))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    t0, first = time.perf_counter(), None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(step).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            lv = float(loss)
            first = first if first is not None else lv
            tps = args.batch * args.seq * (step + 1) / (time.perf_counter() - t0)
            print(f"step {step:4d} loss {lv:.4f} ({tps:,.0f} tok/s)")
    print(f"loss: {first:.3f} -> {lv:.3f} "
          f"({'LEARNING' if lv < first - 0.5 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
